#!/usr/bin/env python3
"""Markdown link checker for README + docs/ — keeps cross-links from rotting.

Checks every relative link in the given markdown files (directories are
scanned for *.md): the target file must exist, and a `#fragment` into a
markdown file must match a heading's GitHub-style anchor. External links
(http/https/mailto) are deliberately skipped — no network, no flakes.

Usage: python3 tools/check_md_links.py README.md docs
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """Approximate GitHub's heading→anchor slug."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)  # strip inline code markers
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # links → text
    text = text.strip().lower()
    out = []
    for ch in text:
        if ch.isalnum() or ch in "-_":
            out.append(ch)
        elif ch == " ":
            out.append("-")
        # all other punctuation is dropped
    return "".join(out)


def md_lines_outside_code(path: Path):
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield line


def anchors_of(path: Path) -> set:
    return {github_slug(m.group(2)) for line in md_lines_outside_code(path) if (m := HEADING_RE.match(line))}


def links_of(path: Path):
    for line in md_lines_outside_code(path):
        for m in LINK_RE.finditer(line):
            yield m.group(1)


def collect_files(args):
    files = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.glob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            sys.exit(f"not a markdown file or directory: {a}")
    return files


def main(argv):
    files = collect_files(argv or ["README.md", "docs"])
    errors = []
    for f in files:
        for link in links_of(f):
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            target, _, fragment = link.partition("#")
            dest = f if not target else (f.parent / target).resolve()
            if not dest.exists():
                errors.append(f"{f}: broken link {link!r} (no such file {dest})")
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in anchors_of(dest):
                    errors.append(f"{f}: broken anchor {link!r} (no heading #{fragment} in {dest.name})")
    if errors:
        print(f"{len(errors)} broken markdown link(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"checked {len(files)} file(s): all relative links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
