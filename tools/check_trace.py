#!/usr/bin/env python3
"""Validate a Chrome trace exported by `failsafe trace` / `TraceLog::to_chrome_trace`.

Checks, in order:
  1. the file parses as JSON and carries a `traceEvents` list;
  2. every event has the required keys for its phase (`ph`);
  3. timestamps are finite, non-negative, and non-decreasing within each
     `(pid, tid)` lane (the exporter emits records in log order, and the
     simulated clock never runs backwards);
  4. `B`/`E` span edges nest and balance per lane;
  5. every `failure.injected` / `gpu.rejoined` instant has a complete
     `recovery` span on the same lane;
  6. each `recovery` span's five phase children (`recovery.detect`,
     `.plan`, `.stream`, `.respread`, `.resume`) tile it exactly: they
     sum to the parent's duration — and to its `latency_s` argument —
     within 1e-3 µs (1e-9 simulated seconds).

Usage: python3 tools/check_trace.py trace.json
Exits non-zero listing every violation.
"""

import json
import math
import sys

TOL_US = 1e-3  # 1e-9 s in microseconds
PHASES = ("recovery.detect", "recovery.plan", "recovery.stream",
          "recovery.respread", "recovery.resume")


def fail(errors, msg):
    errors.append(msg)


def lane(ev):
    return (ev.get("pid"), ev.get("tid"))


def check(path):
    errors = []
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents list"]
    if not events:
        return [f"{path}: traceEvents is empty"]

    # Per-lane walks: monotone timestamps, B/E nesting, span collection.
    last_ts = {}
    stacks = {}          # lane -> [(name, begin event)]
    spans = {}           # lane -> list of (name, t0, t1, args)
    instants = {}        # lane -> list of (name, ts)
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None or "pid" not in ev or "tid" not in ev:
            fail(errors, f"event {i}: missing ph/pid/tid: {ev}")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) or ts < 0:
            fail(errors, f"event {i} ({ev.get('name')}): bad ts {ts!r}")
            continue
        ln = lane(ev)
        if ts < last_ts.get(ln, 0.0) - TOL_US:
            fail(errors, f"event {i} ({ev.get('name')}): ts {ts} runs "
                         f"backwards on lane {ln} (prev {last_ts[ln]})")
        last_ts[ln] = max(last_ts.get(ln, 0.0), ts)

        if ph == "B":
            stacks.setdefault(ln, []).append((ev.get("name"), ts, ev.get("args", {})))
        elif ph == "E":
            stack = stacks.setdefault(ln, [])
            if not stack:
                fail(errors, f"event {i}: E with empty span stack on lane {ln}")
                continue
            name, t0, args = stack.pop()
            if ev.get("name") not in (None, name):
                fail(errors, f"event {i}: E for {ev.get('name')!r} closes "
                             f"open span {name!r} on lane {ln}")
            spans.setdefault(ln, []).append((name, t0, ts, args))
        elif ph == "i":
            instants.setdefault(ln, []).append((ev.get("name"), ts))
        elif ph == "C":
            if "args" not in ev or not ev["args"]:
                fail(errors, f"event {i} ({ev.get('name')}): counter without args")
        else:
            fail(errors, f"event {i}: unknown phase {ph!r}")

    for ln, stack in stacks.items():
        for name, t0, _ in stack:
            fail(errors, f"lane {ln}: span {name!r} opened at {t0} never closed")

    # Recovery coverage: each failure/rejoin instant needs a complete
    # recovery span on its lane that starts at (or after) the instant.
    for ln, insts in instants.items():
        lane_spans = spans.get(ln, [])
        for name, ts in insts:
            if name not in ("failure.injected", "gpu.rejoined"):
                continue
            # The sim stamps the instant at injection (== span start);
            # the engine stamps it at the next step() drain, which can
            # postdate the span start — so require a recovery span that
            # *completes* at or after the instant.
            if not any(n == "recovery" and t1 >= ts - TOL_US and t1 >= t0
                       for (n, t0, t1, _) in lane_spans):
                fail(errors, f"lane {ln}: {name} at {ts} has no complete "
                             f"recovery span")

    # Phase decomposition: children tile the parent, and the parent's
    # duration matches its own latency_s claim.
    n_recoveries = 0
    for ln, lane_spans in spans.items():
        parents = [(t0, t1, args) for (n, t0, t1, args) in lane_spans
                   if n == "recovery"]
        children = [(n, t0, t1) for (n, t0, t1, _) in lane_spans
                    if n.startswith("recovery.")]
        for (t0, t1, args) in parents:
            n_recoveries += 1
            dur = t1 - t0
            latency = args.get("latency_s")
            if isinstance(latency, (int, float)) and \
                    abs(dur - latency * 1e6) > TOL_US:
                fail(errors, f"lane {ln}: recovery span at {t0} lasts "
                             f"{dur}us but claims latency_s={latency}")
            mine = [(n, c0, c1) for (n, c0, c1) in children
                    if c0 >= t0 - TOL_US and c1 <= t1 + TOL_US]
            names = sorted(n for (n, _, _) in mine)
            if names != sorted(PHASES):
                fail(errors, f"lane {ln}: recovery at {t0} has phases "
                             f"{names}, want {sorted(PHASES)}")
                continue
            total = sum(c1 - c0 for (_, c0, c1) in mine)
            if abs(total - dur) > TOL_US:
                fail(errors, f"lane {ln}: recovery at {t0}: phases sum to "
                             f"{total}us, parent spans {dur}us")

    if n_recoveries == 0:
        fail(errors, f"{path}: no recovery spans found — not a fault replay?")
    return errors


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    errors = check(argv[1])
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    if errors:
        print(f"{len(errors)} problem(s) in {argv[1]}", file=sys.stderr)
        return 1
    print(f"{argv[1]}: trace well-formed, recovery decomposition exact")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
