//! Engine/experiment configuration: model presets by name, policy bundles
//! by name, and the knobs every binary shares. Parsed from the tiny CLI
//! layer (`util::cli`) — the offline build has no serde/clap.

use crate::model::{llama3_70b, mixtral_8x22b, small_real, ModelSpec};
use crate::recovery::RecoveryMethod;
use crate::simulator::SystemConfig;
use crate::util::cli::Args;

/// Resolve a model preset by name.
pub fn model_by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "llama" | "llama-3.1-70b" | "llama70b" => Some(llama3_70b()),
        "mixtral" | "mixtral-8x22b" => Some(mixtral_8x22b()),
        "small" | "small-real" => Some(small_real()),
        _ => None,
    }
}

/// Resolve a system configuration by name.
pub fn system_by_name(name: &str) -> Option<SystemConfig> {
    match name {
        "standard" => Some(SystemConfig::standard()),
        "nonuniform" => Some(SystemConfig::nonuniform()),
        "membalance" | "memory-balanced" => Some(SystemConfig::memory_balanced()),
        "failsafe" => Some(SystemConfig::failsafe()),
        _ => None,
    }
}

/// Resolve a recovery method by name.
pub fn recovery_by_name(name: &str) -> Option<RecoveryMethod> {
    match name {
        "recompute" => Some(RecoveryMethod::Recompute),
        "host" => Some(RecoveryMethod::Host),
        "full" => Some(RecoveryMethod::Full),
        "oracle" => Some(RecoveryMethod::Oracle),
        _ => None,
    }
}

/// Shared engine configuration, with CLI overrides.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: ModelSpec,
    pub system: SystemConfig,
    pub world: usize,
    pub recovery: RecoveryMethod,
    /// Directory holding AOT artifacts (HLO text + weights).
    pub artifacts_dir: String,
    /// Prefill token budget per batch.
    pub token_budget: usize,
    /// Decode batch cap.
    pub max_batch: usize,
    /// Shared-prefix KV cache: warm prompt prefixes adopt their cached
    /// blocks copy-on-write instead of re-prefilling, and admission is
    /// biased toward the rank already holding them. Off by default so
    /// existing placement/accounting behaviour is bit-identical unless
    /// opted in (`--prefix-sharing`).
    pub prefix_sharing: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: small_real(),
            system: SystemConfig::failsafe(),
            world: 3,
            recovery: RecoveryMethod::Full,
            artifacts_dir: "artifacts".into(),
            token_budget: 256,
            max_batch: 8,
            prefix_sharing: false,
            seed: 42,
        }
    }
}

impl EngineConfig {
    /// Apply `--model --system --world --recovery --artifacts --budget
    /// --batch --seed` overrides.
    pub fn from_args(args: &Args) -> Self {
        let mut c = EngineConfig::default();
        if let Some(m) = args.get("model").and_then(model_by_name) {
            c.model = m;
        }
        if let Some(s) = args.get("system").and_then(system_by_name) {
            c.system = s;
        }
        if let Some(r) = args.get("recovery").and_then(recovery_by_name) {
            c.recovery = r;
        }
        c.world = args.get_usize("world", c.world);
        c.artifacts_dir = args.get_or("artifacts", &c.artifacts_dir).to_string();
        c.token_budget = args.get_usize("budget", c.token_budget);
        c.max_batch = args.get_usize("batch", c.max_batch);
        c.prefix_sharing = c.prefix_sharing || args.has("prefix-sharing");
        c.seed = args.get_u64("seed", c.seed);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(model_by_name("llama").unwrap().n_layers, 80);
        assert_eq!(model_by_name("mixtral").unwrap().n_experts, 8);
        assert_eq!(model_by_name("small").unwrap().d_model, 256);
        assert!(model_by_name("gpt-5").is_none());
        assert!(system_by_name("failsafe").is_some());
        assert!(recovery_by_name("oracle").is_some());
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            "serve --model llama --world 7 --system nonuniform --recovery host --batch 64"
                .split_whitespace()
                .map(String::from),
        );
        let c = EngineConfig::from_args(&args);
        assert_eq!(c.model.name, "llama-3.1-70b");
        assert_eq!(c.world, 7);
        assert_eq!(c.system.name, "Nonuniform-TP");
        assert_eq!(c.recovery, RecoveryMethod::Host);
        assert_eq!(c.max_batch, 64);
        assert!(!c.prefix_sharing, "sharing is opt-in");
        let args = Args::parse(
            "serve --prefix-sharing --world 2".split_whitespace().map(String::from),
        );
        assert!(EngineConfig::from_args(&args).prefix_sharing);
    }
}
