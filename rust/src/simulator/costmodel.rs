//! Analytic step-time model: per-rank roofline over the shard plan.
//!
//! Tensor parallelism synchronizes at every layer boundary (all-reduce
//! after attention and after FFN), so the step time is the **sum over
//! layers of the per-layer straggler** plus collective and launch
//! overheads. This is exactly the mechanism behind the paper's §2.2.1
//! observation: naive non-uniform TP leaves every layer waiting for the
//! rank with ⌈H/W⌉ heads (up to 2× attention slowdown), while hybrid
//! attention + load-aware routing flattens the per-layer profile.

use crate::cluster::{GpuSpec, Interconnect};
use crate::model::ModelSpec;
use crate::sharding::ShardPlan;
use crate::RankId;

/// One prefill chunk's work: `tokens` new tokens on top of `context`.
#[derive(Debug, Clone, Copy)]
pub struct PrefillWork {
    pub tokens: usize,
    pub context: usize,
    /// Home DP rank of the owning request.
    pub home: RankId,
}

/// One decode request's work: a single new token against `context`.
#[derive(Debug, Clone, Copy)]
pub struct DecodeWork {
    pub context: usize,
    pub home: RankId,
}

impl DecodeWork {
    /// A uniform `n`-request batch homed capacity-proportionally: each
    /// request lands on the rank with the lowest `booked / speed` (ties →
    /// lowest id) — the steady state the capacity-aware
    /// [`crate::router::LoadTracker`] converges to. Shared by the
    /// straggler bench and the mitigation acceptance tests so both
    /// measure the same batch shape.
    pub fn capacity_homed(n: usize, context: usize, speeds: &[f64]) -> Vec<DecodeWork> {
        assert!(!speeds.is_empty() && speeds.iter().all(|s| *s > 0.0));
        let mut booked = vec![0.0f64; speeds.len()];
        (0..n)
            .map(|_| {
                let home = (0..speeds.len())
                    .min_by(|&a, &b| {
                        (booked[a] / speeds[a]).total_cmp(&(booked[b] / speeds[b])).then(a.cmp(&b))
                    })
                    .expect("non-empty world");
                booked[home] += 1.0;
                DecodeWork { context, home }
            })
            .collect()
    }
}

/// One distinct per-layer shard profile: most plans repeat the same
/// head distribution across many layers (hybrid plans across *all*
/// layers), so the step-time inner loop runs once per distinct profile —
/// weighted by multiplicity — instead of once per layer.
#[derive(Debug, Clone)]
struct LayerProfile {
    /// Number of layers sharing this profile.
    layers: f64,
    /// TP KV-head groups owned by each rank.
    tp: Vec<u16>,
    /// DP-replicated heads.
    dp: u16,
}

/// Pre-computed per-plan constants for fast step costing.
#[derive(Debug, Clone)]
pub struct StepCostModel {
    model: ModelSpec,
    /// Per-rank device specs — a uniform fleet repeats one spec; a mixed
    /// fleet (H100+A100) costs each rank against its own generation.
    specs: Vec<GpuSpec>,
    /// Cached per-rank effective FLOP/s (compute roofline side).
    eff: Vec<f64>,
    /// Cached per-rank HBM bandwidth (memory roofline side).
    bw: Vec<f64>,
    /// Per-layer kernel-launch overhead: launches are synchronized, so
    /// the step pays the slowest rank's launch cost.
    launch_s: f64,
    ic: Interconnect,
    world: usize,
    /// `tp_heads[l][r]` = TP KV-head groups owned by rank r in layer l.
    tp_heads: Vec<Vec<u16>>,
    /// DP-replicated heads per layer.
    dp_heads: Vec<u16>,
    /// Distinct (tp, dp) layer profiles with multiplicities — the
    /// straggler scan `Σ_l max_r` collapses to `Σ_profiles n·max_r`.
    profiles: Vec<LayerProfile>,
    /// FFN columns per rank (identical across layers).
    ffn_cols: Vec<usize>,
    /// Per-rank resident weight bytes (for memory-bound decode).
    weight_bytes: Vec<usize>,
    /// Per-rank effective speed factor in `(0, 1]` (1.0 = healthy). A
    /// throttled rank finishes its per-layer work `1/factor`× slower, so
    /// the synchronized step pays `work_r / (rate · speed_r)` at the
    /// per-layer straggler max — soft faults actually hurt modeled
    /// throughput.
    speed: Vec<f64>,
}

impl StepCostModel {
    /// Uniform-fleet model: every rank runs on the same device class.
    pub fn new(plan: &ShardPlan, spec: &GpuSpec, ic: &Interconnect) -> Self {
        Self::new_heterogeneous(plan, &vec![spec.clone(); plan.world()], ic)
    }

    /// Mixed-generation model: rank `r` runs on `specs[r]` and is costed
    /// against its own FLOP/s and HBM bandwidth. With a
    /// capacity-proportional plan the per-layer straggler max is taken
    /// over *proportionally loaded* ranks — work/rate is flat — so the
    /// step no longer pays fast-rank idle time waiting on the slowest
    /// device the way a uniform plan on mixed hardware does.
    pub fn new_heterogeneous(plan: &ShardPlan, specs: &[GpuSpec], ic: &Interconnect) -> Self {
        let world = plan.world();
        assert_eq!(specs.len(), world, "one device spec per rank");
        let tp_heads: Vec<Vec<u16>> = plan
            .heads
            .layers
            .iter()
            .map(|lh| {
                let mut counts = vec![0u16; world];
                for &o in &lh.owner {
                    if o != crate::sharding::DP_OWNER {
                        counts[o] += 1;
                    }
                }
                counts
            })
            .collect();
        let dp_heads: Vec<u16> = plan.heads.layers.iter().map(|lh| lh.n_dp() as u16).collect();
        let mut profiles: Vec<LayerProfile> = Vec::new();
        for (tp, &dp) in tp_heads.iter().zip(&dp_heads) {
            match profiles.iter_mut().find(|p| p.tp == *tp && p.dp == dp) {
                Some(p) => p.layers += 1.0,
                None => profiles.push(LayerProfile { layers: 1.0, tp: tp.clone(), dp }),
            }
        }
        let cols_per_block = plan.model.d_ff / plan.ffn.n_blocks;
        let ffn_cols = (0..world)
            .map(|r| plan.ffn.blocks_of(r).len() * cols_per_block)
            .collect();
        let weight_bytes = plan.rank_loads().iter().map(|l| l.weight_bytes).collect();
        StepCostModel {
            model: plan.model.clone(),
            eff: specs.iter().map(|s| s.effective_flops()).collect(),
            bw: specs.iter().map(|s| s.hbm_bw).collect(),
            launch_s: specs.iter().map(|s| s.kernel_launch_s).fold(0.0, f64::max),
            specs: specs.to_vec(),
            ic: ic.clone(),
            world,
            tp_heads,
            dp_heads,
            profiles,
            ffn_cols,
            weight_bytes,
            speed: vec![1.0; world],
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Set every rank's effective speed factor (1.0 = healthy, 0.5 = a
    /// thermally throttled rank at half speed). Factors must be finite
    /// and in `(0, 1]`.
    pub fn set_speed_factors(&mut self, factors: &[f64]) {
        assert_eq!(factors.len(), self.world, "one speed factor per rank");
        assert!(
            factors.iter().all(|f| f.is_finite() && *f > 0.0 && *f <= 1.0),
            "speed factors must be in (0, 1]: {factors:?}"
        );
        self.speed.copy_from_slice(factors);
    }

    /// Set one rank's effective speed factor (see
    /// [`StepCostModel::set_speed_factors`]).
    pub fn set_speed_factor(&mut self, rank: RankId, factor: f64) {
        assert!(rank < self.world, "rank {rank} out of range (world {})", self.world);
        assert!(
            factor.is_finite() && factor > 0.0 && factor <= 1.0,
            "speed factor must be in (0, 1], got {factor}"
        );
        self.speed[rank] = factor;
    }

    /// Current per-rank effective speed factors.
    pub fn speed_factors(&self) -> &[f64] {
        &self.speed
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// All-reduce bytes per layer boundary for `tokens` tokens.
    fn allreduce_bytes(&self, tokens: usize) -> usize {
        tokens * self.model.d_model * self.model.dtype_bytes
    }

    /// Step time for a prefill batch (compute-bound regime).
    ///
    /// `chunks` — the chunk set formed by the scheduler. Attention and FFN
    /// FLOPs are attributed per rank per layer; the step pays the per-layer
    /// straggler (Σ_l max_r), two all-reduces per layer, and fixed launch
    /// overhead per layer.
    pub fn prefill_step_time(&self, chunks: &[PrefillWork]) -> f64 {
        if chunks.is_empty() {
            return 0.0;
        }
        let m = &self.model;
        let total_tokens: usize = chunks.iter().map(|c| c.tokens).sum();

        // Per-head-group attention flops for the whole chunk set (TP part
        // sees every chunk), and per-home-rank flops (DP part).
        let mut tp_attn_flops = 0.0;
        let mut dp_attn_flops = vec![0.0; self.world];
        for c in chunks {
            let f = m.attn_flops(c.tokens, c.context);
            tp_attn_flops += f.per_head_group();
            dp_attn_flops[c.home] += f.per_head_group();
        }
        let ffn = m.ffn_flops(total_tokens);

        // Sum over layers of the per-layer straggler — one scan per
        // *distinct* layer profile, weighted by multiplicity.
        let mut sum_layer_max = 0.0;
        for p in &self.profiles {
            let mut layer_max: f64 = 0.0;
            for r in 0..self.world {
                let flops = p.tp[r] as f64 * tp_attn_flops
                    + if p.dp > 0 { p.dp as f64 * dp_attn_flops[r] } else { 0.0 }
                    + ffn.per_col * self.ffn_cols[r] as f64 * m.experts_per_token as f64;
                layer_max = layer_max.max(flops / (self.eff[r] * self.speed[r]));
            }
            sum_layer_max += p.layers * layer_max;
        }

        let collectives =
            2.0 * m.n_layers as f64 * self.ic.allreduce_time(self.world, self.allreduce_bytes(total_tokens));
        let launches = 2.0 * m.n_layers as f64 * self.launch_s;
        sum_layer_max + collectives + launches
    }

    /// Step time for a decode batch (memory-bound regime).
    ///
    /// Per layer per rank, the step streams: resident weights (read once
    /// per step regardless of batch — the amortization that makes batch
    /// size matter), the KV of TP heads for *every* request, and the KV of
    /// DP heads for requests homed on the rank.
    pub fn decode_step_time(&self, batch: &[DecodeWork]) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        let m = &self.model;
        let b = batch.len();
        let kvb = m.kv_bytes_per_token_per_head_layer() as f64;

        let total_ctx: usize = batch.iter().map(|d| d.context).sum();
        let mut dp_ctx = vec![0usize; self.world];
        for d in batch {
            dp_ctx[d.home] += d.context;
        }

        // Flops per head-group for one token (context-dependent part).
        let mut tp_attn_flops = 0.0;
        let mut dp_attn_flops = vec![0.0; self.world];
        for d in batch {
            let f = m.attn_flops(1, d.context);
            tp_attn_flops += f.per_head_group();
            dp_attn_flops[d.home] += f.per_head_group();
        }
        let ffn = m.ffn_flops(b);

        // MoE decode touches only routed experts; with batch b and top-k
        // routing, the expected fraction of expert weights touched is
        // 1-(1-k/E)^b, saturating quickly.
        let expert_frac = if m.is_moe() {
            let k = m.experts_per_token as f64 / m.n_experts as f64;
            1.0 - (1.0 - k).powi(b as i32)
        } else {
            1.0
        };

        // Per-rank per-layer weight bytes (amortized over layers).
        let attn_w_per_hg = m.head_group_weight_bytes() as f64;
        let ffn_w_per_col = m.ffn_col_weight_bytes() as f64 * m.n_experts as f64 * expert_frac;

        let mut sum_layer_max = 0.0;
        for p in &self.profiles {
            let mut layer_max: f64 = 0.0;
            let dp = p.dp as f64;
            for r in 0..self.world {
                let tp = p.tp[r] as f64;
                let flops = tp * tp_attn_flops
                    + dp * dp_attn_flops[r]
                    + ffn.per_col * self.ffn_cols[r] as f64 * m.experts_per_token as f64;
                let bytes = (tp + dp) * attn_w_per_hg
                    + self.ffn_cols[r] as f64 * ffn_w_per_col
                    + tp * total_ctx as f64 * kvb
                    + dp * dp_ctx[r] as f64 * kvb;
                layer_max =
                    layer_max.max((flops / self.eff[r]).max(bytes / self.bw[r]) / self.speed[r]);
            }
            sum_layer_max += p.layers * layer_max;
        }

        let collectives =
            2.0 * m.n_layers as f64 * self.ic.allreduce_time(self.world, self.allreduce_bytes(b));
        let launches = 2.0 * m.n_layers as f64 * self.launch_s;
        sum_layer_max + collectives + launches
    }

    /// Closed-form time for `steps` consecutive decode steps of the same
    /// batch (contexts growing by one per step): the trapezoid
    /// `(dt_first + dt_last) / 2 × steps`. Exact when the per-step time
    /// is affine in context over the span with a stable per-layer argmax
    /// rank (the common steady-state regime); an approximation when the
    /// bottleneck rank or roofline side flips mid-span — which is why
    /// the batched simulator core that calls this is *not* part of the
    /// bit-exact contract. `batch` is mutated during evaluation but
    /// restored before returning.
    pub fn decode_span_time(&self, batch: &mut [DecodeWork], steps: usize) -> f64 {
        if batch.is_empty() || steps == 0 {
            return 0.0;
        }
        let first = self.decode_step_time(batch);
        if steps == 1 {
            return first;
        }
        for w in batch.iter_mut() {
            w.context += steps - 1;
        }
        let last = self.decode_step_time(batch);
        for w in batch.iter_mut() {
            w.context -= steps - 1;
        }
        (first + last) * 0.5 * steps as f64
    }

    /// Per-rank KV bytes per cached token (TP share; DP share goes to the
    /// home rank) — used by simulators for capacity admission.
    pub fn kv_rates(&self) -> (Vec<f64>, f64) {
        let kvb = self.model.kv_bytes_per_token_per_head_layer() as f64;
        let tp: Vec<f64> = (0..self.world)
            .map(|r| {
                (0..self.model.n_layers).map(|l| self.tp_heads[l][r] as f64).sum::<f64>() * kvb
            })
            .collect();
        let dp: f64 = self.dp_heads.iter().map(|&d| d as f64).sum::<f64>() * kvb;
        (tp, dp)
    }

    /// Modeled time to move `tokens` tokens of KV across the PCIe host
    /// link — the swap-tier cost, one direction (swap-out and swap-in
    /// each pay it once). The proactive backup mirror usually holds most
    /// of a preempted request's prefix already, so callers charge only
    /// the un-mirrored delta on swap-out but the full private context on
    /// swap-in.
    pub fn swap_time(&self, tokens: usize) -> f64 {
        self.ic.transfer_time(
            crate::cluster::TransferClass::PcieHost,
            tokens * self.model.kv_bytes_per_token(),
        )
    }

    /// Modeled time to *recompute* `tokens` tokens of KV by re-running
    /// prefill — the alternative a swap-in avoids. Used by the overload
    /// drill and bench to assert the swap tier is the cheaper resume
    /// path.
    pub fn recompute_time(&self, tokens: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        self.prefill_step_time(&[PrefillWork { tokens, context: 0, home: 0 }])
    }

    /// KV capacity budget per rank given resident weights and that
    /// rank's own HBM capacity (mixed fleets may differ per rank).
    pub fn kv_budget(&self) -> Vec<usize> {
        (0..self.world)
            .map(|r| {
                let hbm = self.specs[r].hbm_bytes;
                hbm.saturating_sub(self.weight_bytes[r] + hbm / 16)
            })
            .collect()
    }

    pub fn weight_bytes(&self) -> &[usize] {
        &self.weight_bytes
    }

    /// Per-rank device specs (uniform fleets repeat one spec).
    pub fn device_specs(&self) -> &[GpuSpec] {
        &self.specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama3_70b, mixtral_8x22b};
    use crate::sharding::ShardPlan;

    fn cm(plan: &ShardPlan) -> StepCostModel {
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        StepCostModel::new(plan, &spec, &ic)
    }

    fn uniform_batch(n: usize, ctx: usize, world: usize) -> Vec<DecodeWork> {
        (0..n).map(|i| DecodeWork { context: ctx, home: i % world }).collect()
    }

    #[test]
    fn tp8_faster_than_tp4_decode() {
        let m = llama3_70b();
        let c8 = cm(&ShardPlan::failsafe(&m, 8));
        let c4 = cm(&ShardPlan::failsafe(&m, 4));
        let t8 = c8.decode_step_time(&uniform_batch(64, 4096, 8));
        let t4 = c4.decode_step_time(&uniform_batch(64, 4096, 4));
        assert!(t8 < t4, "t8 {t8} t4 {t4}");
        assert!(t4 / t8 > 1.5 && t4 / t8 < 2.5, "ratio {}", t4 / t8);
    }

    #[test]
    fn naive_tp7_attention_straggles_vs_hybrid() {
        // Fig 2 / Fig 10 mechanism: naive TP7 pays the 2-head straggler
        // every layer; hybrid pays ~8/7 heads' worth.
        let m = llama3_70b();
        let naive = cm(&ShardPlan::nonuniform_naive(&m, 7));
        let fs = cm(&ShardPlan::failsafe(&m, 7));
        // Long context so attention dominates.
        let batch = uniform_batch(56, 16_384, 7);
        let tn = naive.decode_step_time(&batch);
        let tf = fs.decode_step_time(&batch);
        assert!(tn > tf * 1.15, "naive {tn} vs hybrid {tf}");
    }

    #[test]
    fn hybrid_tp8_equals_standard_tp8() {
        // At uniform world sizes all policies coincide (Fig 10: identical
        // performance at TP4/TP8).
        let m = llama3_70b();
        let a = cm(&ShardPlan::failsafe(&m, 8));
        let b = cm(&ShardPlan::nonuniform_naive(&m, 8));
        let batch = uniform_batch(32, 8192, 8);
        let ta = a.decode_step_time(&batch);
        let tb = b.decode_step_time(&batch);
        assert!((ta - tb).abs() / tb < 1e-9, "{ta} vs {tb}");
    }

    #[test]
    fn skewed_homes_slow_hybrid_decode() {
        // All requests homed on rank 0 → DP attention straggles; the
        // load-aware router exists to prevent exactly this.
        let m = llama3_70b();
        let fs = cm(&ShardPlan::failsafe(&m, 7));
        let balanced = uniform_batch(56, 16_384, 7);
        let skewed: Vec<DecodeWork> =
            (0..56).map(|_| DecodeWork { context: 16_384, home: 0 }).collect();
        let tb = fs.decode_step_time(&balanced);
        let ts = fs.decode_step_time(&skewed);
        assert!(ts > tb * 1.1, "skewed {ts} vs balanced {tb}");
    }

    #[test]
    fn prefill_compute_bound_scales_with_tokens() {
        let m = llama3_70b();
        let c = cm(&ShardPlan::failsafe(&m, 8));
        let t1 = c.prefill_step_time(&[PrefillWork { tokens: 1024, context: 0, home: 0 }]);
        let t2 = c.prefill_step_time(&[PrefillWork { tokens: 2048, context: 0, home: 0 }]);
        assert!(t2 > 1.9 * t1, "{t2} vs {t1}");
        // Sanity: 2k-token prefill on 8×H100 should be O(100ms).
        assert!((0.01..1.0).contains(&t2), "t2 {t2}");
    }

    #[test]
    fn decode_step_sane_absolute_range() {
        // 64-request batch at 4k ctx on TP8 H100 ≈ tens of ms per token.
        let m = llama3_70b();
        let c = cm(&ShardPlan::failsafe(&m, 8));
        let t = c.decode_step_time(&uniform_batch(64, 4096, 8));
        assert!((0.005..0.2).contains(&t), "step {t}");
    }

    #[test]
    fn moe_expert_fraction_saturates() {
        let m = mixtral_8x22b();
        let c = cm(&ShardPlan::failsafe(&m, 8));
        let t_small = c.decode_step_time(&uniform_batch(1, 1024, 8));
        let t_big = c.decode_step_time(&uniform_batch(64, 1024, 8));
        // 64× the batch must cost far less than 64× the time (weights amortize).
        assert!(t_big < t_small * 8.0, "small {t_small} big {t_big}");
    }

    #[test]
    fn layer_profiles_cover_all_layers() {
        let m = llama3_70b();
        for w in [4usize, 7, 8] {
            let c = cm(&ShardPlan::failsafe(&m, w));
            let covered: f64 = c.profiles.iter().map(|p| p.layers).sum();
            assert_eq!(covered as usize, m.n_layers, "w={w}");
            // Hybrid plans are flat across layers — one profile.
            assert_eq!(c.profiles.len(), 1, "w={w}");
        }
        // The deduped scan must agree with the naive per-layer scan.
        let c = cm(&ShardPlan::nonuniform_naive(&m, 7));
        let covered: f64 = c.profiles.iter().map(|p| p.layers).sum();
        assert_eq!(covered as usize, m.n_layers);
        for p in &c.profiles {
            let n = c
                .tp_heads
                .iter()
                .zip(&c.dp_heads)
                .filter(|(tp, dp)| **tp == p.tp && **dp == p.dp)
                .count();
            assert_eq!(n as f64, p.layers);
        }
    }

    #[test]
    fn slowdown_hurts_monotonically_without_mitigation() {
        // One throttled rank drags every synchronized step: the deeper the
        // throttle, the slower the step — and at factor 1.0 nothing changes.
        let m = llama3_70b();
        let batch = uniform_batch(64, 4096, 8);
        let base = cm(&ShardPlan::failsafe(&m, 8)).decode_step_time(&batch);
        let mut prev = base;
        for factor in [1.0, 0.75, 0.5, 0.25] {
            let mut c = cm(&ShardPlan::failsafe(&m, 8));
            c.set_speed_factor(3, factor);
            let t = c.decode_step_time(&batch);
            if factor == 1.0 {
                assert!((t - base).abs() / base < 1e-12, "factor 1.0 must be free");
            } else {
                assert!(t > prev, "factor {factor}: {t} not worse than {prev}");
            }
            prev = t;
        }
        // Prefill pays the same straggler tax.
        let chunks = vec![PrefillWork { tokens: 4096, context: 0, home: 0 }];
        let healthy = cm(&ShardPlan::failsafe(&m, 8)).prefill_step_time(&chunks);
        let mut c = cm(&ShardPlan::failsafe(&m, 8));
        c.set_speed_factor(0, 0.5);
        assert!(c.prefill_step_time(&chunks) > healthy * 1.5);
    }

    /// The mitigation acceptance bound: with one rank throttled to 0.5×,
    /// the capacity-weighted plan (uneven heads + FFN blocks + DP-routed
    /// remainder) must strictly beat the unmitigated straggler step and
    /// land within 15% of the capacity-proportional ideal
    /// (`healthy_step × world / Σ speed`).
    #[test]
    fn rebalanced_plan_recovers_most_of_the_straggler_loss() {
        let m = llama3_70b();
        let world = 8;
        let factor = 0.5;
        let throttled = 2usize;
        let mut speeds = vec![1.0; world];
        speeds[throttled] = factor;
        let capacity: f64 = speeds.iter().sum();

        // DP work and KV follow the capacity-aware router: homes spread
        // proportionally to speed (the throttled rank receives less).
        let batch = DecodeWork::capacity_homed(64, 4096, &speeds);

        let plan = ShardPlan::failsafe(&m, world);
        let healthy = cm(&plan).decode_step_time(&batch);

        let mut unmitigated = cm(&plan);
        unmitigated.set_speed_factors(&speeds);
        let baseline = unmitigated.decode_step_time(&batch);

        let mut rebalanced = cm(&plan.reweight(&speeds));
        rebalanced.set_speed_factors(&speeds);
        let mitigated = rebalanced.decode_step_time(&batch);

        let ideal = healthy * world as f64 / capacity;
        assert!(
            mitigated < baseline,
            "mitigated step {mitigated} must strictly beat the straggler step {baseline}"
        );
        assert!(
            mitigated <= ideal * 1.15,
            "mitigated {mitigated} more than 15% over the capacity-proportional ideal {ideal}"
        );
        // Sanity on the gap itself: the unmitigated straggler is far from
        // ideal (that is the problem being solved).
        assert!(baseline > ideal * 1.3, "baseline {baseline} vs ideal {ideal}");
    }

    fn mixed_specs() -> Vec<GpuSpec> {
        (0..8).map(|i| if i < 4 { GpuSpec::h100() } else { GpuSpec::a100() }).collect()
    }

    #[test]
    fn heterogeneous_uniform_specs_match_plain_constructor() {
        let m = llama3_70b();
        let plan = ShardPlan::failsafe(&m, 8);
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        let a = StepCostModel::new(&plan, &spec, &ic);
        let b = StepCostModel::new_heterogeneous(&plan, &vec![spec.clone(); 8], &ic);
        let batch = uniform_batch(64, 4096, 8);
        assert_eq!(a.decode_step_time(&batch), b.decode_step_time(&batch));
        assert_eq!(a.kv_budget(), b.kv_budget());
    }

    #[test]
    fn mixed_fleet_uniform_plan_pays_the_a100_straggler() {
        // A uniform plan on 4×H100+4×A100 paces at the A100s; the pure
        // H100 fleet with the same plan is strictly faster on both phases.
        let m = llama3_70b();
        let plan = ShardPlan::failsafe(&m, 8);
        let specs = mixed_specs();
        let ic = Interconnect::for_devices(&specs);
        let mixed = StepCostModel::new_heterogeneous(&plan, &specs, &ic);
        let pure = cm(&plan);
        let batch = uniform_batch(64, 4096, 8);
        assert!(mixed.decode_step_time(&batch) > pure.decode_step_time(&batch) * 1.3);
        let chunks = vec![PrefillWork { tokens: 4096, context: 0, home: 0 }];
        assert!(mixed.prefill_step_time(&chunks) > pure.prefill_step_time(&chunks) * 1.5);
    }

    #[test]
    fn capacity_proportional_plan_beats_uniform_on_mixed_fleet() {
        // The tentpole mechanism: proportional shards mean the per-layer
        // straggler max runs over proportionally-loaded ranks, so the
        // modeled step beats the uniform plan on the same mixed hardware.
        let m = llama3_70b();
        let specs = mixed_specs();
        let ic = Interconnect::for_devices(&specs);
        let uni = StepCostModel::new_heterogeneous(&ShardPlan::failsafe(&m, 8), &specs, &ic);
        let prop = StepCostModel::new_heterogeneous(
            &ShardPlan::capacity_proportional(&m, &specs),
            &specs,
            &ic,
        );
        let w = crate::cluster::capacity_weights(&specs, crate::sharding::CAPACITY_DECODE_FRAC);
        let batch = DecodeWork::capacity_homed(64, 4096, &w);
        let uniform_home = uniform_batch(64, 4096, 8);
        let t_uni = uni.decode_step_time(&uniform_home);
        let t_prop = prop.decode_step_time(&batch);
        assert!(t_prop < t_uni, "proportional {t_prop} vs uniform {t_uni}");
        let chunks = vec![PrefillWork { tokens: 4096, context: 0, home: 0 }];
        assert!(prop.prefill_step_time(&chunks) < uni.prefill_step_time(&chunks));
    }

    #[test]
    fn kv_budget_respects_per_rank_hbm() {
        let m = llama3_70b();
        let mut small = GpuSpec::h100();
        small.hbm_bytes = 40 * (1 << 30);
        let specs: Vec<GpuSpec> =
            (0..8).map(|i| if i == 5 { small.clone() } else { GpuSpec::h100() }).collect();
        let plan = ShardPlan::failsafe(&m, 8);
        let ic = Interconnect::for_devices(&specs);
        let c = StepCostModel::new_heterogeneous(&plan, &specs, &ic);
        let budget = c.kv_budget();
        assert!(budget[5] < budget[4], "half the HBM must mean less KV headroom");
    }

    #[test]
    fn kv_rates_balanced_for_failsafe() {
        let m = llama3_70b();
        let c = cm(&ShardPlan::failsafe(&m, 7));
        let (tp, dp) = c.kv_rates();
        let min = tp.iter().cloned().fold(f64::MAX, f64::min);
        let max = tp.iter().cloned().fold(0.0, f64::max);
        assert!(max / min < 1.01);
        assert!(dp > 0.0);
    }
}
