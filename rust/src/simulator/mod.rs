//! The discrete-event performance simulator.
//!
//! Regenerates the paper's H100-scale evaluation figures on top of the
//! [`crate::cluster`] hardware model. The simulator executes the *same*
//! coordinator logic (shard plans, router, scheduler, recovery planner) as
//! the real engine — only the per-step GPU time comes from the analytic
//! roofline cost model instead of a PJRT execution.
//!
//! * [`StepCostModel`] — per-rank step times for prefill/decode batches
//!   under any shard plan (the straggler max is taken per layer, which is
//!   what makes naive non-uniform TP slow and hybrid attention fast).
//! * [`SystemConfig`] — a named bundle of placement/routing/scheduling
//!   policies (Standard-TP, Nonuniform-TP, FailSafe, and the Fig 11
//!   ablation points).
//! * [`OnlineSim`] — event-driven online serving (prefill or decode
//!   instance, P-D disaggregated as in §4.2) with fault injection.
//! * [`OnlineSession`] — the steppable decode instance behind
//!   [`OnlineSim`], implementing the same
//!   [`ServingBackend`](crate::engine::ServingBackend) trait as the real
//!   engine, so traces/benches/examples run against either backend.
//! * [`simcore`] — the event-span engine behind
//!   [`ServingBackend::advance_until`](crate::engine::ServingBackend::advance_until):
//!   skips between boundary events (arrivals, completions, injected
//!   faults, driver limits) with batched token accounting in between,
//!   selectable per session via [`CoreMode`] and differentially tested
//!   bit-exact against the per-token stepper.
//! * [`offline`] — steady-state throughput for the Fig 8 fault-trace
//!   integration.

mod config;
mod costmodel;
pub mod offline;
mod online;
pub mod simcore;

pub use config::{PrefillPolicy, SystemConfig};
pub use costmodel::{DecodeWork, PrefillWork, StepCostModel};
pub use online::{OnlineMode, OnlineOutcome, OnlineSession, OnlineSim, RecoveryEvent};
pub use simcore::{CoreMode, CoreStats};
