//! The event-span simulation core behind
//! [`ServingBackend::advance_until`](crate::engine::ServingBackend::advance_until).
//!
//! The legacy driver loop runs one scheduler round per `step()` call:
//! drain buffered events, admit due arrivals, sort and admit the
//! waiting line, then cost one decode step. At fleet scale (1M requests
//! × 32 replicas) the per-round head work — admission scans, pending /
//! waiting sorts, per-token `TokenEmitted` materialization — dwarfs the
//! cost-model arithmetic, and almost all of it is provably a no-op:
//! between two *boundary events* nothing the head looks at can change.
//!
//! # The event queue
//!
//! The core advances in **spans**. A span runs from one boundary event
//! to the next, where the boundary set is the head of a degenerate
//! event heap with at most four entries:
//!
//! * **next completion** — the soonest request to exhaust its budget
//!   finishes in exactly `min remaining_out` rounds (decode is
//!   preempt-free and every running request emits one token per round);
//! * **next arrival** — the front of the arrival queue (kept sorted by
//!   the admit phase), due when the clock crosses it;
//! * **driver limits** — the [`AdvanceLimit`] round / token / clock
//!   bounds the caller (fault injector, timeline replayer, fleet
//!   chunker) wants respected;
//! * **injected events** — faults and rejoins land between
//!   `advance_until` calls, so they are span boundaries by construction;
//! * **pending preemption** — while a [`PreemptPolicy`](crate::engine::PreemptPolicy)
//!   is set and requests are parked (waiting or swapped), the SLO
//!   scheduler may evict a running decode at any round head, so the
//!   frozen-running-set invariant below does not hold: both span
//!   engines degrade to one-round spans until the parked lines drain,
//!   which keeps preemption decisions landing at identical clock times
//!   on every core.
//!
//! Because each entry is the minimum of its own ordered source, the
//! "heap" is a constant-size min — popped by comparing four candidates,
//! never allocated.
//!
//! # Why skipping the head is safe mid-span
//!
//! Within a span the running set is frozen (the span is capped at the
//! soonest completion), so no batch slot frees and `running.len()`
//! never shrinks; per-rank `kv_used` only grows, so a request that did
//! not fit at the span's first round cannot fit at a later one; no
//! arrival comes due (the span breaks when the clock crosses one); and
//! the router is only consulted at admission. Hence the head's
//! admission scans and sorts would return identical results every
//! round — the span engines run them once per span instead.
//!
//! # Equivalence contract
//!
//! [`CoreMode::Exact`] (the default) replays the legacy tick's
//! floating-point operations per virtual round in identical order —
//! same `decode_step_time` calls on the same batch, same clock and
//! backup-daemon updates, same per-request metric/KV accounting, same
//! completion handling — so clocks, reports, metrics, and lifecycle
//! events are **bit-exact** against [`CoreMode::Stepper`]. The one
//! observational difference: per-token [`EngineEvent::TokenEmitted`]
//! events are elided; their counts are returned in
//! [`AdvanceOutcome::tokens`] / [`AdvanceOutcome::progressed`] instead
//! (lifecycle events — finishes, aborts, fault notices — still stream
//! through the sink). `tests/simcore_tests.rs` enforces the contract
//! with seeded randomized scenario programs through both engines.
//!
//! [`CoreMode::Batched`] additionally collapses each span's cost-model
//! arithmetic to closed form (trapezoid span time, bulk metrics,
//! O(1) histogram bulk-record) — the 100×+ iteration-saving mode
//! `benches/simcore.rs` measures. It is deliberately **not** part of
//! the bit-exact contract: span time is a trapezoid approximation, TBT
//! samples are uniform-gap, and the backup daemon is modeled as keeping
//! pace.

use crate::engine::{AdvanceLimit, AdvanceOutcome, EngineEvent};

use super::costmodel::DecodeWork;
use super::online::OnlineSession;

/// Which engine [`ServingBackend::advance_until`](crate::engine::ServingBackend::advance_until)
/// runs on for an [`OnlineSession`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreMode {
    /// Event-span core, bit-exact with the stepper (default): skips the
    /// per-round scheduler head and `TokenEmitted` materialization,
    /// keeps every floating-point operation of the legacy tick.
    Exact,
    /// Event-span core with closed-form span accounting: fastest, not
    /// bit-exact (trapezoid span time, uniform-gap TBT samples).
    Batched,
    /// The legacy per-token step loop — the differential baseline.
    Stepper,
}

impl std::str::FromStr for CoreMode {
    type Err = String;

    fn from_str(v: &str) -> Result<Self, Self::Err> {
        match v {
            "exact" => Ok(CoreMode::Exact),
            "batched" => Ok(CoreMode::Batched),
            "stepper" => Ok(CoreMode::Stepper),
            other => {
                Err(format!("unknown core mode {other:?} (expected exact | batched | stepper)"))
            }
        }
    }
}

/// Span-engine telemetry: `steps` costed decode rounds were covered by
/// `spans` span iterations (the stepper pays one full scheduler round
/// per step; the span engines pay one head per span).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreStats {
    /// Event spans executed by the span engines.
    pub spans: usize,
    /// Costed decode rounds (same meter as `ServeReport::steps`).
    pub steps: usize,
}

impl CoreStats {
    /// Stepper iterations per span iteration — the headline ratio
    /// `BENCH_simcore.json` tracks (≥ 100× on the fleet sweep).
    pub fn iters_ratio(&self) -> f64 {
        if self.spans == 0 {
            0.0
        } else {
            self.steps as f64 / self.spans as f64
        }
    }
}

/// Advance `s` until idle or until `limit` is hit, on the session's
/// configured [`CoreMode`]. Events stream into `sink`.
pub(crate) fn advance(
    s: &mut OnlineSession,
    limit: AdvanceLimit,
    sink: &mut Vec<EngineEvent>,
) -> AdvanceOutcome {
    match s.core {
        CoreMode::Stepper => stepper(s, limit, sink),
        CoreMode::Exact => exact(s, limit, sink),
        CoreMode::Batched => batched(s, limit, sink),
    }
}

/// The legacy per-token loop: one full scheduler round per iteration —
/// byte-for-byte what the default `advance_until` trait impl does, kept
/// inline here because the session's override shadows the default.
fn stepper(
    s: &mut OnlineSession,
    limit: AdvanceLimit,
    sink: &mut Vec<EngineEvent>,
) -> AdvanceOutcome {
    let mut out = AdvanceOutcome::default();
    loop {
        if s.events.is_empty() && s.session_idle() {
            break;
        }
        if limit.reached(out.steps, out.tokens, s.clock) {
            break;
        }
        let events = s.tick();
        out.steps += 1;
        out.tokens +=
            events.iter().filter(|e| matches!(e, EngineEvent::TokenEmitted { .. })).count();
        sink.extend(events);
    }
    out
}

/// The bit-exact span engine. See the module docs for the invariant
/// that makes skipping the per-round head safe; everything inside the
/// virtual-step loop replicates the legacy tick's FP operations in
/// identical order.
fn exact(
    s: &mut OnlineSession,
    limit: AdvanceLimit,
    sink: &mut Vec<EngineEvent>,
) -> AdvanceOutcome {
    let mut out = AdvanceOutcome::default();
    loop {
        if s.events.is_empty() && s.session_idle() {
            break;
        }
        if limit.reached(out.steps, out.tokens, s.clock) {
            break;
        }
        // Round head — the legacy tick prologue, run once per span.
        sink.append(&mut s.events);
        s.admit_phase();
        if s.running.is_empty() {
            // A head-only round: fast-forward (or stall) and recheck.
            s.idle_jump();
            out.steps += 1;
            continue;
        }

        // Span boundaries: the soonest completion caps the span length;
        // arrivals and driver limits break it early. A pending
        // preemption pins the span to one round (see module docs).
        let span_cap = if s.preemption_pending() {
            1
        } else {
            s.running.iter().map(|r| r.remaining_out).min().unwrap()
        };
        let next_arr = s.pending.last().map(|p| p.arrival); // sorted by the head
        s.work.clear();
        s.work.extend(s.running.iter().map(|r| DecodeWork { context: r.context, home: r.home }));
        let mut did = 0usize;
        loop {
            // One virtual decode round.
            let dt = s.cost.decode_step_time(&s.work);
            s.clock += dt;
            s.steps += 1;
            s.daemon.advance(dt, &mut s.backup);
            for i in 0..s.running.len() {
                let (id, context) = (s.running[i].id, s.running[i].context);
                s.metrics.on_token(id, s.clock);
                s.daemon.produced(id, context, context + 1);
                let r = &mut s.running[i];
                r.context += 1;
                r.remaining_out -= 1;
                r.emitted += 1; // TokenEmitted elided; see module docs
                let home = r.home;
                for (ru, used) in s.kv_used.iter_mut().enumerate() {
                    *used += s.tp_rate[ru];
                }
                s.kv_used[home] += s.dp_rate;
                s.work[i].context += 1;
            }
            did += 1;
            out.steps += 1;
            out.tokens += s.running.len();
            if did == span_cap {
                break; // the soonest completion lands on this round
            }
            if limit.reached(out.steps, out.tokens, s.clock) {
                break;
            }
            if next_arr.is_some_and(|a| a <= s.clock) {
                break; // an arrival came due: the head must run again
            }
        }
        // Span epilogue. Per-rank KV only grew, so the last round's sum
        // is the span's peak — identical to the per-round max the
        // stepper takes. Completions retire exactly as in the tick.
        s.peak_kv = s.peak_kv.max(s.kv_used.iter().sum());
        for r in &s.running {
            out.progressed.push((r.id, did));
        }
        let finished: Vec<usize> = (0..s.running.len())
            .filter(|&i| s.running[i].remaining_out == 0)
            .collect();
        for &i in finished.iter().rev() {
            let r = s.running.swap_remove(i);
            s.finish_running(r, sink);
        }
        s.spans += 1;
    }
    out
}

/// The closed-form span engine: same boundaries as [`exact`], but the
/// whole span is accounted in O(batch) instead of O(batch × rounds) —
/// trapezoid span time, bulk metrics, bulk KV growth. Clock-based
/// boundaries (arrivals, `clock_at`) are *estimated* with the span's
/// first-round time, so a span may overshoot them by the growth of the
/// per-round time across the span; they are honored at the next head.
fn batched(
    s: &mut OnlineSession,
    limit: AdvanceLimit,
    sink: &mut Vec<EngineEvent>,
) -> AdvanceOutcome {
    let mut out = AdvanceOutcome::default();
    loop {
        if s.events.is_empty() && s.session_idle() {
            break;
        }
        if limit.reached(out.steps, out.tokens, s.clock) {
            break;
        }
        sink.append(&mut s.events);
        s.admit_phase();
        if s.running.is_empty() {
            s.idle_jump();
            out.steps += 1;
            continue;
        }

        let b = s.running.len();
        let span_cap = if s.preemption_pending() {
            1
        } else {
            s.running.iter().map(|r| r.remaining_out).min().unwrap()
        };
        let next_arr = s.pending.last().map(|p| p.arrival);
        s.work.clear();
        s.work.extend(s.running.iter().map(|r| DecodeWork { context: r.context, home: r.home }));
        let dt_first = s.cost.decode_step_time(&s.work);

        // Bound the span by every pending boundary. Round/token bounds
        // are exact; clock bounds are first-round-time estimates.
        let mut span = span_cap;
        if let Some(n) = limit.max_steps {
            span = span.min(n - out.steps); // > 0: limit checked above
        }
        if let Some(n) = limit.max_tokens {
            let deficit = n - out.tokens; // > 0: limit checked above
            span = span.min(deficit.div_euclid(b) + usize::from(deficit % b != 0));
        }
        let est = |target: f64| -> usize {
            if dt_first <= 0.0 {
                return 1;
            }
            let k = ((target - s.clock) / dt_first).ceil();
            if k >= 1.0 {
                k as usize
            } else {
                1
            }
        };
        if let Some(at) = limit.clock_at {
            span = span.min(est(at));
        }
        if let Some(a) = next_arr {
            span = span.min(est(a));
        }
        let span = span.max(1);

        let t0 = s.clock;
        let span_time = s.cost.decode_span_time(&mut s.work, span);
        s.clock += span_time;
        s.steps += span;
        // The daemon is modeled as keeping pace over the span: one bulk
        // advance, no per-token mirror queue (a deliberate divergence
        // from the exact core — backup-lag studies use Exact).
        s.daemon.advance(span_time, &mut s.backup);

        let first_at = t0 + span_time / span as f64;
        for i in 0..s.running.len() {
            let (id, home) = (s.running[i].id, s.running[i].home);
            s.metrics.on_token_span(id, span, first_at, s.clock);
            let r = &mut s.running[i];
            r.context += span;
            r.remaining_out -= span;
            r.emitted += span;
            for (ru, used) in s.kv_used.iter_mut().enumerate() {
                *used += s.tp_rate[ru] * span as f64;
            }
            s.kv_used[home] += s.dp_rate * span as f64;
        }
        out.steps += span;
        out.tokens += span * b;
        s.peak_kv = s.peak_kv.max(s.kv_used.iter().sum());
        for r in &s.running {
            out.progressed.push((r.id, span));
        }
        let finished: Vec<usize> = (0..s.running.len())
            .filter(|&i| s.running[i].remaining_out == 0)
            .collect();
        for &i in finished.iter().rev() {
            let r = s.running.swap_remove(i);
            s.finish_running(r, sink);
        }
        s.spans += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{AdvanceLimit, ServingBackend, SubmitOptions};
    use crate::model::llama3_70b;
    use crate::simulator::{OnlineMode, OnlineSim, SystemConfig};

    fn session(mode: CoreMode) -> OnlineSession {
        let mut s = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b())
            .session();
        s.set_core_mode(mode);
        s
    }

    fn submit_mixed(s: &mut OnlineSession) {
        for i in 0..24 {
            let prompt = vec![0u32; 512 + (i % 5) * 700];
            let opts = SubmitOptions::new(4 + (i % 7)).at(i as f64 * 0.07);
            s.submit_with(&prompt, opts).unwrap();
        }
    }

    /// Field-wise exact comparison (`GenerationResult` has no `PartialEq`).
    fn assert_reports_identical(a: &crate::engine::ServeReport, b: &crate::engine::ServeReport) {
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits(), "wall_s");
        assert_eq!(a.prefill_tokens, b.prefill_tokens);
        assert_eq!(a.decode_tokens, b.decode_tokens);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.recoveries.len(), b.recoveries.len());
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.output_tokens.len(), y.output_tokens.len(), "req {}", x.id);
            assert_eq!(
                x.ttft_s.map(f64::to_bits),
                y.ttft_s.map(f64::to_bits),
                "ttft of req {}",
                x.id
            );
            assert_eq!(x.max_tbt_s.to_bits(), y.max_tbt_s.to_bits(), "max_tbt of req {}", x.id);
            assert_eq!(x.aborted, y.aborted);
        }
    }

    /// The headline contract: the exact span engine is bit-identical to
    /// the stepper on a mixed staggered workload.
    #[test]
    fn exact_core_is_bit_exact_vs_stepper() {
        let run = |mode: CoreMode| {
            let mut s = session(mode);
            submit_mixed(&mut s);
            let mut sink = Vec::new();
            let out = s.advance_until(AdvanceLimit::unbounded(), &mut sink).unwrap();
            (s, out, sink)
        };
        let (step_s, step_out, step_sink) = run(CoreMode::Stepper);
        let (exact_s, exact_out, exact_sink) = run(CoreMode::Exact);
        assert_reports_identical(&step_s.report(), &exact_s.report());
        assert_eq!(step_s.now().to_bits(), exact_s.now().to_bits(), "clock");
        assert_eq!(step_out.steps, exact_out.steps, "scheduler rounds");
        assert_eq!(step_out.tokens, exact_out.tokens, "tokens");
        // Lifecycle events match in order; the span engine elides only
        // the per-token stream.
        let lifecycle = |evs: &[EngineEvent]| -> Vec<EngineEvent> {
            evs.iter()
                .filter(|e| !matches!(e, EngineEvent::TokenEmitted { .. }))
                .copied()
                .collect()
        };
        assert_eq!(lifecycle(&step_sink), lifecycle(&exact_sink));
        // The elided tokens are fully accounted in `progressed`.
        let progressed: usize = exact_out.progressed.iter().map(|&(_, n)| n).sum();
        assert_eq!(progressed, exact_out.tokens);
        assert!(exact_s.core_stats().spans < step_out.steps, "spans must compress rounds");
    }

    /// Round budgets mean the same thing on both engines: advancing in
    /// fixed-size round chunks visits bit-identical intermediate states.
    #[test]
    fn chunked_round_budgets_are_mode_independent() {
        let mut a = session(CoreMode::Stepper);
        let mut b = session(CoreMode::Exact);
        submit_mixed(&mut a);
        submit_mixed(&mut b);
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        for chunk in [1usize, 3, 7, 16, 64, 1000] {
            let oa = a.advance_until(AdvanceLimit::steps(chunk), &mut sa).unwrap();
            let ob = b.advance_until(AdvanceLimit::steps(chunk), &mut sb).unwrap();
            assert_eq!(oa.steps, ob.steps, "chunk {chunk}");
            assert_eq!(oa.tokens, ob.tokens, "chunk {chunk}");
            assert_eq!(a.now().to_bits(), b.now().to_bits(), "clock after chunk {chunk}");
        }
        while !a.is_idle() || !b.is_idle() {
            a.advance_until(AdvanceLimit::steps(32), &mut sa).unwrap();
            b.advance_until(AdvanceLimit::steps(32), &mut sb).unwrap();
        }
        assert_reports_identical(&a.report(), &b.report());
    }

    /// Clock limits stop both engines at the same boundary.
    #[test]
    fn clock_limit_stops_at_same_round() {
        let mut a = session(CoreMode::Stepper);
        let mut b = session(CoreMode::Exact);
        submit_mixed(&mut a);
        submit_mixed(&mut b);
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        let oa = a.advance_until(AdvanceLimit::clock(0.5), &mut sa).unwrap();
        let ob = b.advance_until(AdvanceLimit::clock(0.5), &mut sb).unwrap();
        assert_eq!(oa.steps, ob.steps);
        assert_eq!(a.now().to_bits(), b.now().to_bits());
        assert!(a.now() >= 0.5 || a.is_idle());
    }

    /// The batched core conserves counts (every token, every request)
    /// and compresses iterations, even though timing is approximate.
    #[test]
    fn batched_core_conserves_tokens_and_compresses() {
        let mut exact = session(CoreMode::Exact);
        let mut fast = session(CoreMode::Batched);
        submit_mixed(&mut exact);
        submit_mixed(&mut fast);
        let mut sink = Vec::new();
        let oe = exact.advance_until(AdvanceLimit::unbounded(), &mut sink).unwrap();
        sink.clear();
        let of = fast.advance_until(AdvanceLimit::unbounded(), &mut sink).unwrap();
        assert_eq!(oe.tokens, of.tokens, "decode token conservation");
        let (re, rf) = (exact.report(), fast.report());
        assert_eq!(re.decode_tokens, rf.decode_tokens);
        assert_eq!(re.prefill_tokens, rf.prefill_tokens);
        assert_eq!(re.results.len(), rf.results.len());
        for (x, y) in re.results.iter().zip(&rf.results) {
            assert_eq!(x.output_tokens.len(), y.output_tokens.len(), "req {}", x.id);
            assert!(y.ttft_s.is_some(), "req {} has a first token", y.id);
        }
        assert!(
            fast.core_stats().spans <= exact.core_stats().spans,
            "closed-form spans ({}) never exceed exact spans ({})",
            fast.core_stats().spans,
            exact.core_stats().spans
        );
        assert!(fast.core_stats().iters_ratio() > 1.0);
        // Wall time stays in the same regime as the exact core.
        let (we, wf) = (re.wall_s, rf.wall_s);
        assert!(wf > 0.25 * we && wf < 4.0 * we, "batched wall {wf} vs exact {we}");
    }

    /// `CoreMode` parses from CLI strings, strictly.
    #[test]
    fn core_mode_parses_strictly() {
        assert_eq!("exact".parse::<CoreMode>().unwrap(), CoreMode::Exact);
        assert_eq!("batched".parse::<CoreMode>().unwrap(), CoreMode::Batched);
        assert_eq!("stepper".parse::<CoreMode>().unwrap(), CoreMode::Stepper);
        assert!("fast".parse::<CoreMode>().is_err());
    }
}
