//! Event-driven online serving simulation (P-D disaggregated, §4.2).
//!
//! A simulated instance is either a **prefill instance** (measures TTFT
//! and input-token throughput) or a **decode instance** (measures TBT and
//! generated-token throughput) — mirroring the paper's separate reporting.
//! The decode instance supports mid-run GPU failure with any
//! [`RecoveryMethod`], which is how Fig 12 / Table 3 are produced.

use crate::kvcache::BackupStore;
use crate::metrics::ServingMetrics;
use crate::recovery::{plan_recovery, BackupDaemon, RecoveryInput, RecoveryMethod};
use crate::router::DpRouter;
use crate::scheduler::{adaptive_chunked_prefill, fifo_chunked_prefill, PrefillItem};
use crate::traces::TraceRequest;
use crate::cluster::{GpuSpec, Interconnect};
use crate::{RankId, RequestId, SimTime};

use super::costmodel::{DecodeWork, PrefillWork, StepCostModel};
use super::{PrefillPolicy, SystemConfig};

/// Which serving stage this instance simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnlineMode {
    Prefill,
    Decode,
}

/// A GPU failure to inject mid-run.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryEvent {
    /// Inject 100 ms after this many requests have arrived (paper §4.3.3
    /// injects after the 250th request of a 500-request window).
    pub after_requests: usize,
    /// The failing rank (old numbering).
    pub failed_rank: RankId,
    /// Recovery strategy to apply.
    pub method: RecoveryMethod,
}

/// Results of one simulated run.
#[derive(Debug)]
pub struct OnlineOutcome {
    pub metrics: ServingMetrics,
    /// GPU state recovery latency, if a failure was injected (Table 3).
    pub recovery_latency_s: Option<f64>,
    /// Steps executed (telemetry).
    pub steps: usize,
    /// Final world size.
    pub world: usize,
}

/// Online serving simulator for one TP instance.
pub struct OnlineSim {
    pub config: SystemConfig,
    pub mode: OnlineMode,
    pub world: usize,
    pub spec: GpuSpec,
    /// The served model (defaults to llama-3.1-70B).
    pub model: crate::model::ModelSpec,
    /// Prefill token budget per batch (Algorithm 1's `N`).
    pub token_budget: usize,
    /// Decode batch cap.
    pub max_batch: usize,
    /// Fraction of PCIe bandwidth reserved for background KV backup.
    pub backup_fraction: f64,
}

struct Running {
    id: RequestId,
    home: RankId,
    context: usize,
    remaining_out: usize,
}

impl OnlineSim {
    pub fn new(config: SystemConfig, mode: OnlineMode, world: usize) -> Self {
        OnlineSim {
            config,
            mode,
            world,
            spec: GpuSpec::h100(),
            model: crate::model::llama3_70b(),
            token_budget: 8192,
            max_batch: 256,
            backup_fraction: 0.25,
        }
    }

    /// Select the served model.
    pub fn with_model(mut self, model: crate::model::ModelSpec) -> Self {
        self.model = model;
        self
    }

    /// Run the trace to completion (or until `max_sim_time`).
    pub fn run(&self, trace: &[TraceRequest], fault: Option<RecoveryEvent>) -> OnlineOutcome {
        match self.mode {
            OnlineMode::Prefill => self.run_prefill(trace),
            OnlineMode::Decode => self.run_decode(trace, fault),
        }
    }

    // ---------------------------------------------------------- prefill --

    fn run_prefill(&self, trace: &[TraceRequest]) -> OnlineOutcome {
        let model = self.model.clone();
        let model = &model;
        let plan = self.config.plan(model, self.world);
        let cost = StepCostModel::new(&plan, &self.spec, &Interconnect::new(self.spec.clone()));
        let mut metrics = ServingMetrics::new();
        let mut router = DpRouter::new(self.config.router, self.world);

        let mut arrivals: Vec<&TraceRequest> = trace.iter().collect();
        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut next_arrival = 0usize;
        let mut items: Vec<PrefillItem> = Vec::new();
        let mut clock: SimTime = 0.0;
        let mut steps = 0usize;

        loop {
            // Admit arrivals.
            while next_arrival < arrivals.len() && arrivals[next_arrival].arrival <= clock {
                let r = arrivals[next_arrival];
                metrics.on_arrival(r.id, r.arrival);
                let home = router.route(r.input_tokens as f64);
                items.push(PrefillItem {
                    request: r.id,
                    rank: home,
                    context: 0,
                    remaining: r.input_tokens,
                });
                next_arrival += 1;
            }
            if items.is_empty() {
                if next_arrival >= arrivals.len() {
                    break;
                }
                clock = arrivals[next_arrival].arrival;
                continue;
            }

            // Form the batch under the configured policy. Algorithm 1
            // initializes L_r <- 0: balance is *within-batch* (seeding with
            // the whole backlog would funnel the budget to one rank).
            let carry = vec![0.0; self.world];
            let batch = match self.config.prefill {
                PrefillPolicy::Fifo => {
                    fifo_chunked_prefill(self.token_budget, &items, &carry, self.world)
                }
                PrefillPolicy::Adaptive => {
                    adaptive_chunked_prefill(self.token_budget, &items, &carry, self.world, 16)
                }
            };
            if batch.tokens == 0 {
                break; // defensive: nothing schedulable
            }

            // Cost the step.
            let work: Vec<PrefillWork> = batch
                .chunks
                .iter()
                .map(|c| {
                    let it = items.iter().find(|i| i.request == c.request).unwrap();
                    PrefillWork { tokens: c.tokens, context: it.context, home: c.rank }
                })
                .collect();
            let dt = cost.prefill_step_time(&work);
            clock += dt;
            steps += 1;

            // Apply chunk progress.
            for c in &batch.chunks {
                let it = items.iter_mut().find(|i| i.request == c.request).unwrap();
                it.context += c.tokens;
                it.remaining -= c.tokens;
                router.complete(c.rank, c.tokens as f64);
                metrics.on_prefill_tokens(c.tokens);
            }
            // Finished prefills emit their first token.
            items.retain(|it| {
                if it.remaining == 0 {
                    metrics.on_token(it.request, clock);
                    metrics.on_finish(it.request);
                    false
                } else {
                    true
                }
            });
        }

        OnlineOutcome { metrics, recovery_latency_s: None, steps, world: self.world }
    }

    // ----------------------------------------------------------- decode --

    fn run_decode(&self, trace: &[TraceRequest], fault: Option<RecoveryEvent>) -> OnlineOutcome {
        let model = self.model.clone();
        let ic = Interconnect::new(self.spec.clone());
        let mut plan = self.config.plan(&model, self.world);
        let mut cost = StepCostModel::new(&plan, &self.spec, &ic);
        let mut world = self.world;

        let mut metrics = ServingMetrics::new();
        let mut router = DpRouter::new(self.config.router, world);
        let mut backup = BackupStore::new(1 << 42);
        let mut daemon =
            BackupDaemon::new(self.spec.pcie_bw, self.backup_fraction, model.kv_bytes_per_token());

        let mut arrivals: Vec<&TraceRequest> = trace.iter().collect();
        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut next_arrival = 0usize;
        let mut waiting: Vec<(RequestId, usize, usize)> = Vec::new(); // (id, ctx, out)
        let mut running: Vec<Running> = Vec::new();
        let (mut tp_rate, mut dp_rate) = cost.kv_rates();
        let mut kv_budget = cost.kv_budget();
        let mut kv_used = vec![0.0f64; world];
        let mut clock: SimTime = 0.0;
        let mut steps = 0usize;
        let mut fault_at: Option<SimTime> = None;
        let mut fault_done = false;
        let mut recovery_latency = None;

        loop {
            // Admit arrivals into the waiting queue.
            while next_arrival < arrivals.len() && arrivals[next_arrival].arrival <= clock {
                let r = arrivals[next_arrival];
                metrics.on_arrival(r.id, r.arrival);
                metrics.on_prefill_tokens(r.input_tokens);
                waiting.push((r.id, r.input_tokens, r.output_tokens.max(1)));
                next_arrival += 1;
                if let Some(f) = fault {
                    if !fault_done && fault_at.is_none() && next_arrival >= f.after_requests {
                        fault_at = Some(r.arrival + 0.1);
                    }
                }
            }

            // Inject the failure.
            if let (Some(f), Some(at)) = (fault, fault_at) {
                if !fault_done && clock >= at {
                    let reqs: Vec<(RequestId, usize, RankId)> =
                        running.iter().map(|r| (r.id, r.context, r.home)).collect();
                    let survivor_map: Vec<Option<RankId>> = (0..world)
                        .map(|r| {
                            if r == f.failed_rank {
                                None
                            } else {
                                Some(if r < f.failed_rank { r } else { r - 1 })
                            }
                        })
                        .collect();
                    let new_plan = SystemConfig {
                        // recovery keeps the configured policies
                        ..self.config.clone()
                    }
                    .plan(&model, world - 1);
                    let input = RecoveryInput {
                        spec: &self.spec,
                        ic: &ic,
                        old_plan: &plan,
                        new_plan: &new_plan,
                        survivor_map: &survivor_map,
                        failed_rank: f.failed_rank,
                        requests: &reqs,
                        backup: &backup,
                    };
                    let outcome = plan_recovery(f.method, &input);
                    recovery_latency = Some(outcome.total_s);
                    clock += outcome.total_s; // the stall every in-flight request sees
                    // Reconfigure to the reduced world.
                    world -= 1;
                    plan = new_plan;
                    cost = StepCostModel::new(&plan, &self.spec, &ic);
                    let rates = cost.kv_rates();
                    tp_rate = rates.0;
                    dp_rate = rates.1;
                    kv_budget = cost.kv_budget();
                    router = router.remap(&survivor_map, world);
                    // Re-home requests of the failed rank; recompute KV usage.
                    kv_used = vec![0.0; world];
                    for r in running.iter_mut() {
                        r.home = survivor_map[r.home].unwrap_or_else(|| router.tracker().least_loaded());
                        for (ru, used) in kv_used.iter_mut().enumerate() {
                            *used += tp_rate[ru] * r.context as f64;
                        }
                        kv_used[r.home] += dp_rate * r.context as f64;
                    }
                    fault_done = true;
                }
            }

            // Admit from waiting while KV fits (project to full output length).
            waiting.retain(|&(id, ctx, out)| {
                let total = (ctx + out) as f64;
                let fits = (0..world).all(|r| {
                    let add = tp_rate[r] * total
                        + if r == router.tracker().least_loaded() { dp_rate * total } else { 0.0 };
                    kv_used[r] + add <= kv_budget[r] as f64 * 0.97
                }) && running.len() < self.max_batch;
                if fits {
                    let home = router.route(ctx as f64);
                    for (r, used) in kv_used.iter_mut().enumerate() {
                        *used += tp_rate[r] * ctx as f64;
                    }
                    kv_used[home] += dp_rate * ctx as f64;
                    // P-D disaggregation: the prefill instance ships this
                    // request's KV through host DRAM, so the input context
                    // is host-mirrored the moment the decode instance
                    // admits it; the daemon only trails the decode tokens.
                    backup.backup(id, ctx, model.kv_bytes_per_token());
                    running.push(Running { id, home, context: ctx, remaining_out: out });
                    false
                } else {
                    true
                }
            });

            if running.is_empty() {
                if next_arrival >= arrivals.len() && waiting.is_empty() {
                    break;
                }
                if next_arrival < arrivals.len() {
                    clock = clock.max(arrivals[next_arrival].arrival);
                    // If also waiting requests can never fit → avoid livelock.
                    if waiting.len() >= self.max_batch {
                        break;
                    }
                    continue;
                }
                // Waiting requests that can never fit (cold system): bail.
                break;
            }

            // One decode step.
            let work: Vec<DecodeWork> = running
                .iter()
                .map(|r| DecodeWork { context: r.context, home: r.home })
                .collect();
            let dt = cost.decode_step_time(&work);
            clock += dt;
            steps += 1;
            daemon.advance(dt, &mut backup);

            let mut finished: Vec<usize> = Vec::new();
            for (i, r) in running.iter_mut().enumerate() {
                metrics.on_token(r.id, clock);
                daemon.produced(r.id, r.context, r.context + 1);
                r.context += 1;
                r.remaining_out -= 1;
                for (ru, used) in kv_used.iter_mut().enumerate() {
                    *used += tp_rate[ru];
                }
                kv_used[r.home] += dp_rate;
                if r.remaining_out == 0 {
                    finished.push(i);
                }
            }
            for &i in finished.iter().rev() {
                let r = running.swap_remove(i);
                metrics.on_finish(r.id);
                daemon.forget(r.id);
                backup.release(r.id, model.kv_bytes_per_token());
                for (ru, used) in kv_used.iter_mut().enumerate() {
                    *used = (*used - tp_rate[ru] * r.context as f64).max(0.0);
                }
                kv_used[r.home] = (kv_used[r.home] - dp_rate * r.context as f64).max(0.0);
                router.complete(r.home, 0.0);
            }
        }

        OnlineOutcome { metrics, recovery_latency_s: recovery_latency, steps, world }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;
    use crate::traces::{mooncake_trace, poisson_arrivals};

    fn small_trace(n: usize, rate: f64) -> Vec<TraceRequest> {
        let mut t = mooncake_trace(n, 11);
        // Keep realistic (long) contexts — they drive the KV/compute
        // imbalance under test — but shorten outputs so tests run fast.
        for r in t.iter_mut() {
            r.input_tokens = r.input_tokens.min(8192);
            r.output_tokens = (r.output_tokens / 8).clamp(4, 32);
        }
        poisson_arrivals(&mut t, rate, 11);
        t
    }

    /// Like `small_trace` but with short inputs for prefill-speed tests.
    fn tiny_trace(n: usize, rate: f64) -> Vec<TraceRequest> {
        let mut t = mooncake_trace(n, 11);
        for r in t.iter_mut() {
            r.input_tokens = (r.input_tokens / 16).clamp(16, 1024);
            r.output_tokens = (r.output_tokens / 8).clamp(4, 32);
        }
        poisson_arrivals(&mut t, rate, 11);
        t
    }

    #[test]
    fn decode_sim_completes_all_requests() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let trace = small_trace(40, 5.0);
        let out = sim.run(&trace, None);
        assert_eq!(out.metrics.n_requests(), 40);
        assert!(out.metrics.output_throughput() > 0.0);
        assert!(out.steps > 0);
    }

    #[test]
    fn prefill_sim_ttft_increases_with_rate() {
        let mk = |rate| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Prefill, 8)
                .with_model(llama3_70b());
            let trace = tiny_trace(60, rate);
            let out = sim.run(&trace, None);
            out.metrics.ttft.p90()
        };
        let slow = mk(0.5);
        let fast = mk(50.0);
        assert!(fast > slow, "p90 TTFT at high rate {fast} must exceed low rate {slow}");
    }

    #[test]
    fn failsafe_tp7_decode_beats_nonuniform() {
        let trace = small_trace(60, 10_000.0); // effectively offline (saturating)
        let run = |cfg: SystemConfig| {
            let sim =
                OnlineSim::new(cfg, OnlineMode::Decode, 7).with_model(llama3_70b());
            sim.run(&trace, None).metrics.output_throughput()
        };
        let fs = run(SystemConfig::failsafe());
        let nu = run(SystemConfig::nonuniform());
        assert!(fs > nu * 1.1, "failsafe {fs} vs nonuniform {nu}");
    }

    #[test]
    fn recovery_stall_creates_tbt_spike() {
        let trace = small_trace(100, 20.0);
        let run = |method: RecoveryMethod| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b());
            let out = sim.run(
                &trace,
                Some(RecoveryEvent { after_requests: 50, failed_rank: 3, method }),
            );
            (out.recovery_latency_s.unwrap(), out.world)
        };
        let (rec, w1) = run(RecoveryMethod::Recompute);
        let (full, w2) = run(RecoveryMethod::Full);
        assert_eq!(w1, 7);
        assert_eq!(w2, 7);
        assert!(rec > 10.0 * full, "recompute {rec} vs full {full}");
    }
}
