//! Event-driven online serving simulation (P-D disaggregated, §4.2).
//!
//! A simulated instance is either a **prefill instance** (measures TTFT
//! and input-token throughput) or a **decode instance** (measures TBT and
//! generated-token throughput) — mirroring the paper's separate reporting.
//!
//! The decode instance is a steppable [`OnlineSession`] implementing the
//! same [`ServingBackend`] trait as the real engine: submit with
//! [`SubmitOptions`], tick with `step()`, abort mid-flight, and inject a
//! GPU failure — or rejoin a failed GPU — with any [`RecoveryMethod`] at
//! any step boundary, which is how Fig 12 / Table 3 and the
//! availability-timeline replays are produced. [`OnlineSim::run`] wraps the
//! session for the batch (trace-driven) workflow. Simulated token
//! emissions carry placeholder token id `0`: only counts and timing are
//! meaningful on this backend.

use anyhow::Result;

use crate::cluster::{capacity_weights, GpuSpec, Interconnect, TransferClass};
use crate::engine::{
    AdvanceLimit, AdvanceOutcome, EngineEvent, GenerationResult, PreemptPolicy, ServeReport,
    ServingBackend, SubmitOptions, BLOCK_TOKENS,
};
use crate::kvcache::BackupStore;
use crate::metrics::ServingMetrics;
use crate::obs::{ObsSink, Observer, RecoveryPhases};
use crate::prefix::{PrefixStats, PrefixTrie};
use crate::recovery::{plan_recovery, BackupDaemon, RecoveryInput, RecoveryMethod};
use crate::router::DpRouter;
use crate::scheduler::{adaptive_chunked_prefill, fifo_chunked_prefill, PrefillItem};
use crate::sharding::ShardPlan;
use crate::traces::TraceRequest;
use crate::{RankId, RequestId, SimTime};

use super::costmodel::{DecodeWork, PrefillWork, StepCostModel};
use super::simcore::{self, CoreMode, CoreStats};
use super::{PrefillPolicy, SystemConfig};

/// Which serving stage this instance simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnlineMode {
    Prefill,
    Decode,
}

/// A GPU failure to inject mid-run.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryEvent {
    /// Inject 100 ms after this many requests have arrived (paper §4.3.3
    /// injects after the 250th request of a 500-request window).
    pub after_requests: usize,
    /// The failing rank (old numbering).
    pub failed_rank: RankId,
    /// Recovery strategy to apply.
    pub method: RecoveryMethod,
}

/// Results of one simulated run.
#[derive(Debug)]
pub struct OnlineOutcome {
    pub metrics: ServingMetrics,
    /// GPU state recovery latency, if a failure was injected (Table 3).
    pub recovery_latency_s: Option<f64>,
    /// Steps executed (telemetry).
    pub steps: usize,
    /// Final world size.
    pub world: usize,
}

/// Online serving simulator for one TP instance.
pub struct OnlineSim {
    pub config: SystemConfig,
    pub mode: OnlineMode,
    pub world: usize,
    pub spec: GpuSpec,
    /// The served model (defaults to llama-3.1-70B).
    pub model: crate::model::ModelSpec,
    /// Prefill token budget per batch (Algorithm 1's `N`).
    pub token_budget: usize,
    /// Decode batch cap.
    pub max_batch: usize,
    /// Fraction of PCIe bandwidth reserved for background KV backup.
    pub backup_fraction: f64,
    /// Mirror of the engine's shared-prefix KV cache (see
    /// `crate::prefix`): warm prompt prefixes skip modeled prefill and
    /// their KV bytes are charged once instead of per sharer. Off by
    /// default — the no-sharing accounting is the baseline.
    pub prefix_sharing: bool,
    /// SLO preemption policy for sessions built from this sim: when set,
    /// deadline-at-risk high-priority requests may evict lower-priority
    /// decodes to the KV swap tier. `None` (the default) is the FCFS
    /// baseline — identical scheduling to every pre-overload session.
    pub preempt: Option<PreemptPolicy>,
    /// Explicit per-rank device list for mixed-generation fleets (rank
    /// `r` runs on `devices[r]`). `None` (the default) serves `world`
    /// copies of `spec`.
    pub devices: Option<Vec<GpuSpec>>,
    /// Whether mixed-device sessions serve the capacity-proportional
    /// plan (default true). Off = the uniform plan on mixed hardware,
    /// the straggler baseline the elastic bench compares against.
    pub proportional_plan: bool,
}

pub(crate) struct Running {
    pub(crate) id: RequestId,
    pub(crate) home: RankId,
    pub(crate) context: usize,
    pub(crate) remaining_out: usize,
    pub(crate) emitted: usize,
    /// Leading tokens whose KV bytes live in the shared prefix pool —
    /// this request's private charge is `context - shared`.
    pub(crate) shared: usize,
    pub(crate) priority: i32,
    pub(crate) deadline: Option<SimTime>,
}

/// A preempted request parked in the modeled host swap tier: its device
/// KV is released (mirror authoritative) and it resumes via swap-in —
/// the restore path, never recompute.
pub(crate) struct Swapped {
    pub(crate) id: RequestId,
    pub(crate) context: usize,
    pub(crate) remaining_out: usize,
    pub(crate) emitted: usize,
    pub(crate) shared: usize,
    pub(crate) priority: i32,
    pub(crate) deadline: Option<SimTime>,
    /// Clock time it was parked — the wait that earns starvation
    /// promotion.
    pub(crate) parked_at: SimTime,
}

/// A request known to the session but not yet arrived.
pub(crate) struct Pending {
    id: RequestId,
    pub(crate) arrival: SimTime,
    input_tokens: usize,
    output_tokens: usize,
    priority: i32,
    deadline: Option<SimTime>,
    /// Actual prompt tokens, kept only when prefix sharing is on (the
    /// trace-driven path simulates lengths, not token ids).
    prompt: Option<Vec<u32>>,
}

/// A request that has arrived and waits for KV headroom.
pub(crate) struct Waiting {
    id: RequestId,
    context: usize,
    output: usize,
    priority: i32,
    deadline: Option<SimTime>,
    /// Arrival time — the wait since then earns starvation promotion
    /// under a [`PreemptPolicy`].
    arrived: SimTime,
    prompt: Option<Vec<u32>>,
}

impl OnlineSim {
    pub fn new(config: SystemConfig, mode: OnlineMode, world: usize) -> Self {
        OnlineSim {
            config,
            mode,
            world,
            spec: GpuSpec::h100(),
            model: crate::model::llama3_70b(),
            token_budget: 8192,
            max_batch: 256,
            backup_fraction: 0.25,
            prefix_sharing: false,
            preempt: None,
            devices: None,
            proportional_plan: true,
        }
    }

    /// Serve on an explicit mixed-generation device list: rank `r` runs
    /// on `devices[r]`. Sets the world size from the list, paces the
    /// fabric at the slowest member, and (unless
    /// [`OnlineSim::with_proportional_plan`] turned it off) builds the
    /// capacity-proportional shard plan.
    pub fn with_devices(mut self, devices: Vec<GpuSpec>) -> Self {
        assert!(!devices.is_empty(), "device list cannot be empty");
        self.world = devices.len();
        self.devices = Some(devices);
        self
    }

    /// Toggle capacity-proportional plan construction for mixed-device
    /// sessions (default on).
    pub fn with_proportional_plan(mut self, on: bool) -> Self {
        self.proportional_plan = on;
        self
    }

    /// Select the served model.
    pub fn with_model(mut self, model: crate::model::ModelSpec) -> Self {
        self.model = model;
        self
    }

    /// Enable the shared-prefix mirror on sessions built from this sim.
    pub fn with_prefix_sharing(mut self, on: bool) -> Self {
        self.prefix_sharing = on;
        self
    }

    /// Enable SLO preemption + KV swap on sessions built from this sim.
    pub fn with_preemption(mut self, policy: PreemptPolicy) -> Self {
        self.preempt = Some(policy);
        self
    }

    /// A fresh steppable decode-instance session (the [`ServingBackend`]
    /// surface of the simulator).
    pub fn session(&self) -> OnlineSession {
        let devices: Vec<GpuSpec> =
            self.devices.clone().unwrap_or_else(|| vec![self.spec.clone(); self.world]);
        let heterogeneous = devices.iter().any(|d| *d != devices[0]);
        let proportional = heterogeneous && self.proportional_plan;
        let plan = self.config.plan(&self.model, self.world);
        let ic = Interconnect::for_devices(&devices);
        let cost = StepCostModel::new_heterogeneous(&plan, &devices, &ic);
        let (tp_rate, dp_rate) = cost.kv_rates();
        let kv_budget = cost.kv_budget();
        let daemon = BackupDaemon::new(
            // Backup drains over the slowest member's host link.
            devices.iter().map(|d| d.pcie_bw).fold(f64::INFINITY, f64::min),
            self.backup_fraction,
            self.model.kv_bytes_per_token(),
        );
        let mut session = OnlineSession {
            model: self.model.clone(),
            spec: self.spec.clone(),
            devices,
            lost_devices: Vec::new(),
            proportional,
            ic,
            active: plan.clone(),
            plan,
            cost,
            world: self.world,
            max_batch: self.max_batch,
            metrics: ServingMetrics::new(),
            router: DpRouter::new(self.config.router, self.world),
            backup: BackupStore::new(1 << 42),
            daemon,
            pending: Vec::new(),
            pending_sorted: true,
            waiting: Vec::new(),
            running: Vec::new(),
            swapped: Vec::new(),
            preempt: self.preempt,
            preemptions: 0,
            swap_ins: 0,
            swap_pcie_s: 0.0,
            req_slo: std::collections::HashMap::new(),
            finished_at: std::collections::HashMap::new(),
            tp_rate,
            dp_rate,
            kv_budget,
            kv_used: vec![0.0; self.world],
            prefix_sharing: self.prefix_sharing,
            trie: PrefixTrie::new(),
            peak_kv: 0.0,
            clock: 0.0,
            steps: 0,
            core: CoreMode::Exact,
            spans: 0,
            lost: 0,
            speed: vec![1.0; self.world],
            mitigation: None,
            auto_rebalance: true,
            stalled: false,
            next_id: 0,
            order: Vec::new(),
            aborted: Vec::new(),
            recoveries: Vec::new(),
            events: Vec::new(),
            obs: ObsSink::none(),
            work: Vec::new(),
        };
        if proportional {
            // Capacity-proportionality rides the mitigation machinery:
            // the uniform plan stays the reconfiguration anchor and the
            // served plan is its reweight to device capacities. No
            // weight-move latency is charged — the plan is built this
            // way from admission, nothing streams.
            session.mitigation = Some(session.mitigation_weights());
            session.rebuild_cost();
        }
        session
    }

    /// `n` independent steppable sessions with identical configuration —
    /// the replicas of a [`crate::fleet::Fleet`]. Each session owns its
    /// own clock, router, KV budget, and fault state; nothing is shared.
    pub fn sessions(&self, n: usize) -> Vec<OnlineSession> {
        (0..n).map(|_| self.session()).collect()
    }

    /// Run the trace to completion (or until `max_sim_time`).
    pub fn run(&self, trace: &[TraceRequest], fault: Option<RecoveryEvent>) -> OnlineOutcome {
        match self.mode {
            OnlineMode::Prefill => self.run_prefill(trace),
            OnlineMode::Decode => self.run_decode(trace, fault),
        }
    }

    // ---------------------------------------------------------- prefill --

    fn run_prefill(&self, trace: &[TraceRequest]) -> OnlineOutcome {
        let model = self.model.clone();
        let model = &model;
        let plan = self.config.plan(model, self.world);
        let cost = StepCostModel::new(&plan, &self.spec, &Interconnect::new(self.spec.clone()));
        let mut metrics = ServingMetrics::new();
        let mut router = DpRouter::new(self.config.router, self.world);

        let mut arrivals: Vec<&TraceRequest> = trace.iter().collect();
        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut next_arrival = 0usize;
        let mut items: Vec<PrefillItem> = Vec::new();
        let mut clock: SimTime = 0.0;
        let mut steps = 0usize;

        loop {
            // Admit arrivals.
            while next_arrival < arrivals.len() && arrivals[next_arrival].arrival <= clock {
                let r = arrivals[next_arrival];
                metrics.on_arrival(r.id, r.arrival);
                let home = router.route(r.input_tokens as f64);
                items.push(PrefillItem {
                    request: r.id,
                    rank: home,
                    context: 0,
                    remaining: r.input_tokens,
                });
                next_arrival += 1;
            }
            if items.is_empty() {
                if next_arrival >= arrivals.len() {
                    break;
                }
                clock = arrivals[next_arrival].arrival;
                continue;
            }

            // Form the batch under the configured policy. Algorithm 1
            // initializes L_r <- 0: balance is *within-batch* (seeding with
            // the whole backlog would funnel the budget to one rank).
            let carry = vec![0.0; self.world];
            let batch = match self.config.prefill {
                PrefillPolicy::Fifo => {
                    fifo_chunked_prefill(self.token_budget, &items, &carry, self.world)
                }
                PrefillPolicy::Adaptive => {
                    adaptive_chunked_prefill(self.token_budget, &items, &carry, self.world, 16)
                }
            };
            if batch.tokens == 0 {
                break; // defensive: nothing schedulable
            }

            // Cost the step.
            let work: Vec<PrefillWork> = batch
                .chunks
                .iter()
                .map(|c| {
                    let it = items.iter().find(|i| i.request == c.request).unwrap();
                    PrefillWork { tokens: c.tokens, context: it.context, home: c.rank }
                })
                .collect();
            let dt = cost.prefill_step_time(&work);
            clock += dt;
            steps += 1;

            // Apply chunk progress.
            for c in &batch.chunks {
                let it = items.iter_mut().find(|i| i.request == c.request).unwrap();
                it.context += c.tokens;
                it.remaining -= c.tokens;
                router.complete(c.rank, c.tokens as f64);
                metrics.on_prefill_tokens(c.tokens);
            }
            // Finished prefills emit their first token.
            items.retain(|it| {
                if it.remaining == 0 {
                    metrics.on_token(it.request, clock);
                    metrics.on_finish(it.request);
                    false
                } else {
                    true
                }
            });
        }

        OnlineOutcome { metrics, recovery_latency_s: None, steps, world: self.world }
    }

    // ----------------------------------------------------------- decode --

    /// Decode instance, reimplemented on the steppable [`OnlineSession`].
    fn run_decode(&self, trace: &[TraceRequest], fault: Option<RecoveryEvent>) -> OnlineOutcome {
        let mut arrivals: Vec<&TraceRequest> = trace.iter().collect();
        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());

        let mut session = self.session();
        for r in &arrivals {
            session.enqueue(r.id, r.arrival, r.input_tokens, r.output_tokens.max(1), 0, None, None);
        }
        // The paper's trigger: 100 ms after the `after_requests`-th arrival.
        let mut pending_fault = fault.and_then(|f| {
            let idx = f.after_requests.saturating_sub(1);
            arrivals.get(idx).map(|r| (r.arrival + 0.1, f))
        });

        // Drive the span core between boundaries instead of per-token
        // ticks: run free until the fault's due time (the clock limit is
        // checked exactly where the legacy loop checked it — before each
        // scheduler round), inject at that boundary, then run to idle.
        let mut recovery_latency = None;
        let mut sink = Vec::new();
        loop {
            if session.session_idle() {
                break;
            }
            let limit = match pending_fault {
                Some((at, f)) => {
                    if session.clock >= at {
                        recovery_latency = Some(
                            session.fail_rank(f.failed_rank, f.method).expect("fault injection"),
                        );
                        pending_fault = None;
                        continue;
                    }
                    AdvanceLimit::clock(at)
                }
                None => AdvanceLimit::unbounded(),
            };
            session.advance_until(limit, &mut sink).expect("advance");
            sink.clear();
        }

        OnlineOutcome {
            recovery_latency_s: recovery_latency,
            steps: session.steps,
            world: session.world,
            metrics: session.metrics,
        }
    }
}

/// A steppable decode-instance simulation: the simulator's side of the
/// [`ServingBackend`] trait. State mirrors the real engine's session —
/// queued arrivals, a KV-admission waiting line, and the running decode
/// batch — but every step is costed by the roofline model instead of a
/// PJRT execution, so the clock is simulated time.
pub struct OnlineSession {
    pub(crate) model: crate::model::ModelSpec,
    pub(crate) spec: GpuSpec,
    /// Per-rank device specs (rank `r` serves on `devices[r]`). Uniform
    /// fleets repeat `spec`; mixed fleets (H100+A100) drive the
    /// heterogeneous cost model and, when `proportional`, the
    /// capacity-proportional plan.
    pub(crate) devices: Vec<GpuSpec>,
    /// Specs of failed devices, LIFO — `inject_rejoin` returns the most
    /// recently lost device, so a failed A100 rejoins as an A100.
    pub(crate) lost_devices: Vec<GpuSpec>,
    /// Whether mitigation weights fold in device capacity (mixed fleets
    /// with capacity-proportional planning on).
    pub(crate) proportional: bool,
    pub(crate) ic: Interconnect,
    /// The healthy shard plan for the current world (what recovery
    /// planning and shrink/expand reason over).
    pub(crate) plan: ShardPlan,
    /// The plan the cost model actually serves on: `plan`, or its
    /// capacity-weighted mitigation ([`ShardPlan::reweight`]) while ranks
    /// are degraded and rebalancing is active.
    pub(crate) active: ShardPlan,
    pub(crate) cost: StepCostModel,
    pub(crate) world: usize,
    pub(crate) max_batch: usize,
    pub metrics: ServingMetrics,
    pub(crate) router: DpRouter,
    pub(crate) backup: BackupStore,
    pub(crate) daemon: BackupDaemon,
    /// Submitted but not yet arrived, kept sorted by arrival (descending,
    /// so admission pops from the back).
    pub(crate) pending: Vec<Pending>,
    pub(crate) pending_sorted: bool,
    /// Arrived, waiting for KV headroom, admitted in scheduling order
    /// (priority desc, then deadline asc, then arrival order).
    pub(crate) waiting: Vec<Waiting>,
    pub(crate) running: Vec<Running>,
    /// Preempted requests parked in the host swap tier, resumed in
    /// scheduling order as capacity frees.
    pub(crate) swapped: Vec<Swapped>,
    /// SLO preemption policy (`None` = FCFS, the pre-overload behavior).
    pub(crate) preempt: Option<PreemptPolicy>,
    /// Preemptions performed (telemetry).
    pub(crate) preemptions: usize,
    /// Swap-ins performed (telemetry).
    pub(crate) swap_ins: usize,
    /// Cumulative modeled PCIe time spent on swap traffic (telemetry).
    pub(crate) swap_pcie_s: f64,
    /// Submitted (priority, deadline) per request, for the report.
    pub(crate) req_slo: std::collections::HashMap<RequestId, (i32, Option<SimTime>)>,
    /// Completion clock per finished request, for deadline-miss counts.
    pub(crate) finished_at: std::collections::HashMap<RequestId, SimTime>,
    pub(crate) tp_rate: Vec<f64>,
    pub(crate) dp_rate: f64,
    pub(crate) kv_budget: Vec<usize>,
    pub(crate) kv_used: Vec<f64>,
    /// Shared-prefix mirror (see [`crate::prefix`]): when enabled, warm
    /// prompt prefixes skip modeled prefill and resident chunk bytes are
    /// charged once into `kv_used` instead of once per sharer.
    pub(crate) prefix_sharing: bool,
    pub(crate) trie: PrefixTrie,
    /// High-water mark of total resident KV bytes (bench telemetry).
    pub(crate) peak_kv: f64,
    pub(crate) clock: SimTime,
    pub(crate) steps: usize,
    /// Which engine `advance_until` runs on (default [`CoreMode::Exact`];
    /// `step()` always runs the legacy tick regardless).
    pub(crate) core: CoreMode,
    /// Event spans executed by the span engines (telemetry: one span
    /// replaces up to `min remaining_out` per-token scheduler rounds).
    pub(crate) spans: usize,
    /// GPUs currently out of the group — the budget `inject_rejoin`
    /// draws from.
    pub(crate) lost: usize,
    /// Per-rank effective speed factors (1.0 = healthy) — the injected
    /// ground truth the cost model divides by.
    pub(crate) speed: Vec<f64>,
    /// Capacity weights the mitigation is currently built on (`None` =
    /// serving the healthy plan unweighted — the no-mitigation baseline).
    pub(crate) mitigation: Option<Vec<f64>>,
    /// Whether `inject_slowdown` rebalances automatically (default true;
    /// turn off to measure the unmitigated straggler baseline).
    pub(crate) auto_rebalance: bool,
    /// Set when the waiting line can never drain (cold-system livelock in
    /// the old batch loop) — the session reports idle.
    pub(crate) stalled: bool,
    pub(crate) next_id: RequestId,
    pub(crate) order: Vec<RequestId>,
    pub(crate) aborted: Vec<RequestId>,
    pub(crate) recoveries: Vec<f64>,
    pub(crate) events: Vec<EngineEvent>,
    /// Flight-recorder seam (detached by default — see [`crate::obs`]).
    /// Recording is passive: no FP op of the cost model moves with it.
    pub(crate) obs: ObsSink,
    /// Reused decode-work scratch for the per-tick cost-model call (no
    /// per-step allocation at steady state).
    pub(crate) work: Vec<DecodeWork>,
}

impl OnlineSession {
    /// Register a request. Trace-driven runs pass explicit ids (and no
    /// prompt tokens — lengths only); the [`ServingBackend`] submit path
    /// allocates ids and, with prefix sharing on, keeps the prompt for
    /// trie matching.
    #[allow(clippy::too_many_arguments)]
    fn enqueue(
        &mut self,
        id: RequestId,
        arrival: SimTime,
        input_tokens: usize,
        output_tokens: usize,
        priority: i32,
        deadline: Option<SimTime>,
        prompt: Option<Vec<u32>>,
    ) {
        self.req_slo.insert(id, (priority, deadline));
        self.pending
            .push(Pending { id, arrival, input_tokens, output_tokens, priority, deadline, prompt });
        self.pending_sorted = false;
        self.next_id = self.next_id.max(id + 1);
        self.order.push(id);
        self.stalled = false;
    }

    fn sort_pending(&mut self) {
        if !self.pending_sorted {
            self.pending
                .sort_by(|a, b| b.arrival.partial_cmp(&a.arrival).unwrap());
            self.pending_sorted = true;
        }
    }

    pub(crate) fn next_arrival(&mut self) -> Option<SimTime> {
        self.sort_pending();
        self.pending.last().map(|p| p.arrival)
    }

    /// True when nothing can make further progress: no running batch, no
    /// arrivals left, and the waiting line and swap tier are empty or
    /// marked stuck (the tick loop sets `stalled` when parked requests
    /// can never fit an otherwise empty system).
    pub(crate) fn session_idle(&self) -> bool {
        self.running.is_empty()
            && self.pending.is_empty()
            && ((self.waiting.is_empty() && self.swapped.is_empty()) || self.stalled)
    }

    /// One simulated tick: admit due arrivals, admit waiting requests
    /// under the KV budget, then run one costed decode step (or
    /// fast-forward to the next arrival when the batch is empty).
    pub(crate) fn tick(&mut self) -> Vec<EngineEvent> {
        let mut events = std::mem::take(&mut self.events);
        self.admit_phase();

        if self.running.is_empty() {
            self.idle_jump();
            return events;
        }

        // One decode step (work list reuses the session scratch buffer).
        self.work.clear();
        self.work
            .extend(self.running.iter().map(|r| DecodeWork { context: r.context, home: r.home }));
        let dt = self.cost.decode_step_time(&self.work);
        self.clock += dt;
        self.steps += 1;
        self.daemon.advance(dt, &mut self.backup);

        let mut finished: Vec<usize> = Vec::new();
        for i in 0..self.running.len() {
            let (id, context) = (self.running[i].id, self.running[i].context);
            self.metrics.on_token(id, self.clock);
            self.daemon.produced(id, context, context + 1);
            let r = &mut self.running[i];
            r.context += 1;
            r.remaining_out -= 1;
            events.push(EngineEvent::TokenEmitted { id, token: 0, index: r.emitted });
            r.emitted += 1;
            let home = r.home;
            for (ru, used) in self.kv_used.iter_mut().enumerate() {
                *used += self.tp_rate[ru];
            }
            self.kv_used[home] += self.dp_rate;
            if self.running[i].remaining_out == 0 {
                finished.push(i);
            }
        }
        self.peak_kv = self.peak_kv.max(self.kv_used.iter().sum());
        for &i in finished.iter().rev() {
            let r = self.running.swap_remove(i);
            self.finish_running(r, &mut events);
        }
        events
    }

    /// The tick head shared by the stepper and the span engines: admit
    /// due arrivals into the waiting line, then admit waiting requests
    /// under the KV budget in scheduling order. Safe to run only at span
    /// boundaries — mid-span the running set is frozen, `kv_used` only
    /// grows, and no batch slot frees, so re-running it would be a no-op.
    pub(crate) fn admit_phase(&mut self) {
        // Admit arrivals into the waiting line.
        self.sort_pending();
        while self.pending.last().map(|p| p.arrival <= self.clock).unwrap_or(false) {
            let p = self.pending.pop().unwrap();
            self.metrics.on_arrival(p.id, p.arrival);
            // P-D disaggregation: the prefill instance already processed
            // the input tokens; count them on admission. A warm prefix hit
            // skips that work — the prefill instance adopts the cached
            // chunks and only computes the divergent tail (clamped to
            // leave at least one token: the first token must be emitted).
            let mut warm = 0usize;
            if self.prefix_sharing {
                if let Some(prompt) = &p.prompt {
                    warm = self
                        .trie
                        .lookup(prompt)
                        .live_tokens
                        .min(p.input_tokens.saturating_sub(1));
                }
            }
            self.metrics.on_prefill_tokens(p.input_tokens - warm);
            self.waiting.push(Waiting {
                id: p.id,
                context: p.input_tokens,
                output: p.output_tokens,
                priority: p.priority,
                deadline: p.deadline,
                arrived: p.arrival,
                prompt: p.prompt,
            });
        }

        // Admit from waiting while KV fits (project to full output
        // length), highest priority / earliest deadline first — matching
        // the engine's scheduling order (stable: arrival order for ties).
        // Under a preemption policy the ordering key is the *effective*
        // priority (base + starvation promotion); with no policy it is
        // exactly the legacy key.
        self.sort_waiting();
        if self.preempt.is_some() {
            self.resume_swapped();
        }
        self.admit_waiting();
        if self.preempt.is_some() {
            self.preempt_phase();
        }
    }

    /// Sort the waiting line by (effective priority desc, deadline asc);
    /// the stable sort keeps arrival order for ties. Identical to the
    /// legacy ordering when no [`PreemptPolicy`] is set.
    fn sort_waiting(&mut self) {
        if self.waiting.len() <= 1 {
            return;
        }
        let now = self.clock;
        let pol = self.preempt;
        let eff = |w: &Waiting| match pol {
            Some(p) => p.effective_priority(w.priority, now - w.arrived),
            None => w.priority,
        };
        self.waiting.sort_by(|a, b| {
            eff(b).cmp(&eff(a)).then_with(|| {
                let da = a.deadline.unwrap_or(f64::INFINITY);
                let db = b.deadline.unwrap_or(f64::INFINITY);
                da.total_cmp(&db)
            })
        });
    }

    /// Swap parked requests back in (scheduling order) while capacity
    /// allows — the swap tier's side of admission.
    fn resume_swapped(&mut self) {
        if self.swapped.is_empty() {
            return;
        }
        let now = self.clock;
        let pol = self.preempt.expect("resume_swapped requires a policy");
        self.swapped.sort_by(|a, b| {
            pol.effective_priority(b.priority, now - b.parked_at)
                .cmp(&pol.effective_priority(a.priority, now - a.parked_at))
                .then_with(|| {
                    let da = a.deadline.unwrap_or(f64::INFINITY);
                    let db = b.deadline.unwrap_or(f64::INFINITY);
                    da.total_cmp(&db)
                })
                .then(a.id.cmp(&b.id))
        });
        let swapped = std::mem::take(&mut self.swapped);
        let mut kept = Vec::with_capacity(swapped.len());
        for s in swapped {
            if !self.try_resume(&s) {
                kept.push(s);
            }
        }
        self.swapped = kept;
        self.peak_kv = self.peak_kv.max(self.kv_used.iter().sum());
    }

    /// Swap one parked request back in if its full remaining footprint
    /// fits — mirrors [`OnlineSession::try_admit`]'s projection, charges
    /// the private context back onto the device rates, and pays the
    /// host→device PCIe transfer on the clock (swap-in restores from the
    /// mirror; it never recomputes).
    fn try_resume(&mut self, s: &Swapped) -> bool {
        let total = (s.context - s.shared + s.remaining_out) as f64;
        let fits = (0..self.world).all(|r| {
            let add = self.tp_rate[r] * total
                + if r == self.router.tracker().least_loaded() {
                    self.dp_rate * total
                } else {
                    0.0
                };
            self.kv_used[r] + add <= self.kv_budget[r] as f64 * 0.97
        }) && self.running.len() < self.max_batch;
        if !fits {
            return false;
        }
        let private = (s.context - s.shared) as f64;
        let home = self.router.route(private);
        for (r, used) in self.kv_used.iter_mut().enumerate() {
            *used += self.tp_rate[r] * private;
        }
        self.kv_used[home] += self.dp_rate * private;
        let t = self.cost.swap_time(s.context - s.shared);
        self.clock += t;
        self.swap_pcie_s += t;
        self.swap_ins += 1;
        let ev = EngineEvent::RequestResumed { id: s.id };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        self.running.push(Running {
            id: s.id,
            home,
            context: s.context,
            remaining_out: s.remaining_out,
            emitted: s.emitted,
            shared: s.shared,
            priority: s.priority,
            deadline: s.deadline,
        });
        self.sample_gauges();
        true
    }

    /// The skip-join MLFQ preemption pass: while the best parked request
    /// (waiting or swapped, by effective priority) is at deadline risk
    /// and cannot fit, evict the lowest-effective-priority *strictly
    /// lower* running decode to the swap tier and retry — bounded per
    /// round by the policy's thrash guard. Best-effort requests carry no
    /// deadline, so they never trigger a preemption; starvation
    /// promotion only moves them up the admission order.
    fn preempt_phase(&mut self) {
        let pol = self.preempt.expect("preempt_phase requires a policy");
        let mut evictions = 0usize;
        while evictions < pol.max_preemptions_per_round {
            if self.running.is_empty() {
                return; // nothing to evict
            }
            let now = self.clock;
            // Candidate: head of waiting vs head of swapped (both sorted
            // this round), by (effective priority, deadline).
            let wait_head = self.waiting.first().map(|w| {
                (pol.effective_priority(w.priority, now - w.arrived), w.deadline, w.output)
            });
            let swap_head = self.swapped.first().map(|s| {
                (
                    pol.effective_priority(s.priority, now - s.parked_at),
                    s.deadline,
                    s.remaining_out,
                )
            });
            let better = |a: (i32, Option<SimTime>, usize), b: (i32, Option<SimTime>, usize)| {
                // Higher effective priority wins; earlier deadline breaks
                // ties (negated so the tuple compare runs descending).
                (a.0, -a.1.unwrap_or(f64::INFINITY)) > (b.0, -b.1.unwrap_or(f64::INFINITY))
            };
            let (cand_eff, cand_deadline, cand_out, from_wait) = match (wait_head, swap_head) {
                (Some(w), Some(s)) => {
                    if better(s, w) {
                        (s.0, s.1, s.2, false)
                    } else {
                        (w.0, w.1, w.2, true)
                    }
                }
                (Some(w), None) => (w.0, w.1, w.2, true),
                (None, Some(s)) => (s.0, s.1, s.2, false),
                (None, None) => return,
            };
            // Deadline risk: the candidate's remaining service at the
            // current round pace, with the policy's slack.
            self.work.clear();
            self.work.extend(
                self.running.iter().map(|r| DecodeWork { context: r.context, home: r.home }),
            );
            let round_dt = self.cost.decode_step_time(&self.work);
            let est = round_dt * cand_out as f64;
            if !pol.deadline_at_risk(now, cand_deadline, est) {
                return;
            }
            // Victim: lowest effective priority (running requests do not
            // age — they are being served), latest deadline, youngest id;
            // must be strictly below the candidate.
            let victim = self
                .running
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    a.priority
                        .cmp(&b.priority)
                        .then_with(|| {
                            let da = a.deadline.unwrap_or(f64::INFINITY);
                            let db = b.deadline.unwrap_or(f64::INFINITY);
                            db.total_cmp(&da)
                        })
                        .then(b.id.cmp(&a.id))
                })
                .filter(|(_, v)| pol.may_preempt(cand_eff, v.priority))
                .map(|(i, _)| i);
            let Some(vi) = victim else { return };
            self.swap_out_running(vi);
            evictions += 1;
            // Retry the candidate now that KV freed.
            if from_wait {
                let w = self.waiting.remove(0);
                if !self.try_admit(&w) {
                    self.waiting.insert(0, w);
                }
            } else {
                let s = self.swapped.remove(0);
                if !self.try_resume(&s) {
                    self.swapped.insert(0, s);
                }
            }
            self.peak_kv = self.peak_kv.max(self.kv_used.iter().sum());
        }
    }

    /// Evict `running[i]` to the swap tier: release its private device
    /// KV (exactly the finish/abort arithmetic — shared prefix bytes
    /// stay resident for their sharers), complete its host mirror paying
    /// PCIe only for the rows the write-behind daemon had not mirrored
    /// yet, and park it. The request is paused, not aborted: its metrics
    /// entry stays open and its next token (after resume) records the
    /// preemption gap as TBT.
    fn swap_out_running(&mut self, i: usize) {
        let r = self.running.swap_remove(i);
        let private = (r.context - r.shared) as f64;
        for (ru, used) in self.kv_used.iter_mut().enumerate() {
            *used = (*used - self.tp_rate[ru] * private).max(0.0);
        }
        self.kv_used[r.home] = (self.kv_used[r.home] - self.dp_rate * private).max(0.0);
        self.router.complete(r.home, 0.0);
        let missing = r.context.saturating_sub(self.backup.backed_tokens(r.id));
        self.backup.backup(r.id, r.context, self.model.kv_bytes_per_token());
        self.daemon.forget(r.id);
        let t = self.cost.swap_time(missing);
        self.clock += t;
        self.swap_pcie_s += t;
        self.preemptions += 1;
        let ev = EngineEvent::RequestPreempted { id: r.id };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        self.swapped.push(Swapped {
            id: r.id,
            context: r.context,
            remaining_out: r.remaining_out,
            emitted: r.emitted,
            shared: r.shared,
            priority: r.priority,
            deadline: r.deadline,
            parked_at: self.clock,
        });
        self.sample_gauges();
    }

    /// True when the SLO scheduler may preempt at the next round head —
    /// the span cores cap their span length to one round while this
    /// holds, so preemption decisions land at identical clock times on
    /// every core (see [`crate::simulator::simcore`]).
    pub(crate) fn preemption_pending(&self) -> bool {
        self.preempt.is_some() && (!self.waiting.is_empty() || !self.swapped.is_empty())
    }

    /// The empty-batch branch of a scheduler round: fast-forward the
    /// clock to the next arrival, or mark the waiting line stuck. Call
    /// only when `running` is empty (after [`OnlineSession::admit_phase`]).
    pub(crate) fn idle_jump(&mut self) {
        if let Some(at) = self.next_arrival() {
            self.clock = self.clock.max(at);
            // Livelock guard from the batch loop: a full waiting line
            // that cannot fit an empty system will never drain.
            if self.waiting.len() >= self.max_batch {
                self.stalled = true;
            }
        } else if !self.waiting.is_empty() || !self.swapped.is_empty() {
            // Cold system, nothing arriving: these can never fit.
            self.stalled = true;
        }
    }

    /// Retire one finished (or span-completed) request that has already
    /// been removed from `running`: metrics, lifecycle event, daemon and
    /// backup bookkeeping, and the private-KV release.
    pub(crate) fn finish_running(&mut self, r: Running, events: &mut Vec<EngineEvent>) {
        self.metrics.on_finish(r.id);
        self.finished_at.insert(r.id, self.clock);
        let ev = EngineEvent::RequestFinished { id: r.id };
        self.obs.event(self.clock, &ev);
        events.push(ev);
        self.daemon.forget(r.id);
        self.backup.release(r.id, self.model.kv_bytes_per_token());
        // Only the private tail is released: shared prefix chunks stay
        // resident in the trie's pool for the next sharer (the engine's
        // trie keeps a refcount on them the same way).
        let private = (r.context - r.shared) as f64;
        for (ru, used) in self.kv_used.iter_mut().enumerate() {
            *used = (*used - self.tp_rate[ru] * private).max(0.0);
        }
        self.kv_used[r.home] = (self.kv_used[r.home] - self.dp_rate * private).max(0.0);
        self.router.complete(r.home, 0.0);
        self.sample_gauges();
    }

    /// Set (or clear) the SLO preemption policy on a built session
    /// (replicas inherit [`OnlineSim::preempt`]; this overrides per
    /// session). `None` restores the FCFS baseline.
    pub fn set_preemption(&mut self, policy: Option<PreemptPolicy>) {
        self.preempt = policy;
    }

    /// Preemptions performed so far (decode evicted to the swap tier).
    pub fn preemptions(&self) -> usize {
        self.preemptions
    }

    /// Swap-ins performed so far (parked requests resumed from the tier).
    pub fn swap_ins(&self) -> usize {
        self.swap_ins
    }

    /// Cumulative modeled PCIe seconds spent on swap-out/swap-in traffic.
    pub fn swap_pcie_seconds(&self) -> f64 {
        self.swap_pcie_s
    }

    /// Requests currently parked in the swap tier.
    pub fn swapped_len(&self) -> usize {
        self.swapped.len()
    }

    /// Select which engine [`ServingBackend::advance_until`] runs on:
    /// the bit-exact span core (default), the closed-form batched core,
    /// or the legacy per-token stepper (the differential baseline).
    pub fn set_core_mode(&mut self, mode: CoreMode) {
        self.core = mode;
    }

    /// The active [`CoreMode`].
    pub fn core_mode(&self) -> CoreMode {
        self.core
    }

    /// Span-engine telemetry: how many event spans replaced how many
    /// scheduler rounds so far.
    pub fn core_stats(&self) -> CoreStats {
        CoreStats { spans: self.spans, steps: self.steps }
    }

    /// Attach a flight-recorder observer (see [`crate::obs`]); records
    /// are stamped with replica id 0 until
    /// [`OnlineSession::set_obs_replica`] re-stamps them. Recording is
    /// purely passive — with an observer attached the session's token
    /// streams, clocks, and reports are bit-identical to a detached run.
    pub fn set_observer(&mut self, observer: Box<dyn Observer>) {
        self.obs.set(observer);
    }

    /// Replica id stamped on this session's trace records (fleet
    /// members use their [`crate::fleet::ReplicaId`]).
    pub fn set_obs_replica(&mut self, replica: usize) {
        self.obs.set_replica(replica);
    }

    /// Event-edge gauge sample: per-rank KV residency, headroom, and
    /// speed factors, plus replica-level private/shared/swapped KV
    /// split, queue depths, and effective capacity. Called on lifecycle
    /// edges (completion, preemption, failure, rejoin, mitigation) —
    /// never per token, so tracing cost scales with incidents, not
    /// throughput.
    fn sample_gauges(&mut self) {
        if !self.obs.enabled() {
            return;
        }
        let t = self.clock;
        for r in 0..self.world {
            let used = self.kv_used[r];
            let budget = self.kv_budget[r] as f64;
            let speed = self.speed[r];
            self.obs.gauge(t, Some(r), "kv.used_bytes", used);
            self.obs.gauge(t, Some(r), "kv.free_bytes", (budget - used).max(0.0));
            self.obs.gauge(t, Some(r), "speed.factor", speed);
        }
        let pool = self.prefix_tokens() as f64;
        let shared: f64 = (0..self.world).map(|r| self.prefix_rate(r) * pool).sum();
        let total: f64 = self.kv_used.iter().sum();
        let bpt = self.model.kv_bytes_per_token() as f64;
        let swapped_bytes: f64 = self.swapped.iter().map(|s| s.context as f64 * bpt).sum();
        let effective: f64 = self.speed.iter().sum();
        let (pending, waiting, running, swapped) = (
            self.pending.len() as f64,
            self.waiting.len() as f64,
            self.running.len() as f64,
            self.swapped.len() as f64,
        );
        self.obs.gauge(t, None, "kv.shared_bytes", shared);
        self.obs.gauge(t, None, "kv.private_bytes", (total - shared).max(0.0));
        self.obs.gauge(t, None, "kv.swapped_bytes", swapped_bytes);
        self.obs.gauge(t, None, "queue.pending", pending);
        self.obs.gauge(t, None, "queue.waiting", waiting);
        self.obs.gauge(t, None, "queue.running", running);
        self.obs.gauge(t, None, "queue.swapped", swapped);
        self.obs.gauge(t, None, "capacity.effective", effective);
    }

    fn admit_waiting(&mut self) {
        let waiting = std::mem::take(&mut self.waiting);
        let mut kept = Vec::with_capacity(waiting.len());
        for w in waiting {
            if !self.try_admit(&w) {
                kept.push(w);
            }
        }
        self.waiting = kept;
        self.peak_kv = self.peak_kv.max(self.kv_used.iter().sum());
    }

    /// Admit one waiting request if it fits the KV budget; returns false
    /// (leave it waiting) otherwise.
    fn try_admit(&mut self, w: &Waiting) -> bool {
        // Residency is re-checked at admission time — a failure flush
        // between arrival and admission must not under-charge.
        let live = match (&w.prompt, self.prefix_sharing) {
            (Some(p), true) => self.trie.match_only(p).live_tokens.min(w.context),
            _ => 0,
        };
        let total = (w.context + w.output - live) as f64;
        let fits = (0..self.world).all(|r| {
            let add = self.tp_rate[r] * total
                + if r == self.router.tracker().least_loaded() {
                    self.dp_rate * total
                } else {
                    0.0
                };
            self.kv_used[r] + add <= self.kv_budget[r] as f64 * 0.97
        }) && self.running.len() < self.max_batch;
        if !fits {
            return false;
        }
        // Booked routing work excludes the warm tokens — the prefill
        // instance never recomputed them.
        let home = self.router.route((w.context - live) as f64);
        // Register the prompt's full chunks: newly resident chunks are
        // charged once into the shared pool; every future sharer (and
        // this request itself) charges only its private remainder.
        let mut shared = 0usize;
        if self.prefix_sharing {
            if let Some(p) = &w.prompt {
                let chain = self.trie.insert(p);
                for &n in &chain {
                    self.trie.mark_resident(n);
                }
                let covered = (chain.len() * BLOCK_TOKENS).min(w.context);
                let fresh = (covered.saturating_sub(live)) as f64;
                for r in 0..self.world {
                    self.kv_used[r] += self.prefix_rate(r) * fresh;
                }
                shared = covered;
            }
        }
        let private = (w.context - shared) as f64;
        for (r, used) in self.kv_used.iter_mut().enumerate() {
            *used += self.tp_rate[r] * private;
        }
        self.kv_used[home] += self.dp_rate * private;
        // P-D disaggregation: the prefill instance ships this
        // request's KV through host DRAM, so the input context
        // is host-mirrored the moment the decode instance
        // admits it; the daemon only trails the decode tokens.
        self.backup.backup(w.id, w.context, self.model.kv_bytes_per_token());
        self.running.push(Running {
            id: w.id,
            home,
            context: w.context,
            remaining_out: w.output,
            emitted: 0,
            shared,
            priority: w.priority,
            deadline: w.deadline,
        });
        true
    }

    /// Bytes per shared-prefix token charged on `rank`: the TP-head share
    /// is physically replicated per rank like any context; the DP-head
    /// share is modeled as evenly spread (the engine pins it to the
    /// donor's home, which the sim does not track per chunk).
    fn prefix_rate(&self, rank: usize) -> f64 {
        self.tp_rate[rank] + self.dp_rate / self.world as f64
    }

    /// Total resident shared-prefix tokens (chunk-granular).
    fn prefix_tokens(&self) -> usize {
        self.trie.resident_chunks() * BLOCK_TOKENS
    }

    /// Rebuild the cost model (and KV rates/budgets, router capacities,
    /// usage accounting) on the current healthy plan + mitigation
    /// weights. Returns the modeled weight-movement latency of the plan
    /// change: each rank streams its weight-byte growth from peers over
    /// NVLink concurrently, so the max per-rank receive bounds the stall
    /// (0.0 across world changes — the recovery planner already costed
    /// those moves).
    /// Current mitigation weights: per-rank effective speed, folded with
    /// relative device capacity on proportional mixed-fleet sessions —
    /// an A100 at 0.5× thermal throttle is worth (A100 weight) × 0.5.
    fn mitigation_weights(&self) -> Vec<f64> {
        if self.proportional {
            capacity_weights(&self.devices, crate::sharding::CAPACITY_DECODE_FRAC)
                .iter()
                .zip(&self.speed)
                .map(|(b, s)| b * s)
                .collect()
        } else {
            self.speed.clone()
        }
    }

    fn rebuild_cost(&mut self) -> f64 {
        let new_active = match &self.mitigation {
            Some(w) if w.iter().any(|&x| x < 1.0) => self.plan.reweight(w),
            _ => self.plan.clone(),
        };
        let latency = if new_active.world() == self.active.world() {
            let max_recv = self
                .active
                .rank_loads()
                .iter()
                .zip(&new_active.rank_loads())
                .map(|(o, n)| n.weight_bytes.saturating_sub(o.weight_bytes))
                .max()
                .unwrap_or(0);
            self.ic.parallel_transfer_time(TransferClass::NvLink, max_recv)
        } else {
            0.0
        };
        self.active = new_active;
        self.cost = StepCostModel::new_heterogeneous(&self.active, &self.devices, &self.ic);
        self.cost.set_speed_factors(&self.speed);
        let (tp, dp) = self.cost.kv_rates();
        self.tp_rate = tp;
        self.dp_rate = dp;
        self.kv_budget = self.cost.kv_budget();
        for r in 0..self.world {
            let cap = self.mitigation.as_ref().map(|w| w[r]).unwrap_or(1.0);
            self.router.set_capacity(r, cap);
        }
        // Re-derive per-rank KV usage under the new rates: each running
        // request's private context, plus the shared prefix pool charged
        // once (zero when sharing is off — the trie stays empty).
        self.kv_used = vec![0.0; self.world];
        let pool = self.prefix_tokens() as f64;
        for r in 0..self.world {
            self.kv_used[r] += self.prefix_rate(r) * pool;
        }
        for req in &self.running {
            let private = (req.context - req.shared) as f64;
            for (ru, used) in self.kv_used.iter_mut().enumerate() {
                *used += self.tp_rate[ru] * private;
            }
            self.kv_used[req.home] += self.dp_rate * private;
        }
        // Shifted budgets/rates may unstick a stalled waiting line.
        self.stalled = false;
        latency
    }

    /// Inject a soft fault: `rank` keeps serving at `factor`× speed
    /// (`1.0` restores). The cost model pays the straggler tax either
    /// way; with auto-rebalance (the default) the session also reweights
    /// its shard plan and router capacity-proportionally, pays the
    /// modeled weight-move stall on the clock, and returns it.
    fn slow_rank(&mut self, rank: RankId, factor: f64) -> Result<f64> {
        anyhow::ensure!(rank < self.world, "rank {rank} out of range (world {})", self.world);
        anyhow::ensure!(
            factor.is_finite() && factor > 0.0 && factor <= 1.0,
            "speed factor must be in (0, 1], got {factor}"
        );
        let was = self.speed[rank];
        self.speed[rank] = factor;
        if factor < 1.0 {
            let ev = EngineEvent::GpuDegraded { rank, factor };
            self.obs.event(self.clock, &ev);
            self.events.push(ev);
        } else if was < 1.0 {
            let ev = EngineEvent::GpuRestored { rank };
            self.obs.event(self.clock, &ev);
            self.events.push(ev);
        }
        if self.auto_rebalance {
            self.mitigation = Some(self.mitigation_weights());
            let latency = self.rebuild_cost();
            self.clock += latency;
            if self.obs.enabled() {
                let t = self.clock;
                self.obs.decision(
                    t,
                    Some(rank),
                    "mitigation.rebalance",
                    vec![("factor", factor.into()), ("stall_s", latency.into())],
                );
            }
            self.sample_gauges();
            Ok(latency)
        } else {
            self.cost.set_speed_factor(rank, factor);
            Ok(0.0)
        }
    }

    /// Toggle automatic capacity rebalancing on slowdown injection
    /// (default on). Off = the no-mitigation baseline: the throttled rank
    /// keeps its full share of heads/blocks/routing and paces the group.
    pub fn set_auto_rebalance(&mut self, on: bool) {
        self.auto_rebalance = on;
    }

    /// Per-rank effective speed factors (1.0 = healthy).
    pub fn speed_factors(&self) -> &[f64] {
        &self.speed
    }

    /// Toggle the shared-prefix mirror on a built session (replicas
    /// inherit [`OnlineSim::prefix_sharing`]; this overrides per session).
    pub fn set_prefix_sharing(&mut self, on: bool) {
        self.prefix_sharing = on;
    }

    /// Trie hit/insert counters (the sim's side of
    /// [`crate::engine::Engine::prefix_stats`]).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.trie.stats()
    }

    /// Tokens currently resident in the shared prefix pool.
    pub fn prefix_resident_tokens(&self) -> usize {
        self.prefix_tokens()
    }

    /// Total modeled KV bytes resident right now, summed over ranks.
    pub fn kv_bytes(&self) -> f64 {
        self.kv_used.iter().sum()
    }

    /// High-water mark of [`OnlineSession::kv_bytes`] over the run.
    pub fn peak_kv_bytes(&self) -> f64 {
        self.peak_kv
    }

    /// Apply explicit mitigation weights (e.g. from
    /// [`crate::health::plan_mitigation`] over a
    /// [`crate::health::HealthMonitor`]'s states): the shard plan
    /// reweights capacity-proportionally, the router follows, and the
    /// modeled weight-move stall lands on the clock and is returned.
    pub fn apply_mitigation(&mut self, weights: &[f64]) -> Result<f64> {
        anyhow::ensure!(weights.len() == self.world, "one weight per rank");
        anyhow::ensure!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0) && weights.iter().sum::<f64>() > 0.0,
            "weights must be finite, non-negative, not all zero: {weights:?}"
        );
        self.mitigation = Some(weights.to_vec());
        let latency = self.rebuild_cost();
        self.clock += latency;
        if self.obs.enabled() {
            let t = self.clock;
            let w = format!("{weights:?}");
            self.obs.decision(
                t,
                None,
                "mitigation.apply",
                vec![("weights", w.into()), ("stall_s", latency.into())],
            );
            self.sample_gauges();
        }
        Ok(latency)
    }

    /// The Suspect escalation: host-mirror every running request's full
    /// context *now*, so the hard failure this rank's telemetry predicts
    /// restores from backup instead of recomputing. Pays the PCIe
    /// transfer on the clock; returns the tokens newly mirrored.
    pub fn proactive_backup(&mut self) -> usize {
        let bpt = self.model.kv_bytes_per_token();
        let mut tokens = 0usize;
        for r in &self.running {
            let missing = r.context.saturating_sub(self.backup.backed_tokens(r.id));
            if missing > 0 && self.backup.backup(r.id, r.context, bpt).is_some() {
                tokens += missing;
            }
        }
        self.clock += self.ic.transfer_time(TransferClass::PcieHost, tokens * bpt);
        tokens
    }

    /// Inject a hard failure of `rank` at this step boundary: plan the
    /// recovery, pay the modeled stall on the clock, reconfigure to
    /// `world - 1`, and re-home the failed rank's requests.
    fn fail_rank(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64> {
        anyhow::ensure!(self.world > 1, "cannot lose the last rank");
        anyhow::ensure!(rank < self.world, "rank {rank} out of range (world {})", self.world);
        let t0 = self.clock; // failure observed here; the stall lands after
        let ev = EngineEvent::FailureInjected { rank, method };
        self.obs.event(t0, &ev);
        self.events.push(ev);

        let reqs: Vec<(RequestId, usize, RankId)> =
            self.running.iter().map(|r| (r.id, r.context, r.home)).collect();
        // Same reconfiguration the real engine plans: survivors renumber
        // densely, commutative FFN blocks stay put.
        let (new_plan, survivor_map) = self.plan.shrink(rank);
        let input = RecoveryInput {
            spec: &self.spec,
            ic: &self.ic,
            old_plan: &self.plan,
            new_plan: &new_plan,
            survivor_map: &survivor_map,
            failed_rank: rank,
            requests: &reqs,
            backup: &self.backup,
        };
        let outcome = plan_recovery(method, &input);
        self.clock += outcome.total_s; // the stall every in-flight request sees

        // Reconfigure to the reduced world: survivors keep their speed
        // factors (and any mitigation weights) under renumbering.
        self.world -= 1;
        self.plan = new_plan;
        let remap_vec = |v: &[f64], default: f64| {
            let mut out = vec![default; survivor_map.iter().flatten().count()];
            for (old, &x) in v.iter().enumerate() {
                if let Some(new_r) = survivor_map[old] {
                    out[new_r] = x;
                }
            }
            out
        };
        self.speed = remap_vec(&self.speed, 1.0);
        self.mitigation = self.mitigation.take().map(|w| remap_vec(&w, 1.0));
        // The failed device leaves the group; survivors keep their own
        // specs under renumbering (remove preserves order).
        let lost_spec = self.devices.remove(rank);
        self.lost_devices.push(lost_spec);
        if self.proportional {
            self.mitigation = Some(self.mitigation_weights());
        }
        self.router = self.router.remap(&survivor_map, self.world);
        // Re-home requests of the failed rank before usage is re-derived.
        for r in self.running.iter_mut() {
            r.home = survivor_map[r.home].unwrap_or_else(|| self.router.tracker().least_loaded());
        }
        // Conservative prefix flush: TP-sharded prefix chunks lose a shard
        // with the rank, so every cached chain goes cold and survivors'
        // restored contexts are charged privately again. (The real engine
        // repairs and re-deduplicates — see `Engine::inject_failure`; the
        // sim models the worst case.)
        if self.prefix_sharing {
            self.trie.invalidate_all();
            for r in self.running.iter_mut() {
                r.shared = 0;
            }
            // Swapped requests re-route at resume; their shared prefix is
            // gone with the flush, so they resume fully private.
            for s in self.swapped.iter_mut() {
                s.shared = 0;
            }
        }
        self.rebuild_cost();

        self.lost += 1;
        self.recoveries.push(outcome.total_s);
        if self.obs.enabled() {
            RecoveryPhases::of(&outcome, 0.0).emit(
                &mut self.obs,
                t0,
                Some(rank),
                "failure",
                format!("{method:?}"),
            );
        }
        let ev = EngineEvent::RecoveryCompleted { method, latency_s: outcome.total_s };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        let ev = EngineEvent::Reconfigured { epoch: self.recoveries.len() as u64, world: self.world };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        self.sample_gauges();
        Ok(outcome.total_s)
    }

    /// Rejoin one previously failed GPU at this step boundary — the
    /// simulator's side of [`ServingBackend::inject_rejoin`], mirroring
    /// [`crate::engine::Engine::inject_rejoin`]: the returning GPU is
    /// appended as the last rank, weights stream in on demand (costed by
    /// [`plan_recovery`] on the expand delta), the KV re-spread is costed
    /// as the joining rank's share of resident cache over NVLink, and the
    /// clock pays the modeled stall. The router grows with the new rank
    /// empty, so least-loaded admission rebalances onto it.
    fn rejoin_rank(&mut self, method: RecoveryMethod) -> Result<f64> {
        anyhow::ensure!(
            self.lost > 0,
            "inject_rejoin: no failed GPU to rejoin (world {}, none lost)",
            self.world
        );
        let joined = self.world;
        let (new_plan, survivor_map) = self.plan.expand();
        let input = RecoveryInput {
            spec: &self.spec,
            ic: &self.ic,
            old_plan: &self.plan,
            new_plan: &new_plan,
            survivor_map: &survivor_map,
            failed_rank: usize::MAX, // nothing is lost on a rejoin
            requests: &[],
            backup: &self.backup,
        };
        let outcome = plan_recovery(method, &input);
        // The cost model tracks KV as aggregate per-rank bytes, so the
        // cyclic re-spread is costed as the joining rank's share of the
        // resident cache, moved over NVLink.
        let resident: f64 = self.kv_used.iter().sum();
        let moved = (resident / (self.world + 1) as f64) as usize;
        let kv_move_s = self.ic.parallel_transfer_time(TransferClass::NvLink, moved);
        let total_s = outcome.total_s + kv_move_s;
        let t0 = self.clock; // rejoin observed here; the stall lands after
        self.clock += total_s; // the stall every in-flight request sees

        // Reconfigure to the grown world; the returning GPU starts at
        // full speed. Fresh capacity may also unstick a waiting line
        // that could not fit the smaller world (rebuild_cost re-derives
        // usage and clears the stall).
        self.world += 1;
        self.lost -= 1;
        self.plan = new_plan;
        self.speed.push(1.0);
        // The most recently lost device returns (LIFO): a failed A100
        // rejoins as an A100, not a fresh reference device.
        let returning = self.lost_devices.pop().unwrap_or_else(|| self.spec.clone());
        self.devices.push(returning);
        if let Some(w) = self.mitigation.as_mut() {
            w.push(1.0);
        }
        if self.proportional {
            self.mitigation = Some(self.mitigation_weights());
        }
        self.router = self.router.expand(self.world);
        self.rebuild_cost();

        self.recoveries.push(total_s);
        if self.obs.enabled() {
            RecoveryPhases::of(&outcome, kv_move_s).emit(
                &mut self.obs,
                t0,
                Some(joined),
                "rejoin",
                format!("{method:?}"),
            );
        }
        let ev = EngineEvent::GpuRejoined { rank: joined, method };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        let ev = EngineEvent::ReconfigCompleted {
            epoch: self.recoveries.len() as u64,
            world: self.world,
            latency_s: total_s,
        };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        // Consumers that track the serving plan via `Reconfigured` (as the
        // failure path trains them to) must see expansions too.
        let ev = EngineEvent::Reconfigured {
            epoch: self.recoveries.len() as u64,
            world: self.world,
        };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        self.sample_gauges();
        Ok(total_s)
    }
}

impl ServingBackend for OnlineSession {
    /// Submit a synthetic request: only `prompt.len()` matters to the
    /// cost model (token ids are not simulated).
    fn submit_with(&mut self, prompt: &[u32], opts: SubmitOptions) -> Result<RequestId> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            opts.max_new_tokens > 0,
            "max_new_tokens must be at least 1 (a zero budget is a caller bug, not a no-op)"
        );
        anyhow::ensure!(
            opts.arrival.is_finite() && opts.arrival >= 0.0,
            "arrival must be a finite, non-negative time (got {})",
            opts.arrival
        );
        anyhow::ensure!(opts.deadline.unwrap_or(0.0).is_finite(), "deadline must be finite");
        let id = self.next_id;
        let tokens = self.prefix_sharing.then(|| prompt.to_vec());
        self.enqueue(
            id,
            opts.arrival,
            prompt.len(),
            opts.max_new_tokens,
            opts.priority,
            opts.deadline,
            tokens,
        );
        Ok(id)
    }

    fn step(&mut self) -> Result<Vec<EngineEvent>> {
        Ok(self.tick())
    }

    fn max_tokens_per_step(&self) -> usize {
        // One decode round emits at most one token per running request.
        self.max_batch
    }

    /// Span-engine override: dispatch on the session's [`CoreMode`].
    /// [`CoreMode::Exact`] (the default) is observationally bit-exact
    /// with the per-token stepper except that `TokenEmitted` events are
    /// elided into [`AdvanceOutcome::progressed`]; see
    /// [`crate::simulator::simcore`]'s module docs for the contract.
    fn advance_until(
        &mut self,
        limit: AdvanceLimit,
        sink: &mut Vec<EngineEvent>,
    ) -> Result<AdvanceOutcome> {
        Ok(simcore::advance(self, limit, sink))
    }

    fn abort(&mut self, id: RequestId) -> Result<()> {
        if let Some(i) = self.pending.iter().position(|p| p.id == id) {
            self.pending.remove(i);
        } else if let Some(i) = self.waiting.iter().position(|w| w.id == id) {
            self.waiting.remove(i);
        } else if let Some(i) = self.running.iter().position(|r| r.id == id) {
            let r = self.running.swap_remove(i);
            self.daemon.forget(r.id);
            self.backup.release(r.id, self.model.kv_bytes_per_token());
            let private = (r.context - r.shared) as f64;
            for (ru, used) in self.kv_used.iter_mut().enumerate() {
                *used = (*used - self.tp_rate[ru] * private).max(0.0);
            }
            self.kv_used[r.home] = (self.kv_used[r.home] - self.dp_rate * private).max(0.0);
            self.router.complete(r.home, 0.0);
        } else if let Some(i) = self.swapped.iter().position(|s| s.id == id) {
            // Parked in the swap tier: no device KV to release — just the
            // host mirror and the daemon's trailing-backup state.
            let s = self.swapped.swap_remove(i);
            self.daemon.forget(s.id);
            self.backup.release(s.id, self.model.kv_bytes_per_token());
        } else {
            anyhow::bail!("abort: unknown or already finished request {id}");
        }
        self.aborted.push(id);
        self.metrics.on_abort(id, self.clock);
        let ev = EngineEvent::RequestAborted { id };
        self.obs.event(self.clock, &ev);
        self.events.push(ev);
        self.sample_gauges();
        Ok(())
    }

    fn set_observer(&mut self, observer: Box<dyn Observer>) {
        OnlineSession::set_observer(self, observer)
    }

    fn set_obs_replica(&mut self, replica: usize) {
        OnlineSession::set_obs_replica(self, replica)
    }

    fn inject_failure(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64> {
        self.fail_rank(rank, method)
    }

    fn inject_rejoin(&mut self, method: RecoveryMethod) -> Result<f64> {
        self.rejoin_rank(method)
    }

    fn inject_slowdown(&mut self, rank: RankId, factor: f64) -> Result<f64> {
        self.slow_rank(rank, factor)
    }

    fn world(&self) -> usize {
        self.world
    }

    fn effective_capacity(&self) -> f64 {
        self.speed.iter().sum()
    }

    fn hardware_capacity(&self) -> f64 {
        let h100 = GpuSpec::h100();
        self.devices.iter().map(|d| d.relative_capacity(&h100)).sum()
    }

    fn now(&self) -> SimTime {
        self.clock
    }

    fn is_idle(&self) -> bool {
        // Buffered events (aborts, failure notices) must still be
        // delivered by one more step() before the session counts as idle.
        self.events.is_empty() && self.session_idle()
    }

    /// Report with placeholder output tokens (id `0`): lengths, timing,
    /// and counters are the meaningful fields on this backend.
    fn report(&self) -> ServeReport {
        let mut results = Vec::with_capacity(self.order.len());
        for &id in &self.order {
            let m = self.metrics.request(id);
            let emitted = m.map(|m| m.tokens_out).unwrap_or(0);
            let (priority, deadline) = self.req_slo.get(&id).copied().unwrap_or((0, None));
            results.push(GenerationResult {
                id,
                output_tokens: vec![0; emitted],
                ttft_s: m.and_then(|m| m.ttft()),
                max_tbt_s: m.map(|m| m.max_tbt).unwrap_or(0.0),
                aborted: self.aborted.contains(&id),
                priority,
                deadline,
                finished_at: self.finished_at.get(&id).copied(),
            });
        }
        ServeReport {
            results,
            wall_s: self.metrics.elapsed(),
            prefill_tokens: self.metrics.input_tokens as usize,
            decode_tokens: self.metrics.output_tokens as usize,
            steps: self.steps,
            recoveries: self.recoveries.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{drive, FaultPlan, FaultTrigger};
    use crate::model::llama3_70b;
    use crate::traces::{mooncake_trace, poisson_arrivals};

    fn small_trace(n: usize, rate: f64) -> Vec<TraceRequest> {
        let mut t = mooncake_trace(n, 11);
        // Keep realistic (long) contexts — they drive the KV/compute
        // imbalance under test — but shorten outputs so tests run fast.
        for r in t.iter_mut() {
            r.input_tokens = r.input_tokens.min(8192);
            r.output_tokens = (r.output_tokens / 8).clamp(4, 32);
        }
        poisson_arrivals(&mut t, rate, 11);
        t
    }

    /// Like `small_trace` but with short inputs for prefill-speed tests.
    fn tiny_trace(n: usize, rate: f64) -> Vec<TraceRequest> {
        let mut t = mooncake_trace(n, 11);
        for r in t.iter_mut() {
            r.input_tokens = (r.input_tokens / 16).clamp(16, 1024);
            r.output_tokens = (r.output_tokens / 8).clamp(4, 32);
        }
        poisson_arrivals(&mut t, rate, 11);
        t
    }

    #[test]
    fn decode_sim_completes_all_requests() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let trace = small_trace(40, 5.0);
        let out = sim.run(&trace, None);
        assert_eq!(out.metrics.n_requests(), 40);
        assert!(out.metrics.output_throughput() > 0.0);
        assert!(out.steps > 0);
    }

    #[test]
    fn prefill_sim_ttft_increases_with_rate() {
        let mk = |rate| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Prefill, 8)
                .with_model(llama3_70b());
            let trace = tiny_trace(60, rate);
            let out = sim.run(&trace, None);
            out.metrics.ttft.p90()
        };
        let slow = mk(0.5);
        let fast = mk(50.0);
        assert!(fast > slow, "p90 TTFT at high rate {fast} must exceed low rate {slow}");
    }

    #[test]
    fn failsafe_tp7_decode_beats_nonuniform() {
        let trace = small_trace(60, 10_000.0); // effectively offline (saturating)
        let run = |cfg: SystemConfig| {
            let sim =
                OnlineSim::new(cfg, OnlineMode::Decode, 7).with_model(llama3_70b());
            sim.run(&trace, None).metrics.output_throughput()
        };
        let fs = run(SystemConfig::failsafe());
        let nu = run(SystemConfig::nonuniform());
        assert!(fs > nu * 1.1, "failsafe {fs} vs nonuniform {nu}");
    }

    #[test]
    fn recovery_stall_creates_tbt_spike() {
        let trace = small_trace(100, 20.0);
        let run = |method: RecoveryMethod| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b());
            let out = sim.run(
                &trace,
                Some(RecoveryEvent { after_requests: 50, failed_rank: 3, method }),
            );
            (out.recovery_latency_s.unwrap(), out.world)
        };
        let (rec, w1) = run(RecoveryMethod::Recompute);
        let (full, w2) = run(RecoveryMethod::Full);
        assert_eq!(w1, 7);
        assert_eq!(w2, 7);
        assert!(rec > 10.0 * full, "recompute {rec} vs full {full}");
    }

    /// The trait surface: submit with timed arrivals, drive with a
    /// mid-stream fault, and read the report — no trace plumbing.
    #[test]
    fn session_backend_runs_timed_arrivals_with_fault() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let mut session = sim.session();
        let prompt = vec![0u32; 2048];
        let mut ids = Vec::new();
        for i in 0..20 {
            let opts = SubmitOptions::new(8).at(i as f64 * 0.05);
            ids.push(session.submit_with(&prompt, opts).unwrap());
        }
        let fault = FaultPlan {
            trigger: FaultTrigger::AfterTokens(40),
            rank: 2,
            method: RecoveryMethod::Full,
        };
        let (report, recovery) = drive(&mut session, Some(fault)).unwrap();
        assert_eq!(report.results.len(), 20);
        assert!(recovery.unwrap() > 0.0);
        assert_eq!(session.world, 7);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.output_tokens.len(), 8, "request {i} short");
            assert!(r.ttft_s.is_some());
        }
        assert_eq!(report.recoveries.len(), 1);
    }

    /// Mixed-device sessions: the capacity-proportional plan is served
    /// from admission, hardware capacity reflects the device mix, and a
    /// failed A100 rejoins as an A100.
    #[test]
    fn session_mixed_devices_proportional_plan_and_device_tracking() {
        let devices: Vec<GpuSpec> =
            (0..8).map(|i| if i < 4 { GpuSpec::h100() } else { GpuSpec::a100() }).collect();
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b())
            .with_devices(devices);
        let mut session = sim.session();
        // Served plan is reweighted: H100 ranks carry more head-layers.
        let loads = session.active.rank_loads();
        assert!(loads[0].tp_head_layers > loads[7].tp_head_layers);
        // Hardware capacity: 4 full units + 4 sub-unit A100s.
        let hw = ServingBackend::hardware_capacity(&session);
        assert!(hw > 4.0 && hw < 8.0, "hardware capacity {hw}");
        assert_eq!(ServingBackend::effective_capacity(&session), 8.0, "healthy fleet");

        // Fail an A100 (rank 5): capacity rises per remaining-mix share.
        let prompt = vec![0u32; 1024];
        for i in 0..8 {
            session.submit_with(&prompt, SubmitOptions::new(8).at(i as f64 * 0.01)).unwrap();
        }
        session.step().unwrap();
        session.inject_failure(5, RecoveryMethod::Full).unwrap();
        assert_eq!(session.devices.len(), 7);
        let hw_after = ServingBackend::hardware_capacity(&session);
        assert!(hw_after < hw);
        // The lost A100 rejoins as an A100, restoring exactly hw.
        session.inject_rejoin(RecoveryMethod::Full).unwrap();
        assert_eq!(session.devices.len(), 8);
        let hw_back = ServingBackend::hardware_capacity(&session);
        assert!((hw_back - hw).abs() < 1e-9, "{hw_back} vs {hw}");
        session.run_to_completion().unwrap();
    }

    /// A proportional mixed-fleet session finishes a fixed workload
    /// faster than the same hardware serving the uniform plan.
    #[test]
    fn session_proportional_beats_uniform_on_mixed_fleet() {
        let devices: Vec<GpuSpec> =
            (0..8).map(|i| if i < 4 { GpuSpec::h100() } else { GpuSpec::a100() }).collect();
        let run = |proportional: bool| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b())
                .with_devices(devices.clone())
                .with_proportional_plan(proportional);
            let mut session = sim.session();
            let prompt = vec![0u32; 2048];
            for i in 0..32 {
                session.submit_with(&prompt, SubmitOptions::new(64).at(i as f64 * 0.02)).unwrap();
            }
            let report = session.run_to_completion().unwrap();
            report.wall_s
        };
        let uniform = run(false);
        let proportional = run(true);
        assert!(
            proportional < uniform,
            "proportional wall {proportional} must beat uniform wall {uniform}"
        );
    }

    /// Aborting a running simulated request frees its budget and the
    /// report marks it.
    #[test]
    fn session_abort_releases_and_reports() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let mut session = sim.session();
        let prompt = vec![0u32; 1024];
        let keep = session.submit_with(&prompt, SubmitOptions::new(16)).unwrap();
        let kill = session.submit_with(&prompt, SubmitOptions::new(16)).unwrap();
        // Step until both are running and have emitted a token.
        for _ in 0..3 {
            session.step().unwrap();
        }
        session.abort(kill).unwrap();
        let report = session.run_to_completion().unwrap();
        let kept = report.result(keep).unwrap();
        let killed = report.result(kill).unwrap();
        assert_eq!(kept.output_tokens.len(), 16);
        assert!(killed.aborted);
        assert!(killed.output_tokens.len() < 16);
    }

    /// Rejoin is the inverse of failure: the world grows back, the new
    /// rank's events surface, and rejoining without a failed GPU errors.
    #[test]
    fn session_rejoin_restores_world() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let mut session = sim.session();
        assert!(session.inject_rejoin(RecoveryMethod::Full).is_err(), "no failed GPU yet");

        let prompt = vec![0u32; 2048];
        for i in 0..12 {
            session.submit_with(&prompt, SubmitOptions::new(8).at(i as f64 * 0.01)).unwrap();
        }
        for _ in 0..3 {
            session.step().unwrap();
        }
        session.inject_failure(2, RecoveryMethod::Full).unwrap();
        assert_eq!(ServingBackend::world(&session), 7);
        let lat = session.inject_rejoin(RecoveryMethod::Full).unwrap();
        assert!(lat > 0.0, "rejoin pays a modeled stall");
        assert_eq!(ServingBackend::world(&session), 8);
        assert!(session.inject_rejoin(RecoveryMethod::Full).is_err(), "budget spent");

        let events = session.step().unwrap();
        assert!(events.iter().any(|e| matches!(e, EngineEvent::GpuRejoined { rank: 7, .. })));
        assert!(events
            .iter()
            .any(|e| matches!(e, EngineEvent::ReconfigCompleted { world: 8, .. })));

        let report = session.run_to_completion().unwrap();
        assert_eq!(report.recoveries.len(), 2);
        for r in &report.results {
            assert_eq!(r.output_tokens.len(), 8, "request {} short after rejoin", r.id);
        }
    }

    /// Soft faults: the world never changes, degrade/restore events
    /// surface, bad factors are rejected, and the straggler actually
    /// slows the modeled session when mitigation is off.
    #[test]
    fn session_slowdown_degrades_and_restores() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let mut session = sim.session();
        assert!(session.inject_slowdown(9, 0.5).is_err(), "rank out of range");
        assert!(session.inject_slowdown(1, 0.0).is_err());
        assert!(session.inject_slowdown(1, 1.5).is_err());
        assert!(session.inject_slowdown(1, f64::NAN).is_err());

        let prompt = vec![0u32; 2048];
        session.submit_with(&prompt, SubmitOptions::new(8)).unwrap();
        session.inject_slowdown(2, 0.5).unwrap();
        assert_eq!(ServingBackend::world(&session), 8, "soft faults keep the world");
        assert_eq!(session.effective_capacity(), 7.5);
        let events = session.step().unwrap();
        assert!(events
            .iter()
            .any(|e| matches!(e, EngineEvent::GpuDegraded { rank: 2, factor } if *factor == 0.5)));

        session.inject_slowdown(2, 1.0).unwrap();
        assert_eq!(session.effective_capacity(), 8.0);
        let events = session.step().unwrap();
        assert!(events.iter().any(|e| matches!(e, EngineEvent::GpuRestored { rank: 2 })));
        session.run_to_completion().unwrap();
    }

    /// The modeled cost of a straggler is real: an unmitigated throttled
    /// session takes much longer than a healthy one over the same trace,
    /// and the rebalanced session claws most of it back.
    #[test]
    fn session_rebalance_recovers_straggler_throughput() {
        let factor = 0.5;
        let run = |mode: Option<bool>| {
            let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b());
            let mut s = sim.session();
            if let Some(auto) = mode {
                s.set_auto_rebalance(auto);
                s.inject_slowdown(3, factor).unwrap();
            }
            let prompt = vec![0u32; 4096];
            for _ in 0..32 {
                s.submit_with(&prompt, SubmitOptions::new(32)).unwrap();
            }
            let rep = s.run_to_completion().unwrap();
            rep.decode_tokens as f64 / rep.wall_s
        };
        let healthy = run(None);
        let baseline = run(Some(false));
        let mitigated = run(Some(true));
        let ideal = healthy * 7.5 / 8.0;
        assert!(
            mitigated > baseline * 1.2,
            "rebalanced {mitigated} should clearly beat unmitigated {baseline}"
        );
        assert!(
            mitigated >= ideal * 0.85,
            "rebalanced {mitigated} within 15% of capacity-proportional ideal {ideal}"
        );
        assert!(baseline < healthy * 0.7, "unmitigated straggler {baseline} vs healthy {healthy}");
    }

    /// Build a K-prefix × N-continuation workload: each prompt is a
    /// shared `prefix_len`-token head plus a distinct `suffix_len` tail.
    fn fanout_prompts(k: u32, n: u32, prefix_len: usize, suffix_len: usize) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        for p in 0..k {
            let prefix: Vec<u32> = (0..prefix_len as u32).map(|i| p * 100_000 + (i % 997)).collect();
            for c in 0..n {
                let mut prompt = prefix.clone();
                prompt.extend((0..suffix_len as u32).map(|i| 900_000 + c * 1_000 + i));
                out.push(prompt);
            }
        }
        out
    }

    /// The prefix mirror: staggered repeat-fanout traffic skips most
    /// modeled prefill, and a simultaneous burst keeps one copy of each
    /// prefix resident instead of one per sharer.
    #[test]
    fn prefix_sharing_reduces_prefill_and_kv() {
        let session = |sharing: bool| {
            OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b())
                .with_prefix_sharing(sharing)
                .session()
        };
        // Staggered arrivals: each continuation lands after its donor is
        // resident, so the prefill instance adopts the warm prefix.
        let staggered = |sharing: bool| {
            let mut s = session(sharing);
            for (i, p) in fanout_prompts(4, 8, 2048, 64).iter().enumerate() {
                s.submit_with(p, SubmitOptions::new(4).at(i as f64 * 0.5)).unwrap();
            }
            let rep = s.run_to_completion().unwrap();
            assert_eq!(rep.results.len(), 32);
            for r in &rep.results {
                assert_eq!(r.output_tokens.len(), 4);
            }
            (rep.prefill_tokens, s.prefix_stats())
        };
        let (cold, _) = staggered(false);
        let (warm, stats) = staggered(true);
        assert!(stats.hits >= 24, "continuations hit the trie (got {})", stats.hits);
        assert!(warm * 3 < cold, "modeled prefill {warm} vs no-sharing {cold}");

        // Burst arrivals: everything resident at once — the KV win is the
        // shared pool charged once.
        let burst = |sharing: bool| {
            let mut s = session(sharing);
            for p in fanout_prompts(4, 8, 2048, 64).iter() {
                s.submit_with(p, SubmitOptions::new(16)).unwrap();
            }
            let rep = s.run_to_completion().unwrap();
            assert_eq!(rep.results.len(), 32);
            s.peak_kv_bytes()
        };
        let cold_kv = burst(false);
        let warm_kv = burst(true);
        assert!(
            warm_kv * 2.0 < cold_kv,
            "peak resident KV {warm_kv:.2e} should be under half of no-sharing {cold_kv:.2e}"
        );
    }

    /// A hard failure flushes the sim's prefix pool conservatively: every
    /// survivor's context is charged privately again, and the drained
    /// session holds no KV.
    #[test]
    fn failure_flushes_prefix_pool_and_recharges() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b())
            .with_prefix_sharing(true);
        let mut s = sim.session();
        let prefix: Vec<u32> = (0..1024).collect();
        for c in 0..6u32 {
            let mut p = prefix.clone();
            p.extend([90_000 + c; 32]);
            s.submit_with(&p, SubmitOptions::new(8)).unwrap();
        }
        s.step().unwrap(); // admit the burst
        assert!(s.prefix_resident_tokens() >= 1024, "prefix chunks resident");
        let before = s.kv_bytes();
        s.inject_failure(2, RecoveryMethod::Full).unwrap();
        assert_eq!(s.prefix_resident_tokens(), 0, "conservative flush");
        assert!(s.kv_bytes() > before, "dedup lost: survivors charged privately");
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 6);
        for r in &rep.results {
            assert_eq!(r.output_tokens.len(), 8);
        }
        assert!(s.kv_bytes() < 1.0, "drained session releases all private KV");
    }

    /// The tentpole behavior: under a saturated batch, a high-SLO
    /// request preempts a best-effort decode to the swap tier, finishes
    /// far sooner than FCFS would allow, and the evicted work still
    /// completes in full after swap-in — nothing is aborted or
    /// recomputed.
    #[test]
    fn preemption_boosts_slo_tier_under_overload() {
        let run = |preempt: bool| {
            let mut sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
                .with_model(llama3_70b());
            sim.max_batch = 4;
            if preempt {
                sim = sim.with_preemption(PreemptPolicy::default());
            }
            let mut s = sim.session();
            let prompt = vec![0u32; 2048];
            // Saturate the batch with best-effort long decodes.
            let be: Vec<_> = (0..4)
                .map(|_| s.submit_with(&prompt, SubmitOptions::new(200)).unwrap())
                .collect();
            // A premium request lands once the batch is running, with a
            // deadline it can only approach by jumping the queue.
            let vip = s
                .submit_with(&prompt, SubmitOptions::new(8).at(0.05).priority(2).deadline(0.01))
                .unwrap();
            let rep = s.run_to_completion().unwrap();
            (rep, s.preemptions(), s.swap_ins(), s.swap_pcie_seconds(), vip, be)
        };
        let (fcfs, p0, si0, _, vip0, _) = run(false);
        assert_eq!(p0, 0, "no policy, no preemptions");
        assert_eq!(si0, 0);
        let fcfs_vip = fcfs.result(vip0).unwrap().finished_at.unwrap();
        let (pre, p1, si1, pcie, vip, be) = run(true);
        assert!(p1 >= 1, "the premium request preempts a best-effort decode");
        assert!(si1 >= 1, "evicted work resumes via swap-in, not recompute");
        assert!(pcie > 0.0, "swap traffic is costed on the PCIe clock");
        let pre_vip = pre.result(vip).unwrap().finished_at.unwrap();
        assert!(
            pre_vip < fcfs_vip * 0.5,
            "preemption finishes the SLO tier much sooner: {pre_vip} vs FCFS {fcfs_vip}"
        );
        assert_eq!(pre.result(vip).unwrap().output_tokens.len(), 8);
        // The evicted best-effort requests still complete in full.
        for id in be {
            let r = pre.result(id).unwrap();
            assert!(!r.aborted);
            assert_eq!(r.output_tokens.len(), 200, "request {id} short after swap");
        }
        // Per-tier accounting surfaces the split.
        assert_eq!(pre.tiers(), vec![2, 0]);
        assert_eq!(pre.tier_goodput_tokens(2), 8);
        assert_eq!(pre.tier_goodput_tokens(0), 800);
    }

    /// The swap tier's reason to exist: restoring KV over PCIe is far
    /// cheaper than recomputing the prefill that produced it.
    #[test]
    fn swap_in_is_cheaper_than_recompute() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b());
        let s = sim.session();
        for tokens in [512usize, 4096, 16384] {
            let swap = s.cost.swap_time(tokens);
            let recompute = s.cost.recompute_time(tokens);
            assert!(
                swap < recompute,
                "swap-in of {tokens} tokens ({swap:.4}s) must beat recompute ({recompute:.4}s)"
            );
        }
    }

    /// Aborting a swapped-out request releases its host mirror and the
    /// report marks it — the abort path covers all four queues.
    #[test]
    fn abort_of_swapped_request_cleans_up() {
        let mut sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8)
            .with_model(llama3_70b())
            .with_preemption(PreemptPolicy::default());
        sim.max_batch = 2;
        let mut s = sim.session();
        let prompt = vec![0u32; 2048];
        // Among equal tiers the youngest (highest id) running request is
        // evicted first, so the second submission is the victim.
        s.submit_with(&prompt, SubmitOptions::new(300)).unwrap();
        let victim = s.submit_with(&prompt, SubmitOptions::new(300)).unwrap();
        let vip = s
            .submit_with(&prompt, SubmitOptions::new(4).at(0.05).priority(3).deadline(0.01))
            .unwrap();
        // Step until the preemption lands.
        for _ in 0..64 {
            s.step().unwrap();
            if s.preemptions() > 0 {
                break;
            }
        }
        assert!(s.preemptions() >= 1, "premium request must preempt");
        assert_eq!(s.swapped_len(), 1);
        s.abort(victim).unwrap();
        assert_eq!(s.swapped_len(), 0);
        let rep = s.run_to_completion().unwrap();
        assert!(rep.result(victim).unwrap().aborted);
        assert_eq!(rep.result(vip).unwrap().output_tokens.len(), 4);
    }

    /// Zero generation budget is a caller bug on this backend too.
    #[test]
    fn session_rejects_zero_budget() {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4)
            .with_model(llama3_70b());
        let mut session = sim.session();
        assert!(session.submit_with(&[0; 8], SubmitOptions::new(0)).is_err());
    }
}
