//! Named system configurations: which combination of FailSafe's techniques
//! is active. These are the columns of the paper's comparison figures.

use crate::model::ModelSpec;
use crate::router::RoutePolicy;
use crate::sharding::{AttentionPolicy, FfnPolicy, ShardPlan};

/// Prefill batch-forming policy (Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillPolicy {
    /// FIFO chunked prefill (one request's chunk can hog the budget).
    Fifo,
    /// DP-aware adaptive chunked prefill (Algorithm 1).
    Adaptive,
}

/// A complete policy bundle for one simulated system.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub name: String,
    pub attn: AttentionPolicy,
    pub ffn: FfnPolicy,
    pub router: RoutePolicy,
    pub prefill: PrefillPolicy,
}

impl SystemConfig {
    /// Standard uniform TP (the engine's TP4/TP8 configurations). Placement
    /// policies are irrelevant at uniform world sizes — all reduce to the
    /// same layout — so this doubles as the fault-free upper bound.
    pub fn standard() -> Self {
        SystemConfig {
            name: "Standard-TP".into(),
            attn: AttentionPolicy::NaiveContiguous,
            ffn: FfnPolicy::Contiguous,
            router: RoutePolicy::RoundRobin,
            prefill: PrefillPolicy::Fifo,
        }
    }

    /// Naive non-uniform TP (the paper's `Nonuniform-TP` baseline): runs on
    /// irregular world sizes but with contiguous placement, round-robin
    /// routing and FIFO prefill.
    pub fn nonuniform() -> Self {
        SystemConfig { name: "Nonuniform-TP".into(), ..Self::standard() }
    }

    /// Nonuniform-TP + cyclic memory placement (Fig 11 "+Memory-balancing").
    pub fn memory_balanced() -> Self {
        SystemConfig {
            name: "+Memory-balancing".into(),
            attn: AttentionPolicy::Cyclic,
            ffn: FfnPolicy::Commutative,
            router: RoutePolicy::RoundRobin,
            prefill: PrefillPolicy::Fifo,
        }
    }

    /// Full FailSafe: hybrid attention + cyclic placement + load-aware
    /// router + adaptive chunked prefill (Fig 11 "+Compute-balancing").
    pub fn failsafe() -> Self {
        SystemConfig {
            name: "FailSafe".into(),
            attn: AttentionPolicy::Hybrid,
            ffn: FfnPolicy::Commutative,
            router: RoutePolicy::LeastLoaded,
            prefill: PrefillPolicy::Adaptive,
        }
    }

    /// Build the shard plan this config uses at world size `world`.
    pub fn plan(&self, model: &ModelSpec, world: usize) -> ShardPlan {
        ShardPlan::new(model, world, self.attn, self.ffn)
    }

    pub fn with_name(mut self, name: &str) -> Self {
        self.name = name.into();
        self
    }
}
