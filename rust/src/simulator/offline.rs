//! Steady-state offline throughput (the Fig 8 ingredient).
//!
//! Offline serving keeps an unbounded backlog, so per-configuration
//! throughput is a steady-state property: the decode loop runs at the
//! largest batch the KV pools admit, interleaved with enough prefill work
//! to refill the batch as requests finish. We compute both phase rates
//! from the cost model and combine them by token share — the same
//! closed-form a roofline analysis of a saturated continuous-batching
//! engine gives.

use crate::cluster::{GpuSpec, Interconnect};
use crate::model::ModelSpec;
use crate::traces::TraceRequest;

use super::costmodel::{DecodeWork, PrefillWork, StepCostModel};
use super::SystemConfig;

/// Steady-state serving rates of one TP instance on a workload.
#[derive(Debug, Clone, Copy)]
pub struct SteadyState {
    /// Sustained generated tokens/s (decode side).
    pub decode_tps: f64,
    /// Sustained prefill tokens/s.
    pub prefill_tps: f64,
    /// End-to-end request throughput (requests/s) for the workload mix.
    pub requests_per_s: f64,
    /// The KV-capacity-limited decode batch size.
    pub batch: usize,
}

/// Mean input/output lengths of a workload (from its trace).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadMix {
    pub mean_input: f64,
    pub mean_output: f64,
}

impl WorkloadMix {
    pub fn from_trace(trace: &[TraceRequest]) -> Self {
        let n = trace.len().max(1) as f64;
        WorkloadMix {
            mean_input: trace.iter().map(|r| r.input_tokens as f64).sum::<f64>() / n,
            mean_output: trace.iter().map(|r| r.output_tokens as f64).sum::<f64>() / n,
        }
    }
}

/// Compute the steady state of `config` at `world` ranks on `mix`.
///
/// Returns `None` if the model cannot fit at this world size (the Fig 8
/// tables' "–" entries, e.g. Mixtral below 5 GPUs or llama below 3).
pub fn steady_state(
    model: &ModelSpec,
    config: &SystemConfig,
    world: usize,
    spec: &GpuSpec,
    mix: &WorkloadMix,
) -> Option<SteadyState> {
    if world == 0 {
        return None;
    }
    let plan = config.plan(model, world);
    // Fit check. Serving engines require weights to leave a usable KV +
    // activation pool; at 75%+ weight occupancy continuous batching
    // degenerates and the paper's engine refuses the configuration (the
    // Fig 8 "–" entries: llama-70B needs ≥3 GPUs, Mixtral-8x22B ≥5).
    let min_kv = 16.0 * (mix.mean_input + mix.mean_output) * model.kv_bytes_per_token() as f64
        / world as f64;
    let usable_hbm = spec.hbm_bytes - spec.hbm_bytes / 16; // activation reserve
    let weight_cap = spec.hbm_bytes * 3 / 4;
    let max_weight = plan.rank_loads().iter().map(|l| l.weight_bytes).max().unwrap_or(0);
    if max_weight > weight_cap || !plan.fits(usable_hbm, min_kv as usize) {
        return None;
    }
    let ic = Interconnect::new(spec.clone());
    let cost = StepCostModel::new(&plan, spec, &ic);

    // KV-limited decode batch: each running request averages
    // mean_input + mean_output/2 cached tokens.
    let kv_budget = cost.kv_budget();
    let (tp_rate, dp_rate) = cost.kv_rates();
    let avg_ctx = mix.mean_input + mix.mean_output / 2.0;
    let batch = (0..world)
        .map(|r| {
            let per_req = tp_rate[r] * avg_ctx + dp_rate * avg_ctx / world as f64;
            if per_req <= 0.0 {
                usize::MAX
            } else {
                (kv_budget[r] as f64 / per_req) as usize
            }
        })
        .min()
        .unwrap_or(0)
        .clamp(1, 512);

    // Decode rate at that batch (homes balanced by the router).
    let decode_work: Vec<DecodeWork> = (0..batch)
        .map(|i| DecodeWork { context: avg_ctx as usize, home: i % world })
        .collect();
    let step = cost.decode_step_time(&decode_work);
    let decode_tps = batch as f64 / step;

    // Prefill rate at a full budget batch (chunks spread by Algorithm 1 or
    // hogged by FIFO — here we cost the balanced case; the online simulator
    // captures the scheduling difference, offline runs are
    // prefill-insensitive because decode dominates the token mix).
    let budget = 8192usize;
    let chunk = (budget / world.max(1)).max(1);
    let prefill_work: Vec<PrefillWork> = (0..world)
        .map(|r| PrefillWork { tokens: chunk, context: mix.mean_input as usize / 2, home: r })
        .collect();
    let ptime = cost.prefill_step_time(&prefill_work);
    let prefill_tps = (chunk * world) as f64 / ptime;

    // Request rate: each request needs mean_input prefill tokens and
    // mean_output decode tokens; phases time-share the same GPUs.
    let per_req_time = mix.mean_input / prefill_tps + mix.mean_output / decode_tps;
    Some(SteadyState {
        decode_tps,
        prefill_tps,
        requests_per_s: 1.0 / per_req_time,
        batch,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama3_70b, mixtral_8x22b};
    use crate::traces::openthoughts_trace;

    fn mix() -> WorkloadMix {
        WorkloadMix::from_trace(&openthoughts_trace(2000, 5))
    }

    #[test]
    fn llama_fits_down_to_tp3() {
        // Fig 8 table: FailSafe serves llama-70B with ≥3 GPUs.
        let m = llama3_70b();
        let spec = GpuSpec::h100();
        let cfg = SystemConfig::failsafe();
        assert!(steady_state(&m, &cfg, 3, &spec, &mix()).is_some());
        assert!(steady_state(&m, &cfg, 2, &spec, &mix()).is_none());
    }

    #[test]
    fn mixtral_fits_down_to_tp5() {
        // Fig 8 table: Mixtral-8x22B needs ≥5 GPUs.
        let m = mixtral_8x22b();
        let spec = GpuSpec::h100();
        let cfg = SystemConfig::failsafe();
        assert!(steady_state(&m, &cfg, 5, &spec, &mix()).is_some());
        assert!(steady_state(&m, &cfg, 4, &spec, &mix()).is_none());
    }

    #[test]
    fn throughput_monotone_in_world() {
        let m = llama3_70b();
        let spec = GpuSpec::h100();
        let cfg = SystemConfig::failsafe();
        let mut last = 0.0;
        for w in 3..=8 {
            let s = steady_state(&m, &cfg, w, &spec, &mix()).unwrap();
            assert!(
                s.decode_tps > last,
                "decode tput must grow with world: w={w} {} <= {last}",
                s.decode_tps
            );
            last = s.decode_tps;
        }
    }

    #[test]
    fn failsafe_beats_nonuniform_at_tp7() {
        let m = llama3_70b();
        let spec = GpuSpec::h100();
        let fs = steady_state(&m, &SystemConfig::failsafe(), 7, &spec, &mix()).unwrap();
        let nu = steady_state(&m, &SystemConfig::nonuniform(), 7, &spec, &mix()).unwrap();
        assert!(
            fs.decode_tps > nu.decode_tps * 1.3,
            "failsafe {} vs nonuniform {}",
            fs.decode_tps,
            nu.decode_tps
        );
        assert!(fs.batch > nu.batch, "batch {} vs {}", fs.batch, nu.batch);
    }
}
