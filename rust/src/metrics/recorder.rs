//! Per-request and aggregate serving metrics.

use std::collections::HashMap;


use super::{Cdf, Histogram};
use crate::{RequestId, SimTime};

/// Terminal state of one request's metrics timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Still live (or the run ended without a terminal event).
    InFlight,
    /// Emitted its full generation budget.
    Completed,
    /// Cancelled (operator abort, redirect, admission-deadline expiry).
    Aborted,
}

/// Timeline of one request, from which TTFT/TBT derive.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMetrics {
    pub arrival: SimTime,
    pub first_token: Option<SimTime>,
    pub last_token: Option<SimTime>,
    pub tokens_out: usize,
    /// Largest gap between consecutive output tokens — the paper's SLO
    /// metric for decode ("a request violates its decode SLO if any of its
    /// TBTs exceed the threshold", §4.3.3).
    pub max_tbt: f64,
    /// How the request left the system ([`RequestOutcome::InFlight`]
    /// until [`ServingMetrics::on_finish`] / [`ServingMetrics::on_abort`]).
    pub outcome: RequestOutcome,
}

impl RequestMetrics {
    pub fn ttft(&self) -> Option<f64> {
        self.first_token.map(|t| t - self.arrival)
    }

    pub fn completed(&self) -> bool {
        self.outcome == RequestOutcome::Completed
    }

    pub fn aborted(&self) -> bool {
        self.outcome == RequestOutcome::Aborted
    }
}

/// Aggregate recorder for a serving run.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    requests: HashMap<RequestId, RequestMetrics>,
    pub ttft: Histogram,
    pub tbt: Histogram,
    /// Exact CDF of per-request max TBT (Fig 12).
    pub max_tbt_cdf: Cdf,
    pub input_tokens: u64,
    pub output_tokens: u64,
    start: SimTime,
    end: SimTime,
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            requests: HashMap::new(),
            ttft: Histogram::latency(),
            tbt: Histogram::latency(),
            max_tbt_cdf: Cdf::new(),
            input_tokens: 0,
            output_tokens: 0,
            start: f64::INFINITY,
            end: 0.0,
        }
    }

    pub fn on_arrival(&mut self, id: RequestId, at: SimTime) {
        self.requests.insert(
            id,
            RequestMetrics {
                arrival: at,
                first_token: None,
                last_token: None,
                tokens_out: 0,
                max_tbt: 0.0,
                outcome: RequestOutcome::InFlight,
            },
        );
        self.start = self.start.min(at);
    }

    /// `n_input` prefill tokens processed for `id` (throughput accounting).
    pub fn on_prefill_tokens(&mut self, n_input: usize) {
        self.input_tokens += n_input as u64;
    }

    /// One output token emitted for `id` at `at`.
    pub fn on_token(&mut self, id: RequestId, at: SimTime) {
        self.end = self.end.max(at);
        self.output_tokens += 1;
        let Some(r) = self.requests.get_mut(&id) else { return };
        match r.last_token {
            None => {
                r.first_token = Some(at);
                self.ttft.record(at - r.arrival);
            }
            Some(prev) => {
                let tbt = at - prev;
                self.tbt.record(tbt);
                if tbt > r.max_tbt {
                    r.max_tbt = tbt;
                }
            }
        }
        r.last_token = Some(at);
        r.tokens_out += 1;
    }

    /// Bulk path for the batched simulator core: `n` output tokens for
    /// `id`, the first at `first_at`, the last at `last_at`, with the
    /// intermediate emissions modeled as uniformly spaced. Aggregates
    /// (counts, TTFT, mean TBT) match `n` calls of
    /// [`ServingMetrics::on_token`]; individual TBT samples are the
    /// uniform-gap approximation, so this path is *not* bit-exact with
    /// per-token recording — the bit-exact span core calls `on_token`
    /// per virtual step instead.
    pub fn on_token_span(&mut self, id: RequestId, n: usize, first_at: SimTime, last_at: SimTime) {
        if n == 0 {
            return;
        }
        self.end = self.end.max(last_at);
        self.output_tokens += n as u64;
        let Some(r) = self.requests.get_mut(&id) else { return };
        let mut gaps = n as u64;
        let mut prev = match r.last_token {
            None => {
                r.first_token = Some(first_at);
                self.ttft.record(first_at - r.arrival);
                gaps -= 1;
                first_at
            }
            Some(prev) => prev,
        };
        if gaps > 0 {
            let gap = (last_at - prev) / gaps as f64;
            self.tbt.record_n(gap, gaps);
            if gap > r.max_tbt {
                r.max_tbt = gap;
            }
            prev = last_at;
        }
        r.last_token = Some(prev.max(last_at));
        r.tokens_out += n;
    }

    /// Request finished: fold its max TBT into the CDF.
    pub fn on_finish(&mut self, id: RequestId) {
        if let Some(r) = self.requests.get_mut(&id) {
            r.outcome = RequestOutcome::Completed;
            if r.tokens_out > 1 {
                self.max_tbt_cdf.record(r.max_tbt);
            }
        }
    }

    /// Request aborted at `at` (operator cancel, redirect off a failing
    /// replica, admission-deadline expiry). A terminal state like any
    /// other: the tokens it did emit stay counted, and its max TBT folds
    /// into the CDF exactly as a completion's would — an SLO analysis
    /// that silently drops aborted requests overstates the tail.
    pub fn on_abort(&mut self, id: RequestId, at: SimTime) {
        self.end = self.end.max(at);
        if let Some(r) = self.requests.get_mut(&id) {
            r.outcome = RequestOutcome::Aborted;
            if r.tokens_out > 1 {
                self.max_tbt_cdf.record(r.max_tbt);
            }
        }
    }

    /// Requests whose terminal state is `outcome`.
    pub fn n_with_outcome(&self, outcome: RequestOutcome) -> usize {
        self.requests.values().filter(|r| r.outcome == outcome).count()
    }

    pub fn request(&self, id: RequestId) -> Option<&RequestMetrics> {
        self.requests.get(&id)
    }

    pub fn n_requests(&self) -> usize {
        self.requests.len()
    }

    pub fn elapsed(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }

    /// Generated-token throughput (decode tokens/s) over the run.
    pub fn output_throughput(&self) -> f64 {
        if self.elapsed() == 0.0 {
            0.0
        } else {
            self.output_tokens as f64 / self.elapsed()
        }
    }

    /// Input-token throughput (prefill tokens/s) over the run.
    pub fn input_throughput(&self) -> f64 {
        if self.elapsed() == 0.0 {
            0.0
        } else {
            self.input_tokens as f64 / self.elapsed()
        }
    }
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Sliding-window throughput series for "real-time throughput" plots (Fig 8).
#[derive(Debug, Clone)]
pub struct ThroughputWindow {
    window: f64,
    /// (window_end_time, tokens_in_window)
    buckets: Vec<(SimTime, u64)>,
}

impl ThroughputWindow {
    pub fn new(window: f64) -> Self {
        ThroughputWindow { window, buckets: Vec::new() }
    }

    pub fn record(&mut self, at: SimTime, tokens: u64) {
        let end = (at / self.window).floor() * self.window + self.window;
        // Out-of-order arrivals (fleet replicas on skewed clocks, span
        // cores attributing bulk emissions) must merge into their
        // window, not append a stale-end duplicate: binary search keeps
        // the buckets sorted and unique by window end.
        match self.buckets.binary_search_by(|(e, _)| e.total_cmp(&end)) {
            Ok(i) => self.buckets[i].1 += tokens,
            Err(i) => self.buckets.insert(i, (end, tokens)),
        }
    }

    /// `(window_end_time, tokens_per_second)` series, with zero-valued
    /// windows filled in for idle gaps so plots show the stall instead
    /// of silently skipping it.
    pub fn series(&self) -> Vec<(SimTime, f64)> {
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut next: Option<SimTime> = None;
        for &(e, t) in &self.buckets {
            if let Some(mut n) = next {
                // Emit empty windows until we reach this bucket (the
                // half-window tolerance absorbs float stepping error).
                while e - n > self.window / 2.0 {
                    out.push((n, 0.0));
                    n += self.window;
                }
            }
            out.push((e, t as f64 / self.window));
            next = Some(e + self.window);
        }
        out
    }

    /// Average throughput over the whole run (the dashed line in Fig 8).
    pub fn average(&self) -> f64 {
        if self.buckets.is_empty() {
            return 0.0;
        }
        let total: u64 = self.buckets.iter().map(|&(_, t)| t).sum();
        let span = self.buckets.last().unwrap().0 - (self.buckets.first().unwrap().0 - self.window);
        total as f64 / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tbt_tracked() {
        let mut m = ServingMetrics::new();
        m.on_arrival(1, 0.0);
        m.on_token(1, 2.0); // TTFT 2s
        m.on_token(1, 2.1);
        m.on_token(1, 12.1); // stall: max TBT 10s
        m.on_finish(1);
        let r = m.request(1).unwrap();
        assert_eq!(r.ttft(), Some(2.0));
        assert!((r.max_tbt - 10.0).abs() < 1e-9);
        assert_eq!(m.output_tokens, 3);
    }

    #[test]
    fn throughput_window_series() {
        let mut w = ThroughputWindow::new(10.0);
        w.record(1.0, 100);
        w.record(5.0, 100);
        w.record(15.0, 300);
        let s = w.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], (10.0, 20.0));
        assert_eq!(s[1], (20.0, 30.0));
        assert!((w.average() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn abort_is_terminal_and_counted() {
        let mut m = ServingMetrics::new();
        m.on_arrival(1, 0.0);
        m.on_token(1, 1.0);
        m.on_token(1, 4.0); // max TBT 3s
        m.on_abort(1, 5.0);
        let r = m.request(1).unwrap();
        assert!(r.aborted() && !r.completed());
        assert_eq!(m.n_with_outcome(RequestOutcome::Aborted), 1);
        assert_eq!(m.n_with_outcome(RequestOutcome::Completed), 0);
        // The aborted request's tail latency stays in the SLO CDF...
        assert_eq!(m.max_tbt_cdf.len(), 1);
        // ...and the abort time extends the run for throughput math.
        assert!((m.elapsed() - 5.0).abs() < 1e-9);

        // A zero/one-token abort records no TBT sample.
        m.on_arrival(2, 0.0);
        m.on_abort(2, 6.0);
        assert_eq!(m.max_tbt_cdf.len(), 1);
        assert!(m.request(2).unwrap().aborted());
    }

    #[test]
    fn throughput_window_out_of_order_merges() {
        let mut w = ThroughputWindow::new(10.0);
        w.record(15.0, 100);
        // Earlier-window stragglers (skewed fleet clocks) must merge,
        // not append stale-end duplicates.
        w.record(5.0, 50);
        w.record(3.0, 50);
        let s = w.series();
        assert_eq!(s, vec![(10.0, 10.0), (20.0, 10.0)]);
        assert!((w.average() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn series_fills_idle_gaps() {
        let mut w = ThroughputWindow::new(10.0);
        w.record(5.0, 100);
        w.record(45.0, 100);
        let s = w.series();
        assert_eq!(
            s,
            vec![(10.0, 10.0), (20.0, 0.0), (30.0, 0.0), (40.0, 0.0), (50.0, 10.0)]
        );
    }

    #[test]
    fn output_throughput() {
        let mut m = ServingMetrics::new();
        m.on_arrival(1, 0.0);
        for i in 1..=100 {
            m.on_token(1, i as f64 * 0.1);
        }
        assert!((m.output_throughput() - 10.0).abs() < 0.2);
    }
}
