//! Log-bucketed histogram with percentile queries, and exact CDFs for
//! figure generation.


/// Log-bucketed latency histogram: constant-memory, ~1% relative error —
/// fine for serving percentiles across many orders of magnitude (the paper
/// spans 15 ms oracle recovery to 22 s recompute).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [min * ratio^i, min * ratio^(i+1))
    counts: Vec<u64>,
    min_value: f64,
    ratio: f64,
    n: u64,
    sum: f64,
    max_seen: f64,
}

impl Histogram {
    /// Buckets spanning `[min_value, max_value]` with `per_decade` buckets
    /// per 10×.
    pub fn new(min_value: f64, max_value: f64, per_decade: usize) -> Self {
        let ratio = 10f64.powf(1.0 / per_decade as f64);
        let n_buckets = ((max_value / min_value).log10() * per_decade as f64).ceil() as usize + 2;
        Histogram {
            counts: vec![0; n_buckets],
            min_value,
            ratio,
            n: 0,
            sum: 0.0,
            max_seen: f64::NEG_INFINITY,
        }
    }

    /// Default latency histogram: 100 µs .. 1000 s, 20 buckets/decade.
    pub fn latency() -> Self {
        Self::new(1e-4, 1e3, 20)
    }

    fn bucket(&self, v: f64) -> usize {
        if v <= self.min_value {
            return 0;
        }
        let b = (v / self.min_value).log(self.ratio).floor() as usize + 1;
        b.min(self.counts.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        let b = self.bucket(v);
        self.counts[b] += 1;
        self.n += 1;
        self.sum += v;
        if v > self.max_seen {
            self.max_seen = v;
        }
    }

    /// Record `n` identical samples of `v` in O(1) — the bulk path the
    /// batched simulator core uses for a whole span's worth of
    /// uniform-gap TBT samples.
    pub fn record_n(&mut self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        let b = self.bucket(v);
        self.counts[b] += n;
        self.n += n;
        self.sum += v * n as f64;
        if v > self.max_seen {
            self.max_seen = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max_seen
        }
    }

    /// Value at quantile `q` in [0,1] (bucket upper bound).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.min_value * self.ratio.powi(i as i32);
            }
        }
        self.max_seen
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Exact empirical CDF (keeps all samples) — used to regenerate Fig 12.
#[derive(Debug, Clone, Default)]
pub struct Cdf {
    samples: Vec<f64>,
    sorted: bool,
}

impl Cdf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Fold another CDF's samples into this one — how the fleet layer
    /// aggregates per-replica latency distributions into one fleet-level
    /// distribution without losing exactness.
    pub fn merge(&mut self, other: &Cdf) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact quantile (linear interpolation).
    pub fn quantile(&mut self, q: f64) -> f64 {
        self.ensure_sorted();
        if self.samples.is_empty() {
            return 0.0;
        }
        let pos = q.clamp(0.0, 1.0) * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    /// `(value, cumulative_fraction)` points for plotting.
    pub fn points(&mut self) -> Vec<(f64, f64)> {
        self.ensure_sorted();
        let n = self.samples.len();
        self.samples
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, (i + 1) as f64 / n as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_close() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms .. 1s uniform
        }
        let p50 = h.p50();
        assert!((0.45..0.62).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((0.9..1.2).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn histogram_spans_decades() {
        let mut h = Histogram::latency();
        h.record(15e-3); // oracle recovery
        h.record(22.0); // recompute recovery
        assert_eq!(h.count(), 2);
        assert!(h.max() == 22.0);
        assert!(h.quantile(0.4) < 0.1);
    }

    #[test]
    fn cdf_merge_combines_samples() {
        let mut a = Cdf::new();
        let mut b = Cdf::new();
        for v in [1.0, 3.0] {
            a.record(v);
        }
        for v in [2.0, 4.0] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), 4);
        assert_eq!(a.quantile(0.0), 1.0);
        assert_eq!(a.quantile(1.0), 4.0);
        assert_eq!(a.quantile(0.5), 2.5);
    }

    #[test]
    fn cdf_exact() {
        let mut c = Cdf::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            c.record(v);
        }
        assert_eq!(c.quantile(0.0), 1.0);
        assert_eq!(c.quantile(1.0), 5.0);
        assert_eq!(c.quantile(0.5), 3.0);
        let pts = c.points();
        assert_eq!(pts.len(), 5);
        assert_eq!(pts[4], (5.0, 1.0));
    }
}
