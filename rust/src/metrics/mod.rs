//! Serving metrics: TTFT / TBT recorders, streaming percentiles, CDFs, and
//! throughput windows — the measurement vocabulary of the paper's §4.

mod histogram;
mod recorder;

pub use histogram::{Cdf, Histogram};
pub use recorder::{RequestMetrics, RequestOutcome, ServingMetrics, ThroughputWindow};
