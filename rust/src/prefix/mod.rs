//! Shared-prefix KV cache: a trie over token-block hashes whose nodes
//! are refcounted, copy-on-write references into the paged
//! [`crate::engine::KvStore`].
//!
//! At fleet scale most traffic shares system prompts and few-shot
//! prefixes. Re-prefilling them burns FLOPs, and keeping N private copies
//! resident burns HBM. This module caches each [`BLOCK_TOKENS`]-token
//! prompt chunk once:
//!
//! * [`PrefixTrie`] — nodes keyed on chunk hashes (exact chunk tokens
//!   stored and verified, so hash collisions cannot alias). A node caches
//!   the physical block its chunk occupies in **every** pool of the
//!   current epoch, holding one refcount on each (the store frees a block
//!   only when runs *and* the trie are done with it).
//! * Admission adopts a warm prefix's blocks instead of re-prefilling
//!   (zero prefill FLOPs, zero new KV blocks for the covered tokens);
//!   the first divergent append into a partially-used shared block
//!   CoW-splits it inside the store.
//! * The trie is an **epoch-scoped cache**: a failure wipe or reconfig
//!   calls [`PrefixTrie::invalidate_device`] (drop all device refs, keep
//!   the hash structure), recovery restores requests privately from
//!   their mirrors, then re-registers the first restored sharer as the
//!   donor and re-deduplicates the rest via
//!   [`crate::engine::KvStore::switch_to_shared`] — so sharing survives
//!   fail → shrink-reconfig → rejoin instead of decaying to N private
//!   copies.
//! * [`PrefixDirectory`] — the fleet front end's view: which replica
//!   last served each prefix chain, for prefix-affinity placement
//!   (a hash-only hint; a collision misroutes, never corrupts).
//!
//! The simulator mirrors the same trie without a `KvStore`, using
//! [`PrefixTrie::mark_resident`] for residency and its own byte
//! accounting (see `simulator/online.rs`).

use std::collections::HashMap;

use crate::engine::{KvStore, PoolId, BLOCK_TOKENS};

/// Handle to one trie node (one cached prompt chunk).
pub type NodeId = u32;

/// FNV-1a over a token chunk — deterministic across runs and platforms.
fn chunk_hash(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Running chain hash: parent chain ⊕ next chunk. Used by the fleet
/// directory, where a 64-bit key without token verification is fine
/// (placement hint only).
fn chain_hash(parent: u64, chunk: u64) -> u64 {
    parent.rotate_left(5) ^ chunk.wrapping_mul(0x9E3779B97F4A7C15)
}

#[derive(Debug)]
struct Node {
    /// The exact chunk tokens — lookups verify against these, so a hash
    /// collision degrades to a miss, never to wrong KV. (The trie edge
    /// `(parent, hash) → node` lives in the index map.)
    chunk: Vec<u32>,
    /// Physical block holding this chunk's rows, per pool of the epoch
    /// that registered it; one trie refcount is held on each. Empty while
    /// the device copy is lost (wiped / pre-registration).
    blocks: Vec<(PoolId, u32)>,
    resident: bool,
}

/// Cumulative counters — read by the `prefix` subcommand and the bench.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    pub lookups: u64,
    /// Lookups that matched at least one chunk.
    pub hits: u64,
    /// Prompt tokens covered by hits (prefill work avoided).
    pub hit_tokens: u64,
    pub inserted_chunks: u64,
    /// Nodes re-registered after a device wipe (recovery repairs).
    pub repairs: u64,
}

/// Result of matching a prompt against the trie.
#[derive(Debug, Clone, Default)]
pub struct PrefixMatch {
    /// Matched nodes, root-first — one per full prompt chunk found.
    pub nodes: Vec<NodeId>,
    /// Tokens the full match covers (`nodes.len() × BLOCK_TOKENS`).
    pub tokens: usize,
    /// Leading nodes whose device blocks are resident (adoptable now).
    pub live_nodes: usize,
    /// Tokens the resident leading run covers.
    pub live_tokens: usize,
}

/// The prefix trie. See module docs for the lifecycle
/// (share → diverge → split → release) and the reconfiguration contract.
#[derive(Debug, Default)]
pub struct PrefixTrie {
    nodes: Vec<Node>,
    index: HashMap<(Option<NodeId>, u64), NodeId>,
    stats: PrefixStats,
}

impl PrefixTrie {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Nodes whose device blocks are currently resident.
    pub fn resident_chunks(&self) -> usize {
        self.nodes.iter().filter(|n| n.resident).count()
    }

    /// Match `prompt`'s full [`BLOCK_TOKENS`] chunks against the trie.
    /// Counts stats; read-only otherwise.
    pub fn lookup(&mut self, prompt: &[u32]) -> PrefixMatch {
        self.stats.lookups += 1;
        let m = self.match_only(prompt);
        if !m.nodes.is_empty() {
            self.stats.hits += 1;
            self.stats.hit_tokens += m.live_tokens as u64;
        }
        m
    }

    /// [`PrefixTrie::lookup`] without touching the hit counters — used by
    /// recovery resharing, which revisits known chains rather than
    /// serving new traffic.
    pub fn match_only(&self, prompt: &[u32]) -> PrefixMatch {
        let mut m = PrefixMatch::default();
        let mut parent: Option<NodeId> = None;
        let mut live_run = true;
        for chunk in prompt.chunks_exact(BLOCK_TOKENS) {
            let h = chunk_hash(chunk);
            let Some(&id) = self.index.get(&(parent, h)) else { break };
            let node = &self.nodes[id as usize];
            if node.chunk != chunk {
                break; // hash collision — treat as a miss
            }
            m.nodes.push(id);
            live_run &= node.resident;
            if live_run {
                m.live_nodes += 1;
            }
            parent = Some(id);
        }
        m.tokens = m.nodes.len() * BLOCK_TOKENS;
        m.live_tokens = m.live_nodes * BLOCK_TOKENS;
        m
    }

    /// Find-or-create nodes for every full chunk of `prompt`; returns the
    /// chain root-first. New nodes start non-resident (no device blocks)
    /// until [`PrefixTrie::register_blocks`] / [`PrefixTrie::mark_resident`].
    pub fn insert(&mut self, prompt: &[u32]) -> Vec<NodeId> {
        let mut chain = Vec::new();
        let mut parent: Option<NodeId> = None;
        for chunk in prompt.chunks_exact(BLOCK_TOKENS) {
            let h = chunk_hash(chunk);
            let id = match self.index.get(&(parent, h)) {
                Some(&id) if self.nodes[id as usize].chunk == chunk => id,
                Some(_) => break, // collision slot taken — stop extending
                None => {
                    let id = self.nodes.len() as NodeId;
                    self.nodes.push(Node {
                        chunk: chunk.to_vec(),
                        blocks: Vec::new(),
                        resident: false,
                    });
                    self.index.insert((parent, h), id);
                    self.stats.inserted_chunks += 1;
                    id
                }
            };
            chain.push(id);
            parent = Some(id);
        }
        chain
    }

    /// True when `node`'s device blocks are resident (adoptable).
    pub fn is_resident(&self, node: NodeId) -> bool {
        self.nodes[node as usize].resident
    }

    /// The cached `(pool, block)` references of `node` (empty when not
    /// resident).
    pub fn node_blocks(&self, node: NodeId) -> &[(PoolId, u32)] {
        &self.nodes[node as usize].blocks
    }

    /// Cache `blocks` as `node`'s device copy, taking one reference on
    /// each in `kv`. No-op if the node is already resident. Counts as a
    /// repair when the node was previously wiped.
    pub fn register_blocks(
        &mut self,
        node: NodeId,
        kv: &mut KvStore,
        blocks: Vec<(PoolId, u32)>,
    ) {
        let n = &mut self.nodes[node as usize];
        if n.resident || blocks.is_empty() {
            return;
        }
        for &(pool, b) in &blocks {
            kv.retain_blocks(pool, &[b]);
        }
        n.blocks = blocks;
        n.resident = true;
    }

    /// Like [`PrefixTrie::register_blocks`] but flags the registration as
    /// a recovery repair (stats only).
    pub fn repair_blocks(&mut self, node: NodeId, kv: &mut KvStore, blocks: Vec<(PoolId, u32)>) {
        if !self.nodes[node as usize].resident {
            self.stats.repairs += 1;
        }
        self.register_blocks(node, kv, blocks);
    }

    /// Simulator-side residency (no physical blocks to pin).
    pub fn mark_resident(&mut self, node: NodeId) {
        self.nodes[node as usize].resident = true;
    }

    /// Drop every device reference the trie holds — called on failure
    /// wipes and before `relayout()` (the trie must never pin blocks of a
    /// stale epoch's pools). The hash structure survives, so recovery can
    /// repair nodes instead of relearning prefixes.
    pub fn invalidate_device(&mut self, kv: &mut KvStore) {
        for n in self.nodes.iter_mut() {
            for &(pool, b) in &n.blocks {
                kv.release_external(pool, &[b]);
            }
            n.blocks.clear();
            n.resident = false;
        }
    }

    /// Simulator-side flush: mark everything non-resident.
    pub fn invalidate_all(&mut self) {
        for n in self.nodes.iter_mut() {
            debug_assert!(n.blocks.is_empty(), "device refs flushed without a KvStore");
            n.resident = false;
        }
    }

    /// Release all device references and forget every node.
    pub fn clear(&mut self, kv: &mut KvStore) {
        self.invalidate_device(kv);
        self.nodes.clear();
        self.index.clear();
    }
}

/// Fleet front-end directory of prefix chains → the replica that last
/// served them. Pure hash index (no tokens kept): a collision can only
/// misroute a request to a colder replica, never corrupt state.
#[derive(Debug, Default)]
pub struct PrefixDirectory {
    /// Cumulative chain hash of chunks `0..=i` → replica.
    chains: HashMap<u64, usize>,
}

impl PrefixDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative chain hashes of `prompt`'s full chunks, root-first.
    fn hashes(prompt: &[u32]) -> Vec<u64> {
        let mut out = Vec::new();
        let mut h = 0u64;
        for chunk in prompt.chunks_exact(BLOCK_TOKENS) {
            h = chain_hash(h, chunk_hash(chunk));
            out.push(h);
        }
        out
    }

    /// Deepest known chain of `prompt` → `(replica, covered_tokens)`.
    pub fn lookup(&self, prompt: &[u32]) -> Option<(usize, usize)> {
        let mut best = None;
        for (i, h) in Self::hashes(prompt).iter().enumerate() {
            match self.chains.get(h) {
                Some(&replica) => best = Some((replica, (i + 1) * BLOCK_TOKENS)),
                None => break,
            }
        }
        best
    }

    /// Record that `replica` now holds `prompt`'s prefix chain (latest
    /// placement wins — deterministic).
    pub fn register(&mut self, prompt: &[u32], replica: usize) {
        for h in Self::hashes(prompt) {
            self.chains.insert(h, replica);
        }
    }

    /// Forget every chain pointing at `replica` (failure / drain — its
    /// cache is cold or gone).
    pub fn purge_replica(&mut self, replica: usize) {
        self.chains.retain(|_, &mut r| r != replica);
    }

    pub fn len(&self) -> usize {
        self.chains.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chains.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(prefix: u32, n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| prefix * 1000 + i).collect()
    }

    #[test]
    fn lookup_matches_full_chunks_only() {
        let mut trie = PrefixTrie::new();
        let p = prompt(1, BLOCK_TOKENS * 2 + 5);
        let chain = trie.insert(&p);
        assert_eq!(chain.len(), 2, "two full chunks, partial tail ignored");
        for &n in &chain {
            trie.mark_resident(n);
        }
        let m = trie.lookup(&p);
        assert_eq!(m.tokens, BLOCK_TOKENS * 2);
        assert_eq!(m.live_tokens, BLOCK_TOKENS * 2);
        // A divergent continuation still hits the shared prefix.
        let mut q = p[..BLOCK_TOKENS * 2].to_vec();
        q.extend([9999; 40]);
        assert_eq!(trie.lookup(&q).live_tokens, BLOCK_TOKENS * 2);
        // A different prefix misses.
        assert_eq!(trie.lookup(&prompt(2, BLOCK_TOKENS * 2)).tokens, 0);
    }

    #[test]
    fn non_resident_nodes_do_not_count_live() {
        let mut trie = PrefixTrie::new();
        let p = prompt(3, BLOCK_TOKENS * 3);
        let chain = trie.insert(&p);
        trie.mark_resident(chain[0]);
        trie.mark_resident(chain[2]); // gap at chunk 1
        let m = trie.lookup(&p);
        assert_eq!(m.nodes.len(), 3);
        assert_eq!(m.live_tokens, BLOCK_TOKENS, "live run stops at the gap");
        trie.invalidate_all();
        assert_eq!(trie.lookup(&p).live_tokens, 0);
        assert_eq!(trie.lookup(&p).tokens, BLOCK_TOKENS * 3, "structure survives the flush");
    }

    #[test]
    fn trie_refcounts_drain_through_kv() {
        let mut kv = KvStore::new(1);
        let pool = kv.pool_handle(0, &[0]);
        let rows = vec![1.0f32; BLOCK_TOKENS];
        kv.append_group(1, pool, 0, BLOCK_TOKENS, &rows, &rows, 1);
        let blocks = kv.prefix_blocks(1, pool, 1).unwrap();
        let mut trie = PrefixTrie::new();
        let p = prompt(1, BLOCK_TOKENS);
        let chain = trie.insert(&p);
        trie.register_blocks(chain[0], &mut kv, vec![(pool, blocks[0])]);
        kv.release(1);
        assert!(!kv.drained(), "trie still pins the donor's block");
        trie.invalidate_device(&mut kv);
        assert!(kv.drained(), "invalidate drops the last reference");
    }

    #[test]
    fn directory_prefers_deepest_chain() {
        let mut dir = PrefixDirectory::new();
        let p = prompt(7, BLOCK_TOKENS * 4);
        dir.register(&p[..BLOCK_TOKENS * 2], 0);
        dir.register(&p, 1);
        assert_eq!(dir.lookup(&p), Some((1, BLOCK_TOKENS * 4)));
        assert_eq!(dir.lookup(&p[..BLOCK_TOKENS * 2]), Some((1, BLOCK_TOKENS * 2)));
        dir.purge_replica(1);
        assert_eq!(dir.lookup(&p), None, "purged replica's chains are gone");
        assert_eq!(dir.lookup(&prompt(8, BLOCK_TOKENS)), None);
    }
}
