//! [`ModelSpec`]: the single source of truth for a model's architecture.


/// Architecture description of a llama-style (optionally MoE) transformer.
///
/// All byte/FLOP accounting in FailSafe derives from this struct, so the
/// sharding planner, the KV accountant, and the recovery latency model can
/// never disagree about sizes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Human-readable name, e.g. `"llama-3.1-70b"`.
    pub name: String,
    /// Number of transformer layers.
    pub n_layers: usize,
    /// Hidden (embedding) dimension.
    pub d_model: usize,
    /// Number of query heads per layer.
    pub n_q_heads: usize,
    /// Number of key/value heads per layer (GQA groups; == `n_q_heads` for MHA).
    pub n_kv_heads: usize,
    /// Per-head dimension (`d_model / n_q_heads` for standard llama).
    pub head_dim: usize,
    /// FFN intermediate dimension (per expert for MoE).
    pub d_ff: usize,
    /// Number of FFN experts (1 for dense models).
    pub n_experts: usize,
    /// Experts activated per token (1 for dense models).
    pub experts_per_token: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Bytes per parameter / activation element (2 for bf16).
    pub dtype_bytes: usize,
}

/// The distinct weight tensors of one transformer layer (plus embeddings),
/// used to enumerate shard contents and recovery byte ranges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Token embedding table `[vocab, d_model]` (replicated).
    Embedding,
    /// Attention input RMSNorm gain `[d_model]` (replicated).
    AttnNorm,
    /// Query projection `[d_model, n_q_heads * head_dim]` (head-sharded).
    Wq,
    /// Key projection `[d_model, n_kv_heads * head_dim]` (head-sharded).
    Wk,
    /// Value projection `[d_model, n_kv_heads * head_dim]` (head-sharded).
    Wv,
    /// Output projection `[n_q_heads * head_dim, d_model]` (head-sharded on rows).
    Wo,
    /// FFN input RMSNorm gain `[d_model]` (replicated).
    FfnNorm,
    /// FFN gate projection `[d_model, d_ff]` (column-sharded).
    WGate,
    /// FFN up projection `[d_model, d_ff]` (column-sharded).
    WUp,
    /// FFN down projection `[d_ff, d_model]` (row-sharded, matching columns).
    WDown,
    /// Final RMSNorm gain `[d_model]` (replicated).
    FinalNorm,
    /// LM head `[d_model, vocab]` (replicated in this system).
    LmHead,
}

/// Shape of a weight tensor, in elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub rows: usize,
    pub cols: usize,
}

impl TensorShape {
    pub fn elems(&self) -> usize {
        self.rows * self.cols
    }
}

impl ModelSpec {
    /// Shape of a given tensor kind (unsharded, single expert for FFN).
    pub fn tensor_shape(&self, kind: TensorKind) -> TensorShape {
        let qd = self.n_q_heads * self.head_dim;
        let kvd = self.n_kv_heads * self.head_dim;
        match kind {
            TensorKind::Embedding => TensorShape { rows: self.vocab, cols: self.d_model },
            TensorKind::AttnNorm | TensorKind::FfnNorm | TensorKind::FinalNorm => {
                TensorShape { rows: 1, cols: self.d_model }
            }
            TensorKind::Wq => TensorShape { rows: self.d_model, cols: qd },
            TensorKind::Wk | TensorKind::Wv => TensorShape { rows: self.d_model, cols: kvd },
            TensorKind::Wo => TensorShape { rows: qd, cols: self.d_model },
            TensorKind::WGate | TensorKind::WUp => {
                TensorShape { rows: self.d_model, cols: self.d_ff }
            }
            TensorKind::WDown => TensorShape { rows: self.d_ff, cols: self.d_model },
            TensorKind::LmHead => TensorShape { rows: self.d_model, cols: self.vocab },
        }
    }

    /// Query heads per KV head (GQA group size).
    pub fn gqa_group(&self) -> usize {
        debug_assert_eq!(self.n_q_heads % self.n_kv_heads, 0);
        self.n_q_heads / self.n_kv_heads
    }

    /// Total parameter count (all experts included).
    pub fn param_count(&self) -> usize {
        let per_layer_attn = self.tensor_shape(TensorKind::Wq).elems()
            + 2 * self.tensor_shape(TensorKind::Wk).elems()
            + self.tensor_shape(TensorKind::Wo).elems()
            + self.d_model; // attn norm
        let per_layer_ffn = self.n_experts
            * (2 * self.tensor_shape(TensorKind::WGate).elems()
                + self.tensor_shape(TensorKind::WDown).elems())
            + self.d_model; // ffn norm
        self.n_layers * (per_layer_attn + per_layer_ffn)
            + self.tensor_shape(TensorKind::Embedding).elems()
            + self.tensor_shape(TensorKind::LmHead).elems()
            + self.d_model // final norm
    }

    /// Total model weight bytes.
    pub fn weight_bytes(&self) -> usize {
        self.param_count() * self.dtype_bytes
    }

    /// Attention weight bytes **per KV-head group per layer**: the unit of
    /// head-granular sharding. Includes the q-heads of the GQA group and the
    /// matching `Wo` rows.
    pub fn head_group_weight_bytes(&self) -> usize {
        let q_cols = self.gqa_group() * self.head_dim; // q heads in this group
        let kv_cols = self.head_dim;
        let wq = self.d_model * q_cols;
        let wk = self.d_model * kv_cols;
        let wv = self.d_model * kv_cols;
        let wo = q_cols * self.d_model;
        (wq + wk + wv + wo) * self.dtype_bytes
    }

    /// FFN weight bytes per intermediate column, per layer (all experts the
    /// column appears in — i.e. one expert's column).
    pub fn ffn_col_weight_bytes(&self) -> usize {
        // gate + up: one column of [d_model, d_ff]; down: one row of [d_ff, d_model]
        3 * self.d_model * self.dtype_bytes
    }

    /// FFN weight bytes per layer (all experts).
    pub fn ffn_layer_weight_bytes(&self) -> usize {
        self.n_experts * self.d_ff * self.ffn_col_weight_bytes()
    }

    /// Attention weight bytes per layer (all head groups).
    pub fn attn_layer_weight_bytes(&self) -> usize {
        self.n_kv_heads * self.head_group_weight_bytes()
    }

    /// Replicated (unshardable) weight bytes: embeddings, norms, LM head.
    pub fn replicated_weight_bytes(&self) -> usize {
        (self.tensor_shape(TensorKind::Embedding).elems()
            + self.tensor_shape(TensorKind::LmHead).elems()
            + self.d_model * (2 * self.n_layers + 1))
            * self.dtype_bytes
    }

    /// KV cache bytes per token per KV head **for one layer**.
    pub fn kv_bytes_per_token_per_head_layer(&self) -> usize {
        2 * self.head_dim * self.dtype_bytes // K and V
    }

    /// KV cache bytes per token across all layers and KV heads.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.kv_bytes_per_token_per_head_layer()
    }

    /// Whether this is a mixture-of-experts model.
    pub fn is_moe(&self) -> bool {
        self.n_experts > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::*;

    #[test]
    fn llama70b_param_count_close_to_70b() {
        let m = llama3_70b();
        let p = m.param_count() as f64;
        assert!((6.5e10..7.5e10).contains(&p), "param count {p:.3e} not ~70B");
    }

    #[test]
    fn mixtral_param_count_close_to_141b() {
        let m = mixtral_8x22b();
        let p = m.param_count() as f64;
        assert!((1.3e11..1.5e11).contains(&p), "param count {p:.3e} not ~141B");
    }

    #[test]
    fn llama70b_kv_bytes_per_token() {
        let m = llama3_70b();
        // 80 layers * 8 kv heads * 2 (K,V) * 128 dim * 2 bytes = 327,680 B/token
        assert_eq!(m.kv_bytes_per_token(), 80 * 8 * 2 * 128 * 2);
    }

    #[test]
    fn shard_units_sum_to_total() {
        for m in [llama3_70b(), mixtral_8x22b(), small_real()] {
            let sharded = m.n_layers * (m.attn_layer_weight_bytes() + m.ffn_layer_weight_bytes());
            let total = sharded + m.replicated_weight_bytes();
            assert_eq!(total, m.weight_bytes(), "{}", m.name);
        }
    }

    #[test]
    fn gqa_group_divides() {
        assert_eq!(llama3_70b().gqa_group(), 8);
        assert_eq!(mixtral_8x22b().gqa_group(), 6);
        assert_eq!(small_real().gqa_group(), 1);
    }
}
