//! Model architecture math: parameter/byte accounting, FLOP counts, and
//! per-tensor shard slicing for non-uniform tensor parallelism.
//!
//! Everything downstream (the sharding planner, the KV cache accountant,
//! the performance simulator, the recovery latency model) is driven by the
//! numbers computed here, so this module is deliberately exact about shapes.

mod flops;
mod presets;
mod spec;

pub use flops::{AttnFlops, FfnFlops, StepFlops};
pub use presets::{llama3_70b, mixtral_8x22b, small_real};
pub use spec::{ModelSpec, TensorKind, TensorShape};
