//! FLOP accounting for prefill and decode steps.
//!
//! These formulas drive the performance simulator's step-time model. They
//! count multiply-accumulates as 2 FLOPs (the convention of every roofline
//! analysis the paper's comparisons rely on).

use super::ModelSpec;

/// Attention FLOPs for a (chunk, context) pair, per layer, split by
/// head-granular unit so non-uniform shards can be costed per rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnFlops {
    /// QKV + output projection FLOPs per KV-head group (GQA group), per layer.
    pub proj_per_head_group: f64,
    /// Score/softmax/value FLOPs per KV-head group, per layer
    /// (depends on chunk and context lengths).
    pub sdpa_per_head_group: f64,
}

impl AttnFlops {
    /// Total per head group.
    pub fn per_head_group(&self) -> f64 {
        self.proj_per_head_group + self.sdpa_per_head_group
    }
}

/// FFN FLOPs per layer, per intermediate column, so column-sharded
/// non-uniform partitions can be costed per rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FfnFlops {
    /// FLOPs per intermediate column per layer (gate+up+down), for the
    /// tokens in this step.
    pub per_col: f64,
    /// Number of *active* expert-columns per token (d_ff × experts_per_token).
    pub active_cols: f64,
}

/// FLOPs for one engine step (a prefill chunk batch or a decode batch).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepFlops {
    pub attn: AttnFlops,
    pub ffn: FfnFlops,
}

impl ModelSpec {
    /// Attention FLOPs per layer for processing `chunk` new tokens of a
    /// request that already has `context` tokens cached.
    ///
    /// Prefill attention cost over a chunk of size N after L cached tokens is
    /// O(N² + N·L) — the quadratic term the adaptive chunked prefill
    /// scheduler (Algorithm 1) balances.
    pub fn attn_flops(&self, chunk: usize, context: usize) -> AttnFlops {
        let n = chunk as f64;
        let l = context as f64;
        let d = self.d_model as f64;
        let hd = self.head_dim as f64;
        let g = self.gqa_group() as f64; // q heads per kv head

        // Projections per kv-head group: Wq (g q-heads) + Wk + Wv + Wo rows.
        let proj_cols = (g + 2.0) * hd; // q cols + k + v
        let proj = 2.0 * n * d * proj_cols + 2.0 * n * (g * hd) * d; // + Wo
        // SDPA: for each q head in group: scores n×(l+n̄) + AV. Causal chunk:
        // effective keys per query ≈ l + (n+1)/2.
        let keys = l + (n + 1.0) / 2.0;
        let sdpa = g * (2.0 * n * keys * hd) * 2.0; // QK^T and AV

        AttnFlops { proj_per_head_group: proj, sdpa_per_head_group: sdpa }
    }

    /// FFN FLOPs per layer for `tokens` tokens in a step.
    pub fn ffn_flops(&self, tokens: usize) -> FfnFlops {
        let t = tokens as f64;
        let d = self.d_model as f64;
        // gate + up + down: 3 matvecs of d per column, 2 FLOPs per MAC.
        let per_col = 2.0 * t * 3.0 * d;
        let active_cols = (self.d_ff * self.experts_per_token) as f64;
        FfnFlops { per_col, active_cols }
    }

    /// Total model FLOPs for a full prefill of `seq` tokens (all layers,
    /// all heads/columns) — used by the recompute-recovery cost model.
    pub fn prefill_total_flops(&self, seq: usize) -> f64 {
        let a = self.attn_flops(seq, 0);
        let f = self.ffn_flops(seq);
        self.n_layers as f64
            * (a.per_head_group() * self.n_kv_heads as f64 + f.per_col * f.active_cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::*;

    #[test]
    fn prefill_flops_scale_superlinearly() {
        let m = llama3_70b();
        let f1 = m.prefill_total_flops(1024);
        let f2 = m.prefill_total_flops(2048);
        assert!(f2 > 2.0 * f1, "prefill must be superlinear (attention quadratic)");
        assert!(f2 < 4.0 * f1);
    }

    #[test]
    fn llama70b_decode_flops_near_2x_params() {
        // Decode of 1 token with short context ≈ 2 × params FLOPs.
        let m = llama3_70b();
        let a = m.attn_flops(1, 0);
        let f = m.ffn_flops(1);
        let total = m.n_layers as f64
            * (a.per_head_group() * m.n_kv_heads as f64 + f.per_col * f.active_cols);
        let two_p = 2.0 * m.param_count() as f64;
        let ratio = total / two_p;
        assert!((0.8..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn moe_ffn_uses_active_experts_only() {
        let m = mixtral_8x22b();
        let f = m.ffn_flops(1);
        assert_eq!(f.active_cols as usize, m.d_ff * 2);
    }

    #[test]
    fn attn_context_term_linear() {
        let m = llama3_70b();
        let short = m.attn_flops(1, 1000).sdpa_per_head_group;
        let long = m.attn_flops(1, 2000).sdpa_per_head_group;
        assert!((long / short - 2.0).abs() < 0.01);
    }
}
