//! Architecture presets for the models evaluated in the paper, plus the
//! small real model actually served by the engine on CPU.

use super::ModelSpec;

/// LLaMA-3.1-70B-Instruct — the paper's dense model (§4).
///
/// 80 layers, d=8192, 64 query heads / 8 KV heads (GQA), head dim 128,
/// FFN 28672, vocab 128256, bf16.
pub fn llama3_70b() -> ModelSpec {
    ModelSpec {
        name: "llama-3.1-70b".into(),
        n_layers: 80,
        d_model: 8192,
        n_q_heads: 64,
        n_kv_heads: 8,
        head_dim: 128,
        d_ff: 28672,
        n_experts: 1,
        experts_per_token: 1,
        vocab: 128_256,
        dtype_bytes: 2,
    }
}

/// Mixtral-8x22B-Instruct-v0.1 — the paper's MoE model (§4).
///
/// 56 layers, d=6144, 48 query heads / 8 KV heads, head dim 128,
/// 8 experts × FFN 16384, top-2 routing, vocab 32768, bf16.
pub fn mixtral_8x22b() -> ModelSpec {
    ModelSpec {
        name: "mixtral-8x22b".into(),
        n_layers: 56,
        d_model: 6144,
        n_q_heads: 48,
        n_kv_heads: 8,
        head_dim: 128,
        d_ff: 16384,
        n_experts: 8,
        experts_per_token: 2,
        vocab: 32_768,
        dtype_bytes: 2,
    }
}

/// The small llama-style model that the *real* engine serves on CPU-PJRT.
///
/// Mirrors the property that matters for FailSafe — **8 KV heads**, the same
/// count as both paper models, so the non-uniform head-assignment math is
/// exercised with identical arithmetic (e.g. TP7 → 1 TP head + 1 DP head).
/// Weights are f32 because the CPU plugin path computes in f32.
pub fn small_real() -> ModelSpec {
    ModelSpec {
        name: "small-real".into(),
        n_layers: 4,
        d_model: 256,
        n_q_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 1024,
        n_experts: 1,
        experts_per_token: 1,
        vocab: 512,
        dtype_bytes: 4,
    }
}
