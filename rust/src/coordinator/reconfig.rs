//! The reconfiguration controller: device health → shard plan epochs.

use crate::cluster::{GpuSpec, Interconnect, Node};
use crate::kvcache::BackupStore;
use crate::model::ModelSpec;
use crate::recovery::{plan_recovery, RecoveryInput, RecoveryMethod, RecoveryOutcome};
use crate::simulator::SystemConfig;
use crate::sharding::ShardPlan;
use crate::{RankId, RequestId};

/// Result of one reconfiguration epoch.
#[derive(Debug)]
pub struct ReconfigOutcome {
    /// Epoch number after the change.
    pub epoch: u64,
    /// New world size.
    pub world: usize,
    /// Old-rank → new-rank map (None for the removed rank).
    pub survivor_map: Vec<Option<RankId>>,
    /// The recovery plan/cost that was applied.
    pub recovery: RecoveryOutcome,
}

/// Tracks the node's health, the active shard plan, and epochs. Every
/// failure or rejoin produces a new epoch with a recovery cost.
pub struct ReconfigController {
    pub node: Node,
    pub config: SystemConfig,
    pub model: ModelSpec,
    pub recovery_method: RecoveryMethod,
    plan: ShardPlan,
    epoch: u64,
    spec: GpuSpec,
    ic: Interconnect,
}

impl ReconfigController {
    pub fn new(model: ModelSpec, config: SystemConfig, n_devices: usize, spec: GpuSpec) -> Self {
        let node = Node::new(n_devices, spec.clone());
        let plan = config.plan(&model, n_devices);
        let ic = Interconnect::new(spec.clone());
        ReconfigController {
            node,
            config,
            model,
            recovery_method: RecoveryMethod::Full,
            plan,
            epoch: 0,
            spec,
            ic,
        }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn world(&self) -> usize {
        self.plan.world()
    }

    /// Handle a hard failure of physical device `device_id`.
    ///
    /// `requests` = in-flight (id, context tokens, home rank) — the state
    /// whose loss must be recovered. Returns the new epoch's outcome, or
    /// `None` if the device was already down.
    pub fn on_device_failed(
        &mut self,
        device_id: usize,
        requests: &[(RequestId, usize, RankId)],
        backup: &BackupStore,
    ) -> Option<ReconfigOutcome> {
        // Which TP rank did this device carry?
        let failed_rank = self.node.healthy_ids().iter().position(|&d| d == device_id)?;
        if !self.node.device(device_id).is_healthy() {
            return None;
        }
        self.node.device_mut(device_id).fail();
        let new_world = self.world() - 1;

        // Commutative policy keeps surviving FFN blocks in place.
        let (new_plan, survivor_map) = self.plan.shrink(failed_rank);

        let input = RecoveryInput {
            spec: &self.spec,
            ic: &self.ic,
            old_plan: &self.plan,
            new_plan: &new_plan,
            survivor_map: &survivor_map,
            failed_rank,
            requests,
            backup,
        };
        let recovery = plan_recovery(self.recovery_method, &input);

        self.plan = new_plan;
        self.epoch += 1;
        self.apply_weight_accounting();
        Some(ReconfigOutcome { epoch: self.epoch, world: new_world, survivor_map, recovery })
    }

    /// Handle a device rejoining (restored from maintenance). The new rank
    /// is appended at the end of the rank order; weights stream in from
    /// host + peers like a recovery in reverse.
    pub fn on_device_recovered(
        &mut self,
        device_id: usize,
        backup: &BackupStore,
    ) -> Option<ReconfigOutcome> {
        if self.node.device(device_id).is_healthy() {
            return None;
        }
        let old_world = self.world();
        self.node.device_mut(device_id).recover();
        let new_world = old_world + 1;

        // Existing ranks keep their ids if their device order allows; the
        // controller re-derives ranks from healthy device order, so compute
        // the old→new map through device ids.
        let new_ids = self.node.healthy_ids();
        let old_ids: Vec<usize> = new_ids.iter().copied().filter(|&d| d != device_id).collect();
        let survivor_map: Vec<Option<RankId>> = old_ids
            .iter()
            .map(|d| new_ids.iter().position(|x| x == d))
            .collect();

        let new_plan = ShardPlan {
            model: self.model.clone(),
            heads: crate::sharding::HeadAssignment::new(
                self.config.attn,
                self.model.n_kv_heads,
                self.model.n_layers,
                new_world,
            ),
            ffn: self.plan.ffn.reshard(&survivor_map, new_world),
        };
        let input = RecoveryInput {
            spec: &self.spec,
            ic: &self.ic,
            old_plan: &self.plan,
            new_plan: &new_plan,
            survivor_map: &survivor_map,
            failed_rank: usize::MAX, // nothing lost on a rejoin
            requests: &[],
            backup,
        };
        let recovery = plan_recovery(self.recovery_method, &input);

        self.plan = new_plan;
        self.epoch += 1;
        self.apply_weight_accounting();
        Some(ReconfigOutcome { epoch: self.epoch, world: new_world, survivor_map, recovery })
    }

    /// Push the plan's per-rank weight bytes into the node's HBM accounting.
    fn apply_weight_accounting(&mut self) {
        let loads = self.plan.rank_loads();
        let ids = self.node.healthy_ids();
        for (rank, &dev) in ids.iter().enumerate() {
            self.node.device_mut(dev).weight_bytes = loads[rank].weight_bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;

    fn controller() -> ReconfigController {
        let mut c = ReconfigController::new(
            llama3_70b(),
            SystemConfig::failsafe(),
            8,
            GpuSpec::h100(),
        );
        c.recovery_method = RecoveryMethod::Full;
        c
    }

    #[test]
    fn failure_shrinks_world_and_costs_recovery() {
        let mut c = controller();
        let backup = BackupStore::new(1 << 42);
        let reqs: Vec<(RequestId, usize, RankId)> =
            (0..20).map(|i| (i, 4000, (i % 8) as usize)).collect();
        let out = c.on_device_failed(3, &reqs, &backup).unwrap();
        assert_eq!(out.world, 7);
        assert_eq!(c.world(), 7);
        assert_eq!(c.epoch(), 1);
        assert_eq!(out.survivor_map[3], None);
        assert_eq!(out.survivor_map[4], Some(3));
        assert!(out.recovery.total_s > 0.0);
        // Node accounting updated.
        assert_eq!(c.node.n_healthy(), 7);
        assert!(c.node.device(4).weight_bytes > 0);
        assert_eq!(c.node.device(3).weight_bytes, 0);
    }

    #[test]
    fn double_failure_handled() {
        let mut c = controller();
        let backup = BackupStore::new(1 << 42);
        c.on_device_failed(0, &[], &backup).unwrap();
        let out = c.on_device_failed(7, &[], &backup).unwrap();
        assert_eq!(out.world, 6);
        assert_eq!(c.epoch(), 2);
        // Device 7 was rank 6 after the first failure.
        assert_eq!(out.survivor_map.len(), 7);
        assert_eq!(out.survivor_map[6], None);
    }

    #[test]
    fn failed_device_id_second_time_is_none() {
        let mut c = controller();
        let backup = BackupStore::new(1 << 42);
        assert!(c.on_device_failed(2, &[], &backup).is_some());
        assert!(c.on_device_failed(2, &[], &backup).is_none());
    }

    #[test]
    fn rejoin_restores_world() {
        let mut c = controller();
        let backup = BackupStore::new(1 << 42);
        c.on_device_failed(5, &[], &backup).unwrap();
        let out = c.on_device_recovered(5, &backup).unwrap();
        assert_eq!(out.world, 8);
        assert_eq!(c.world(), 8);
        assert_eq!(c.node.n_healthy(), 8);
        // The rejoining device streams a full shard's worth — all of it
        // available from surviving peers, so on-demand recovery uses pure
        // NVLink and zero PCIe (faster than any host reload).
        assert!(out.recovery.weight_delta.max_nvlink() > 0);
        assert_eq!(out.recovery.weight_delta.total_pcie(), 0);
    }

    #[test]
    fn recovery_faster_with_full_than_recompute() {
        let backup = {
            let mut b = BackupStore::new(1 << 42);
            let m = llama3_70b();
            for i in 0..20u64 {
                b.backup(i, 4000, m.kv_bytes_per_token());
            }
            b
        };
        let reqs: Vec<(RequestId, usize, RankId)> =
            (0..20).map(|i| (i, 4000, (i % 8) as usize)).collect();

        let mut c1 = controller();
        c1.recovery_method = RecoveryMethod::Recompute;
        let slow = c1.on_device_failed(1, &reqs, &backup).unwrap();

        let mut c2 = controller();
        c2.recovery_method = RecoveryMethod::Full;
        let fast = c2.on_device_failed(1, &reqs, &backup).unwrap();

        assert!(slow.recovery.total_s > 10.0 * fast.recovery.total_s);
    }
}
