//! Request lifecycle state machine.

use crate::{RankId, RequestId, SimTime};

/// Lifecycle of a serving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Arrived, waiting for admission.
    Queued,
    /// Prefill in progress (context < input length).
    Prefilling,
    /// Decoding (one token per step).
    Decoding,
    /// Preempted by the SLO scheduler: device KV released to the host
    /// swap tier (mirror authoritative); resumes into `Decoding` via
    /// swap-in, never recompute.
    Swapped,
    /// All output tokens produced.
    Finished,
    /// Cancelled by the client before finishing; resources released.
    Aborted,
}

/// A request as tracked by the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub arrival: SimTime,
    /// Prompt tokens (the real engine stores the actual ids; the
    /// simulators only need the count).
    pub input_tokens: Vec<u32>,
    /// Generation budget (max new tokens).
    pub max_new_tokens: usize,
    pub state: RequestState,
    /// Home DP rank (valid once routed).
    pub home: RankId,
    /// Tokens currently represented in KV (prefilled + decoded).
    pub context: usize,
    /// Decoded output so far (engine fills real token ids).
    pub output_tokens: Vec<u32>,
    /// Scheduling priority (higher runs first; default 0).
    pub priority: i32,
    /// Optional SLO deadline on the serving clock; among equal priorities
    /// the earliest deadline is scheduled first.
    pub deadline: Option<SimTime>,
}

impl Request {
    pub fn new(id: RequestId, arrival: SimTime, input_tokens: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            arrival,
            input_tokens,
            max_new_tokens,
            state: RequestState::Queued,
            home: 0,
            context: 0,
            output_tokens: Vec::new(),
            priority: 0,
            deadline: None,
        }
    }

    pub fn input_len(&self) -> usize {
        self.input_tokens.len()
    }

    /// Prefill tokens still to process.
    pub fn prefill_remaining(&self) -> usize {
        self.input_len().saturating_sub(self.context)
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, RequestState::Finished | RequestState::Aborted)
    }

    /// Advance state after a prefill chunk of `n` tokens.
    pub fn on_prefilled(&mut self, n: usize) {
        debug_assert!(n <= self.prefill_remaining());
        self.context += n;
        self.state = if self.prefill_remaining() == 0 {
            RequestState::Decoding
        } else {
            RequestState::Prefilling
        };
    }

    /// Record a decoded token.
    pub fn on_decoded(&mut self, token: u32) {
        self.context += 1;
        self.output_tokens.push(token);
        if self.output_tokens.len() >= self.max_new_tokens {
            self.state = RequestState::Finished;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut r = Request::new(1, 0.0, vec![1, 2, 3, 4], 2);
        assert_eq!(r.state, RequestState::Queued);
        assert_eq!(r.prefill_remaining(), 4);
        r.state = RequestState::Prefilling;
        r.on_prefilled(3);
        assert_eq!(r.state, RequestState::Prefilling);
        r.on_prefilled(1);
        assert_eq!(r.state, RequestState::Decoding);
        assert_eq!(r.context, 4);
        r.on_decoded(7);
        assert_eq!(r.state, RequestState::Decoding);
        r.on_decoded(8);
        assert_eq!(r.state, RequestState::Finished);
        assert_eq!(r.output_tokens, vec![7, 8]);
        assert_eq!(r.context, 6);
    }

    #[test]
    fn aborted_counts_as_done() {
        let mut r = Request::new(2, 0.0, vec![1, 2], 4);
        assert!(!r.is_done());
        r.state = RequestState::Aborted;
        assert!(r.is_done());
    }
}
