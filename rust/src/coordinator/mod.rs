//! The coordinator: request lifecycle state and the reconfiguration
//! controller that reacts to device failures/recoveries by re-planning
//! shards, costing recovery, and re-homing orphaned requests.
//!
//! This is the leader-side brain shared by the real engine
//! ([`crate::engine`]) and the simulators: the engine executes its
//! decisions against PJRT, the simulators against the cost model.
//!
//! A request walks `Queued → Prefilling → Decoding → Finished` (or
//! `Aborted`), with every transition driven by the owning session:
//!
//! ```
//! use failsafe::coordinator::{Request, RequestState};
//!
//! let mut req = Request::new(7, 0.0, vec![1, 2, 3], 2);
//! assert_eq!(req.state, RequestState::Queued);
//! req.state = RequestState::Prefilling;  // admission: a router picks `home`
//! req.on_prefilled(3);                   // whole prompt processed…
//! assert_eq!(req.state, RequestState::Decoding); // …so decode begins
//! req.on_decoded(42);
//! req.on_decoded(43);                    // generation budget (2) reached
//! assert_eq!(req.state, RequestState::Finished);
//! assert_eq!(req.output_tokens, vec![42, 43]);
//! assert!(req.is_done());
//! ```

mod reconfig;
mod request;

pub use reconfig::{ReconfigController, ReconfigOutcome};
pub use request::{Request, RequestState};
