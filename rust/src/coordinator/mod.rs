//! The coordinator: request lifecycle state and the reconfiguration
//! controller that reacts to device failures/recoveries by re-planning
//! shards, costing recovery, and re-homing orphaned requests.
//!
//! This is the leader-side brain shared by the real engine
//! ([`crate::engine`]) and the simulators: the engine executes its
//! decisions against PJRT, the simulators against the cost model.

mod reconfig;
mod request;

pub use reconfig::{ReconfigController, ReconfigOutcome};
pub use request::{Request, RequestState};
