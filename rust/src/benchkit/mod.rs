//! Benchmark + property-test harness (offline stand-in for `criterion`
//! and `proptest`).
//!
//! Each paper table/figure bench is a `harness = false` binary that uses
//! [`Bench`] for wall-clock micro-measurements and prints paper-vs-measured
//! rows. [`forall`] gives proptest-style randomized property sweeps with
//! seed reporting on failure.

use std::time::Instant;

use crate::util::Rng;

/// Timing statistics of one measured routine.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        );
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Micro-benchmark runner: warms up, then samples batches until the time
/// budget is spent.
pub struct Bench {
    /// Total sampling budget per routine.
    pub budget: std::time::Duration,
    /// Warm-up time before sampling.
    pub warmup: std::time::Duration,
}

impl Default for Bench {
    /// 700 ms budget / 150 ms warmup, overridable via the
    /// `FAILSAFE_BENCH_MS` env var (budget in ms; warmup scales to ~1/5)
    /// — how the CI smoke job runs the hotpath bench in a few seconds.
    fn default() -> Self {
        if let Some(ms) = std::env::var("FAILSAFE_BENCH_MS").ok().and_then(|v| v.parse().ok()) {
            let ms: u64 = ms;
            return Bench {
                budget: std::time::Duration::from_millis(ms.max(1)),
                warmup: std::time::Duration::from_millis((ms / 5).max(1)),
            };
        }
        Bench {
            budget: std::time::Duration::from_millis(700),
            warmup: std::time::Duration::from_millis(150),
        }
    }
}

impl Bench {
    /// Measure `f`, treating each call as one iteration. `black_box` the
    /// result inside `f` yourself if needed — [`sink`] helps.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warm-up.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed() < self.budget {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            iters += 1;
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let q = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: q(0.5),
            p99_ns: q(0.99),
            min_ns: samples_ns[0],
        };
        m.report();
        m
    }
}

/// Opaque value sink preventing the optimizer from deleting a computation.
#[inline]
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// Collects [`Measurement`]s and writes them as machine-readable JSON so
/// the perf trajectory is tracked across PRs (`BENCH_<name>.json` at the
/// repo root — regenerate by running the bench, compare across commits).
#[derive(Debug, Default)]
pub struct BenchLog {
    pub measurements: Vec<Measurement>,
}

impl BenchLog {
    pub fn new() -> Self {
        BenchLog::default()
    }

    /// Measure `f` through `bench` and record the result.
    pub fn run<F: FnMut()>(&mut self, bench: &Bench, name: &str, f: F) -> &Measurement {
        let m = bench.run(name, f);
        self.measurements.push(m);
        self.measurements.last().expect("just pushed")
    }

    /// Record a *derived* quantity (e.g. a modeled step time) in
    /// nanoseconds rather than a wall-clock sample: one "iteration" whose
    /// every quantile is the value. Keeps analytic results (the
    /// straggler sweep's modeled throughput gap) in the same
    /// `BENCH_*.json` trajectory as measured ones.
    pub fn record_ns(&mut self, name: &str, ns: f64) -> &Measurement {
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            p50_ns: ns,
            p99_ns: ns,
            min_ns: ns,
        };
        m.report();
        self.measurements.push(m);
        self.measurements.last().expect("just pushed")
    }

    /// Record a dimensionless ratio `numer / denom` (e.g. stepper
    /// iterations over event-core spans for the same workload) as a
    /// result row: the ratio lands in `ns_per_iter` (the tracked value
    /// column) with one "iteration", same shape as [`BenchLog::record_ns`]
    /// rows, so ratio trajectories live in the same `BENCH_*.json` files.
    pub fn record_ratio(&mut self, name: &str, numer: f64, denom: f64) -> &Measurement {
        let ratio = if denom == 0.0 { 0.0 } else { numer / denom };
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_ns: ratio,
            p50_ns: ratio,
            p99_ns: ratio,
            min_ns: ratio,
        };
        println!("{:<44} ratio {ratio:>12.1}×  ({numer:.0} / {denom:.0})", m.name);
        self.measurements.push(m);
        self.measurements.last().expect("just pushed")
    }

    /// Serialize to JSON: `{"bench": ..., "results": [{name, iters,
    /// ns_per_iter, p50_ns, p99_ns, min_ns}, ...]}`. Hand-rolled — the
    /// offline build has no serde.
    pub fn to_json(&self, bench_name: &str) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench_name)));
        s.push_str("  \"results\": [\n");
        for (i, m) in self.measurements.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_iter\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"min_ns\": {:.1}}}{}\n",
                json_escape(&m.name),
                m.iters,
                m.mean_ns,
                m.p50_ns,
                m.p99_ns,
                m.min_ns,
                if i + 1 < self.measurements.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path` (creating or overwriting it).
    pub fn write_json(&self, bench_name: &str, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(bench_name))
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Property-test sweep: run `prop` over `cases` randomized cases derived
/// from a seeded RNG; on failure, panic with the failing case seed so it
/// can be replayed exactly.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, cases: u64, base_seed: u64, mut prop: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case);
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at case {case} (replay seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Section header for figure benches.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// One paper-vs-measured comparison row.
pub fn paper_row(label: &str, paper: &str, measured: &str, ok: bool) {
    println!(
        "{:<46} paper: {:>14}   measured: {:>14}   [{}]",
        label,
        paper,
        measured,
        if ok { "OK" } else { "MISMATCH" }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            budget: std::time::Duration::from_millis(30),
            warmup: std::time::Duration::from_millis(5),
        };
        let m = b.run("noop-ish", || {
            sink((0..100).sum::<u64>());
        });
        assert!(m.iters > 10);
        assert!(m.mean_ns > 0.0);
    }

    #[test]
    fn bench_log_emits_json() {
        let b = Bench {
            budget: std::time::Duration::from_millis(10),
            warmup: std::time::Duration::from_millis(2),
        };
        let mut log = BenchLog::new();
        log.run(&b, "spin \"quoted\"", || {
            sink((0..50).sum::<u64>());
        });
        let json = log.to_json("hotpath");
        assert!(json.contains("\"bench\": \"hotpath\""));
        assert!(json.contains("spin \\\"quoted\\\""));
        assert!(json.contains("\"ns_per_iter\""));
        // Parse-light sanity: balanced braces/brackets.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall("counts", 25, 7, |_rng| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall("fails", 10, 7, |rng| {
            assert!(rng.f64() < 0.5, "will eventually fail");
        });
    }
}
