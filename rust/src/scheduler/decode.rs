//! Continuous decode batching.
//!
//! Every running request contributes one token per decode step. The batch
//! former's job is capacity admission (KV pool headroom on the *tightest*
//! rank — the synchronized-TP constraint of §2.2.1) and exposing the
//! per-rank DP attention composition so the step-time model (or the real
//! engine) can cost the straggler.


use crate::{RankId, RequestId};

/// One running request in the decode pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeItem {
    pub request: RequestId,
    /// Home DP rank (stores/computes the replicated heads for this request).
    pub rank: RankId,
    /// Current context length (tokens in KV).
    pub context: usize,
}

/// A formed decode step.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeBatch {
    pub items: Vec<DecodeItem>,
    /// Sum of context lengths of requests homed on each rank — the DP
    /// attention work profile of the step.
    pub dp_context_per_rank: Vec<usize>,
    /// Sum of all context lengths (the TP attention work, identical shape
    /// on every rank since TP heads see every request).
    pub total_context: usize,
}

impl DecodeBatch {
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// DP imbalance: max/mean of per-rank DP context (1.0 = flat). The
    /// quantity the load-aware router minimizes over time.
    pub fn dp_imbalance(&self) -> f64 {
        let w = self.dp_context_per_rank.len().max(1);
        let mean = self.dp_context_per_rank.iter().sum::<usize>() as f64 / w as f64;
        if mean == 0.0 {
            return 1.0;
        }
        self.dp_context_per_rank.iter().copied().max().unwrap_or(0) as f64 / mean
    }
}

/// Form the next decode batch from the running pool, admitting at most
/// `max_batch` requests (engine limit) in pool order. `world` sizes the
/// DP profile vector.
pub fn form_decode_batch(pool: &[DecodeItem], max_batch: usize, world: usize) -> DecodeBatch {
    let items: Vec<DecodeItem> = pool.iter().copied().take(max_batch).collect();
    let mut dp = vec![0usize; world];
    let mut total = 0usize;
    for it in &items {
        dp[it.rank] += it.context;
        total += it.context;
    }
    DecodeBatch { items, dp_context_per_rank: dp, total_context: total }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_respects_max() {
        let pool: Vec<DecodeItem> = (0..100)
            .map(|i| DecodeItem { request: i, rank: (i % 4) as usize, context: 128 })
            .collect();
        let b = form_decode_batch(&pool, 32, 4);
        assert_eq!(b.len(), 32);
        assert_eq!(b.total_context, 32 * 128);
    }

    #[test]
    fn dp_profile_tracks_homes() {
        let pool = vec![
            DecodeItem { request: 0, rank: 0, context: 100 },
            DecodeItem { request: 1, rank: 0, context: 200 },
            DecodeItem { request: 2, rank: 2, context: 50 },
        ];
        let b = form_decode_batch(&pool, 8, 3);
        assert_eq!(b.dp_context_per_rank, vec![300, 0, 50]);
        assert!(b.dp_imbalance() > 2.0);
    }

    #[test]
    fn empty_pool() {
        let b = form_decode_batch(&[], 8, 4);
        assert!(b.is_empty());
        assert_eq!(b.dp_imbalance(), 1.0);
    }
}
