//! DP-aware adaptive chunked prefill — the paper's Algorithm 1 — plus the
//! FIFO baseline it replaces.


use crate::{RankId, RequestId};

/// A request with prefill work pending, as seen by the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillItem {
    pub request: RequestId,
    /// Home DP rank chosen by the router.
    pub rank: RankId,
    /// Tokens already prefilled (the `L` in the chunk cost O(N² + NL + N)).
    pub context: usize,
    /// Prefill tokens still to process.
    pub remaining: usize,
}

/// Chunk of one request scheduled into the next batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub request: RequestId,
    pub rank: RankId,
    pub tokens: usize,
}

/// The formed prefill batch with its per-rank cost profile.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefillBatch {
    pub chunks: Vec<ChunkAssignment>,
    /// Estimated DP cost booked per rank (token-units, incl. carry-in).
    pub rank_load: Vec<f64>,
    /// Total tokens scheduled.
    pub tokens: usize,
}

impl PrefillBatch {
    /// Makespan estimate: the straggler rank's load.
    pub fn makespan(&self) -> f64 {
        self.rank_load.iter().cloned().fold(0.0, f64::max)
    }

    /// Balance ratio max/mean (1.0 = flat).
    pub fn imbalance(&self) -> f64 {
        let mean = self.rank_load.iter().sum::<f64>() / self.rank_load.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.makespan() / mean
        }
    }
}

/// Incremental cost of the next token of a request whose effective context
/// (already-prefilled + already-scheduled-this-batch) is `ctx`.
///
/// Prefill attention for a chunk of size N after L tokens costs
/// O(N² + N·L + N); the per-token marginal cost is linear in the running
/// context. `CTX_COST` converts context tokens into token-units so that a
/// context-free token costs 1.
const CTX_COST: f64 = 1.0 / 512.0; // attention context weight per token

#[inline]
pub(crate) fn token_cost(ctx: usize) -> f64 {
    1.0 + ctx as f64 * CTX_COST
}

/// Paper Algorithm 1: iteratively give the next token to the least-loaded
/// rank's oldest schedulable request, recording candidate batches; return
/// the best candidate (here: the largest batch whose imbalance does not
/// exceed `MAX_IMBALANCE`, falling back to the full fill).
///
/// `carry[r]` = work already queued on rank r before this batch (decode
/// carry and previous chunks) so chronic stragglers receive fewer tokens.
/// `granule` trades scheduling fidelity for speed (1 = exact Algorithm 1).
pub fn adaptive_chunked_prefill(
    budget: usize,
    items: &[PrefillItem],
    carry: &[f64],
    world: usize,
    granule: usize,
) -> PrefillBatch {
    assert_eq!(carry.len(), world);
    let granule = granule.max(1);

    // Per-rank FIFO queues of (item index, remaining, effective ctx).
    let mut queues: Vec<std::collections::VecDeque<(usize, usize, usize)>> =
        vec![std::collections::VecDeque::new(); world];
    for (i, it) in items.iter().enumerate() {
        if it.remaining > 0 {
            queues[it.rank].push_back((i, it.remaining, it.context));
        }
    }

    let mut load: Vec<f64> = carry.to_vec();
    let mut total = 0usize;

    // Allocation log: (item index, rank, tokens, cost). Candidate prefixes
    // of Algorithm 1's `H` set are cuts into this log — O(1) to remember,
    // one replay at the end (snapshotting per step would clone O(items)
    // per token; see EXPERIMENTS.md §Perf).
    let mut log: Vec<(usize, usize, usize, f64)> = Vec::new();
    let mut best_cut = 0usize; // log length of the best balanced candidate
    let mut sum_load: f64 = carry.iter().sum();
    // Loads only grow, so the running max is maintainable in O(1).
    let mut max_load: f64 = carry.iter().cloned().fold(0.0, f64::max);
    const MAX_IMBALANCE: f64 = 1.25;

    while total < budget {
        // Least-loaded rank that still has schedulable tokens.
        let r = match (0..world)
            .filter(|&r| !queues[r].is_empty())
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
        {
            Some(r) => r,
            None => break,
        };
        let (i, remaining, ctx) = queues[r].front_mut().map(|e| (e.0, e.1, e.2)).unwrap();
        let take = granule.min(remaining).min(budget - total);
        // Closed-form cost of `take` tokens with linearly growing context:
        // Σ token_cost(ctx+k) = take + (ctx·take + take(take−1)/2)·CTX.
        let cost = take as f64
            + (ctx as f64 * take as f64 + (take * (take - 1)) as f64 / 2.0) * CTX_COST;
        load[r] += cost;
        sum_load += cost;
        total += take;
        log.push((i, r, take, cost));
        {
            let e = queues[r].front_mut().unwrap();
            e.1 -= take;
            e.2 += take;
            if e.1 == 0 {
                queues[r].pop_front();
            }
        }

        // Candidate bookkeeping (the `H` set): mark this prefix if balanced.
        max_load = max_load.max(load[r]);
        let mean = sum_load / world as f64;
        if mean == 0.0 || max_load / mean <= MAX_IMBALANCE {
            best_cut = log.len();
        }
    }

    // choose_best_batch(H): the largest balanced prefix; if none was
    // balanced (e.g. one rank hogs all requests), take the full fill —
    // progress beats stalling.
    let cut = if best_cut > 0 { best_cut } else { log.len() };
    let mut sched: Vec<usize> = vec![0; items.len()];
    let mut load: Vec<f64> = carry.to_vec();
    for &(i, r, take, cost) in &log[..cut] {
        sched[i] += take;
        load[r] += cost;
    }

    let chunks = items
        .iter()
        .enumerate()
        .filter(|&(i, _)| sched[i] > 0)
        .map(|(i, it)| ChunkAssignment { request: it.request, rank: it.rank, tokens: sched[i] })
        .collect::<Vec<_>>();
    let tokens = chunks.iter().map(|c| c.tokens).sum();
    PrefillBatch { chunks, rank_load: load, tokens }
}

/// The conventional baseline (Fig 3 top): fill the budget with chunks in
/// strict FIFO arrival order, one request at a time, ignoring rank loads.
pub fn fifo_chunked_prefill(
    budget: usize,
    items: &[PrefillItem],
    carry: &[f64],
    world: usize,
) -> PrefillBatch {
    assert_eq!(carry.len(), world);
    let mut load: Vec<f64> = carry.to_vec();
    let mut chunks = Vec::new();
    let mut total = 0usize;
    for it in items {
        if total >= budget {
            break;
        }
        let take = it.remaining.min(budget - total);
        if take == 0 {
            continue;
        }
        let mut cost = 0.0;
        for k in 0..take {
            cost += token_cost(it.context + k);
        }
        load[it.rank] += cost;
        chunks.push(ChunkAssignment { request: it.request, rank: it.rank, tokens: take });
        total += take;
    }
    PrefillBatch { chunks, rank_load: load, tokens: total }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items_fig3() -> Vec<PrefillItem> {
        // Fig 3: request 0 has 4 tokens (rank 0), requests 1 and 2 have 1
        // token (ranks 1, 2), new request 3 with 1 token. Budget 3.
        vec![
            PrefillItem { request: 0, rank: 0, context: 0, remaining: 4 },
            PrefillItem { request: 1, rank: 1, context: 0, remaining: 1 },
            PrefillItem { request: 2, rank: 2, context: 0, remaining: 1 },
            PrefillItem { request: 3, rank: 1, context: 0, remaining: 1 },
        ]
    }

    #[test]
    fn fig3_naive_overloads_gpu0() {
        let b = fifo_chunked_prefill(3, &items_fig3(), &[0.0; 3], 3);
        // FIFO spends the whole budget on request 0's chunk.
        assert_eq!(b.chunks.len(), 1);
        assert_eq!(b.chunks[0].request, 0);
        assert_eq!(b.chunks[0].tokens, 3);
        assert!(b.imbalance() > 2.0, "imbalance {}", b.imbalance());
    }

    #[test]
    fn fig3_adaptive_balances() {
        let b = adaptive_chunked_prefill(3, &items_fig3(), &[0.0; 3], 3, 1);
        // Adaptive spreads one token to each rank.
        assert_eq!(b.tokens, 3);
        assert!(b.imbalance() < 1.1, "imbalance {} chunks {:?}", b.imbalance(), b.chunks);
        let ranks: Vec<RankId> = b.chunks.iter().map(|c| c.rank).collect();
        assert!(ranks.contains(&0) && ranks.contains(&1) && ranks.contains(&2));
    }

    #[test]
    fn budget_respected() {
        let items: Vec<PrefillItem> = (0..10)
            .map(|i| PrefillItem { request: i, rank: (i % 4) as usize, context: 0, remaining: 100 })
            .collect();
        let b = adaptive_chunked_prefill(64, &items, &[0.0; 4], 4, 1);
        assert!(b.tokens <= 64);
        assert_eq!(b.tokens, 64);
    }

    #[test]
    fn context_makes_tokens_expensive() {
        // A long-context request's tokens cost more, so the adaptive
        // scheduler gives the rank hosting it fewer of them.
        let items = vec![
            PrefillItem { request: 0, rank: 0, context: 8192, remaining: 100 },
            PrefillItem { request: 1, rank: 1, context: 0, remaining: 100 },
        ];
        let b = adaptive_chunked_prefill(100, &items, &[0.0; 2], 2, 1);
        let t0: usize =
            b.chunks.iter().filter(|c| c.request == 0).map(|c| c.tokens).sum();
        let t1: usize =
            b.chunks.iter().filter(|c| c.request == 1).map(|c| c.tokens).sum();
        assert!(t1 > 2 * t0, "cheap request should get more tokens: {t0} vs {t1}");
        assert!(b.imbalance() < 1.3);
    }

    #[test]
    fn carry_in_respected() {
        // Rank 0 is already busy: the batch should favor rank 1.
        let items = vec![
            PrefillItem { request: 0, rank: 0, context: 0, remaining: 50 },
            PrefillItem { request: 1, rank: 1, context: 0, remaining: 50 },
        ];
        let b = adaptive_chunked_prefill(50, &items, &[40.0, 0.0], 2, 1);
        let t0: usize = b.chunks.iter().filter(|c| c.rank == 0).map(|c| c.tokens).sum();
        let t1: usize = b.chunks.iter().filter(|c| c.rank == 1).map(|c| c.tokens).sum();
        assert!(t1 > t0, "busy rank must receive fewer tokens ({t0} vs {t1})");
    }

    #[test]
    fn granule_speedup_preserves_balance() {
        let items: Vec<PrefillItem> = (0..32)
            .map(|i| PrefillItem {
                request: i,
                rank: (i % 8) as usize,
                context: (i as usize * 97) % 4096,
                remaining: 64 + (i as usize * 37) % 512,
            })
            .collect();
        let exact = adaptive_chunked_prefill(2048, &items, &[0.0; 8], 8, 1);
        let fast = adaptive_chunked_prefill(2048, &items, &[0.0; 8], 8, 16);
        assert!(fast.imbalance() < exact.imbalance() * 1.15 + 0.1);
        assert_eq!(fast.tokens, exact.tokens);
    }

    #[test]
    fn empty_items_empty_batch() {
        let b = adaptive_chunked_prefill(128, &[], &[0.0; 4], 4, 1);
        assert_eq!(b.tokens, 0);
        assert!(b.chunks.is_empty());
    }
}
