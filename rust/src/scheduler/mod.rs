//! Batch forming: chunked prefill (paper Algorithm 1) and continuous
//! decode batching.
//!
//! The prefill scheduler decides *which tokens of which requests* run in
//! the next prefill step, under a global token budget `N` (bounding
//! intermediate activation memory). The naive policy fills the budget in
//! FIFO order — one request's chunk can consume the whole budget and leave
//! every other DP rank idle (Fig 3 top). FailSafe's **DP-aware adaptive
//! chunked prefill** allocates token by token to the least-loaded rank and
//! keeps the per-rank makespan flat (Fig 3 bottom).

mod chunked_prefill;
mod decode;

pub use chunked_prefill::{
    adaptive_chunked_prefill, fifo_chunked_prefill, ChunkAssignment, PrefillBatch, PrefillItem,
};
pub use decode::{form_decode_batch, DecodeBatch, DecodeItem};
