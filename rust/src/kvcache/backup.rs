//! Proactive KVCache backup to host DRAM (§3.2).
//!
//! During normal operation the backup store asynchronously mirrors KV
//! blocks to host memory (write-behind: the GPU copy is authoritative, the
//! host copy trails by the tokens generated since the last backup pass).
//! On failure, the surviving ranks restore **only the lost subset** from
//! host; tokens produced after the last backup must still be recomputed,
//! so the backup cadence bounds recomputation.

use std::collections::HashMap;


use super::placement::KvPlacement;
use crate::{RankId, RequestId};

/// Host-DRAM mirror of request KV state.
#[derive(Debug, Clone, Default)]
pub struct BackupStore {
    /// Tokens backed up per request (host copy is a prefix of the KV).
    backed: HashMap<RequestId, usize>,
    /// Total bytes resident in host DRAM.
    pub host_bytes: usize,
    /// Capacity limit (host DRAM reserved for backup).
    pub capacity_bytes: usize,
}

/// The restore work after a failure: per-rank bytes to pull from host over
/// PCIe, plus tokens whose KV was produced after the last backup and must
/// be recomputed.
#[derive(Debug, Clone, PartialEq)]
pub struct RestorePlan {
    /// `pcie_bytes[r]` — backup bytes rank r pulls from host.
    pub pcie_bytes: Vec<usize>,
    /// Tokens per request that must be re-prefilled (backup lag).
    pub recompute_tokens: HashMap<RequestId, usize>,
    /// Total lost bytes covered by the backup.
    pub restored_bytes: usize,
}

impl BackupStore {
    pub fn new(capacity_bytes: usize) -> Self {
        BackupStore { backed: HashMap::new(), host_bytes: 0, capacity_bytes }
    }

    /// Record a backup pass for `req`: host now mirrors the first `tokens`
    /// tokens. `bytes_per_token` = full-model KV bytes per token. Returns
    /// the bytes written (the increment), or `None` if capacity would be
    /// exceeded (backup skipped — the request simply stays recompute-bound).
    pub fn backup(&mut self, req: RequestId, tokens: usize, bytes_per_token: usize) -> Option<usize> {
        let prev = self.backed.get(&req).copied().unwrap_or(0);
        if tokens <= prev {
            return Some(0);
        }
        let inc = (tokens - prev) * bytes_per_token;
        if self.host_bytes + inc > self.capacity_bytes {
            return None;
        }
        self.host_bytes += inc;
        self.backed.insert(req, tokens);
        Some(inc)
    }

    /// Tokens currently mirrored for `req`.
    pub fn backed_tokens(&self, req: RequestId) -> usize {
        self.backed.get(&req).copied().unwrap_or(0)
    }

    /// Drop a finished request's backup.
    pub fn release(&mut self, req: RequestId, bytes_per_token: usize) {
        if let Some(tokens) = self.backed.remove(&req) {
            self.host_bytes = self.host_bytes.saturating_sub(tokens * bytes_per_token);
        }
    }

    /// Plan the restore after rank `failed_rank` (old numbering) is lost.
    ///
    /// `requests` = (id, current_tokens, home_rank in *old* numbering).
    /// `placement_old` gives where KV lived pre-failure; `placement_new` +
    /// `survivor_map` decide which surviving rank pulls each lost slice.
    /// Thanks to cyclic placement, the lost slices spread evenly over the
    /// new ranks, balancing PCIe restore bandwidth (§3.2).
    pub fn plan_restore(
        &self,
        failed_rank: RankId,
        requests: &[(RequestId, usize, RankId)],
        placement_old: &KvPlacement,
        placement_new: &KvPlacement,
        survivor_map: &[Option<RankId>],
    ) -> RestorePlan {
        let new_world = placement_new.plan().world();
        let kvb = placement_old.plan().model.kv_bytes_per_token_per_head_layer();
        let mut pcie = vec![0usize; new_world];
        let mut recompute = HashMap::new();
        let mut restored = 0usize;

        for &(req, tokens, old_home) in requests {
            let backed = self.backed_tokens(req).min(tokens);
            let lag = tokens - backed;
            if lag > 0 {
                recompute.insert(req, lag);
            }
            if backed == 0 {
                continue;
            }
            // New home: survivor renumbering (failed home → reassigned later
            // by the router; for restore accounting, home 0 is fine because
            // DP KV of a failed home is part of the lost set either way).
            let new_home = survivor_map.get(old_home).copied().flatten().unwrap_or(0);
            let old_plan = placement_old.plan();
            for layer in 0..old_plan.model.n_layers {
                for head in 0..old_plan.model.n_kv_heads {
                    let old_rank = placement_old.rank_for(layer, head, old_home);
                    if old_rank != failed_rank {
                        continue; // slice survived on its device
                    }
                    // Lost slice: the *new* owner pulls it from host.
                    let new_rank = placement_new.rank_for(layer, head, new_home);
                    let bytes = backed * kvb;
                    pcie[new_rank] += bytes;
                    restored += bytes;
                }
            }
        }
        RestorePlan { pcie_bytes: pcie, recompute_tokens: recompute, restored_bytes: restored }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;
    use crate::sharding::ShardPlan;

    fn fail_rank_map(w: usize, f: usize) -> Vec<Option<RankId>> {
        (0..w)
            .map(|r| if r == f { None } else { Some(if r < f { r } else { r - 1 }) })
            .collect()
    }

    #[test]
    fn backup_tracks_increments() {
        let mut s = BackupStore::new(1 << 40);
        assert_eq!(s.backup(1, 100, 1000), Some(100_000));
        assert_eq!(s.backup(1, 150, 1000), Some(50_000));
        assert_eq!(s.backup(1, 150, 1000), Some(0));
        assert_eq!(s.host_bytes, 150_000);
        s.release(1, 1000);
        assert_eq!(s.host_bytes, 0);
    }

    #[test]
    fn capacity_limit_skips() {
        let mut s = BackupStore::new(1000);
        assert_eq!(s.backup(1, 1, 800), Some(800));
        assert_eq!(s.backup(2, 1, 800), None);
        assert_eq!(s.backed_tokens(2), 0);
    }

    #[test]
    fn restore_covers_lost_and_flags_lag() {
        let m = llama3_70b();
        let p8 = KvPlacement::new(&ShardPlan::failsafe(&m, 8));
        let p7 = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let mut s = BackupStore::new(1 << 42);
        let kv_per_token = m.kv_bytes_per_token();
        // 10 requests, 1000 tokens each, backed to 900.
        let reqs: Vec<(RequestId, usize, RankId)> =
            (0..10).map(|i| (i as RequestId, 1000, (i % 8) as RankId)).collect();
        for &(id, _, _) in &reqs {
            s.backup(id, 900, kv_per_token);
        }
        let map = fail_rank_map(8, 3);
        let plan = s.plan_restore(3, &reqs, &p8, &p7, &map);
        assert!(plan.restored_bytes > 0);
        assert_eq!(plan.recompute_tokens.len(), 10);
        assert!(plan.recompute_tokens.values().all(|&t| t == 100));
        // Cyclic placement spreads the restore across ranks.
        let nonzero = plan.pcie_bytes.iter().filter(|&&b| b > 0).count();
        assert!(nonzero >= 6, "restore should be spread, got {:?}", plan.pcie_bytes);
    }

    #[test]
    fn restore_balanced_under_cyclic() {
        let m = llama3_70b();
        let p8 = KvPlacement::new(&ShardPlan::failsafe(&m, 8));
        let p7 = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let mut s = BackupStore::new(1 << 42);
        let reqs: Vec<(RequestId, usize, RankId)> =
            (0..56).map(|i| (i as RequestId, 2000, (i % 8) as RankId)).collect();
        for &(id, t, _) in &reqs {
            s.backup(id, t, m.kv_bytes_per_token());
        }
        let map = fail_rank_map(8, 0);
        let plan = s.plan_restore(0, &reqs, &p8, &p7, &map);
        let max = *plan.pcie_bytes.iter().max().unwrap() as f64;
        let mean = plan.pcie_bytes.iter().sum::<usize>() as f64 / 7.0;
        assert!(max / mean < 1.6, "restore imbalance {max}/{mean}");
    }
}
