//! Per-device paged block allocator.
//!
//! Tracks KV block occupancy on one device. Blocks are the vLLM-style
//! paging unit; the allocator only does accounting (free list + owner map)
//! — actual tensor storage lives with the engine or is simulated.

use std::collections::HashMap;


use crate::RequestId;

/// Index of a block within one device's KV pool.
pub type BlockId = u32;

/// Allocation failure: the device pool is exhausted. Under synchronized TP
/// this stalls the *whole group* — which is exactly why cyclic placement's
/// capacity balancing matters (§2.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocError {
    pub requested: usize,
    pub available: usize,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV pool exhausted: requested {} blocks, {} free", self.requested, self.available)
    }
}

impl std::error::Error for AllocError {}

/// Block accounting for one device's KV pool.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    n_blocks: usize,
    free: Vec<BlockId>,
    /// Blocks held by each request on this device.
    held: HashMap<RequestId, Vec<BlockId>>,
}

impl BlockAllocator {
    /// Pool with `n_blocks` blocks.
    pub fn new(n_blocks: usize) -> Self {
        BlockAllocator {
            n_blocks,
            // Pop order: descending ids; purely cosmetic.
            free: (0..n_blocks as BlockId).rev().collect(),
            held: HashMap::new(),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_used(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Allocate `n` blocks for `req`. All-or-nothing.
    pub fn alloc(&mut self, req: RequestId, n: usize) -> Result<Vec<BlockId>, AllocError> {
        if self.free.len() < n {
            return Err(AllocError { requested: n, available: self.free.len() });
        }
        let at = self.free.len() - n;
        let blocks: Vec<BlockId> = self.free.split_off(at);
        // Stale-reuse guard: a block handed out must not still be on the
        // free list or registered to any holder — either would mean two
        // owners share (and clobber) the same physical rows. O(free+held)
        // scans, so debug builds only.
        debug_assert!(
            blocks.iter().all(|b| !self.free.contains(b)),
            "allocator handed out a block still on the free list"
        );
        debug_assert!(
            blocks
                .iter()
                .all(|b| self.held.values().all(|held| !held.contains(b))),
            "allocator handed out a block another request still holds"
        );
        self.held.entry(req).or_default().extend(&blocks);
        Ok(blocks)
    }

    /// Release all blocks of `req` (request finished or evicted).
    ///
    /// Freed blocks re-enter the free list in **descending id order**
    /// (matching the initial fill), so within one freed batch the
    /// lowest id is reused first and allocation order is a deterministic
    /// function of the alloc/free history — never of map iteration or
    /// insertion order.
    pub fn free_request(&mut self, req: RequestId) -> usize {
        match self.held.remove(&req) {
            Some(mut blocks) => {
                let n = blocks.len();
                // Double-free guard: a freed block must not already be on
                // the free list (the held map prevents the same request
                // double-freeing, but a stale id crossing requests would
                // land here).
                debug_assert!(
                    blocks.iter().all(|b| !self.free.contains(b)),
                    "double free: request {req} released a block already free"
                );
                blocks.sort_unstable_by(|a, b| b.cmp(a));
                self.free.append(&mut blocks);
                debug_assert!(
                    self.free.len() + self.held.values().map(Vec::len).sum::<usize>()
                        == self.n_blocks,
                    "block conservation violated after freeing request {req}"
                );
                n
            }
            None => 0,
        }
    }

    /// Blocks currently held by `req`.
    pub fn blocks_of(&self, req: RequestId) -> &[BlockId] {
        self.held.get(&req).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Requests with at least one block here.
    pub fn requests(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.held.keys().copied()
    }

    /// Drop everything (device failed: HBM contents lost).
    pub fn wipe(&mut self) {
        self.held.clear();
        self.free = (0..self.n_blocks as BlockId).rev().collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = BlockAllocator::new(10);
        let b1 = a.alloc(1, 4).unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(a.n_free(), 6);
        let _b2 = a.alloc(2, 6).unwrap();
        assert_eq!(a.n_free(), 0);
        assert!(a.alloc(3, 1).is_err());
        assert_eq!(a.free_request(1), 4);
        assert_eq!(a.n_free(), 4);
        assert!(a.alloc(3, 4).is_ok());
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4);
        let err = a.alloc(1, 5).unwrap_err();
        assert_eq!(err.requested, 5);
        assert_eq!(err.available, 4);
        assert_eq!(a.n_free(), 4, "failed alloc must not leak");
    }

    #[test]
    fn no_double_allocation() {
        let mut a = BlockAllocator::new(64);
        let b1 = a.alloc(1, 32).unwrap();
        let b2 = a.alloc(2, 32).unwrap();
        let mut all: Vec<BlockId> = b1.into_iter().chain(b2).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64);
    }

    #[test]
    fn free_order_is_defined() {
        let mut a = BlockAllocator::new(8);
        let first = a.alloc(1, 3).unwrap();
        let _hold = a.alloc(2, 2).unwrap();
        a.free_request(1);
        // Freed blocks come back lowest-id-first: a re-alloc of the same
        // size sees exactly the same blocks, independent of history.
        let again = a.alloc(3, 3).unwrap();
        assert_eq!(again, first, "freed blocks are reused lowest-id first");
        assert_eq!(again.last(), again.iter().min(), "pop order ends on the lowest id");
    }

    /// Hardening regression: random-ish alloc/free churn (including
    /// double `free_request` calls and failed allocs) conserves blocks,
    /// never aliases two holders, and trips none of the debug
    /// assertions.
    #[test]
    fn churn_conserves_blocks_and_never_aliases() {
        let mut a = BlockAllocator::new(24);
        let mut live: Vec<RequestId> = Vec::new();
        for round in 0..300u64 {
            match round % 5 {
                0 | 1 | 3 => {
                    if a.alloc(round, 1 + (round as usize * 7 % 5)).is_ok() {
                        live.push(round);
                    }
                }
                2 => {
                    if let Some(r) = live.first().copied() {
                        assert!(a.free_request(r) > 0);
                        live.retain(|&x| x != r);
                        // Freeing again is a no-op, not a corruption.
                        assert_eq!(a.free_request(r), 0);
                    }
                }
                _ => {
                    if let Some(r) = live.last().copied() {
                        a.free_request(r);
                        live.pop();
                    }
                }
            }
            let mut held: Vec<BlockId> =
                live.iter().flat_map(|&r| a.blocks_of(r).to_vec()).collect();
            let n_held = held.len();
            held.sort_unstable();
            held.dedup();
            assert_eq!(held.len(), n_held, "two holders share a block");
            assert_eq!(a.n_free() + n_held, 24, "block conservation");
        }
    }

    #[test]
    fn wipe_resets() {
        let mut a = BlockAllocator::new(8);
        a.alloc(1, 8).unwrap();
        a.wipe();
        assert_eq!(a.n_free(), 8);
        assert_eq!(a.blocks_of(1), &[]);
    }
}
