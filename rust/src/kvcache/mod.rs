//! Paged KVCache management: block allocation per device, head-granular
//! placement (following the shard plan's cyclic map), and the host-DRAM
//! backup store behind FailSafe's proactive KVCache backup (§3.2).

mod allocator;
mod backup;
mod placement;

pub use allocator::{AllocError, BlockAllocator, BlockId};
pub use backup::{BackupStore, RestorePlan};
pub use placement::{KvPlacement, RequestKvFootprint};

/// Tokens per KV block (vLLM-style paging granularity).
pub const BLOCK_TOKENS: usize = 16;
