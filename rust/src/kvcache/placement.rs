//! Head-granular KV placement: which rank stores which (layer, head) KV
//! slice of a request, following the shard plan's head assignment.
//!
//! TP-head KV lives on the owning rank; DP-head KV lives on the request's
//! *home* rank (the DP rank the router chose). Cyclic rotation of TP
//! ownership is what evens the TP component out across devices (Fig 1).


use crate::sharding::{ShardPlan, DP_OWNER};
use crate::{RankId, RequestId};

/// Per-rank KV footprint of one request, in bytes, given its token count.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestKvFootprint {
    pub request: RequestId,
    pub tokens: usize,
    pub home: RankId,
    /// `bytes[r]` = KV bytes of this request resident on rank r.
    pub bytes: Vec<usize>,
}

/// Placement calculator bound to a shard plan.
#[derive(Debug, Clone)]
pub struct KvPlacement {
    plan: ShardPlan,
    /// Pre-computed per-rank TP head-layer counts.
    tp_head_layers: Vec<usize>,
    dp_head_layers: usize,
}

impl KvPlacement {
    pub fn new(plan: &ShardPlan) -> Self {
        let tp_head_layers =
            (0..plan.world()).map(|r| plan.heads.tp_head_layers_of(r)).collect();
        let dp_head_layers = plan.heads.dp_heads_per_layer() * plan.model.n_layers;
        KvPlacement { plan: plan.clone(), tp_head_layers, dp_head_layers }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Rank storing KV for head `head` of layer `layer` of a request homed
    /// on `home`.
    pub fn rank_for(&self, layer: usize, head: usize, home: RankId) -> RankId {
        let owner = self.plan.heads.layers[layer].owner[head];
        if owner == DP_OWNER {
            home
        } else {
            owner
        }
    }

    /// Full per-rank byte footprint for a request of `tokens` tokens.
    pub fn footprint(&self, request: RequestId, tokens: usize, home: RankId) -> RequestKvFootprint {
        let kvb = self.plan.model.kv_bytes_per_token_per_head_layer();
        let mut bytes: Vec<usize> =
            self.tp_head_layers.iter().map(|&hl| hl * kvb * tokens).collect();
        bytes[home] += self.dp_head_layers * kvb * tokens;
        RequestKvFootprint { request, tokens, home, bytes }
    }

    /// KV bytes lost when device holding rank `rank` fails, for a request
    /// of `tokens` tokens homed on `home`.
    pub fn lost_bytes(&self, rank: RankId, tokens: usize, home: RankId) -> usize {
        self.footprint(0, tokens, home).bytes[rank]
    }

    /// Bytes each *new-plan* rank receives when one request's KV is
    /// re-spread from this placement onto `new` (same home rank): for every
    /// (layer, head) whose owner changes, the slice's bytes land on the new
    /// owner. This is the per-request cost of re-spreading cyclic KV
    /// placement onto a rejoining GPU — under cyclic/hybrid plans the new
    /// rank absorbs ≈ `1/new_world` of the resident KV and every other
    /// rank's share shrinks accordingly.
    pub fn respread_bytes(&self, new: &KvPlacement, tokens: usize, home: RankId) -> Vec<usize> {
        let kvb = self.plan.model.kv_bytes_per_token_per_head_layer() * tokens;
        let mut recv = vec![0usize; new.plan.world()];
        for layer in 0..self.plan.model.n_layers {
            for head in 0..self.plan.heads.n_heads {
                let old_rank = self.rank_for(layer, head, home);
                let new_rank = new.rank_for(layer, head, home);
                if old_rank != new_rank {
                    recv[new_rank] += kvb;
                }
            }
        }
        recv
    }

    /// Imbalance ratio of per-rank KV for an even mix of requests: max/mean
    /// of per-rank bytes when each rank homes the same token count. 1.0 is
    /// perfect balance.
    pub fn imbalance(&self) -> f64 {
        let w = self.plan.world();
        let kvb = self.plan.model.kv_bytes_per_token_per_head_layer() as f64;
        let per_rank: Vec<f64> = (0..w)
            .map(|r| (self.tp_head_layers[r] as f64 + self.dp_head_layers as f64 / w as f64) * kvb)
            .collect();
        let mean = per_rank.iter().sum::<f64>() / w as f64;
        let max = per_rank.iter().cloned().fold(0.0, f64::max);
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;
    use crate::sharding::{AttentionPolicy, FfnPolicy};

    #[test]
    fn failsafe_tp7_balanced_naive_skewed() {
        let m = llama3_70b();
        let fs = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let nv = KvPlacement::new(&ShardPlan::nonuniform_naive(&m, 7));
        assert!(fs.imbalance() < 1.01, "failsafe imbalance {}", fs.imbalance());
        assert!(nv.imbalance() > 1.5, "naive imbalance {}", nv.imbalance());
    }

    #[test]
    fn footprint_sums_to_total_kv() {
        let m = llama3_70b();
        let p = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let fp = p.footprint(1, 1000, 3);
        let total: usize = fp.bytes.iter().sum();
        assert_eq!(total, m.kv_bytes_per_token() * 1000);
    }

    #[test]
    fn dp_kv_lands_on_home() {
        let m = llama3_70b();
        let p = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let fp_home2 = p.footprint(1, 100, 2);
        let fp_home5 = p.footprint(1, 100, 5);
        assert!(fp_home2.bytes[2] > fp_home5.bytes[2]);
        assert!(fp_home5.bytes[5] > fp_home2.bytes[5]);
    }

    #[test]
    fn cyclic_without_hybrid_still_balances_memory() {
        let m = llama3_70b();
        let plan = ShardPlan::new(&m, 7, AttentionPolicy::Cyclic, FfnPolicy::Commutative);
        let p = KvPlacement::new(&plan);
        assert!(p.imbalance() < 1.01, "cyclic imbalance {}", p.imbalance());
    }

    #[test]
    fn respread_targets_the_joining_rank() {
        let m = llama3_70b();
        let p7 = KvPlacement::new(&ShardPlan::failsafe(&m, 7));
        let (plan8, _) = ShardPlan::failsafe(&m, 7).expand();
        let p8 = KvPlacement::new(&plan8);
        let recv = p7.respread_bytes(&p8, 1000, 2);
        assert_eq!(recv.len(), 8);
        // The joining rank (7) held nothing, so it must receive KV.
        assert!(recv[7] > 0, "joining rank receives its cyclic share: {recv:?}");
        let total: usize = recv.iter().sum();
        let full = m.kv_bytes_per_token() * 1000;
        assert!(total <= full, "re-spread can never move more than the whole cache");
        // Identity re-spread is free.
        assert!(p7.respread_bytes(&p7, 1000, 2).iter().all(|&b| b == 0));
    }

    #[test]
    fn rank_for_respects_ownership() {
        let m = llama3_70b();
        let plan = ShardPlan::failsafe(&m, 7);
        let p = KvPlacement::new(&plan);
        for layer in 0..4 {
            for head in 0..m.n_kv_heads {
                let owner = plan.heads.layers[layer].owner[head];
                let r = p.rank_for(layer, head, 6);
                if owner == crate::sharding::DP_OWNER {
                    assert_eq!(r, 6);
                } else {
                    assert_eq!(r, owner);
                }
            }
        }
    }
}
