//! The recovery latency model.

use crate::cluster::{GpuSpec, Interconnect, TransferClass};
use crate::kvcache::{BackupStore, KvPlacement, RestorePlan};
use crate::sharding::{plan_reconfig, ReconfigDelta, ShardPlan};
use crate::{RankId, RequestId};

/// Recovery strategy (§4.3.3 nomenclature).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RecoveryMethod {
    /// Regenerate lost KV by re-running prefill; reload all re-sharded
    /// weights — the standard fault-handling practice.
    Recompute,
    /// FailSafe-Host: restore backed-up KV from host DRAM instead of
    /// recomputing (still reloads full re-sharded weights).
    Host,
    /// FailSafe-Full: Host + joint on-demand weight loading (no redundant
    /// PCIe transfers, NVLink peer exchange).
    Full,
    /// Idealized floor: restore only metadata.
    Oracle,
}

impl RecoveryMethod {
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryMethod::Recompute => "Recompute",
            RecoveryMethod::Host => "FailSafe-Host",
            RecoveryMethod::Full => "FailSafe-Full",
            RecoveryMethod::Oracle => "FailSafe-Oracle",
        }
    }
}

/// Everything the planner needs to cost a recovery.
pub struct RecoveryInput<'a> {
    pub spec: &'a GpuSpec,
    pub ic: &'a Interconnect,
    /// Shard plan before the failure (old world).
    pub old_plan: &'a ShardPlan,
    /// Shard plan after the failure (new world).
    pub new_plan: &'a ShardPlan,
    /// `survivor_map[old_rank] = Some(new_rank)` / `None` for the failed rank.
    pub survivor_map: &'a [Option<RankId>],
    /// The failed rank (old numbering).
    pub failed_rank: RankId,
    /// In-flight requests: (id, current tokens, home rank in old numbering).
    pub requests: &'a [(RequestId, usize, RankId)],
    /// The proactive backup state (empty store ⇒ everything recomputes).
    pub backup: &'a BackupStore,
}

/// Costed recovery decision.
#[derive(Debug, Clone)]
pub struct RecoveryOutcome {
    pub method: RecoveryMethod,
    /// Time to restore model weights.
    pub weight_time_s: f64,
    /// Time to restore backed-up KV from host.
    pub kv_restore_time_s: f64,
    /// Time to recompute KV not covered by backup.
    pub recompute_time_s: f64,
    /// End-to-end GPU state recovery latency (incl. the software floor).
    pub total_s: f64,
    /// The weight movement plan (empty for Oracle).
    pub weight_delta: ReconfigDelta,
    /// The KV restore plan, if the method restores from host.
    pub kv_restore: Option<RestorePlan>,
}

/// Time to re-prefill `tokens_by_request` contexts on the new (reduced)
/// configuration. Prefill is compute-bound; the whole group works on it.
fn recompute_time(
    input: &RecoveryInput<'_>,
    tokens_by_request: impl Iterator<Item = usize>,
) -> f64 {
    let model = &input.new_plan.model;
    let total_flops: f64 = tokens_by_request.map(|t| model.prefill_total_flops(t)).sum();
    let world_flops = input.new_plan.world() as f64 * input.spec.effective_flops();
    if total_flops == 0.0 {
        0.0
    } else {
        total_flops / world_flops
    }
}

/// Weight reload time from a reconfig delta: the PCIe phase is per-device
/// parallel (max over ranks); NVLink redistribution overlaps with PCIe
/// streaming (§3.2: "the synchronization overhead is minimal and can be
/// overlapped"), so the total is the max of the two phases per rank.
fn weight_time(input: &RecoveryInput<'_>, delta: &ReconfigDelta) -> f64 {
    let pcie = input.ic.parallel_transfer_time(TransferClass::PcieHost, delta.max_pcie());
    let nvl = input.ic.parallel_transfer_time(TransferClass::NvLink, delta.max_nvlink());
    pcie.max(nvl)
}

/// The conventional weight path (§3.2): "when the TP world size changes,
/// existing shards misalign with new ranks, forcing **full-shard
/// reloads**" — every rank re-pulls its entire sharded weights (attention
/// head groups + FFN blocks; replicated tensors stay resident) over PCIe.
fn full_reload_delta(input: &RecoveryInput<'_>) -> ReconfigDelta {
    let world = input.new_plan.world();
    let repl = input.new_plan.model.replicated_weight_bytes();
    let pcie_bytes: Vec<usize> = (0..world)
        .map(|r| input.new_plan.rank_load(r).weight_bytes - repl)
        .collect();
    ReconfigDelta {
        pcie_bytes,
        nvlink_recv_bytes: vec![0; world],
        nvlink_send_bytes: vec![0; world],
        lost_bytes: 0,
    }
}

/// KV restore time: per-rank host pulls proceed in parallel over each
/// device's own PCIe link; cyclic placement balances `pcie_bytes`.
fn kv_restore_time(input: &RecoveryInput<'_>, plan: &RestorePlan) -> f64 {
    let max = plan.pcie_bytes.iter().copied().max().unwrap_or(0);
    input.ic.parallel_transfer_time(TransferClass::PcieHost, max)
}

/// Cost a recovery under `method`. Pure planning — nothing is moved.
pub fn plan_recovery(method: RecoveryMethod, input: &RecoveryInput<'_>) -> RecoveryOutcome {
    let floor = input.spec.recovery_floor_s;
    let empty_delta = || ReconfigDelta {
        pcie_bytes: vec![0; input.new_plan.world()],
        nvlink_recv_bytes: vec![0; input.new_plan.world()],
        nvlink_send_bytes: vec![0; input.new_plan.world()],
        lost_bytes: 0,
    };

    match method {
        RecoveryMethod::Oracle => RecoveryOutcome {
            method,
            weight_time_s: 0.0,
            kv_restore_time_s: 0.0,
            recompute_time_s: 0.0,
            total_s: floor,
            weight_delta: empty_delta(),
            kv_restore: None,
        },
        RecoveryMethod::Recompute => {
            // Conventional: every rank reloads its whole new shard; all KV
            // of in-flight requests is regenerated by re-running prefill
            // over the *entire* context of each affected request (TP
            // recompute regenerates every rank's slice, but the wall-clock
            // is the full re-prefill).
            let delta = full_reload_delta(input);
            let w = weight_time(input, &delta);
            let rc = recompute_time(input, input.requests.iter().map(|&(_, t, _)| t));
            RecoveryOutcome {
                method,
                weight_time_s: w,
                kv_restore_time_s: 0.0,
                recompute_time_s: rc,
                total_s: floor + w + rc, // weights must land before prefill
                weight_delta: delta,
                kv_restore: None,
            }
        }
        RecoveryMethod::Host | RecoveryMethod::Full => {
            // Host keeps the conventional full-shard weight reload; Full
            // replaces it with the joint, non-redundant on-demand plan.
            let delta = if method == RecoveryMethod::Full {
                plan_reconfig(input.old_plan, input.new_plan, input.survivor_map, true)
            } else {
                full_reload_delta(input)
            };
            let w = weight_time(input, &delta);
            let old_place = KvPlacement::new(input.old_plan);
            let new_place = KvPlacement::new(input.new_plan);
            let restore = input.backup.plan_restore(
                input.failed_rank,
                input.requests,
                &old_place,
                &new_place,
                input.survivor_map,
            );
            let kv = kv_restore_time(input, &restore);
            // Backup lag: tokens written since the last backup pass must be
            // recomputed (usually a handful of decode tokens).
            let rc = recompute_time(input, restore.recompute_tokens.values().copied());
            RecoveryOutcome {
                method,
                weight_time_s: w,
                kv_restore_time_s: kv,
                recompute_time_s: rc,
                // Weight and KV restore share the PCIe link → serialize
                // them; lag recompute runs after state is back.
                total_s: floor + w + kv + rc,
                weight_delta: delta,
                kv_restore: Some(restore),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuSpec;
    use crate::model::llama3_70b;
    use crate::sharding::ShardPlan;

    fn fail_map(w: usize, f: usize) -> Vec<Option<RankId>> {
        (0..w)
            .map(|r| if r == f { None } else { Some(if r < f { r } else { r - 1 }) })
            .collect()
    }

    /// Build the §4.3.3 scenario: TP8 decode instance on llama-70B, a
    /// realistic in-flight set, failure of rank 3.
    fn scenario(backed: bool) -> (GpuSpec, Interconnect, ShardPlan, ShardPlan, Vec<Option<RankId>>, Vec<(RequestId, usize, RankId)>, BackupStore) {
        let m = llama3_70b();
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        let old = ShardPlan::failsafe(&m, 8);
        let map = fail_map(8, 3);
        let new = ShardPlan {
            model: m.clone(),
            heads: crate::sharding::HeadAssignment::new(
                crate::sharding::AttentionPolicy::Hybrid,
                m.n_kv_heads,
                m.n_layers,
                7,
            ),
            ffn: old.ffn.reshard(&map, 7),
        };
        // ~100 in-flight requests, 8k context each → ~262 GB total KV.
        let reqs: Vec<(RequestId, usize, RankId)> =
            (0..100).map(|i| (i as u64, 8000, (i % 8) as usize)).collect();
        let mut backup = BackupStore::new(1 << 42);
        if backed {
            for &(id, t, _) in &reqs {
                // Backup trails by 8 tokens (one backup pass period).
                backup.backup(id, t - 8, m.kv_bytes_per_token());
            }
        }
        (spec, ic, old, new, map, reqs, backup)
    }

    fn run(method: RecoveryMethod, backed: bool) -> RecoveryOutcome {
        let (spec, ic, old, new, map, reqs, backup) = scenario(backed);
        let input = RecoveryInput {
            spec: &spec,
            ic: &ic,
            old_plan: &old,
            new_plan: &new,
            survivor_map: &map,
            failed_rank: 3,
            requests: &reqs,
            backup: &backup,
        };
        plan_recovery(method, &input)
    }

    /// Table 3 orders of magnitude: Recompute ≫ Host ≫ Full ≫ Oracle.
    #[test]
    fn table3_ordering_and_magnitudes() {
        let recompute = run(RecoveryMethod::Recompute, false);
        let host = run(RecoveryMethod::Host, true);
        let full = run(RecoveryMethod::Full, true);
        let oracle = run(RecoveryMethod::Oracle, true);

        assert!(recompute.total_s > 5.0, "recompute {}", recompute.total_s);
        assert!(
            (0.1..2.0).contains(&host.total_s),
            "host should be sub-second-ish: {}",
            host.total_s
        );
        assert!(full.total_s < host.total_s / 2.0, "full {} host {}", full.total_s, host.total_s);
        assert!((oracle.total_s - 0.015).abs() < 1e-9);
        assert!(recompute.total_s / host.total_s > 10.0, "paper reports 41.5×");
        assert!(recompute.total_s / full.total_s > 50.0, "paper reports 183×");
    }

    #[test]
    fn backup_lag_costs_little() {
        let full = run(RecoveryMethod::Full, true);
        assert!(full.recompute_time_s < 0.05, "lag recompute {}", full.recompute_time_s);
        assert!(full.kv_restore_time_s > 0.0);
    }

    #[test]
    fn no_backup_degrades_host_to_recompute_cost() {
        let host_nobackup = run(RecoveryMethod::Host, false);
        let recompute = run(RecoveryMethod::Recompute, false);
        // Without backup, Host still pays (almost) the whole re-prefill.
        assert!(host_nobackup.recompute_time_s > recompute.recompute_time_s * 0.9);
    }

    #[test]
    fn oracle_is_floor() {
        let o = run(RecoveryMethod::Oracle, true);
        assert_eq!(o.weight_time_s, 0.0);
        assert_eq!(o.total_s, GpuSpec::h100().recovery_floor_s);
    }
}
