//! The asynchronous backup daemon: mirrors freshly produced KV to host
//! DRAM in the background, budgeted to a fraction of PCIe bandwidth so it
//! never competes with serving traffic (§3.2: "KVCache backups are
//! asynchronously maintained in the background").

use std::collections::VecDeque;

use crate::kvcache::BackupStore;
use crate::{RequestId, SimTime};

/// Background write-behind mirror. The simulator (or engine) notifies the
/// daemon of produced tokens; `advance(dt)` drains the queue at the
/// configured bandwidth, updating the backup store's high-water marks.
#[derive(Debug)]
pub struct BackupDaemon {
    /// Host-link bytes/second available to backup traffic.
    pub backup_bw: f64,
    /// Full-model KV bytes per token.
    bytes_per_token: usize,
    /// FIFO of (request, token index) waiting to be mirrored.
    queue: VecDeque<(RequestId, usize)>,
    /// Partial-byte carry across `advance` calls.
    credit: f64,
    /// Bytes mirrored in total (telemetry).
    pub mirrored_bytes: u64,
}

impl BackupDaemon {
    /// `backup_bw_fraction` of one device's PCIe bandwidth is reserved for
    /// backup traffic (the rest carries weight loads, restores, swaps).
    pub fn new(pcie_bw: f64, backup_bw_fraction: f64, bytes_per_token: usize) -> Self {
        BackupDaemon {
            backup_bw: pcie_bw * backup_bw_fraction,
            bytes_per_token,
            queue: VecDeque::new(),
            credit: 0.0,
            mirrored_bytes: 0,
        }
    }

    /// Request produced tokens `[from, to)` — enqueue them for mirroring.
    pub fn produced(&mut self, req: RequestId, from: usize, to: usize) {
        for t in from..to {
            self.queue.push_back((req, t + 1)); // token count after t-th token
        }
    }

    /// A request finished or was evicted: its queued tokens are moot.
    pub fn forget(&mut self, req: RequestId) {
        self.queue.retain(|&(r, _)| r != req);
    }

    /// Advance simulated time by `dt` seconds, mirroring as many queued
    /// tokens as bandwidth allows into `store`.
    pub fn advance(&mut self, dt: SimTime, store: &mut BackupStore) {
        self.credit += self.backup_bw * dt;
        while let Some(&(req, tokens)) = self.queue.front() {
            let cost = self.bytes_per_token as f64;
            if self.credit < cost {
                break;
            }
            self.credit -= cost;
            self.queue.pop_front();
            if store.backup(req, tokens, self.bytes_per_token).is_some() {
                self.mirrored_bytes += self.bytes_per_token as u64;
            }
        }
        // Don't bank unbounded credit while idle.
        if self.queue.is_empty() {
            self.credit = self.credit.min(self.backup_bw * 0.01);
        }
    }

    /// Tokens waiting to be mirrored (the worst-case recompute lag).
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Whether the daemon keeps up with a production rate of
    /// `tokens_per_s` across all requests.
    pub fn keeps_up_with(&self, tokens_per_s: f64) -> bool {
        tokens_per_s * self.bytes_per_token as f64 <= self.backup_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;

    #[test]
    fn daemon_keeps_up_with_decode_rate() {
        // llama-70B on 8×H100 decodes O(1k) tokens/s; KV production is
        // ~328 KB/token → ~0.3 GB/s, a sliver of one PCIe link.
        let m = llama3_70b();
        let d = BackupDaemon::new(55e9, 0.2, m.kv_bytes_per_token());
        assert!(d.keeps_up_with(5_000.0));
    }

    #[test]
    fn advance_drains_queue() {
        let mut d = BackupDaemon::new(1000.0, 1.0, 100); // 10 tokens/s
        let mut store = BackupStore::new(1 << 30);
        d.produced(1, 0, 20);
        d.advance(1.0, &mut store); // 10 tokens mirrored
        assert_eq!(store.backed_tokens(1), 10);
        assert_eq!(d.backlog(), 10);
        d.advance(1.0, &mut store);
        assert_eq!(store.backed_tokens(1), 20);
        assert_eq!(d.backlog(), 0);
    }

    #[test]
    fn forget_clears_queue() {
        let mut d = BackupDaemon::new(1.0, 1.0, 1000);
        d.produced(1, 0, 5);
        d.produced(2, 0, 5);
        d.forget(1);
        assert_eq!(d.backlog(), 5);
    }

    #[test]
    fn slow_daemon_lags() {
        let mut d = BackupDaemon::new(100.0, 1.0, 100); // 1 token/s
        let mut store = BackupStore::new(1 << 30);
        d.produced(1, 0, 100);
        d.advance(5.0, &mut store);
        assert_eq!(store.backed_tokens(1), 5, "only 5 tokens in 5s");
        assert_eq!(d.backlog(), 95);
    }
}
