//! Lightning Recovery (§3.2): proactive KVCache backup + on-demand weight
//! recovery, and the latency model comparing it against conventional
//! fault handling (paper Table 3 / Fig 12).
//!
//! Four recovery methods are modeled, matching §4.3.3 exactly:
//!
//! | method      | lost KVCache            | model weights              |
//! |-------------|-------------------------|----------------------------|
//! | `Recompute` | re-prefill from scratch | full re-shard reload (PCIe)|
//! | `Host`      | restore from host DRAM  | full re-shard reload (PCIe)|
//! | `Full`      | restore from host DRAM  | on-demand, non-redundant   |
//! | `Oracle`    | metadata only (free)    | metadata only (free)       |
//!
//! [`plan_recovery`] costs one failure given the shard plans before and
//! after, the in-flight requests, and the proactive backup state:
//!
//! ```
//! use failsafe::cluster::{GpuSpec, Interconnect};
//! use failsafe::kvcache::BackupStore;
//! use failsafe::model::llama3_70b;
//! use failsafe::recovery::{plan_recovery, RecoveryInput, RecoveryMethod};
//! use failsafe::sharding::ShardPlan;
//!
//! let model = llama3_70b();
//! let spec = GpuSpec::h100();
//! let ic = Interconnect::new(spec.clone());
//! let old_plan = ShardPlan::failsafe(&model, 8);
//! let (new_plan, survivor_map) = old_plan.shrink(3); // rank 3 dies
//! let mut backup = BackupStore::new(1 << 42);
//! backup.backup(0, 8000, model.kv_bytes_per_token()); // proactive mirror
//! let input = RecoveryInput {
//!     spec: &spec,
//!     ic: &ic,
//!     old_plan: &old_plan,
//!     new_plan: &new_plan,
//!     survivor_map: &survivor_map,
//!     failed_rank: 3,
//!     requests: &[(0, 8000, 1)], // one 8000-token request homed on rank 1
//!     backup: &backup,
//! };
//! let full = plan_recovery(RecoveryMethod::Full, &input);
//! let recompute = plan_recovery(RecoveryMethod::Recompute, &input);
//! assert!(full.total_s < recompute.total_s, "lightning recovery wins");
//! assert!(plan_recovery(RecoveryMethod::Oracle, &input).total_s <= full.total_s);
//! ```

mod daemon;
mod latency;

pub use daemon::BackupDaemon;
pub use latency::{plan_recovery, RecoveryInput, RecoveryMethod, RecoveryOutcome};
