//! Lightning Recovery (§3.2): proactive KVCache backup + on-demand weight
//! recovery, and the latency model comparing it against conventional
//! fault handling (paper Table 3 / Fig 12).
//!
//! Four recovery methods are modeled, matching §4.3.3 exactly:
//!
//! | method      | lost KVCache            | model weights              |
//! |-------------|-------------------------|----------------------------|
//! | `Recompute` | re-prefill from scratch | full re-shard reload (PCIe)|
//! | `Host`      | restore from host DRAM  | full re-shard reload (PCIe)|
//! | `Full`      | restore from host DRAM  | on-demand, non-redundant   |
//! | `Oracle`    | metadata only (free)    | metadata only (free)       |

mod daemon;
mod latency;

pub use daemon::BackupDaemon;
pub use latency::{plan_recovery, RecoveryInput, RecoveryMethod, RecoveryOutcome};
