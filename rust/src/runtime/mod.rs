//! The PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! and executes them from the serving hot path. Python never runs here —
//! the rust binary is self-contained once `artifacts/` exists.
//!
//! * [`Manifest`] — parses `artifacts/manifest.txt` (variants + weights).
//! * [`WeightStore`] — the **host DRAM** weight copy: the same store the
//!   paper's on-demand weight recovery reads over PCIe. Provides the
//!   head/column slicing + zero-padding that maps full tensors onto
//!   non-uniform shard buckets.
//! * [`RuntimeClient`] — PJRT CPU client with a compiled-executable cache
//!   keyed by variant name; HLO **text** loading (xla_extension 0.5.1
//!   rejects jax≥0.5 serialized protos).

mod client;
mod manifest;
mod weights;

pub use client::{literal_f32, literal_i32, literal_tensor, to_vec_f32, RuntimeClient};
pub use manifest::{HloVariant, Manifest, ModelMeta, WeightEntry};
pub use weights::{HostTensor, WeightStore};
