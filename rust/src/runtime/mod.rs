//! The PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! and executes them from the serving hot path. Python never runs here —
//! the rust binary is self-contained once `artifacts/` exists.
//!
//! * [`Manifest`] — parses `artifacts/manifest.txt` (variants + weights).
//! * [`WeightStore`] — the **host DRAM** weight copy: the same store the
//!   paper's on-demand weight recovery reads over PCIe. Provides the
//!   head/column slicing + zero-padding that maps full tensors onto
//!   non-uniform shard buckets.
//! * [`RuntimeClient`] — PJRT CPU client with a compiled-executable cache
//!   keyed by variant name; HLO **text** loading (xla_extension 0.5.1
//!   rejects jax≥0.5 serialized protos).
//!
//! The manifest layer needs no artifacts beyond its text file, so it can
//! be exercised standalone:
//!
//! ```
//! use failsafe::runtime::Manifest;
//!
//! let dir = std::env::temp_dir().join("failsafe_runtime_doctest");
//! std::fs::create_dir_all(&dir)?;
//! std::fs::write(
//!     dir.join("manifest.txt"),
//!     "model d_model=256 n_heads=8 head_dim=32 d_ff=1024 n_layers=4 vocab=512\n\
//!      hlo attn_b1_s16_c0_h2 kind=attn b=1 s=16 c=0 h=2 path=hlo/a.hlo.txt\n\
//!      weight wq.0 rows=256 cols=256 path=weights/wq.0.bin\n",
//! )?;
//! let manifest = Manifest::load(&dir)?;
//! assert_eq!(manifest.model.n_layers, 4);
//! assert!(manifest.attn_variant(1, 16, 0, 2).is_some());
//! assert!(manifest.attn_variant(1, 16, 0, 4).is_none());
//! assert_eq!(manifest.buckets("attn", |v| v.s), vec![16]);
//! # anyhow::Ok(())
//! ```

mod client;
mod manifest;
mod weights;

pub use client::{literal_f32, literal_i32, literal_tensor, to_vec_f32, RuntimeClient};
pub use manifest::{HloVariant, Manifest, ModelMeta, WeightEntry};
pub use weights::{HostTensor, WeightStore};
