//! Host-DRAM weight store + shard slicing.
//!
//! This is the concrete realization of "model weights stored in CPU
//! DRAM" from §3.2: the full f32 tensors live here; each rank's shard is
//! *sliced out on demand* — head columns for attention, column blocks for
//! FFN — and zero-padded up to the compiled bucket sizes. On-demand weight
//! recovery reads exactly the byte ranges it needs from this store.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::Manifest;

/// A full weight tensor in host memory (row-major f32).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// All model weights, loaded once from `artifacts/weights/*.bin`.
#[derive(Debug)]
pub struct WeightStore {
    tensors: HashMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let mut tensors = HashMap::new();
        for w in &manifest.weights {
            let bytes = std::fs::read(&w.path)
                .with_context(|| format!("reading weight {}", w.path.display()))?;
            anyhow::ensure!(
                bytes.len() == w.rows * w.cols * 4,
                "weight {} size mismatch: {} bytes for {}x{}",
                w.name,
                bytes.len(),
                w.rows,
                w.cols
            );
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(w.name.clone(), HostTensor { rows: w.rows, cols: w.cols, data });
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).with_context(|| format!("missing weight tensor {name}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(String::as_str)
    }

    /// Total bytes resident (the host copy the recovery path reads).
    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.data.len() * 4).sum()
    }

    /// Slice columns `head*head_dim..(head+1)*head_dim` for each head in
    /// `heads`, then zero-pad the head axis to `h_bucket` heads.
    /// Input `[rows, n_heads*head_dim]` → output `[rows, h_bucket*head_dim]`.
    pub fn slice_head_cols(
        &self,
        name: &str,
        heads: &[usize],
        head_dim: usize,
        h_bucket: usize,
    ) -> Result<HostTensor> {
        let t = self.get(name)?;
        anyhow::ensure!(heads.len() <= h_bucket, "{} heads > bucket {h_bucket}", heads.len());
        let out_cols = h_bucket * head_dim;
        let mut data = vec![0.0f32; t.rows * out_cols];
        for r in 0..t.rows {
            for (hi, &h) in heads.iter().enumerate() {
                let src = r * t.cols + h * head_dim;
                let dst = r * out_cols + hi * head_dim;
                data[dst..dst + head_dim].copy_from_slice(&t.data[src..src + head_dim]);
            }
        }
        Ok(HostTensor { rows: t.rows, cols: out_cols, data })
    }

    /// Slice rows (same head selection on the row axis, for `Wo`), padded
    /// to `h_bucket*head_dim` rows of zeros.
    pub fn slice_head_rows(
        &self,
        name: &str,
        heads: &[usize],
        head_dim: usize,
        h_bucket: usize,
    ) -> Result<HostTensor> {
        let t = self.get(name)?;
        let out_rows = h_bucket * head_dim;
        let mut data = vec![0.0f32; out_rows * t.cols];
        for (hi, &h) in heads.iter().enumerate() {
            for d in 0..head_dim {
                let src = (h * head_dim + d) * t.cols;
                let dst = (hi * head_dim + d) * t.cols;
                data[dst..dst + t.cols].copy_from_slice(&t.data[src..src + t.cols]);
            }
        }
        Ok(HostTensor { rows: out_rows, cols: t.cols, data })
    }

    /// Slice arbitrary columns (FFN gate/up), zero-padded to `col_bucket`.
    pub fn slice_cols(&self, name: &str, cols: &[usize], col_bucket: usize) -> Result<HostTensor> {
        let t = self.get(name)?;
        anyhow::ensure!(cols.len() <= col_bucket);
        let mut data = vec![0.0f32; t.rows * col_bucket];
        for r in 0..t.rows {
            for (ci, &c) in cols.iter().enumerate() {
                data[r * col_bucket + ci] = t.data[r * t.cols + c];
            }
        }
        Ok(HostTensor { rows: t.rows, cols: col_bucket, data })
    }

    /// Slice arbitrary rows (FFN down), zero-padded to `row_bucket`.
    pub fn slice_rows(&self, name: &str, rows: &[usize], row_bucket: usize) -> Result<HostTensor> {
        let t = self.get(name)?;
        anyhow::ensure!(rows.len() <= row_bucket);
        let mut data = vec![0.0f32; row_bucket * t.cols];
        for (ri, &r) in rows.iter().enumerate() {
            data[ri * t.cols..(ri + 1) * t.cols]
                .copy_from_slice(&t.data[r * t.cols..(r + 1) * t.cols]);
        }
        Ok(HostTensor { rows: row_bucket, cols: t.cols, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(name: &str, rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> WeightStore {
        let data = (0..rows * cols).map(|i| f(i / cols, i % cols)).collect();
        let mut tensors = HashMap::new();
        tensors.insert(name.to_string(), HostTensor { rows, cols, data });
        WeightStore { tensors }
    }

    #[test]
    fn head_col_slice_and_pad() {
        // 2 rows, 4 heads × dim 2. Select heads [2, 0], bucket 3.
        let s = store_with("w", 2, 8, |r, c| (r * 8 + c) as f32);
        let t = s.slice_head_cols("w", &[2, 0], 2, 3).unwrap();
        assert_eq!((t.rows, t.cols), (2, 6));
        // row 0: head2 = cols 4,5 → [4,5]; head0 = [0,1]; pad = [0,0]
        assert_eq!(&t.data[0..6], &[4.0, 5.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn head_row_slice_for_wo() {
        // 4 heads × dim 2 rows, 3 cols.
        let s = store_with("wo", 8, 3, |r, c| (r * 3 + c) as f32);
        let t = s.slice_head_rows("wo", &[1], 2, 2).unwrap();
        assert_eq!((t.rows, t.cols), (4, 3));
        assert_eq!(&t.data[0..3], &[6.0, 7.0, 8.0]); // head1 row0 = abs row 2
        assert_eq!(&t.data[6..12], &[0.0; 6]); // padded head
    }

    #[test]
    fn col_and_row_slices() {
        let s = store_with("g", 2, 5, |r, c| (r * 5 + c) as f32);
        let t = s.slice_cols("g", &[4, 1], 3).unwrap();
        assert_eq!(&t.data, &[4.0, 1.0, 0.0, 9.0, 6.0, 0.0]);
        let s2 = store_with("d", 5, 2, |r, c| (r * 2 + c) as f32);
        let t2 = s2.slice_rows("d", &[3], 2).unwrap();
        assert_eq!(&t2.data, &[6.0, 7.0, 0.0, 0.0]);
    }
}
