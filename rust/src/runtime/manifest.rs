//! Parser for the line-oriented artifact manifest (`manifest.txt`).
//!
//! Format (written by `python/compile/aot.py`):
//! ```text
//! model d_model=256 n_heads=8 head_dim=32 d_ff=1024 n_layers=4 vocab=512
//! hlo attn_b1_s16_c0_h2 kind=attn b=1 s=16 c=0 h=2 path=hlo/attn_b1_s16_c0_h2.hlo.txt
//! weight wq.0 rows=256 cols=256 path=weights/wq.0.bin
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Small-real model metadata from the manifest header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelMeta {
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
}

/// One compiled HLO variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HloVariant {
    pub name: String,
    /// `embed` | `head` | `attn` | `ffn`.
    pub kind: String,
    pub b: usize,
    pub s: usize,
    /// Cached-context bucket (attn only).
    pub c: usize,
    /// Local-head bucket (attn only).
    pub h: usize,
    /// Column bucket (ffn only).
    pub cols: usize,
    pub path: PathBuf,
}

/// One dumped weight tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightEntry {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub path: PathBuf,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub model: ModelMeta,
    pub variants: Vec<HloVariant>,
    pub weights: Vec<WeightEntry>,
}

fn kv_map(fields: &[&str]) -> HashMap<String, String> {
    fields
        .iter()
        .filter_map(|f| f.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn get_usize(m: &HashMap<String, String>, k: &str) -> usize {
    m.get(k).and_then(|v| v.parse().ok()).unwrap_or(0)
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let mut model = None;
        let mut variants = Vec::new();
        let mut weights = Vec::new();

        for line in text.lines() {
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.first() {
                Some(&"model") => {
                    let m = kv_map(&fields[1..]);
                    model = Some(ModelMeta {
                        d_model: get_usize(&m, "d_model"),
                        n_heads: get_usize(&m, "n_heads"),
                        head_dim: get_usize(&m, "head_dim"),
                        d_ff: get_usize(&m, "d_ff"),
                        n_layers: get_usize(&m, "n_layers"),
                        vocab: get_usize(&m, "vocab"),
                    });
                }
                Some(&"hlo") => {
                    let name = fields.get(1).context("hlo line missing name")?.to_string();
                    let m = kv_map(&fields[2..]);
                    variants.push(HloVariant {
                        name,
                        kind: m.get("kind").cloned().unwrap_or_default(),
                        b: get_usize(&m, "b"),
                        s: get_usize(&m, "s"),
                        c: get_usize(&m, "c"),
                        h: get_usize(&m, "h"),
                        cols: get_usize(&m, "cols"),
                        path: root.join(m.get("path").context("hlo line missing path")?),
                    });
                }
                Some(&"weight") => {
                    let name = fields.get(1).context("weight line missing name")?.to_string();
                    let m = kv_map(&fields[2..]);
                    weights.push(WeightEntry {
                        name,
                        rows: get_usize(&m, "rows"),
                        cols: get_usize(&m, "cols"),
                        path: root.join(m.get("path").context("weight line missing path")?),
                    });
                }
                _ => {}
            }
        }
        let model = match model {
            Some(m) => m,
            None => bail!("manifest has no model line"),
        };
        Ok(Manifest { root, model, variants, weights })
    }

    /// Find the attn variant for exact bucket values.
    pub fn attn_variant(&self, b: usize, s: usize, c: usize, h: usize) -> Option<&HloVariant> {
        self.variants
            .iter()
            .find(|v| v.kind == "attn" && v.b == b && v.s == s && v.c == c && v.h == h)
    }

    pub fn ffn_variant(&self, b: usize, s: usize, cols: usize) -> Option<&HloVariant> {
        self.variants
            .iter()
            .find(|v| v.kind == "ffn" && v.b == b && v.s == s && v.cols == cols)
    }

    pub fn simple_variant(&self, kind: &str, b: usize, s: usize) -> Option<&HloVariant> {
        self.variants.iter().find(|v| v.kind == kind && v.b == b && v.s == s)
    }

    /// Available bucket lists (sorted, deduped) for the engine's padding.
    pub fn buckets(&self, kind: &str, field: fn(&HloVariant) -> usize) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.variants.iter().filter(|x| x.kind == kind).map(field).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "model d_model=256 n_heads=8 head_dim=32 d_ff=1024 n_layers=4 vocab=512\n\
             hlo attn_b1_s16_c0_h2 kind=attn b=1 s=16 c=0 h=2 path=hlo/a.hlo.txt\n\
             hlo ffn_b1_s16_f256 kind=ffn b=1 s=16 cols=256 path=hlo/f.hlo.txt\n\
             hlo embed_b1_s16 kind=embed b=1 s=16 path=hlo/e.hlo.txt\n\
             weight wq.0 rows=256 cols=256 path=weights/wq.0.bin\n",
        )
        .unwrap();
    }

    #[test]
    fn parses_all_line_kinds() {
        let dir = std::env::temp_dir().join("failsafe_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.n_heads, 8);
        assert_eq!(m.variants.len(), 3);
        assert_eq!(m.weights.len(), 1);
        assert!(m.attn_variant(1, 16, 0, 2).is_some());
        assert!(m.attn_variant(1, 16, 0, 4).is_none());
        assert!(m.ffn_variant(1, 16, 256).is_some());
        assert!(m.simple_variant("embed", 1, 16).is_some());
        assert_eq!(m.buckets("attn", |v| v.h), vec![2]);
    }

    #[test]
    fn missing_dir_is_friendly_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
