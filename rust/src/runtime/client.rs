//! PJRT CPU client wrapper with an executable cache.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{Context, Result};

use super::manifest::HloVariant;
use super::weights::HostTensor;

/// PJRT client + compiled-executable cache keyed by variant name.
///
/// Executables compile lazily on first use (compilation is the expensive
/// part; execution reuses the cache on every subsequent step). The CPU
/// client is single-process; "ranks" are logical — the physical
/// distribution the paper runs on is modeled by [`crate::cluster`].
pub struct RuntimeClient {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of distinct executables compiled so far.
    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Get (compiling if needed) the executable for `variant`.
    pub fn executable(&self, variant: &HloVariant) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&variant.name) {
            return Ok(e.clone());
        }
        let path = variant
            .path
            .to_str()
            .context("non-utf8 artifact path")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", variant.name))?;
        let exe = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(variant.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute a variant with the given literals; returns the un-tupled
    /// output literals (aot.py lowers with `return_tuple=True`).
    /// Accepts owned or borrowed literals (`&[Literal]` or `&[&Literal]`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        variant: &HloVariant,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(variant)?;
        let result = exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", variant.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", variant.name))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {}: {e:?}", variant.name))
    }
}

/// Build an f32 literal of the given shape from host data.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Literal for a [`HostTensor`] as `[rows, cols]` (or `[cols]` if 1-row).
pub fn literal_tensor(t: &HostTensor) -> Result<xla::Literal> {
    if t.rows == 1 {
        literal_f32(&t.data, &[t.cols as i64])
    } else {
        literal_f32(&t.data, &[t.rows as i64, t.cols as i64])
    }
}

/// Extract an f32 vec from a literal.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))
}
