//! `failsafe` — the leader binary.
//!
//! Subcommands (the runtime `USAGE` listing is the same inventory;
//! `docs/OPERATIONS.md` is the full operator guide):
//!   serve     serve random prompts on the real engine (PJRT, AOT artifacts)
//!   sim       online serving simulation at H100 scale (prefill|decode)
//!   replay    step a serving session through an availability timeline of
//!             GPU failures AND rejoins (cascades, flaky GPUs, rolling
//!             maintenance), on the simulator or the real engine
//!   degrade   soft-fault drill: throttle one GPU to --factor × speed under
//!             the thermal_throttle scenario and compare no-mitigation vs
//!             capacity-rebalanced serving vs the capacity-proportional
//!             ideal (sim), or assert bit-exact continuation (engine)
//!   fleet     N replicas behind the cluster-level load-aware router, with
//!             a fault timeline on one replica while the rest keep serving
//!   overload  overload-survival drill: a priority-tiered storm at --load ×
//!             the fleet's calibrated sustainable rate, served FCFS vs
//!             preempt+swap vs preempt+swap+admission; prints per-tier
//!             goodput/deadline tables and asserts admission beats FCFS
//!   elastic   heterogeneous + elastic fleet drill: capacity-proportional
//!             vs uniform sharding on a mixed H100/A100 group, then
//!             homogeneous vs heterogeneous vs autoscaled fleets under a
//!             diurnal arrival trace, compared on cost-per-token
//!   recover   cost one failure under every recovery method
//!   prefix    shared-prefix drill: serve a repeat-fanout trace with the
//!             prefix trie off (cold) and on (shared) and compare prefill
//!             work, peak resident KV, and trie hit rates
//!   simcore   event-core drill: run one workload through the per-token
//!             stepper, the bit-exact event core, and the batched span
//!             core; print the rounds/spans/timing table and assert the
//!             event core matches the stepper bit for bit
//!   trace     flight-recorder replay: the `replay` drill with the
//!             structured trace log attached — writes a Chrome/Perfetto
//!             trace (--out), prints the incident timeline, and asserts
//!             each recovery's phase spans sum to its reported latency
//!   traces    print workload/availability trace statistics
//!
//! Examples:
//!   failsafe serve --world 3 --requests 6 --max-new 12
//!   failsafe serve --world 3 --fail-rank 1 --recovery full
//!   failsafe serve --world 3 --fail-rank 1 --fail-after-tokens 12
//!   failsafe sim --model llama --system failsafe --world 7 --mode decode --rate 4
//!   failsafe replay --world 8 --scenario cascade --requests 40
//!   failsafe replay --world 8 --scenario gcp --duration 1800 --rate 0.5
//!   failsafe replay --backend engine --world 3 --requests 6 --max-new 16
//!   failsafe replay --timeline my_trace.txt --world 8
//!   failsafe degrade --world 8 --gpu 1 --factor 0.5 --requests 32
//!   failsafe degrade --backend engine --world 3 --gpu 1 --factor 0.5
//!   failsafe fleet --replicas 4 --world 8 --requests 80 --rate 8
//!   failsafe fleet --replicas 4 --scenario cascade --fault-replica 0 --pace tokens
//!   failsafe fleet --backend engine --replicas 2 --world 3 --requests 6
//!   failsafe overload --replicas 2 --world 8 --requests 160 --load 2
//!   failsafe elastic --h100 4 --a100 4 --replicas 4 --requests 96
//!   failsafe recover --model llama --world 8 --requests 60 --ctx 8000
//!   failsafe prefix --prefixes 4 --fanout 8 --prefix-tokens 2048
//!   failsafe simcore --world 8 --requests 512 --burst 64 --output-tokens 64
//!   failsafe trace --world 8 --scenario cascade --requests 40 --out trace.json
//!   failsafe traces --n 3000

use failsafe::benchkit::section;
use failsafe::cluster::{capacity_weights, FaultTimeline, GpuSpec, Interconnect, TimelineEvent};
use failsafe::config::{model_by_name, recovery_by_name, system_by_name, EngineConfig};
use failsafe::engine::{
    drive, replay, AdvanceLimit, Engine, EngineEvent, FaultPlan, FaultTrigger, PreemptPolicy,
    ReplayPace, ServingBackend, SubmitOptions,
};
use failsafe::fleet::{
    fleet_unit_rate, run_autoscaled, run_gated, run_static, AdmissionGateway, AdmissionPolicy,
    AutoscalePolicy, Autoscaler, Fleet, FleetReport,
};
use failsafe::kvcache::BackupStore;
use failsafe::model::ModelSpec;
use failsafe::obs::{prometheus_text, RecordKind, SharedLog, Value};
use failsafe::recovery::{plan_recovery, RecoveryInput, RecoveryMethod};
use failsafe::sharding::{HeadAssignment, ShardPlan, CAPACITY_DECODE_FRAC};
use failsafe::simulator::{
    CoreMode, DecodeWork, OnlineMode, OnlineSim, PrefillWork, StepCostModel, SystemConfig,
};
use failsafe::traces::{
    cascade_then_heal, diurnal_arrivals, flaky_gpu, gcp_availability, mooncake_trace,
    openthoughts_trace, overload_storm, poisson_arrivals, repeat_fanout, rolling_maintenance,
    spot_preemptions, spot_timeline, thermal_throttle, TraceStats, TIER_BEST_EFFORT,
    TIER_PREMIUM, TIER_STANDARD,
};
use failsafe::util::cli::Args;
use failsafe::util::Rng;
use failsafe::{RankId, RequestId};

/// The complete subcommand inventory, printed on unknown/missing
/// subcommands (and kept in sync with `docs/OPERATIONS.md`).
const USAGE: &str = "\
usage: failsafe <subcommand> [--flags]

subcommands:
  serve     serve random prompts on the real engine (PJRT, AOT artifacts)
  sim       online serving simulation at H100 scale (--mode prefill|decode)
  replay    step one serving session through a fail/rejoin availability
            timeline (--scenario cascade|flaky|rolling|gcp|synth, or
            --timeline FILE), on the simulator or the real engine
  degrade   soft-fault drill: throttle --gpu to --factor × speed
            (thermal_throttle scenario) and compare no-mitigation vs
            rebalanced vs the capacity-proportional ideal (sim), or
            assert bit-exact degrade/fail/rejoin continuation (engine)
  fleet     N replicas behind the cluster-level load-aware router; a fault
            timeline hits one replica (--fault-replica) while the others
            keep serving (--backend sim|engine, --pace clock|tokens)
  overload  overload-survival drill: a 20/30/50 premium/standard/best-effort
            storm at --load × the fleet's calibrated sustainable rate,
            served FCFS vs preempt+swap vs preempt+swap+admission; prints
            per-tier goodput/deadline tables and (at --load >= 2) asserts
            admission control beats FCFS on the SLO tiers
  elastic   heterogeneous + elastic fleet drill: asserts the
            capacity-proportional plan beats uniform sharding >= 1.3x on
            a mixed --h100/--a100 group, then serves a diurnal trace on
            homogeneous / heterogeneous / autoscaled fleets and asserts
            autoscaling beats static peak provisioning on cost-per-token
  recover   cost one failure under every recovery method (Table 3 style)
  prefix    shared-prefix drill: serve a repeat-fanout trace (--prefixes
            × --fanout continuations of a --prefix-tokens shared prompt)
            cold and with the prefix trie, and compare prefill work,
            peak resident KV, and trie hit rates
  simcore   event-core drill: one workload (--requests in bursts of
            --burst, --output-tokens each) through the per-token stepper,
            the bit-exact event core, and the batched span core; prints
            the rounds/spans/timing table and asserts bit-equality
  trace     flight-recorder replay: the sim replay drill with the
            structured trace log attached; writes Chrome/Perfetto
            traceEvents JSON (--out trace.json, --prom FILE for a
            Prometheus snapshot), prints the incident timeline, and
            asserts each recovery's detect/plan/stream/respread/resume
            spans sum to its reported latency
  traces    print workload/availability trace statistics

see docs/OPERATIONS.md for every flag and sample output, or the
`rust/src/main.rs` header for one-line examples";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("serve") => serve(&args),
        Some("sim") => sim(&args),
        Some("replay") => replay_cmd(&args),
        Some("degrade") => degrade_cmd(&args),
        Some("fleet") => fleet_cmd(&args),
        Some("overload") => overload_cmd(&args),
        Some("elastic") => elastic_cmd(&args),
        Some("recover") => recover(&args),
        Some("prefix") => prefix_cmd(&args),
        Some("simcore") => simcore_cmd(&args),
        Some("trace") => trace_cmd(&args),
        Some("traces") => traces(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
        None => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// `--model` with a friendly error instead of a panic on a bad value.
fn model_arg(args: &Args) -> anyhow::Result<ModelSpec> {
    let name = args.get_or("model", "llama");
    model_by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name:?} (llama|mixtral|small)"))
}

/// `--system` with a friendly error instead of a panic on a bad value.
fn system_arg(args: &Args) -> anyhow::Result<SystemConfig> {
    let name = args.get_or("system", "failsafe");
    system_by_name(name).ok_or_else(|| {
        anyhow::anyhow!("unknown system {name:?} (standard|nonuniform|membalance|failsafe)")
    })
}

/// `--recovery` with a friendly error instead of silently defaulting on a
/// bad value.
fn recovery_arg(args: &Args) -> anyhow::Result<RecoveryMethod> {
    let name = args.get_or("recovery", "full");
    recovery_by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown recovery {name:?} (recompute|host|full|oracle)"))
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let cfg = EngineConfig::from_args(args);
    let n = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 12);
    let fail_rank = args.get("fail-rank").and_then(|v| v.parse::<usize>().ok());
    // With --fail-after-tokens N the failure hits mid-stream, between
    // decode steps, with requests in flight; without it (but with
    // --fail-rank) it hits before serving starts.
    let fail_after = args.get("fail-after-tokens").and_then(|v| v.parse::<usize>().ok());
    let seed = cfg.seed;

    section(&format!("serving {} requests on world={} ({})", n, cfg.world, cfg.system.name));
    let mut rng = Rng::seed_from_u64(seed);
    let mut engine = Engine::new(cfg)?;
    for _ in 0..n {
        let len = rng.range(8, 48);
        let prompt: Vec<u32> = (0..len).map(|_| rng.range(1, 512) as u32).collect();
        engine.submit(&prompt, max_new)?;
    }
    let method = recovery_arg(args)?;
    let fault = fail_rank.map(|rank| FaultPlan {
        trigger: FaultTrigger::AfterTokens(fail_after.unwrap_or(0)),
        rank,
        method,
    });
    let (report, recovery) = drive(&mut engine as &mut dyn ServingBackend, fault)?;
    if let (Some(rank), Some(lat)) = (fail_rank, recovery) {
        println!(
            "injected failure of rank {rank} after {} tokens: recovery {:.1} ms (modeled H100)",
            fail_after.unwrap_or(0),
            lat * 1e3
        );
    }
    println!(
        "done: {} prefill tok, {} decode tok in {:.2}s ({:.1} decode tok/s), epoch {}",
        report.prefill_tokens,
        report.decode_tokens,
        report.wall_s,
        report.decode_tps(),
        engine.epoch()
    );
    for r in report.results.iter().take(8) {
        println!("  req {}: {:?}...", r.id, &r.output_tokens[..4.min(r.output_tokens.len())]);
    }
    Ok(())
}

fn sim(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 7);
    let mode = match args.get_or("mode", "decode") {
        "prefill" => OnlineMode::Prefill,
        _ => OnlineMode::Decode,
    };
    let rate = args.get_f64("rate", 2.0);
    let n = args.get_usize("requests", 300);

    section(&format!(
        "simulating {} {:?} instance: {} TP{} @ {} req/s",
        model.name, mode, system.name, world, rate
    ));
    let mut trace = mooncake_trace(n, args.get_u64("seed", 2));
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.min(64_000);
    }
    poisson_arrivals(&mut trace, rate, args.get_u64("seed", 2));
    let sim = OnlineSim::new(system, mode, world).with_model(model);
    let mut out = sim.run(&trace, None);
    println!(
        "input tput {:.0} tok/s | output tput {:.0} tok/s | steps {}",
        out.metrics.input_throughput(),
        out.metrics.output_throughput(),
        out.steps
    );
    println!(
        "TTFT p50/p90/p99: {:.2}/{:.2}/{:.2} s | TBT p50/p90/p99: {:.1}/{:.1}/{:.1} ms",
        out.metrics.ttft.p50(),
        out.metrics.ttft.p90(),
        out.metrics.ttft.p99(),
        out.metrics.tbt.p50() * 1e3,
        out.metrics.tbt.p90() * 1e3,
        out.metrics.tbt.p99() * 1e3
    );
    println!("max-TBT p99: {:.3} s", out.metrics.max_tbt_cdf.quantile(0.99));
    Ok(())
}

/// Build the availability timeline for `replay`: from `--timeline FILE`,
/// or a named `--scenario` (cascade|flaky|rolling|gcp|synth).
fn build_timeline(args: &Args, world: usize) -> anyhow::Result<FaultTimeline> {
    if let Some(path) = args.get("timeline") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading timeline {path}: {e}"))?;
        return FaultTimeline::parse(&text);
    }
    let seed = args.get_u64("seed", 42);
    let duration = args.get_f64("duration", 600.0);
    let downtime = args.get_f64("downtime", 6.0);
    Ok(match args.get_or("scenario", "cascade") {
        "cascade" => cascade_then_heal(
            args.get_usize("k", (world.saturating_sub(1)).clamp(1, 2)),
            args.get_f64("at", 2.0),
            args.get_f64("stagger", 1.0),
            downtime,
        ),
        "flaky" => flaky_gpu(
            args.get_usize("gpu", 1),
            args.get_usize("cycles", 3),
            args.get_f64("at", 2.0),
            downtime.min(3.0),
            args.get_f64("uptime", 5.0),
        ),
        "rolling" => rolling_maintenance(
            world,
            args.get_f64("at", 2.0),
            downtime.min(4.0),
            args.get_f64("gap", 2.0),
        ),
        "gcp" => {
            FaultTimeline::from_availability(&gcp_availability(world, duration, seed), world, seed)
        }
        "synth" => FaultTimeline::synthesize(
            world,
            duration,
            args.get_f64("mtbf", 120.0),
            args.get_f64("mttr", 30.0),
            world - 1,
            seed,
        ),
        other => anyhow::bail!("unknown scenario {other:?} (cascade|flaky|rolling|gcp|synth)"),
    })
}

fn replay_cmd(args: &Args) -> anyhow::Result<()> {
    let method = recovery_arg(args)?;
    match args.get_or("backend", "sim") {
        "engine" => replay_engine(args, method),
        "sim" => replay_sim(args, method),
        other => anyhow::bail!("unknown backend {other:?} (sim|engine)"),
    }
}

/// Replay on the cost-model backend: a Mooncake-style trace in flight
/// while the timeline fires on the simulated clock.
fn replay_sim(args: &Args, method: RecoveryMethod) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 8);
    let n = args.get_usize("requests", 40);
    let rate = args.get_f64("rate", 4.0);
    let seed = args.get_u64("seed", 42);
    let timeline = build_timeline(args, world)?;
    timeline.validate(world)?;

    section(&format!(
        "replaying {} availability events over {} TP{} ({} requests @ {} req/s, {})",
        timeline.len(),
        system.name,
        world,
        n,
        rate,
        method.name()
    ));
    let mut trace = mooncake_trace(n, seed);
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.clamp(1, 16_000);
        r.output_tokens = r.output_tokens.clamp(8, 64);
    }
    poisson_arrivals(&mut trace, rate, seed);
    let sim = OnlineSim::new(system, OnlineMode::Decode, world).with_model(model);
    let mut session = sim.session();
    for r in &trace {
        session.submit_with(
            &vec![0u32; r.input_tokens],
            SubmitOptions::new(r.output_tokens).at(r.arrival),
        )?;
    }
    let out = replay(&mut session, &timeline, method, ReplayPace::Clock)?;
    for a in &out.applied {
        println!(
            "  t={:>8.2}s  {:<6} gpu {} (rank {:>2})  latency {:>8.1} ms",
            a.applied_at,
            a.event.kind.name(),
            a.event.gpu,
            a.rank,
            a.latency_s * 1e3
        );
    }
    println!(
        "final world {} | {} reconfigs | {} decode tok in {:.1}s sim ({:.0} tok/s) \
         | max concurrent down {}",
        out.final_world,
        out.applied.len(),
        out.report.decode_tokens,
        out.report.wall_s,
        out.report.decode_tps(),
        timeline.max_concurrent_down()
    );
    Ok(())
}

/// Replay on the real engine (needs AOT artifacts), token-paced so the
/// injection points are deterministic, and verify the outputs are
/// bit-exact versus a fault-free run of the same session.
fn replay_engine(args: &Args, method: RecoveryMethod) -> anyhow::Result<()> {
    let cfg = EngineConfig::from_args(args);
    let n = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 12);
    let per_sec = args.get_f64("tokens-per-sec", 2.0);
    let timeline = build_timeline(args, cfg.world)?;
    timeline.validate(cfg.world)?;

    section(&format!(
        "replaying {} availability events on the real engine (world {}, {})",
        timeline.len(),
        cfg.world,
        method.name()
    ));
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            let len = rng.range(8, 48);
            (0..len).map(|_| rng.range(1, 512) as u32).collect()
        })
        .collect();

    // Fault-free reference of the same session on the same world.
    let mut reference = Engine::new(cfg.clone())?;
    for p in &prompts {
        reference.submit(p, max_new)?;
    }
    let expect = reference.run_to_completion()?;

    let mut engine = Engine::new(cfg)?;
    for p in &prompts {
        engine.submit(p, max_new)?;
    }
    let out = replay(&mut engine, &timeline, method, ReplayPace::Tokens { per_sec })?;
    for a in &out.applied {
        println!(
            "  after {:>4} tokens  {:<6} gpu {} (rank {:>2})  modeled latency {:>8.1} ms",
            (a.event.at * per_sec).ceil() as usize,
            a.event.kind.name(),
            a.event.gpu,
            a.rank,
            a.latency_s * 1e3
        );
    }
    println!(
        "final world {} (epoch {}) | {} decode tok | {} events applied",
        out.final_world,
        engine.epoch(),
        out.report.decode_tokens,
        out.applied.len()
    );
    anyhow::ensure!(
        out.report.outputs_owned() == expect.outputs_owned(),
        "outputs diverged from the fault-free run"
    );
    println!(
        "bit-exact vs the fault-free run across {} reconfigurations ✓",
        out.applied.len()
    );
    Ok(())
}

/// Flight-recorder replay: the cost-model replay drill with the
/// structured trace log attached. Writes Chrome/Perfetto traceEvents
/// JSON, prints the incident timeline, and asserts the recovery-phase
/// decomposition — every recovery's detect/plan/stream/respread/resume
/// spans must sum to the latency the backend reported (±1e-9 s).
fn trace_cmd(args: &Args) -> anyhow::Result<()> {
    let method = recovery_arg(args)?;
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 8);
    let n = args.get_usize("requests", 40);
    let rate = args.get_f64("rate", 4.0);
    let seed = args.get_u64("seed", 42);
    let out_path = args.get_or("out", "trace.json");
    let timeline = build_timeline(args, world)?;
    timeline.validate(world)?;

    section(&format!(
        "flight recorder: {} availability events over {} TP{} ({} requests @ {} req/s, {})",
        timeline.len(),
        system.name,
        world,
        n,
        rate,
        method.name()
    ));
    let mut trace = mooncake_trace(n, seed);
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.clamp(1, 16_000);
        r.output_tokens = r.output_tokens.clamp(8, 64);
    }
    poisson_arrivals(&mut trace, rate, seed);
    let log = SharedLog::new();
    let sim = OnlineSim::new(system, OnlineMode::Decode, world).with_model(model);
    let mut session = sim.session();
    session.set_observer(log.observer());
    for r in &trace {
        session.submit_with(
            &vec![0u32; r.input_tokens],
            SubmitOptions::new(r.output_tokens).at(r.arrival),
        )?;
    }
    let out = replay(&mut session, &timeline, method, ReplayPace::Clock)?;
    let snap = log.snapshot();

    // Cross-check the span decomposition against what the backend
    // reported in its event stream: walk the records once, pairing each
    // "recovery" parent span with its five phase children and with the
    // next recovery.completed / reconfig.completed latency.
    let mut parents: Vec<f64> = Vec::new(); // latency_s on each parent span
    let mut child_sums: Vec<f64> = Vec::new();
    let mut reported: Vec<f64> = Vec::new();
    for rec in snap.records() {
        match rec.kind {
            RecordKind::SpanBegin if rec.name == "recovery" => {
                if let Some(Value::F(v)) = rec.field("latency_s") {
                    parents.push(*v);
                    child_sums.push(0.0);
                }
            }
            RecordKind::SpanBegin if rec.name.starts_with("recovery.") => {
                if let (Some(sum), Some(Value::F(d))) =
                    (child_sums.last_mut(), rec.field("dur_s"))
                {
                    *sum += *d;
                }
            }
            RecordKind::Event
                if rec.name == "recovery.completed" || rec.name == "reconfig.completed" =>
            {
                if let Some(Value::F(v)) = rec.field("latency_s") {
                    reported.push(*v);
                }
            }
            _ => {}
        }
    }
    anyhow::ensure!(
        parents.len() == reported.len(),
        "span/event mismatch: {} recovery spans vs {} completion events",
        parents.len(),
        reported.len()
    );
    for (i, ((span, sum), rep)) in
        parents.iter().zip(&child_sums).zip(&reported).enumerate()
    {
        anyhow::ensure!(
            (span - rep).abs() <= 1e-9 && (sum - rep).abs() <= 1e-9,
            "recovery {i}: span {span:.9}s / phases {sum:.9}s vs reported {rep:.9}s"
        );
    }

    std::fs::write(out_path, snap.to_chrome_trace())?;
    if let Some(prom) = args.get("prom") {
        std::fs::write(prom, prometheus_text(&snap))?;
    }
    print!("{}", snap.incident_timeline());
    println!(
        "{} records ({} dropped) -> {} | {} recoveries, phase spans sum to reported latency ±1e-9 ✓",
        snap.records().count(),
        snap.dropped(),
        out_path,
        parents.len()
    );
    println!(
        "final world {} | {} decode tok in {:.1}s sim ({:.0} tok/s)",
        out.final_world,
        out.report.decode_tokens,
        out.report.wall_s,
        out.report.decode_tps()
    );
    Ok(())
}

/// Strict `--flag` number parsing for the degrade drill: a present but
/// malformed (or out-of-range) value prints the problem and exits 2 —
/// the same treatment unknown subcommands get — instead of silently
/// serving the default, which would turn a typo'd drill into a wrong
/// conclusion about mitigation.
fn strict_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> T {
    match args.get(key) {
        None => default,
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad --{key} value {v:?}\n\n{USAGE}");
            std::process::exit(2);
        }),
    }
}

/// Print a flag-validation failure and exit 2 (strict-parsing treatment).
fn flag_error(msg: String) -> ! {
    eprintln!("{msg}\n\n{USAGE}");
    std::process::exit(2);
}

/// Soft-fault drill: one GPU throttles to `--factor`× effective speed
/// under the `thermal_throttle` scenario. On the simulator this compares
/// no-mitigation vs capacity-rebalanced serving against the
/// capacity-proportional ideal; on the real engine it replays a
/// degrade → hard-fail → rejoin escalation token-paced and asserts the
/// outputs stay bit-exact.
fn degrade_cmd(args: &Args) -> anyhow::Result<()> {
    let backend = args.get_or("backend", "sim");
    // The strict --gpu range check must use the world the chosen backend
    // will actually serve with (the engine defaults to 3, the sim to 8).
    let world = strict_flag::<usize>(args, "world", if backend == "engine" { 3 } else { 8 });
    let gpu = strict_flag::<usize>(args, "gpu", 1);
    let factor = strict_flag::<f64>(args, "factor", 0.5);
    if world < 2 {
        flag_error(format!("--world {world} is too small for a straggler drill (need >= 2)"));
    }
    if gpu >= world {
        flag_error(format!("--gpu {gpu} out of range (world {world})"));
    }
    if !(factor.is_finite() && factor > 0.0 && factor < 1.0) {
        flag_error(format!("--factor {factor} must be in (0, 1) — 1.0 is not degraded"));
    }
    match backend {
        "engine" => degrade_engine(args, gpu, factor),
        "sim" => degrade_sim(args, world, gpu, factor),
        other => anyhow::bail!("unknown backend {other:?} (sim|engine)"),
    }
}

/// The simulator side of the drill: three runs over the same trace —
/// healthy, throttled without mitigation, throttled with capacity-aware
/// rebalancing — plus the capacity-proportional ideal they bracket.
fn degrade_sim(args: &Args, world: usize, gpu: usize, factor: f64) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let method = recovery_arg(args)?;
    let n = args.get_usize("requests", 32);
    let rate = args.get_f64("rate", 50.0);
    let seed = args.get_u64("seed", 42);
    // Default: the throttle spell covers the whole run (the restore
    // fires post-drain, time-warped) — the cleanest A/B. Strict like
    // --gpu/--factor: a bad spell shape would drill the wrong scenario.
    let slow_for = strict_flag::<f64>(args, "slow-for", 1e6);
    let at = strict_flag::<f64>(args, "at", 0.0);
    if !(slow_for.is_finite() && slow_for > 0.0) {
        flag_error(format!("--slow-for {slow_for} must be a positive duration"));
    }
    if !(at.is_finite() && at >= 0.0) {
        flag_error(format!("--at {at} must be a finite, non-negative time"));
    }
    let timeline = thermal_throttle(gpu, 1, at, factor, slow_for, 1.0);
    timeline.validate(world)?;

    section(&format!(
        "degrade drill: {} TP{world} ({}), gpu {gpu} at {factor}x for the whole run",
        model.name, system.name,
    ));
    let mut trace = mooncake_trace(n, seed);
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.clamp(1, 8_192);
        r.output_tokens = r.output_tokens.clamp(16, 48);
    }
    poisson_arrivals(&mut trace, rate, seed);

    let run = |mitigate: Option<bool>| -> anyhow::Result<f64> {
        let sim =
            OnlineSim::new(system.clone(), OnlineMode::Decode, world).with_model(model.clone());
        let mut session = sim.session();
        for r in &trace {
            session.submit_with(
                &vec![0u32; r.input_tokens],
                SubmitOptions::new(r.output_tokens).at(r.arrival),
            )?;
        }
        let report = match mitigate {
            None => session.run_to_completion()?,
            Some(auto) => {
                session.set_auto_rebalance(auto);
                replay(&mut session, &timeline, method, ReplayPace::Clock)?.report
            }
        };
        Ok(report.decode_tokens as f64 / report.wall_s)
    };

    let healthy = run(None)?;
    let baseline = run(Some(false))?;
    let mitigated = run(Some(true))?;
    let capacity = (world - 1) as f64 + factor;
    let ideal = healthy * capacity / world as f64;
    println!("healthy                  {healthy:>9.0} tok/s  (no fault)");
    println!(
        "no mitigation            {baseline:>9.0} tok/s  ({:>5.1}% of healthy — straggler paces all)",
        100.0 * baseline / healthy
    );
    println!(
        "rebalanced               {mitigated:>9.0} tok/s  ({:>5.1}% of healthy)",
        100.0 * mitigated / healthy
    );
    println!(
        "capacity-proportional    {ideal:>9.0} tok/s  ({capacity:.1}/{world} effective ranks)"
    );
    println!(
        "mitigation recovers {:.1}% of the ideal (gap to ideal {:+.1}%)",
        100.0 * mitigated / ideal,
        100.0 * (mitigated / ideal - 1.0)
    );
    anyhow::ensure!(mitigated > baseline, "rebalancing must beat the unmitigated straggler");
    Ok(())
}

/// The engine side: a degrade → hard-fail → rejoin escalation on the
/// same GPU, token-paced for determinism, asserting the outputs match a
/// fault-free run bit for bit (slowdowns only re-weight routing — they
/// never touch the numerics).
fn degrade_engine(args: &Args, gpu: usize, factor: f64) -> anyhow::Result<()> {
    let cfg = EngineConfig::from_args(args);
    let n = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 12);
    let per_sec = args.get_f64("tokens-per-sec", 2.0);
    let timeline = FaultTimeline::new(vec![
        TimelineEvent::slow_down(2.0, gpu, factor),
        TimelineEvent::fail(6.0, gpu), // the soft fault goes hard
        TimelineEvent::rejoin(10.0, gpu),
    ]);
    timeline.validate(cfg.world)?;

    section(&format!(
        "degrade drill on the real engine (world {}): gpu {gpu} throttles to {factor}x, then dies, then rejoins",
        cfg.world
    ));
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            let len = rng.range(8, 48);
            (0..len).map(|_| rng.range(1, 512) as u32).collect()
        })
        .collect();

    let mut reference = Engine::new(cfg.clone())?;
    for p in &prompts {
        reference.submit(p, max_new)?;
    }
    let expect = reference.run_to_completion()?;

    let mut engine = Engine::new(cfg)?;
    for p in &prompts {
        engine.submit(p, max_new)?;
    }
    let out = replay(&mut engine, &timeline, recovery_arg(args)?, ReplayPace::Tokens { per_sec })?;
    for a in &out.applied {
        println!(
            "  after {:>4} tokens  {:<8} gpu {} (rank {:>2})",
            (a.event.at * per_sec).ceil() as usize,
            a.event.kind.name(),
            a.event.gpu,
            a.rank,
        );
    }
    anyhow::ensure!(
        out.report.outputs_owned() == expect.outputs_owned(),
        "outputs diverged from the fault-free run"
    );
    println!(
        "final world {} | {} events applied | bit-exact vs the fault-free run ✓",
        out.final_world,
        out.applied.len()
    );
    Ok(())
}

/// Output tokens of `priority`-tier requests that finished without
/// aborting *and met their deadline* — the overload drill's headline
/// per-tier metric (plain goodput hides lateness: under FCFS everything
/// eventually completes, just uselessly late).
fn met_goodput(report: &FleetReport, priority: i32) -> usize {
    report
        .results
        .iter()
        .filter(|r| {
            r.result.priority == priority && !r.result.aborted && !r.result.deadline_missed()
        })
        .map(|r| r.result.output_tokens.len())
        .sum()
}

/// Overload-survival drill: the same priority-tiered storm
/// ([`overload_storm`]: 20% premium / 30% standard / 50% best-effort) at
/// `--load` × the fleet's *calibrated* sustainable rate, served three
/// ways — FCFS, SLO preemption + KV swap-out, and preemption + swap
/// behind the admission gateway. Calibration (all requests at t=0, FCFS)
/// measures what the fleet actually sustains, so `--load 2` is genuinely
/// 2× capacity on any machine and model. At `--load >= 2` the drill
/// exits nonzero unless admission control beats FCFS on the SLO tiers
/// and the preempt/swap machinery actually engaged.
fn overload_cmd(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 8);
    let replicas = args.get_usize("replicas", 2);
    let n = args.get_usize("requests", 160);
    let load = strict_flag::<f64>(args, "load", 2.0);
    let slo_flag = strict_flag::<f64>(args, "slo", 0.0);
    let max_batch = args.get_usize("max-batch", 16);
    let seed = args.get_u64("seed", 42);
    if replicas == 0 || n == 0 {
        flag_error(format!("--replicas {replicas} / --requests {n} must be positive"));
    }
    if !(load.is_finite() && load > 0.0) {
        flag_error(format!("--load {load} must be a positive overload multiple"));
    }
    let policy = AdmissionPolicy {
        target_load: strict_flag::<f64>(args, "target-load", 2048.0),
        queue_capacity: args.get_usize("queue-cap", 256),
        shed_load_factor: strict_flag::<f64>(args, "shed-factor", 3.0),
    };

    // The swap tier's reason to exist, asserted up front: restoring a
    // parked context over PCIe must undercut recomputing its prefill.
    let plan = system.plan(&model, world);
    let spec = GpuSpec::h100();
    let cost = StepCostModel::new(&plan, &spec, &Interconnect::new(spec.clone()));
    for tokens in [512usize, 4096, 16384] {
        anyhow::ensure!(
            cost.swap_time(tokens) < cost.recompute_time(tokens),
            "swap-in of {tokens} tokens ({:.2} ms) must be cheaper than recompute ({:.2} ms)",
            cost.swap_time(tokens) * 1e3,
            cost.recompute_time(tokens) * 1e3
        );
    }

    let build_fleet = |preempt: bool| -> Fleet {
        let mut sim =
            OnlineSim::new(system.clone(), OnlineMode::Decode, world).with_model(model.clone());
        sim.max_batch = max_batch;
        if preempt {
            sim = sim.with_preemption(PreemptPolicy::default());
        }
        let mut fleet = Fleet::new();
        for session in sim.sessions(replicas) {
            fleet.add_replica(Box::new(session));
        }
        fleet
    };

    // Calibrate: the storm's exact request lengths (seeded — rate and SLO
    // don't change them), all at t=0, FCFS. The makespan is the fleet's
    // sustained capacity for this workload.
    let shape = overload_storm(n, 1.0, 1.0, seed);
    let mut cal = build_fleet(false);
    for r in &shape {
        cal.submit_with(&r.prompt(), SubmitOptions::new(r.output_tokens.max(1)))?;
    }
    let cal_wall = cal.run_to_completion()?.wall_s;
    anyhow::ensure!(cal_wall > 0.0, "calibration run produced no makespan");
    let base_rate = n as f64 / cal_wall;
    let slo = if slo_flag > 0.0 { slo_flag } else { (cal_wall / 8.0).max(1.0) };
    let storm = overload_storm(n, base_rate * load, slo, seed);

    section(&format!(
        "overload drill: {replicas}x {} TP{world} ({}), {n} requests @ {load}x sustained \
         ({:.1} req/s), premium SLO {slo:.2}s",
        model.name,
        system.name,
        base_rate * load
    ));
    println!(
        "calibrated capacity: {n} requests in {cal_wall:.1}s ({base_rate:.1} req/s sustained)"
    );

    // FCFS: everything admitted, arrival order, no preemption.
    let mut fcfs = build_fleet(false);
    for r in &storm {
        fcfs.submit_with(&r.prompt(), r.options())?;
    }
    let fcfs_report = fcfs.run_to_completion()?;

    // Preempt+swap: same open door, but the scheduler triages.
    let mut pre = build_fleet(true);
    for r in &storm {
        pre.submit_with(&r.prompt(), r.options())?;
    }
    let (mut preemptions, mut swap_ins) = (0usize, 0usize);
    while !pre.is_idle() {
        for e in pre.step()? {
            match e.event {
                EngineEvent::RequestPreempted { .. } => preemptions += 1,
                EngineEvent::RequestResumed { .. } => swap_ins += 1,
                _ => {}
            }
        }
    }
    let pre_report = pre.report();

    // Preempt+swap+admission: the gateway queues SLO work over target
    // load and sheds best-effort.
    let mut adm_fleet = build_fleet(true);
    let mut gate = AdmissionGateway::new(policy);
    let workload: Vec<(Vec<u32>, SubmitOptions)> =
        storm.iter().map(|r| (r.prompt(), r.options())).collect();
    let adm_report = run_gated(&mut adm_fleet, &mut gate, &workload)?;

    // Per-tier table. "Unserved" SLO requests (shed or expired at the
    // gateway) never reach a replica report, so they are added back as
    // deadline misses — shedding must not launder a miss into a no-show.
    let tier_name = |p: i32| match p {
        TIER_PREMIUM => "premium",
        TIER_STANDARD => "standard",
        _ => "best-effort",
    };
    let tier_misses = |report: &FleetReport, p: i32| -> usize {
        let offered = storm.iter().filter(|r| r.priority == p).count();
        let reported = report.results.iter().filter(|r| r.result.priority == p).count();
        let unserved = offered.saturating_sub(reported);
        report.tier_deadline_misses(p) + if p > 0 { unserved } else { 0 }
    };
    println!(
        "{:<22} {:>9} {:>10} {:>10} {:>8}",
        "config / tier", "offered", "goodput", "met-SLO", "misses"
    );
    for (name, report) in
        [("fcfs", &fcfs_report), ("preempt+swap", &pre_report), ("+admission", &adm_report)]
    {
        for p in [TIER_PREMIUM, TIER_STANDARD, TIER_BEST_EFFORT] {
            println!(
                "{:<22} {:>9} {:>10} {:>10} {:>8}",
                format!("{name} {}", tier_name(p)),
                storm.iter().filter(|r| r.priority == p).count(),
                report.tier_goodput_tokens(p),
                met_goodput(report, p),
                tier_misses(report, p)
            );
        }
    }
    let stats = gate.stats();
    println!(
        "preempt+swap engaged: {preemptions} preemptions, {swap_ins} swap-ins | gateway: \
         {} admitted, {} queued, {} readmitted, {} shed, {} expired",
        stats.admitted, stats.queued, stats.readmitted, stats.shed, stats.expired
    );

    let slo_met = |r: &FleetReport| met_goodput(r, TIER_PREMIUM) + met_goodput(r, TIER_STANDARD);
    let slo_misses =
        |r: &FleetReport| tier_misses(r, TIER_PREMIUM) + tier_misses(r, TIER_STANDARD);
    let (fcfs_met, adm_met) = (slo_met(&fcfs_report), slo_met(&adm_report));
    let (fcfs_miss, adm_miss) = (slo_misses(&fcfs_report), slo_misses(&adm_report));
    println!(
        "SLO tiers: FCFS {fcfs_met} met-SLO tok / {fcfs_miss} misses → admission \
         {adm_met} met-SLO tok / {adm_miss} misses"
    );
    if load >= 2.0 {
        anyhow::ensure!(
            preemptions > 0 && swap_ins > 0,
            "preemption/swap never engaged at {load}x overload \
             (preemptions {preemptions}, swap-ins {swap_ins})"
        );
        anyhow::ensure!(
            adm_met > fcfs_met || adm_miss < fcfs_miss,
            "admission control must beat FCFS on the SLO tiers at {load}x overload: \
             met-SLO goodput {adm_met} vs {fcfs_met} tok, misses {adm_miss} vs {fcfs_miss}"
        );
        println!("admission control beats FCFS on the SLO tiers at {load}x overload ✓");
    }
    Ok(())
}

/// Heterogeneous + elastic fleet drill, in three movements:
///
/// 1. **Heterogeneity** — one mixed `--h100 + --a100` TP group, modeled
///    twice: the uniform FailSafe plan (every per-layer straggler max
///    waits on an equally-loaded A100) vs the capacity-proportional plan
///    (heads and KV apportioned by blended device capacity, batch homed
///    the same way). Asserts the proportional plan wins >= 1.3x combined
///    (prefill + decode) modeled goodput.
/// 2. **Elasticity** — a diurnal arrival trace (sinusoidal
///    `--base-rate`..`--peak-rate`, period `--period`) served by three
///    fleets: static all-H100, static mixed (half the replicas A100),
///    and the same mixed fleet behind the autoscaler. Bills each in
///    unit-seconds (1 unit = one H100-rank-second) and asserts the
///    autoscaled fleet beats its static twin on cost-per-token.
/// 3. **Spot churn** — prints the correlated-preemption schedule the
///    resilience tests race proactive drains against (stats only here).
fn elastic_cmd(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let h100s = args.get_usize("h100", 4);
    let a100s = args.get_usize("a100", 4);
    let replicas = args.get_usize("replicas", 4);
    let n = args.get_usize("requests", 96);
    let period = strict_flag::<f64>(args, "period", 60.0);
    let base_rate = strict_flag::<f64>(args, "base-rate", 0.5);
    let peak_rate = strict_flag::<f64>(args, "peak-rate", 8.0);
    let seed = args.get_u64("seed", 42);
    if h100s == 0 || a100s == 0 {
        flag_error(format!(
            "--h100 {h100s} / --a100 {a100s}: the drill needs a genuinely mixed group"
        ));
    }
    if replicas < 2 || n == 0 {
        flag_error(format!("--replicas {replicas} (need >= 2) / --requests {n} (need > 0)"));
    }
    if !(period > 0.0 && base_rate > 0.0 && peak_rate >= base_rate) {
        flag_error(format!(
            "--period {period} / --base-rate {base_rate} / --peak-rate {peak_rate} must \
             describe a positive diurnal swing"
        ));
    }

    // ── 1. capacity-proportional vs uniform sharding on mixed hardware ──
    let world = h100s + a100s;
    let specs: Vec<GpuSpec> = (0..world)
        .map(|r| if r < h100s { GpuSpec::h100() } else { GpuSpec::a100() })
        .collect();
    let ic = Interconnect::for_devices(&specs);
    let uni = StepCostModel::new_heterogeneous(&ShardPlan::failsafe(&model, world), &specs, &ic);
    let prop = StepCostModel::new_heterogeneous(
        &ShardPlan::capacity_proportional(&model, &specs),
        &specs,
        &ic,
    );
    section(&format!(
        "elastic drill: {} on {h100s}x H100 + {a100s}x A100 (TP{world})",
        model.name
    ));
    let weights = capacity_weights(&specs, CAPACITY_DECODE_FRAC);
    println!(
        "capacity weights: H100 1.00, A100 {:.2} (blended roofline, decode_frac {})",
        weights[world - 1],
        CAPACITY_DECODE_FRAC
    );
    // A representative serving round: one 4096-token prefill plus 64
    // decode steps of a 64-deep batch, homed uniformly vs by capacity.
    let (batch, ctx, steps) = (64usize, 4096usize, 64usize);
    let uni_batch = DecodeWork::capacity_homed(batch, ctx, &vec![1.0; world]);
    let prop_batch = DecodeWork::capacity_homed(batch, ctx, &weights);
    let chunks = vec![PrefillWork { tokens: ctx, context: 0, home: 0 }];
    let goodput = |cost: &StepCostModel, batch: &[DecodeWork]| -> f64 {
        let wall = cost.prefill_step_time(&chunks) + steps as f64 * cost.decode_step_time(batch);
        (ctx + steps * batch.len()) as f64 / wall
    };
    let (g_uni, g_prop) = (goodput(&uni, &uni_batch), goodput(&prop, &prop_batch));
    let ratio = g_prop / g_uni;
    println!(
        "modeled goodput: uniform plan {g_uni:.0} tok/s, capacity-proportional {g_prop:.0} \
         tok/s ({ratio:.2}x)"
    );
    anyhow::ensure!(
        ratio >= 1.3,
        "capacity-proportional plan must beat uniform sharding >= 1.3x on mixed hardware, \
         got {ratio:.2}x"
    );
    println!("capacity-proportional sharding beats uniform >= 1.3x on mixed hardware ✓");

    // ── 2. homogeneous vs heterogeneous vs autoscaled under diurnal load ──
    let mut trace = mooncake_trace(n, seed);
    diurnal_arrivals(&mut trace, base_rate, peak_rate, period, seed);
    let workload: Vec<(Vec<u32>, SubmitOptions)> = trace
        .iter()
        .map(|r| {
            (
                vec![1u32; r.input_tokens.max(1)],
                SubmitOptions::new(r.output_tokens.max(1)).at(r.arrival),
            )
        })
        .collect();
    let a100_replicas = replicas / 2;
    let build = |mixed: bool| -> Fleet {
        let h_sim = OnlineSim::new(system.clone(), OnlineMode::Decode, world)
            .with_model(model.clone());
        let a_sim = OnlineSim::new(system.clone(), OnlineMode::Decode, world)
            .with_model(model.clone())
            .with_devices(vec![GpuSpec::a100(); world]);
        let mut fleet = Fleet::new();
        let h_count = if mixed { replicas - a100_replicas } else { replicas };
        for session in h_sim.sessions(h_count) {
            fleet.add_replica(Box::new(session));
        }
        if mixed {
            for session in a_sim.sessions(a100_replicas) {
                fleet.add_replica(Box::new(session));
            }
        }
        fleet
    };
    let policy = AdmissionPolicy::default();
    let scale_policy = AutoscalePolicy {
        scale_up_load: strict_flag::<f64>(args, "scale-up-load", 512.0),
        scale_down_load: strict_flag::<f64>(args, "scale-down-load", 64.0),
        cooldown_s: strict_flag::<f64>(args, "cooldown", 1.0),
        ..AutoscalePolicy::default()
    };

    let mut homo = build(false);
    let mut gate = AdmissionGateway::new(policy);
    let (homo_report, homo_bill) = run_static(&mut homo, &mut gate, &workload)?;

    let mut hetero = build(true);
    let mut gate = AdmissionGateway::new(policy);
    let (hetero_report, hetero_bill) = run_static(&mut hetero, &mut gate, &workload)?;

    let mut auto_fleet = build(true);
    let mut gate = AdmissionGateway::new(policy);
    let mut scaler = Autoscaler::new(scale_policy);
    let auto_report = run_autoscaled(&mut auto_fleet, &mut gate, &mut scaler, &workload)?;
    let auto_bill = scaler.unit_seconds();

    let cpt = |bill: f64, r: &FleetReport| -> f64 {
        if r.goodput_tokens() == 0 { f64::INFINITY } else { bill / r.goodput_tokens() as f64 }
    };
    println!(
        "\ndiurnal trace: {n} requests, rate {base_rate}..{peak_rate} req/s, period {period}s"
    );
    println!(
        "{:<26} {:>9} {:>9} {:>11} {:>14}",
        "fleet", "goodput", "wall s", "unit-sec", "cost/1k tok"
    );
    let (ups, downs) = scaler.action_counts();
    for (name, report, bill) in [
        (format!("{replicas}x H100 static"), &homo_report, homo_bill),
        (
            format!("{}+{} H100/A100 static", replicas - a100_replicas, a100_replicas),
            &hetero_report,
            hetero_bill,
        ),
        (format!("same, autoscaled ({ups}up/{downs}dn)"), &auto_report, auto_bill),
    ] {
        println!(
            "{:<26} {:>9} {:>9.1} {:>11.0} {:>14.3}",
            name,
            report.goodput_tokens(),
            report.wall_s,
            bill,
            1000.0 * cpt(bill, report)
        );
    }
    let static_cpt = cpt(hetero_bill, &hetero_report);
    let auto_cpt = cpt(auto_bill, &auto_report);
    anyhow::ensure!(
        ups >= 1 && downs >= 1,
        "the diurnal swing must drive both scale directions (got {ups} up / {downs} down)"
    );
    anyhow::ensure!(
        auto_cpt < static_cpt,
        "autoscaling must beat static peak provisioning on cost-per-token: \
         {auto_cpt:.4} vs {static_cpt:.4} unit-s/tok"
    );
    println!(
        "autoscaled cost-per-token beats static peak provisioning \
         ({:.3} vs {:.3} unit-s per 1k tok) ✓",
        1000.0 * auto_cpt,
        1000.0 * static_cpt
    );
    println!(
        "fleet unit rates: homogeneous {:.1}/s, mixed {:.1}/s",
        fleet_unit_rate(&homo),
        fleet_unit_rate(&hetero)
    );

    // ── 3. spot-churn schedule (the resilience tests race this) ──
    let preemptions = spot_preemptions(world, 3, 2.0 * period.max(120.0), 5.0 * period, seed);
    let tl = spot_timeline(&preemptions);
    tl.validate(world)?;
    let mean_warn =
        preemptions.iter().map(|p| p.warning_s()).sum::<f64>() / preemptions.len() as f64;
    println!(
        "spot schedule: {} preemptions in 3 waves, mean warning {:.0}s, worst wave takes \
         {} of {world} GPUs",
        preemptions.len(),
        mean_warn,
        tl.max_concurrent_down()
    );
    Ok(())
}

/// Multi-replica fleet: N independent backends behind the cluster-level
/// load-aware router, with a fault timeline on one replica while the rest
/// keep serving. Sim backend by default; `--backend engine` needs AOT
/// artifacts (one engine per replica).
fn fleet_cmd(args: &Args) -> anyhow::Result<()> {
    let method = recovery_arg(args)?;
    let pace = match args.get_or("pace", "clock") {
        "clock" => ReplayPace::Clock,
        "tokens" => ReplayPace::Tokens { per_sec: args.get_f64("tokens-per-sec", 100.0) },
        other => anyhow::bail!("unknown pace {other:?} (clock|tokens)"),
    };
    match args.get_or("backend", "sim") {
        "engine" => fleet_engine(args, method, pace),
        "sim" => fleet_sim(args, method, pace),
        other => anyhow::bail!("unknown backend {other:?} (sim|engine)"),
    }
}

/// The fleet's fault plan: one timeline on `--fault-replica` (default 0),
/// from `--timeline FILE` or `--scenario`; `--scenario none` serves
/// fault-free.
fn fleet_timelines(
    args: &Args,
    world: usize,
    replicas: usize,
) -> anyhow::Result<Vec<(usize, FaultTimeline)>> {
    if args.get("timeline").is_none() && args.get_or("scenario", "cascade") == "none" {
        return Ok(Vec::new());
    }
    let fault_replica = args.get_usize("fault-replica", 0);
    anyhow::ensure!(
        fault_replica < replicas,
        "--fault-replica {fault_replica} out of range (replicas {replicas})"
    );
    let timeline = build_timeline(args, world)?;
    timeline.validate(world)?;
    Ok(vec![(fault_replica, timeline)])
}

/// Fleet on the cost-model backend: a shared Mooncake-style arrival trace
/// placed across the replicas by the load-aware fleet router.
fn fleet_sim(args: &Args, method: RecoveryMethod, pace: ReplayPace) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 8);
    let replicas = args.get_usize("replicas", 4);
    let n = args.get_usize("requests", 80);
    let rate = args.get_f64("rate", 8.0);
    let seed = args.get_u64("seed", 42);
    let timelines = fleet_timelines(args, world, replicas)?;

    section(&format!(
        "fleet: {replicas} × {} TP{world} replicas (sim), {n} requests @ {rate} req/s",
        system.name
    ));
    let mut trace = mooncake_trace(n, seed);
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.clamp(1, 16_000);
        r.output_tokens = r.output_tokens.clamp(8, 64);
    }
    poisson_arrivals(&mut trace, rate, seed);

    let sim = OnlineSim::new(system, OnlineMode::Decode, world).with_model(model);
    let mut fleet = Fleet::new();
    for session in sim.sessions(replicas) {
        fleet.add_replica(Box::new(session));
    }
    for r in &trace {
        fleet.submit_with(
            &vec![0u32; r.input_tokens],
            SubmitOptions::new(r.output_tokens).at(r.arrival),
        )?;
    }
    let out = fleet.replay(&timelines, method, pace)?;
    print_fleet_outcome(&out);
    Ok(())
}

/// Fleet on the real engine (needs AOT artifacts): one engine per
/// replica, random prompts placed by the fleet router.
fn fleet_engine(args: &Args, method: RecoveryMethod, pace: ReplayPace) -> anyhow::Result<()> {
    let cfg = EngineConfig::from_args(args);
    let replicas = args.get_usize("replicas", 2);
    let n = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 12);
    let timelines = fleet_timelines(args, cfg.world, replicas)?;

    section(&format!(
        "fleet: {replicas} × TP{} replicas on the real engine ({n} requests, budget {max_new})",
        cfg.world
    ));
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut fleet = Fleet::new();
    for _ in 0..replicas {
        fleet.add_replica(Box::new(Engine::new(cfg.clone())?));
    }
    for _ in 0..n {
        let len = rng.range(8, 48);
        let prompt: Vec<u32> = (0..len).map(|_| rng.range(1, 512) as u32).collect();
        fleet.submit_with(&prompt, SubmitOptions::new(max_new))?;
    }
    let out = fleet.replay(&timelines, method, pace)?;
    print_fleet_outcome(&out);
    Ok(())
}

/// Shared printer for both fleet backends: applied events, per-replica
/// summaries, and the fleet-level goodput line.
fn print_fleet_outcome(out: &failsafe::fleet::FleetReplayOutcome) {
    for (replica, a) in &out.applied {
        println!(
            "  replica {replica}: t={:>8.2}s  {:<6} gpu {} (rank {:>2})  latency {:>8.1} ms",
            a.applied_at,
            a.event.kind.name(),
            a.event.gpu,
            a.rank,
            a.latency_s * 1e3
        );
    }
    let report = &out.report;
    for (r, rep) in report.replicas.iter().enumerate() {
        let mut ttft = report.replica_ttft_cdf(r);
        println!(
            "  replica {r}: world {} | {} req | {} decode tok | goodput {:>6.0} tok/s \
             | TTFT p50/p90 {:.2}/{:.2} s",
            out.final_worlds[r],
            rep.results.len(),
            rep.decode_tokens,
            report.replica_goodput_tps(r),
            ttft.quantile(0.5),
            ttft.quantile(0.9),
        );
    }
    let best = (0..report.replicas.len())
        .map(|r| report.replica_goodput_tps(r))
        .fold(0.0, f64::max);
    println!(
        "fleet: goodput {:.0} tok/s over {:.1}s (best single replica {:.0} tok/s) \
         | {} redirected | {} reconfigs",
        report.goodput_tps(),
        report.wall_s,
        best,
        out.redirected,
        report.recoveries()
    );
}

fn recover(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let world = args.get_usize("world", 8);
    let n_req = args.get_usize("requests", 60);
    let ctx = args.get_usize("ctx", 8000);
    let failed: RankId = args.get_usize("fail-rank", 3);

    section(&format!("recovery costing: {} TP{} -> TP{}", model.name, world, world - 1));
    let spec = GpuSpec::h100();
    let ic = Interconnect::new(spec.clone());
    let old = ShardPlan::failsafe(&model, world);
    let survivor_map: Vec<Option<RankId>> = (0..world)
        .map(|r| if r == failed { None } else { Some(if r < failed { r } else { r - 1 }) })
        .collect();
    let new_plan = ShardPlan {
        model: model.clone(),
        heads: HeadAssignment::new(
            failsafe::sharding::AttentionPolicy::Hybrid,
            model.n_kv_heads,
            model.n_layers,
            world - 1,
        ),
        ffn: old.ffn.reshard(&survivor_map, world - 1),
    };
    let reqs: Vec<(RequestId, usize, RankId)> =
        (0..n_req as u64).map(|i| (i, ctx, (i as usize) % world)).collect();
    let mut backup = BackupStore::new(1 << 42);
    for &(id, t, _) in &reqs {
        backup.backup(id, t, model.kv_bytes_per_token());
    }
    let input = RecoveryInput {
        spec: &spec,
        ic: &ic,
        old_plan: &old,
        new_plan: &new_plan,
        survivor_map: &survivor_map,
        failed_rank: failed,
        requests: &reqs,
        backup: &backup,
    };
    for method in [
        RecoveryMethod::Recompute,
        RecoveryMethod::Host,
        RecoveryMethod::Full,
        RecoveryMethod::Oracle,
    ] {
        let out = plan_recovery(method, &input);
        println!("{:<16} {:.3} s", method.name(), out.total_s);
    }
    Ok(())
}

/// Shared-prefix drill: the same repeat-fanout trace (K distinct
/// prefixes, each continued by N requests) served twice on the online
/// simulator — prefix trie off (cold) and on (shared) — with staggered
/// arrivals so every continuation lands after its donor is admitted.
fn prefix_cmd(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = args.get_usize("world", 8);
    let prefixes = args.get_usize("prefixes", 4);
    let fanout = args.get_usize("fanout", 8);
    let prefix_tokens = args.get_usize("prefix-tokens", 2048);
    let suffix_tokens = args.get_usize("suffix-tokens", 64);
    let seed = args.get_u64("seed", 42);
    if prefixes < 1 || fanout < 1 || prefix_tokens < 1 {
        flag_error(format!(
            "--prefixes {prefixes} / --fanout {fanout} / --prefix-tokens {prefix_tokens} \
             must all be >= 1"
        ));
    }

    section(&format!(
        "shared-prefix drill: {} TP{world} ({}), {prefixes} prefixes × {fanout} continuations \
         of {prefix_tokens}+{suffix_tokens} tokens",
        model.name, system.name
    ));
    let fan = repeat_fanout(prefixes, fanout, prefix_tokens, suffix_tokens, seed);
    type PrefixRun = (failsafe::engine::ServeReport, f64, failsafe::prefix::PrefixStats);
    let run = |sharing: bool| -> anyhow::Result<PrefixRun> {
        let sim = OnlineSim::new(system.clone(), OnlineMode::Decode, world)
            .with_model(model.clone())
            .with_prefix_sharing(sharing);
        let mut session = sim.session();
        for (i, r) in fan.iter().enumerate() {
            session.submit_with(
                &r.prompt,
                SubmitOptions::new(r.request.output_tokens).at(i as f64 * 0.25),
            )?;
        }
        let report = session.run_to_completion()?;
        Ok((report, session.peak_kv_bytes(), session.prefix_stats()))
    };
    let (cold, cold_kv, _) = run(false)?;
    let (warm, warm_kv, stats) = run(true)?;

    println!("{:<12} {:>13} {:>15} {:>10}", "", "prefill tok", "peak KV (GB)", "wall (s)");
    println!(
        "{:<12} {:>13} {:>15.2} {:>10.1}",
        "no sharing",
        cold.prefill_tokens,
        cold_kv / 1e9,
        cold.wall_s
    );
    println!(
        "{:<12} {:>13} {:>15.2} {:>10.1}",
        "shared",
        warm.prefill_tokens,
        warm_kv / 1e9,
        warm.wall_s
    );
    println!(
        "savings: {:.1}x less prefill, {:.1}x less peak KV",
        cold.prefill_tokens as f64 / warm.prefill_tokens.max(1) as f64,
        cold_kv / warm_kv.max(1.0)
    );
    println!(
        "trie: {} lookups, {} hits ({} tokens adopted), {} chunks inserted",
        stats.lookups, stats.hits, stats.hit_tokens, stats.inserted_chunks
    );
    anyhow::ensure!(
        warm.prefill_tokens <= cold.prefill_tokens && warm_kv <= cold_kv * 1.001,
        "sharing must never add prefill work or resident KV"
    );
    Ok(())
}

/// Event-core drill: run one burst workload through all three simulator
/// cores (per-token stepper, bit-exact event core, batched span core),
/// print the rounds/spans/timing table, and assert the event core's
/// report is bit-identical to the stepper's. The same comparison runs —
/// randomized, with faults — in `tests/simcore_tests.rs`; this drill is
/// the operator-facing smoke for one deterministic workload.
fn simcore_cmd(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let system = system_arg(args)?;
    let world = strict_flag::<usize>(args, "world", 8);
    let requests = strict_flag::<usize>(args, "requests", 512);
    let burst = strict_flag::<usize>(args, "burst", 64);
    let output_tokens = strict_flag::<usize>(args, "output-tokens", 64);
    if world < 1 || requests < 1 || burst < 1 || output_tokens < 1 {
        flag_error(format!(
            "--world {world} / --requests {requests} / --burst {burst} / \
             --output-tokens {output_tokens} must all be >= 1"
        ));
    }

    section(&format!(
        "event-core drill: {} TP{world} ({}), {requests} requests in bursts of {burst} × \
         {output_tokens} tokens",
        model.name, system.name
    ));
    let prompt = vec![7u32; 64];
    type CoreRun =
        (failsafe::engine::ServeReport, failsafe::simulator::CoreStats, std::time::Duration);
    let run = |mode: CoreMode| -> anyhow::Result<CoreRun> {
        let mut session = OnlineSim::new(system.clone(), OnlineMode::Decode, world)
            .with_model(model.clone())
            .session();
        session.set_core_mode(mode);
        for i in 0..requests {
            session.submit_with(
                &prompt,
                SubmitOptions::new(output_tokens).at((i / burst) as f64 * 10.0),
            )?;
        }
        let start = std::time::Instant::now();
        let mut events = Vec::new();
        while !session.is_idle() {
            session.advance_until(AdvanceLimit::unbounded(), &mut events)?;
            events.clear();
        }
        let wall = start.elapsed();
        let stats = session.core_stats();
        Ok((session.report(), stats, wall))
    };

    println!(
        "{:<10} {:>14} {:>10} {:>10} {:>12}",
        "core", "decode rounds", "spans", "ratio", "wall"
    );
    let mut reports = Vec::new();
    for mode in [CoreMode::Stepper, CoreMode::Exact, CoreMode::Batched] {
        let (report, stats, wall) = run(mode)?;
        let ratio = if stats.spans == 0 {
            "-".to_string()
        } else {
            format!("{:.1}×", stats.iters_ratio())
        };
        println!(
            "{:<10} {:>14} {:>10} {:>10} {:>12}",
            format!("{mode:?}").to_lowercase(),
            stats.steps,
            stats.spans,
            ratio,
            format!("{wall:.1?}")
        );
        reports.push(report);
    }

    let (stepper, exact, batched) = (&reports[0], &reports[1], &reports[2]);
    anyhow::ensure!(
        stepper.wall_s.to_bits() == exact.wall_s.to_bits()
            && stepper.steps == exact.steps
            && stepper.decode_tokens == exact.decode_tokens
            && stepper.prefill_tokens == exact.prefill_tokens
            && stepper.outputs_owned() == exact.outputs_owned()
            && stepper
                .results
                .iter()
                .zip(exact.results.iter())
                .all(|(a, b)| a.ttft_s.map(f64::to_bits) == b.ttft_s.map(f64::to_bits)),
        "event core diverged from the per-token stepper"
    );
    anyhow::ensure!(
        stepper.decode_tokens == batched.decode_tokens
            && stepper.prefill_tokens == batched.prefill_tokens,
        "batched core lost or invented tokens"
    );
    println!(
        "exact core bit-identical to the stepper across {} requests ✓ \
         (batched core conserves tokens)",
        stepper.results.len()
    );
    Ok(())
}

fn traces(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 3000);
    let seed = args.get_u64("seed", 2);
    for (name, t) in [
        ("openthoughts", openthoughts_trace(n, seed)),
        ("mooncake", mooncake_trace(n, seed)),
    ] {
        let inp = TraceStats::of(&t.iter().map(|r| r.input_tokens).collect::<Vec<_>>());
        let out = TraceStats::of(&t.iter().map(|r| r.output_tokens).collect::<Vec<_>>());
        println!(
            "{name:<14} in: mean {:>6.0} median {:>6.0} max {:>6} | out: mean {:>6.0} median {:>6.0} max {:>6}",
            inp.mean, inp.median, inp.max, out.mean, out.median, out.max
        );
    }
    let avail = gcp_availability(64, 6.0 * 3600.0, 42);
    println!(
        "gcp-availability: {} events, min {}",
        avail.len(),
        avail.iter().map(|e| e.1).min().unwrap()
    );
    Ok(())
}
