//! Fleet autoscaling: grow and shrink the set of *active* replicas from
//! the load signals the fleet already produces.
//!
//! The fleet is provisioned at its peak size once; what the autoscaler
//! changes is how many replicas are actually serving (and being billed).
//! Scaling **down** drains a replica with the existing
//! [`Fleet::drain`] machinery — no new placements, fresh requests
//! redirect immediately, started requests finish in place — so no token
//! is ever lost to a scale-down. Scaling **up** resumes a drained
//! replica ([`Fleet::resume`]); the next router placement and gateway
//! pump start feeding it. Both directions reuse the exact reconfig +
//! redirect paths that failure handling exercises, which is what makes
//! the autoscaled fleet differentially testable against a static one.
//!
//! Signals, read per tick: router load per health-effective capacity
//! ([`fleet_load`]) and the admission gateway's queue depth — a deep
//! gateway queue means the fleet is refusing work the operator wants
//! served, the strongest possible scale-up signal.
//!
//! Cost accounting bills **unit-seconds**: one unit-second is one
//! H100-rank active for one second, so an all-A100 replica accrues at
//! ~0.4× the rate of an H100 one ([`crate::cluster::DeviceClass`] and
//! [`ServingBackend::hardware_capacity`] agree on the ratio). A
//! draining replica keeps billing until it actually goes idle — drains
//! are not free the instant they are requested.

use anyhow::Result;

use super::admission::{fleet_load, fleet_now, run_gated, AdmissionGateway};
use super::{Fleet, FleetReport, ReplicaId};
use crate::engine::SubmitOptions;
use crate::obs::{ObsSink, Observer};
use crate::SimTime;

/// Autoscaler thresholds. Loads are in the same booked-token-units per
/// effective rank that [`fleet_load`] reports (and that
/// [`super::AdmissionPolicy::target_load`] gates on).
#[derive(Debug, Clone, Copy)]
pub struct AutoscalePolicy {
    /// Load at or above which one drained replica is resumed per tick.
    pub scale_up_load: f64,
    /// Load at or below which one active replica is drained per tick.
    pub scale_down_load: f64,
    /// Gateway queue depth that also triggers a scale-up (parked work is
    /// demand the load signal cannot see).
    pub queue_up: usize,
    /// Never drain below this many active replicas.
    pub min_active: usize,
    /// Never resume above this many active replicas.
    pub max_active: usize,
    /// Minimum simulated seconds between scaling actions (hysteresis —
    /// without it the scaler flaps on every load oscillation).
    pub cooldown_s: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            scale_up_load: 1536.0,
            scale_down_load: 256.0,
            queue_up: 1,
            min_active: 1,
            max_active: usize::MAX,
            cooldown_s: 2.0,
        }
    }
}

/// One scaling action, in fleet time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    pub at: SimTime,
    pub replica: ReplicaId,
    /// True for a resume (scale-up), false for a drain (scale-down).
    pub up: bool,
}

/// The scaling loop driver plus the unit-second meter. One instance per
/// fleet run; tick it after every fleet step (and gateway pump).
pub struct Autoscaler {
    policy: AutoscalePolicy,
    last_action: SimTime,
    events: Vec<ScaleEvent>,
    /// Unit-seconds billed per replica, settled lazily up to
    /// `settled_at` on every tick.
    billed: Vec<f64>,
    settled_at: SimTime,
    /// Flight-recorder seam for scale decisions and billing ticks
    /// (passive, detached by default).
    obs: ObsSink,
    /// Last time a `billing.settle` record was emitted — settlements
    /// happen every tick, records at most once per simulated second.
    last_billing_note: SimTime,
}

impl Autoscaler {
    pub fn new(policy: AutoscalePolicy) -> Autoscaler {
        assert!(policy.scale_up_load > policy.scale_down_load, "thresholds must not overlap");
        assert!(policy.min_active >= 1, "an autoscaled fleet keeps at least one active replica");
        assert!(policy.cooldown_s >= 0.0);
        Autoscaler {
            policy,
            last_action: f64::NEG_INFINITY,
            events: Vec::new(),
            billed: Vec::new(),
            settled_at: 0.0,
            obs: ObsSink::none(),
            last_billing_note: f64::NEG_INFINITY,
        }
    }

    /// Attach a flight-recorder observer: scale-up/-down decisions and
    /// billing settlements record with the load and queue depth they
    /// acted on.
    pub fn set_observer(&mut self, observer: Box<dyn Observer>) {
        self.obs.set(observer);
    }

    pub fn policy(&self) -> AutoscalePolicy {
        self.policy
    }

    /// All scaling actions so far, in order.
    pub fn scale_events(&self) -> &[ScaleEvent] {
        &self.events
    }

    /// `(ups, downs)` action counts — the differential fuzz harness
    /// asserts both directions were exercised.
    pub fn action_counts(&self) -> (usize, usize) {
        let ups = self.events.iter().filter(|e| e.up).count();
        (ups, self.events.len() - ups)
    }

    /// Unit-seconds billed so far (settled through the last tick).
    pub fn unit_seconds(&self) -> f64 {
        self.billed.iter().sum()
    }

    /// Billed cost per goodput token — the figure of merit the elastic
    /// bench compares against static peak provisioning.
    pub fn cost_per_token(&self, report: &FleetReport) -> f64 {
        let tokens = report.goodput_tokens();
        if tokens == 0 {
            f64::INFINITY
        } else {
            self.unit_seconds() / tokens as f64
        }
    }

    /// Pre-run setup: drain the highest-id replicas down to
    /// `min_active`, so the fleet starts small and *grows* into demand.
    /// Not billed and not cooldown-relevant — the run has not started.
    pub fn park_to_min(&mut self, fleet: &mut Fleet) -> Result<()> {
        for r in (self.policy.min_active..fleet.len()).rev() {
            fleet.drain(r)?;
        }
        Ok(())
    }

    /// Advance the meter and apply at most one scaling action. Call
    /// after every fleet step with the gateway's current queue depth.
    pub fn tick(&mut self, fleet: &mut Fleet, queue_len: usize) -> Result<Option<ScaleEvent>> {
        let now = fleet_now(fleet);
        self.settle(fleet, now);
        if now - self.last_action < self.policy.cooldown_s {
            return Ok(None);
        }
        let load = fleet_load(fleet);
        let active: Vec<ReplicaId> =
            (0..fleet.len()).filter(|&r| !fleet.is_draining(r)).collect();
        let parked: Vec<ReplicaId> =
            (0..fleet.len()).filter(|&r| fleet.is_draining(r)).collect();

        let event = if (load >= self.policy.scale_up_load || queue_len >= self.policy.queue_up)
            && active.len() < self.policy.max_active
        {
            // Resume the lowest-id drained replica (deterministic).
            parked.first().map(|&r| {
                fleet.resume(r);
                ScaleEvent { at: now, replica: r, up: true }
            })
        } else if load <= self.policy.scale_down_load && active.len() > self.policy.min_active {
            // Drain the highest-id active replica (deterministic); its
            // fresh requests redirect, started ones finish in place.
            match active.last() {
                Some(&r) => {
                    fleet.drain(r)?;
                    Some(ScaleEvent { at: now, replica: r, up: false })
                }
                None => None,
            }
        } else {
            None
        };
        if let Some(e) = event {
            self.last_action = now;
            self.events.push(e);
            if self.obs.enabled() {
                let name = if e.up { "scale.up" } else { "scale.down" };
                let actives = if e.up { active.len() + 1 } else { active.len() - 1 };
                self.obs.decision(
                    now,
                    None,
                    name,
                    vec![
                        ("replica", e.replica.into()),
                        ("load", load.into()),
                        ("queue", queue_len.into()),
                        ("active", actives.into()),
                    ],
                );
            }
        }
        Ok(event)
    }

    /// Settle unit-second billing up to `now`: every replica that is
    /// serving — or still draining in-flight work — accrues at its
    /// hardware capacity.
    fn settle(&mut self, fleet: &Fleet, now: SimTime) {
        self.billed.resize(fleet.len(), 0.0);
        let dt = now - self.settled_at;
        if dt <= 0.0 {
            return;
        }
        for r in 0..fleet.len() {
            if !fleet.is_draining(r) || !fleet.backend(r).is_idle() {
                self.billed[r] += fleet.backend(r).hardware_capacity() * dt;
            }
        }
        self.settled_at = now;
        if self.obs.enabled() && now - self.last_billing_note >= 1.0 {
            self.last_billing_note = now;
            let total: f64 = self.billed.iter().sum();
            self.obs.decision(
                now,
                None,
                "billing.settle",
                vec![("dt_s", dt.into()), ("unit_seconds", total.into())],
            );
        }
    }
}

/// Unit-second rate of the *whole* fleet regardless of draining state —
/// what a static peak-provisioned deployment pays per second. Multiply
/// by a run's wall-clock for the static bill the autoscaler undercuts.
pub fn fleet_unit_rate(fleet: &Fleet) -> f64 {
    (0..fleet.len()).map(|r| fleet.backend(r).hardware_capacity()).sum()
}

/// Drive an arrival-ordered workload through a gated, autoscaled fleet
/// to completion: [`run_gated`]'s loop with an autoscaler tick after
/// every step. The fleet starts parked at `min_active` and grows into
/// demand; the meter settles through the final step.
pub fn run_autoscaled(
    fleet: &mut Fleet,
    gateway: &mut AdmissionGateway,
    scaler: &mut Autoscaler,
    workload: &[(Vec<u32>, SubmitOptions)],
) -> Result<FleetReport> {
    scaler.park_to_min(fleet)?;
    let mut order: Vec<usize> = (0..workload.len()).collect();
    order.sort_by(|&a, &b| workload[a].1.arrival.total_cmp(&workload[b].1.arrival));
    for i in order {
        let (prompt, opts) = &workload[i];
        while fleet_now(fleet) < opts.arrival && !fleet.is_idle() {
            fleet.step()?;
            gateway.pump(fleet)?;
            scaler.tick(fleet, gateway.queue_len())?;
        }
        gateway.pump(fleet)?;
        gateway.offer(fleet, prompt, *opts)?;
        scaler.tick(fleet, gateway.queue_len())?;
    }
    loop {
        let admitted = gateway.pump(fleet)?;
        scaler.tick(fleet, gateway.queue_len())?;
        if fleet.is_idle() {
            if gateway.queue_len() == 0 {
                break;
            }
            if admitted == 0 {
                gateway.shed_remaining();
                break;
            }
        } else {
            fleet.step()?;
        }
    }
    scaler.settle(fleet, fleet_now(fleet));
    Ok(fleet.report())
}

/// The static baseline for the same workload: every replica active for
/// the whole run, no scaling. Returns the report and the peak bill
/// (`fleet_unit_rate × wall`).
pub fn run_static(
    fleet: &mut Fleet,
    gateway: &mut AdmissionGateway,
    workload: &[(Vec<u32>, SubmitOptions)],
) -> Result<(FleetReport, f64)> {
    let rate = fleet_unit_rate(fleet);
    let report = run_gated(fleet, gateway, workload)?;
    let bill = rate * report.wall_s;
    Ok((report, bill))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::AdmissionPolicy;
    use crate::simulator::{OnlineMode, OnlineSim, SystemConfig};

    fn fleet(replicas: usize) -> Fleet {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4);
        let mut fleet = Fleet::new();
        for session in sim.sessions(replicas) {
            fleet.add_replica(Box::new(session));
        }
        fleet
    }

    fn burst_then_quiet() -> Vec<(Vec<u32>, SubmitOptions)> {
        // A front-loaded burst followed by a thin tail: load spikes,
        // then collapses — both scaling directions must fire.
        let mut w = Vec::new();
        for i in 0..24 {
            w.push((vec![1u32; 512], SubmitOptions::new(32).at(i as f64 * 1e-3)));
        }
        for i in 0..4 {
            w.push((vec![1u32; 64], SubmitOptions::new(4).at(40.0 + i as f64 * 20.0)));
        }
        w
    }

    #[test]
    fn scales_up_under_load_and_down_when_quiet() {
        let mut f = fleet(4);
        let mut gate = AdmissionGateway::new(AdmissionPolicy {
            target_load: 512.0,
            ..AdmissionPolicy::default()
        });
        let mut scaler = Autoscaler::new(AutoscalePolicy {
            scale_up_load: 384.0,
            scale_down_load: 16.0,
            cooldown_s: 0.5,
            ..AutoscalePolicy::default()
        });
        let report = run_autoscaled(&mut f, &mut gate, &mut scaler, &burst_then_quiet()).unwrap();
        let (ups, downs) = scaler.action_counts();
        assert!(ups >= 1, "the burst must trigger at least one scale-up");
        assert!(downs >= 1, "the quiet tail must trigger at least one scale-down");
        // Nothing is lost to scaling: every request completes.
        assert_eq!(report.results.len(), 28);
        assert!(report.results.iter().all(|r| !r.result.aborted));
        assert!(scaler.unit_seconds() > 0.0);
        assert!(scaler.cost_per_token(&report).is_finite());
    }

    #[test]
    fn autoscaled_bill_undercuts_static_peak_on_bursty_load() {
        let workload = burst_then_quiet();
        let policy = AdmissionPolicy { target_load: 512.0, ..AdmissionPolicy::default() };

        let mut f = fleet(4);
        let mut gate = AdmissionGateway::new(policy);
        let (static_report, static_bill) = run_static(&mut f, &mut gate, &workload).unwrap();

        let mut f = fleet(4);
        let mut gate = AdmissionGateway::new(policy);
        let mut scaler = Autoscaler::new(AutoscalePolicy {
            scale_up_load: 384.0,
            scale_down_load: 16.0,
            cooldown_s: 0.5,
            ..AutoscalePolicy::default()
        });
        let auto_report = run_autoscaled(&mut f, &mut gate, &mut scaler, &workload).unwrap();

        // Same goodput either way (nothing sheds at these rates)...
        assert_eq!(auto_report.goodput_tokens(), static_report.goodput_tokens());
        // ...but the autoscaled bill is strictly smaller: the quiet tail
        // runs on one replica instead of four.
        assert!(
            scaler.unit_seconds() < static_bill,
            "autoscaled {} vs static {static_bill}",
            scaler.unit_seconds()
        );
        let static_cpt = static_bill / static_report.goodput_tokens() as f64;
        assert!(scaler.cost_per_token(&auto_report) < static_cpt);
    }

    #[test]
    fn cooldown_limits_flapping_and_min_active_holds() {
        let mut f = fleet(3);
        let mut scaler = Autoscaler::new(AutoscalePolicy {
            scale_down_load: 1e9, // always wants to drain
            scale_up_load: 2e9,
            cooldown_s: 1e12,     // but may act only once
            ..AutoscalePolicy::default()
        });
        // Idle fleet at load 0: one drain fires, then cooldown pins it.
        for _ in 0..5 {
            scaler.tick(&mut f, 0).unwrap();
        }
        assert_eq!(scaler.scale_events().len(), 1);
        assert!(!scaler.scale_events()[0].up);
        // min_active floors the shrink even without cooldown.
        let mut f = fleet(2);
        let mut scaler = Autoscaler::new(AutoscalePolicy {
            scale_down_load: 1e9,
            scale_up_load: 2e9,
            cooldown_s: 0.0,
            min_active: 1,
            ..AutoscalePolicy::default()
        });
        for _ in 0..5 {
            scaler.tick(&mut f, 0).unwrap();
        }
        let active = (0..f.len()).filter(|&r| !f.is_draining(r)).count();
        assert_eq!(active, 1, "never drains below min_active");
    }

    #[test]
    fn a100_fleet_bills_cheaper_than_h100() {
        use crate::cluster::GpuSpec;
        let h100 = fleet(2);
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4)
            .with_devices(vec![GpuSpec::a100(); 4]);
        let mut a100 = Fleet::new();
        for session in sim.sessions(2) {
            a100.add_replica(Box::new(session));
        }
        let rh = fleet_unit_rate(&h100);
        let ra = fleet_unit_rate(&a100);
        assert!((rh - 8.0).abs() < 1e-9, "2×4 H100 ranks = 8 units/s, got {rh}");
        assert!(ra > 0.3 * rh && ra < 0.5 * rh, "A100 rate {ra} vs H100 {rh}");
    }
}
