//! Fleet admission control: a deadline-aware gateway queue in front of
//! the router.
//!
//! Preemption and KV swap-out triage work the fleet has *already
//! accepted*. Under sustained overload that is not enough — admitting
//! everything just moves the pile-up inside the replicas, where every
//! queued prompt holds booked capacity and stretches every deadline.
//! The gateway moves the triage to the front door:
//!
//! * while fleet load is below [`AdmissionPolicy::target_load`], work is
//!   **admitted** straight through [`Fleet::submit_with`];
//! * above it, SLO-tier requests (positive priority or a deadline) are
//!   **queued** at the gateway — unbooked, costing nothing — and
//!   re-admitted highest-priority-first as capacity returns (completions,
//!   failed GPUs rejoining, drained replicas resuming);
//! * best-effort traffic is the shock absorber: it queues only behind
//!   spare room and is **shed** outright once load passes
//!   `target_load × shed_load_factor` or the queue fills — and a full
//!   queue evicts a parked best-effort request before refusing an SLO
//!   one;
//! * queued requests whose deadline has already passed are dropped at
//!   [`AdmissionGateway::pump`] time rather than admitted to burn
//!   capacity on a guaranteed miss.
//!
//! The gateway deliberately owns no clock and no replicas — it reads
//! load from the [`super::FleetRouter`]'s booked token-units and time from the
//! replica clocks, so it composes with failures, rejoins, draining and
//! prefix affinity without special cases.

use anyhow::Result;

use super::{Fleet, FleetReport, FleetRequestId};
use crate::engine::SubmitOptions;
use crate::obs::{ObsSink, Observer, Value};
use crate::SimTime;

/// Front-door thresholds. Defaults suit the simulated drills; real
/// deployments tune `target_load` to the backlog (in prompt+budget token
/// units per effective rank) they are willing to carry inside replicas.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Booked token-units per health-effective rank above which new work
    /// queues at the gateway instead of entering a replica.
    pub target_load: f64,
    /// Gateway queue capacity; beyond it, best-effort is shed and SLO
    /// work evicts parked best-effort entries.
    pub queue_capacity: usize,
    /// Load multiple of `target_load` beyond which best-effort work is
    /// shed immediately instead of queued.
    pub shed_load_factor: f64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { target_load: 2048.0, queue_capacity: 256, shed_load_factor: 3.0 }
    }
}

/// Outcome of [`AdmissionGateway::offer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Submitted to a replica; the fleet id tracks it to completion.
    Admitted(FleetRequestId),
    /// Parked at the gateway; a later [`AdmissionGateway::pump`] admits
    /// it when capacity returns (or drops it if its deadline expires).
    Queued,
    /// Refused: shed best-effort, or SLO work against a full queue with
    /// nothing evictable.
    Rejected,
}

/// Gateway counters (monotone over the gateway's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests admitted straight through at offer time.
    pub admitted: usize,
    /// Requests that were parked in the queue at least once.
    pub queued: usize,
    /// Queued requests later admitted by [`AdmissionGateway::pump`].
    pub readmitted: usize,
    /// Requests refused or evicted (load shedding).
    pub shed: usize,
    /// Queued requests dropped because their deadline passed before
    /// capacity returned.
    pub expired: usize,
}

/// A request parked at the gateway.
struct Gated {
    prompt: Vec<u32>,
    opts: SubmitOptions,
    /// Arrival order within the gateway — the final FIFO tie-break.
    seq: u64,
}

impl Gated {
    fn best_effort(&self) -> bool {
        best_effort(&self.opts)
    }
}

fn best_effort(opts: &SubmitOptions) -> bool {
    opts.priority <= 0 && opts.deadline.is_none()
}

/// Fleet load in booked token-units per health-effective rank, over the
/// placeable (non-draining) replicas. Infinite when nothing is placeable
/// — every threshold then reads "over".
pub fn fleet_load(fleet: &Fleet) -> f64 {
    let mut booked = 0.0;
    let mut capacity = 0.0;
    for r in 0..fleet.len() {
        if fleet.is_draining(r) {
            continue;
        }
        booked += fleet.router().pending(r);
        capacity += fleet.replica_capacity(r);
    }
    if capacity > 0.0 {
        booked / capacity
    } else {
        f64::INFINITY
    }
}

/// The fleet's front-of-house clock: the furthest replica clock (the
/// replicas share one time axis — see [`FleetReport::wall_s`]).
pub fn fleet_now(fleet: &Fleet) -> SimTime {
    (0..fleet.len()).map(|r| fleet.clock(r)).fold(0.0, f64::max)
}

/// Deadline-aware admission gateway. See the module docs for the policy.
pub struct AdmissionGateway {
    policy: AdmissionPolicy,
    queue: Vec<Gated>,
    seq: u64,
    stats: AdmissionStats,
    /// Flight-recorder seam for gate verdicts (passive, detached by
    /// default).
    obs: ObsSink,
}

impl AdmissionGateway {
    pub fn new(policy: AdmissionPolicy) -> AdmissionGateway {
        assert!(policy.target_load >= 0.0 && policy.target_load.is_finite());
        assert!(policy.shed_load_factor >= 1.0);
        AdmissionGateway {
            policy,
            queue: Vec::new(),
            seq: 0,
            stats: AdmissionStats::default(),
            obs: ObsSink::none(),
        }
    }

    /// Attach a flight-recorder observer: every gate verdict (admit /
    /// park / shed / evict / expire / readmit) records with the fleet
    /// load and queue depth it was made against.
    pub fn set_observer(&mut self, observer: Box<dyn Observer>) {
        self.obs.set(observer);
    }

    /// Record one gateway verdict (no-op while detached).
    fn note(
        &mut self,
        fleet: &Fleet,
        name: &'static str,
        load: f64,
        opts: &SubmitOptions,
        mut extra: Vec<(&'static str, Value)>,
    ) {
        if !self.obs.enabled() {
            return;
        }
        let t = fleet_now(fleet);
        let mut fields: Vec<(&'static str, Value)> = vec![
            ("load", load.into()),
            ("queue", self.queue.len().into()),
            ("priority", opts.priority.into()),
            ("best_effort", best_effort(opts).into()),
        ];
        fields.append(&mut extra);
        self.obs.decision(t, None, name, fields);
    }

    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    pub fn stats(&self) -> AdmissionStats {
        self.stats
    }

    /// Requests currently parked at the gateway.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Offer one request to the fleet: admit under target load, queue
    /// SLO work over it, shed best-effort once saturated. Never errors on
    /// load — only a backend rejection of an admissible request surfaces.
    pub fn offer(
        &mut self,
        fleet: &mut Fleet,
        prompt: &[u32],
        opts: SubmitOptions,
    ) -> Result<AdmissionDecision> {
        let load = fleet_load(fleet);
        if load < self.policy.target_load {
            // Under target: straight through. `submit_with` only fails
            // when nothing is placeable (all draining) — park the
            // request instead of surfacing that transient.
            if let Ok(id) = fleet.submit_with(prompt, opts) {
                self.stats.admitted += 1;
                self.note(fleet, "gate.admit", load, &opts, vec![("fleet_id", id.into())]);
                return Ok(AdmissionDecision::Admitted(id));
            }
        }
        if best_effort(&opts) {
            let saturated = load >= self.policy.target_load * self.policy.shed_load_factor;
            if saturated || self.queue.len() >= self.policy.queue_capacity {
                self.stats.shed += 1;
                self.note(fleet, "gate.shed", load, &opts, vec![]);
                return Ok(AdmissionDecision::Rejected);
            }
        } else if self.queue.len() >= self.policy.queue_capacity {
            // SLO work against a full queue: evict a parked best-effort
            // request (the newest — it has waited least) to make room.
            match self.queue.iter().rposition(Gated::best_effort) {
                Some(i) => {
                    self.queue.remove(i);
                    self.stats.shed += 1;
                    self.note(fleet, "gate.evict", load, &opts, vec![]);
                }
                None => {
                    self.stats.shed += 1;
                    self.note(fleet, "gate.shed", load, &opts, vec![]);
                    return Ok(AdmissionDecision::Rejected);
                }
            }
        }
        self.queue.push(Gated { prompt: prompt.to_vec(), opts, seq: self.seq });
        self.seq += 1;
        self.stats.queued += 1;
        self.note(fleet, "gate.park", load, &opts, vec![]);
        Ok(AdmissionDecision::Queued)
    }

    /// Re-admit parked work as capacity allows: drop entries whose
    /// deadline already passed, then admit highest-priority /
    /// earliest-deadline first while load stays under target. Returns how
    /// many requests were admitted. Call after every fleet step (and
    /// after rejoins/resumes) — re-admission is how queued SLO work rides
    /// returning capacity.
    pub fn pump(&mut self, fleet: &mut Fleet) -> Result<usize> {
        if self.queue.is_empty() {
            return Ok(0);
        }
        let now = fleet_now(fleet);
        let before = self.queue.len();
        self.queue.retain(|g| g.opts.deadline.map_or(true, |d| d >= now));
        let expired = before - self.queue.len();
        self.stats.expired += expired;
        if expired > 0 && self.obs.enabled() {
            let q = self.queue.len();
            self.obs.decision(
                now,
                None,
                "gate.expire",
                vec![("count", expired.into()), ("queue", q.into())],
            );
        }
        // Priority desc, deadline asc (None last), gateway FIFO — the
        // same order the in-replica scheduler uses, so the gateway never
        // inverts the triage the scheduler would apply.
        self.queue.sort_by(|a, b| {
            b.opts
                .priority
                .cmp(&a.opts.priority)
                .then(
                    a.opts
                        .deadline
                        .unwrap_or(f64::INFINITY)
                        .total_cmp(&b.opts.deadline.unwrap_or(f64::INFINITY)),
                )
                .then(a.seq.cmp(&b.seq))
        });
        let mut admitted = 0usize;
        while !self.queue.is_empty() && fleet_load(fleet) < self.policy.target_load {
            let g = self.queue.remove(0);
            match fleet.submit_with(&g.prompt, g.opts) {
                Ok(id) => {
                    self.stats.readmitted += 1;
                    admitted += 1;
                    let load = fleet_load(fleet);
                    self.note(fleet, "gate.readmit", load, &g.opts, vec![(
                        "fleet_id",
                        id.into(),
                    )]);
                }
                Err(_) => {
                    // Nothing placeable right now (all draining): put it
                    // back and wait for the next pump.
                    self.queue.insert(0, g);
                    break;
                }
            }
        }
        Ok(admitted)
    }

    /// Drop everything still parked (end-of-run cleanup when capacity
    /// will never return). Returns how many were shed.
    pub fn shed_remaining(&mut self) -> usize {
        let n = self.queue.len();
        self.stats.shed += n;
        self.queue.clear();
        n
    }
}

/// Drive an arrival-ordered workload through a gated fleet to
/// completion: each request is offered when the fleet clock reaches its
/// arrival, the gateway is pumped after every step, and parked work
/// drains once arrivals stop. Requests still parked when the fleet can
/// no longer place anything are shed.
pub fn run_gated(
    fleet: &mut Fleet,
    gateway: &mut AdmissionGateway,
    workload: &[(Vec<u32>, SubmitOptions)],
) -> Result<FleetReport> {
    let mut order: Vec<usize> = (0..workload.len()).collect();
    order.sort_by(|&a, &b| workload[a].1.arrival.total_cmp(&workload[b].1.arrival));
    for i in order {
        let (prompt, opts) = &workload[i];
        while fleet_now(fleet) < opts.arrival && !fleet.is_idle() {
            fleet.step()?;
            gateway.pump(fleet)?;
        }
        gateway.pump(fleet)?;
        gateway.offer(fleet, prompt, *opts)?;
    }
    loop {
        let admitted = gateway.pump(fleet)?;
        if fleet.is_idle() {
            if gateway.queue_len() == 0 {
                break;
            }
            if admitted == 0 {
                // Idle fleet that admits nothing: capacity is gone for
                // good (all draining) — the parked work will never run.
                gateway.shed_remaining();
                break;
            }
        } else {
            fleet.step()?;
        }
    }
    Ok(fleet.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{OnlineMode, OnlineSim, SystemConfig};

    fn fleet(replicas: usize) -> Fleet {
        let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4);
        let mut fleet = Fleet::new();
        for session in sim.sessions(replicas) {
            fleet.add_replica(Box::new(session));
        }
        fleet
    }

    fn slo(max_new: usize, priority: i32, deadline: SimTime) -> SubmitOptions {
        SubmitOptions::new(max_new).priority(priority).deadline(deadline)
    }

    #[test]
    fn admits_under_target_queues_over_and_drains() {
        let mut fleet = fleet(2);
        // Tiny target: the first request saturates the gate.
        let policy = AdmissionPolicy { target_load: 1.0, ..AdmissionPolicy::default() };
        let mut gate = AdmissionGateway::new(policy);
        let first = gate.offer(&mut fleet, &[1u32; 64], SubmitOptions::new(4)).unwrap();
        assert!(matches!(first, AdmissionDecision::Admitted(_)));
        let second = gate.offer(&mut fleet, &[1u32; 64], slo(4, 2, 1e6)).unwrap();
        assert_eq!(second, AdmissionDecision::Queued);
        assert_eq!(gate.queue_len(), 1);
        // Stepping the fleet to completion frees booked load; pump
        // re-admits the parked SLO request and the fleet finishes it too.
        while !fleet.is_idle() || gate.queue_len() > 0 {
            gate.pump(&mut fleet).unwrap();
            if !fleet.is_idle() {
                fleet.step().unwrap();
            }
        }
        let report = fleet.report();
        assert_eq!(report.results.len(), 2);
        assert!(report.results.iter().all(|r| !r.result.aborted));
        assert_eq!(report.goodput_tokens(), 8);
        let stats = gate.stats();
        assert_eq!((stats.admitted, stats.queued, stats.readmitted), (1, 1, 1));
        assert_eq!((stats.shed, stats.expired), (0, 0));
    }

    #[test]
    fn best_effort_sheds_first_and_slo_evicts_parked_best_effort() {
        let mut fleet = fleet(1);
        // target 0: everything takes the over-load path from the start;
        // a tiny queue forces the eviction logic.
        let policy =
            AdmissionPolicy { target_load: 0.0, queue_capacity: 1, shed_load_factor: 1.0 };
        let mut gate = AdmissionGateway::new(policy);
        // Best-effort at/over target × shed factor: shed outright.
        let be = gate.offer(&mut fleet, &[1u32; 16], SubmitOptions::new(2)).unwrap();
        assert_eq!(be, AdmissionDecision::Rejected);
        assert_eq!(gate.stats().shed, 1);
        // A deadline-less positive-priority request is SLO work: queued.
        let parked =
            gate.offer(&mut fleet, &[1u32; 16], SubmitOptions::new(2).priority(1)).unwrap();
        assert_eq!(parked, AdmissionDecision::Queued);
        // Queue full + higher-priority SLO arrival: nothing best-effort
        // to evict, so it is refused...
        let refused = gate.offer(&mut fleet, &[1u32; 16], slo(2, 2, 1e6)).unwrap();
        assert_eq!(refused, AdmissionDecision::Rejected);
        assert_eq!(gate.queue_len(), 1);
        assert_eq!(gate.stats().shed, 2);
        assert_eq!(gate.shed_remaining(), 1);
        assert_eq!(gate.queue_len(), 0);
    }

    #[test]
    fn slo_evicts_newest_parked_best_effort_when_queue_fills() {
        let mut fleet = fleet(1);
        let policy = AdmissionPolicy {
            target_load: 1e-9,
            queue_capacity: 2,
            shed_load_factor: f64::MAX,
        };
        let mut gate = AdmissionGateway::new(policy);
        // Saturate the gate so the queue path engages.
        let seed = gate.offer(&mut fleet, &[1u32; 64], SubmitOptions::new(4)).unwrap();
        assert!(matches!(seed, AdmissionDecision::Admitted(_)));
        // Park two best-effort requests, filling the queue.
        for _ in 0..2 {
            let d = gate.offer(&mut fleet, &[1u32; 16], SubmitOptions::new(2)).unwrap();
            assert_eq!(d, AdmissionDecision::Queued);
        }
        // An SLO request evicts one of them rather than being refused.
        let d = gate.offer(&mut fleet, &[1u32; 16], slo(2, 2, 1e6)).unwrap();
        assert_eq!(d, AdmissionDecision::Queued);
        assert_eq!(gate.queue_len(), 2);
        assert_eq!(gate.stats().shed, 1);
    }

    #[test]
    fn pump_drops_expired_deadlines_instead_of_admitting_them() {
        let mut fleet = fleet(1);
        let policy = AdmissionPolicy { target_load: 1.0, ..AdmissionPolicy::default() };
        let mut gate = AdmissionGateway::new(policy);
        // Saturate with a direct submission, then park one SLO request
        // with a deadline the backlog is guaranteed to blow through.
        let first = gate.offer(&mut fleet, &[1u32; 512], SubmitOptions::new(64)).unwrap();
        assert!(matches!(first, AdmissionDecision::Admitted(_)));
        let parked = gate.offer(&mut fleet, &[1u32; 16], slo(2, 2, 1e-9)).unwrap();
        assert_eq!(parked, AdmissionDecision::Queued);
        while !fleet.is_idle() {
            fleet.step().unwrap();
        }
        assert!(fleet_now(&fleet) > 1e-9);
        assert_eq!(gate.pump(&mut fleet).unwrap(), 0);
        assert_eq!(gate.queue_len(), 0);
        assert_eq!(gate.stats().expired, 1);
        // The dropped request never reached a replica.
        assert_eq!(fleet.report().results.len(), 1);
    }

    #[test]
    fn run_gated_serves_a_tiered_workload_to_completion() {
        let mut fleet = fleet(2);
        let policy = AdmissionPolicy { target_load: 512.0, ..AdmissionPolicy::default() };
        let mut gate = AdmissionGateway::new(policy);
        let mut workload: Vec<(Vec<u32>, SubmitOptions)> = Vec::new();
        for i in 0..12 {
            let arrival = i as f64 * 1e-3;
            let opts = match i % 3 {
                0 => slo(4, 2, arrival + 60.0).at(arrival),
                1 => slo(4, 1, arrival + 240.0).at(arrival),
                _ => SubmitOptions::new(4).at(arrival),
            };
            workload.push((vec![1u32; 128], opts));
        }
        let report = run_gated(&mut fleet, &mut gate, &workload).unwrap();
        let stats = gate.stats();
        assert_eq!(stats.admitted + stats.readmitted, report.results.len());
        assert_eq!(stats.shed, 0, "capacity returns, nothing should shed");
        assert_eq!(report.goodput_tokens(), 12 * 4);
        assert_eq!(report.deadline_misses(), 0);
        // Per-tier accounting covers the whole workload.
        assert_eq!(report.tiers(), vec![2, 1, 0]);
        let total: usize =
            report.tiers().iter().map(|&p| report.tier_goodput_tokens(p)).sum();
        assert_eq!(total, report.goodput_tokens());
    }
}
