//! Multi-replica availability replay: one [`TimelineCursor`] per replica,
//! each fired at its own replica's pace, so a cascade on one replica
//! overlaps healthy decode on the others — the fleet-level scenario family
//! (replica loss, rolling maintenance across the fleet, hot-replica skew)
//! a single serving group cannot express.

use anyhow::Result;

use crate::cluster::{FaultTimeline, TimelineEvent, TimelineEventKind};
use crate::engine::{AppliedEvent, EngineEvent, ReplayPace, TimelineCursor};
use crate::recovery::RecoveryMethod;

use super::{Fleet, FleetReport, ReplicaId};

/// Result of replaying per-replica timelines across a fleet.
#[derive(Debug)]
pub struct FleetReplayOutcome {
    /// The aggregate report after the replay.
    pub report: FleetReport,
    /// Events applied in firing order, tagged with their replica.
    pub applied: Vec<(ReplicaId, AppliedEvent)>,
    /// Events that could not be applied (see
    /// [`crate::engine::ReplayOutcome::skipped`]).
    pub skipped: Vec<(ReplicaId, TimelineEvent)>,
    /// World size of every replica after the replay, by id.
    pub final_worlds: Vec<usize>,
    /// Tokens emitted fleet-wide during the replay.
    pub tokens_emitted: usize,
    /// Requests moved off a failing replica before they started.
    pub redirected: usize,
}

impl Fleet {
    /// Step the fleet to completion while firing each replica's
    /// [`FaultTimeline`] at that replica's own pace (its clock under
    /// [`ReplayPace::Clock`], its emitted-token count under
    /// [`ReplayPace::Tokens`] — the latter is deterministic and
    /// bit-reproducible on the simulator). `timelines` pairs replica ids
    /// with their timelines; replicas without an entry just serve.
    ///
    /// Each `Fail` event degrades one replica: it reconfigures, its
    /// zero-progress requests redirect to healthy replicas, its started
    /// requests drain in place, and the router's degraded down-weight
    /// steers new placements away until the matching `Rejoin` restores
    /// the capacity. Replicas left idle with events still pending apply
    /// them back-to-back, exactly like the single-backend
    /// [`crate::engine::replay()`].
    pub fn replay(
        &mut self,
        timelines: &[(ReplicaId, FaultTimeline)],
        method: RecoveryMethod,
        pace: ReplayPace,
    ) -> Result<FleetReplayOutcome> {
        let n = self.len();
        let mut cursors: Vec<Option<TimelineCursor>> = (0..n).map(|_| None).collect();
        for (replica, timeline) in timelines {
            anyhow::ensure!(*replica < n, "timeline for unknown replica {replica}");
            anyhow::ensure!(
                cursors[*replica].is_none(),
                "two timelines for replica {replica}"
            );
            cursors[*replica] =
                Some(TimelineCursor::new(timeline, self.replica_world(*replica))?);
        }

        let mut emitted = vec![0usize; n];
        let mut applied: Vec<(ReplicaId, AppliedEvent)> = Vec::new();
        let mut redirected = 0usize;

        loop {
            // Fire due events replica by replica (id order — deterministic).
            for replica in 0..n {
                let Some(cursor) = cursors[replica].as_mut() else { continue };
                if cursor.is_done() {
                    continue;
                }
                let backend = self.replicas[replica].backend.as_mut();
                let newly = cursor.fire_due(backend, method, pace, emitted[replica])?;
                for ev in newly {
                    if ev.event.kind == TimelineEventKind::Fail {
                        redirected += self.redirect_fresh(replica)?;
                    }
                    applied.push((replica, ev));
                }
            }
            let events_done = cursors.iter().flatten().all(TimelineCursor::is_done);
            if events_done && self.is_idle() {
                break;
            }
            for ev in self.step()? {
                if matches!(ev.event, EngineEvent::TokenEmitted { .. }) {
                    emitted[ev.replica] += 1;
                }
            }
        }

        let skipped = cursors
            .iter()
            .enumerate()
            .flat_map(|(r, c)| {
                c.iter().flat_map(move |c| c.skipped.iter().map(move |&ev| (r, ev)))
            })
            .collect();
        Ok(FleetReplayOutcome {
            report: self.report(),
            applied,
            skipped,
            final_worlds: self.worlds(),
            tokens_emitted: emitted.iter().sum(),
            redirected,
        })
    }
}
