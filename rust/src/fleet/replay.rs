//! Multi-replica availability replay: one [`TimelineCursor`] per replica,
//! each fired at its own replica's pace, so a cascade on one replica
//! overlaps healthy decode on the others — the fleet-level scenario family
//! (replica loss, rolling maintenance across the fleet, hot-replica skew)
//! a single serving group cannot express.
//!
//! Under token pacing the loop advances in *chunks*: before each chunk it
//! computes, per replica with pending events, the largest number of fleet
//! rounds its next event provably cannot come due inside (token deficit ÷
//! the backend's max tokens per round), takes the minimum across
//! replicas, and drives every replica that many rounds through
//! [`crate::engine::ServingBackend::advance_until`]. Events therefore
//! fire at the same round boundaries as the historical one-round
//! lock-step loop; clock pacing keeps the one-round cadence (a round's
//! time advance is unbounded, so no chunk is provably safe).

use anyhow::Result;

use crate::cluster::{FaultTimeline, TimelineEvent, TimelineEventKind};
use crate::engine::{AdvanceLimit, AppliedEvent, EngineEvent, ReplayPace, TimelineCursor};
use crate::recovery::RecoveryMethod;

use super::{Fleet, FleetReport, ReplicaId};

/// Result of replaying per-replica timelines across a fleet.
#[derive(Debug)]
pub struct FleetReplayOutcome {
    /// The aggregate report after the replay.
    pub report: FleetReport,
    /// Events applied in firing order, tagged with their replica.
    pub applied: Vec<(ReplicaId, AppliedEvent)>,
    /// Events that could not be applied (see
    /// [`crate::engine::ReplayOutcome::skipped`]).
    pub skipped: Vec<(ReplicaId, TimelineEvent)>,
    /// World size of every replica after the replay, by id.
    pub final_worlds: Vec<usize>,
    /// Tokens emitted fleet-wide during the replay.
    pub tokens_emitted: usize,
    /// Requests moved off a failing replica before they started.
    pub redirected: usize,
}

impl Fleet {
    /// Step the fleet to completion while firing each replica's
    /// [`FaultTimeline`] at that replica's own pace (its clock under
    /// [`ReplayPace::Clock`], its emitted-token count under
    /// [`ReplayPace::Tokens`] — the latter is deterministic and
    /// bit-reproducible on the simulator). `timelines` pairs replica ids
    /// with their timelines; replicas without an entry just serve.
    ///
    /// Each `Fail` event degrades one replica: it reconfigures, its
    /// zero-progress requests redirect to healthy replicas, its started
    /// requests drain in place, and the router's degraded down-weight
    /// steers new placements away until the matching `Rejoin` restores
    /// the capacity. Replicas left idle with events still pending apply
    /// them back-to-back, exactly like the single-backend
    /// [`crate::engine::replay()`].
    pub fn replay(
        &mut self,
        timelines: &[(ReplicaId, FaultTimeline)],
        method: RecoveryMethod,
        pace: ReplayPace,
    ) -> Result<FleetReplayOutcome> {
        let n = self.len();
        let mut cursors: Vec<Option<TimelineCursor>> = (0..n).map(|_| None).collect();
        for (replica, timeline) in timelines {
            anyhow::ensure!(*replica < n, "timeline for unknown replica {replica}");
            anyhow::ensure!(
                cursors[*replica].is_none(),
                "two timelines for replica {replica}"
            );
            cursors[*replica] =
                Some(TimelineCursor::new(timeline, self.replica_world(*replica))?);
        }

        let mut emitted = vec![0usize; n];
        let mut applied: Vec<(ReplicaId, AppliedEvent)> = Vec::new();
        let mut redirected = 0usize;

        loop {
            // Fire due events replica by replica (id order — deterministic).
            for replica in 0..n {
                let Some(cursor) = cursors[replica].as_mut() else { continue };
                if cursor.is_done() {
                    continue;
                }
                let backend = self.replicas[replica].backend.as_mut();
                let newly = cursor.fire_due(backend, method, pace, emitted[replica])?;
                for ev in newly {
                    if ev.event.kind == TimelineEventKind::Fail {
                        redirected += self.redirect_fresh(replica)?;
                    }
                    applied.push((replica, ev));
                }
            }
            let events_done = cursors.iter().flatten().all(TimelineCursor::is_done);
            if events_done && self.is_idle() {
                break;
            }

            // Chunk horizon: the largest number of fleet rounds no
            // replica's next event can come due strictly inside. A
            // replica emits at most `max_tokens_per_step()` tokens per
            // round, so after `⌈deficit/b⌉ − 1` rounds it is still short
            // of its threshold; the minimum over replicas keeps every
            // cursor honest. Replicas whose timelines are exhausted (or
            // absent) put no bound on the horizon — with no event left
            // anywhere the fleet free-runs to idle in one call.
            let mut horizon = usize::MAX;
            for replica in 0..n {
                let Some(cursor) = cursors[replica].as_ref() else { continue };
                let Some(ev) = cursor.next_due() else { continue };
                let h = match pace.token_threshold(ev.at) {
                    // Clock pacing: one round can advance the clock
                    // arbitrarily far, so stay at the legacy cadence.
                    None => 1,
                    Some(threshold) => {
                        let b = self.replicas[replica].backend.max_tokens_per_step().max(1);
                        let deficit = threshold.saturating_sub(emitted[replica]).max(1);
                        (deficit.div_euclid(b) + usize::from(deficit % b != 0)).max(1)
                    }
                };
                horizon = horizon.min(h);
            }

            if horizon == 1 {
                // Lock-step round, bit-identical to the historical loop.
                for ev in self.step()? {
                    if matches!(ev.event, EngineEvent::TokenEmitted { .. }) {
                        emitted[ev.replica] += 1;
                    }
                }
                continue;
            }

            // Span chunk: advance each non-idle replica up to `horizon`
            // rounds (replica-id order, same as [`Fleet::step`]). A token
            // is either materialized as a `TokenEmitted` in the sink
            // (stepper backends) or folded into `progressed` (span
            // cores), never both, so routing both through the
            // bookkeeping counts each exactly once; `out.tokens` covers
            // the union for the pace counter.
            let mut sink = Vec::new();
            for replica in 0..n {
                if self.replicas[replica].backend.is_idle() {
                    continue;
                }
                sink.clear();
                let out = self.replicas[replica]
                    .backend
                    .advance_until(AdvanceLimit::steps(horizon), &mut sink)?;
                for &(local, tokens) in out.progressed.iter() {
                    self.note_progress(replica, local, tokens);
                }
                for event in sink.drain(..) {
                    self.note_event(replica, &event);
                }
                emitted[replica] += out.tokens;
            }
        }

        let skipped = cursors
            .iter()
            .enumerate()
            .flat_map(|(r, c)| {
                c.iter().flat_map(move |c| c.skipped.iter().map(move |&ev| (r, ev)))
            })
            .collect();
        Ok(FleetReplayOutcome {
            report: self.report(),
            applied,
            skipped,
            final_worlds: self.worlds(),
            tokens_emitted: emitted.iter().sum(),
            redirected,
        })
    }
}
