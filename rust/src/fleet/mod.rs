//! Fleet-scale serving: N independent TP replicas behind one
//! cluster-level load-aware router.
//!
//! One [`crate::engine::ServingBackend`] is a single TP group — FailSafe's
//! §3 techniques keep *that group* fast when a GPU fails. A production
//! deployment serves millions of users with **multiple** such groups
//! (replicas) behind one front end, where a failure degrades *one*
//! replica while the fleet keeps serving. This module is that front end:
//!
//! * [`Fleet`] owns the replicas (real [`crate::engine::Engine`]s or
//!   simulated [`crate::simulator::OnlineSession`]s — anything behind the
//!   `ServingBackend` trait) and steps them in lock-step rounds;
//! * [`FleetRouter`] generalizes the intra-group load-aware routing to
//!   replica granularity: admission-time placement by capacity-normalized
//!   booked work, where capacity is each replica's *current* shard-plan
//!   world size × its health-effective speed (a replica with a thermally
//!   throttled rank counts as e.g. 7.5 of 8 ranks — see
//!   [`crate::health`]), degraded replicas (mid-reconfiguration after a
//!   failure) are down-weighted, and draining replicas receive nothing;
//! * on a replica failure, the fleet **redirects** that replica's
//!   fresh (zero-progress) requests to healthy replicas and lets its
//!   started requests **drain** in place — the coordinated cluster-level
//!   view of recovery;
//! * [`Fleet::replay`] drives per-replica
//!   [`crate::cluster::FaultTimeline`]s through the shared
//!   [`crate::engine::TimelineCursor`] machinery, so a cascade on one
//!   replica overlaps healthy decode on the others;
//! * [`FleetReport`] aggregates per-replica [`ServeReport`]s into
//!   fleet-level goodput and latency distributions.
//!
//! ```
//! use failsafe::engine::SubmitOptions;
//! use failsafe::fleet::Fleet;
//! use failsafe::recovery::RecoveryMethod;
//! use failsafe::simulator::{OnlineMode, OnlineSim, SystemConfig};
//!
//! let sim = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4);
//! let mut fleet = Fleet::new();
//! for session in sim.sessions(2) {
//!     fleet.add_replica(Box::new(session));
//! }
//! // Load-aware placement: equal work spreads across the replicas.
//! let a = fleet.submit_with(&vec![0u32; 512], SubmitOptions::new(4))?;
//! let b = fleet.submit_with(&vec![0u32; 512], SubmitOptions::new(4))?;
//! assert_eq!((fleet.replica_of(a), fleet.replica_of(b)), (Some(0), Some(1)));
//! // Replica 0 loses a GPU: it reconfigures to TP3 and its un-started
//! // work redirects to replica 1; the fleet keeps serving throughout.
//! fleet.inject_failure(0, 1, RecoveryMethod::Full)?;
//! let report = fleet.run_to_completion()?;
//! assert_eq!(report.results.len(), 2);
//! assert_eq!(report.goodput_tokens(), 8);
//! # anyhow::Ok(())
//! ```

mod admission;
mod autoscaler;
mod replay;
mod router;

pub use admission::{
    fleet_load, fleet_now, run_gated, AdmissionDecision, AdmissionGateway, AdmissionPolicy,
    AdmissionStats,
};
pub use autoscaler::{
    fleet_unit_rate, run_autoscaled, run_static, AutoscalePolicy, Autoscaler, ScaleEvent,
};
pub use replay::FleetReplayOutcome;
pub use router::{FleetRouter, ReplicaHealth, DEGRADED_WEIGHT};

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::engine::{
    EngineEvent, GenerationResult, ServeReport, ServingBackend, SubmitOptions,
};
use crate::metrics::Cdf;
use crate::obs::{ObsSink, SharedLog};
use crate::prefix::PrefixDirectory;
use crate::recovery::RecoveryMethod;
use crate::{RankId, RequestId, SimTime};

/// Index of one replica within a fleet.
pub type ReplicaId = usize;

/// Fleet-level request handle — stable across redirects between replicas
/// (the per-replica [`RequestId`] is not).
pub type FleetRequestId = u64;

/// Load-credit multiplier for a warm prefix hit at placement time (see
/// [`Fleet::submit_with`]): the covered tokens count once as prefill the
/// warm replica skips and once as duplicate compute + resident KV the
/// fleet avoids, so a hit attracts placement until the warm replica's
/// backlog exceeds this multiple of the prefix length.
const PREFIX_CREDIT_WEIGHT: f64 = 2.0;

/// One replica: a serving backend plus the fleet's operator state for it.
struct Replica {
    backend: Box<dyn ServingBackend>,
    /// World size the replica was added with — the denominator of
    /// "degraded" (currently serving on fewer ranks than built for).
    spec_world: usize,
    draining: bool,
}

/// Fleet-side bookkeeping for one submitted request.
struct Tracked {
    replica: ReplicaId,
    local: RequestId,
    /// Kept for redirects: a fresh request moved to another replica is
    /// resubmitted from its original prompt and options.
    prompt: Vec<u32>,
    opts: SubmitOptions,
    emitted: usize,
    done: bool,
    /// Token-units booked on the router for this request.
    booked: f64,
    redirects: usize,
}

/// One event observed while stepping the fleet: which replica produced
/// it, and — for request-scoped events — the fleet-level request id it
/// refers to (the raw [`EngineEvent`] still carries the replica-local
/// id).
#[derive(Debug, Clone)]
pub struct FleetEvent {
    pub replica: ReplicaId,
    pub id: Option<FleetRequestId>,
    pub event: EngineEvent,
}

/// Result of one fleet request, resolved on whichever replica finally
/// served it.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub id: FleetRequestId,
    /// Replica that served (or is serving) the request after any
    /// redirects.
    pub replica: ReplicaId,
    /// Times the request was moved off a failing/draining replica before
    /// it started.
    pub redirects: usize,
    /// The per-request outcome (its `id` field is rewritten to the fleet
    /// id).
    pub result: GenerationResult,
}

/// Aggregate report over every replica and every fleet request.
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    /// Per-replica cumulative reports, indexed by [`ReplicaId`].
    pub replicas: Vec<ServeReport>,
    /// Per-request results in fleet submission order.
    pub results: Vec<FleetResult>,
    /// Fleet makespan: the slowest replica's wall/simulated time (the
    /// replicas share one time axis — arrivals come from one trace).
    pub wall_s: f64,
}

impl FleetReport {
    /// Output tokens of non-aborted fleet requests (see
    /// [`ServeReport::goodput_tokens`]).
    pub fn goodput_tokens(&self) -> usize {
        self.results
            .iter()
            .filter(|r| !r.result.aborted)
            .map(|r| r.result.output_tokens.len())
            .sum()
    }

    /// Fleet goodput rate: useful output tokens per second of makespan.
    pub fn goodput_tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.goodput_tokens() as f64 / self.wall_s
        }
    }

    /// One replica's useful output tokens per second of *fleet* makespan
    /// — directly comparable against [`FleetReport::goodput_tps`].
    pub fn replica_goodput_tps(&self, replica: ReplicaId) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.replicas[replica].goodput_tokens() as f64 / self.wall_s
        }
    }

    /// Total decode tokens across the fleet (including aborted requests'
    /// partial output).
    pub fn decode_tokens(&self) -> usize {
        self.replicas.iter().map(|r| r.decode_tokens).sum()
    }

    /// Total modeled recovery/reconfiguration stalls across the fleet.
    pub fn recoveries(&self) -> usize {
        self.replicas.iter().map(|r| r.recoveries.len()).sum()
    }

    /// Exact TTFT distribution of one replica's requests.
    pub fn replica_ttft_cdf(&self, replica: ReplicaId) -> Cdf {
        let mut cdf = Cdf::new();
        for r in self.replicas[replica].results.iter() {
            if let Some(t) = r.ttft_s {
                cdf.record(t);
            }
        }
        cdf
    }

    /// Exact fleet-wide TTFT distribution (per-replica CDFs merged).
    pub fn ttft_cdf(&self) -> Cdf {
        let mut cdf = Cdf::new();
        for r in 0..self.replicas.len() {
            cdf.merge(&self.replica_ttft_cdf(r));
        }
        cdf
    }

    /// Result of one fleet request by id.
    pub fn result(&self, id: FleetRequestId) -> Option<&FleetResult> {
        self.results.get(id as usize)
    }

    /// Distinct priority tiers across the fleet's requests, highest first
    /// (see [`ServeReport::tiers`]).
    pub fn tiers(&self) -> Vec<i32> {
        let mut tiers: Vec<i32> = self.results.iter().map(|r| r.result.priority).collect();
        tiers.sort_unstable_by(|a, b| b.cmp(a));
        tiers.dedup();
        tiers
    }

    /// [`FleetReport::goodput_tokens`] restricted to one priority tier.
    pub fn tier_goodput_tokens(&self, priority: i32) -> usize {
        self.results
            .iter()
            .filter(|r| !r.result.aborted && r.result.priority == priority)
            .map(|r| r.result.output_tokens.len())
            .sum()
    }

    /// Fleet requests in `priority`'s tier that missed their SLO deadline.
    pub fn tier_deadline_misses(&self, priority: i32) -> usize {
        self.results
            .iter()
            .filter(|r| r.result.priority == priority && r.result.deadline_missed())
            .count()
    }

    /// Deadline misses across every tier of the fleet.
    pub fn deadline_misses(&self) -> usize {
        self.results.iter().filter(|r| r.result.deadline_missed()).count()
    }
}

/// N independent serving replicas behind one load-aware router. See the
/// module docs for the placement and failure semantics.
pub struct Fleet {
    replicas: Vec<Replica>,
    router: FleetRouter,
    requests: Vec<Tracked>,
    /// `(replica, local id)` → fleet id, maintained across redirects.
    local_map: HashMap<(ReplicaId, RequestId), FleetRequestId>,
    /// Prefix-affinity directory (opt-in via
    /// [`Fleet::enable_prefix_affinity`]): which replica last served each
    /// prompt-prefix chain. `None` keeps classic capacity-normalized
    /// placement bit-identical.
    prefix: Option<PrefixDirectory>,
    /// Fleet-level flight-recorder seam (placements, redirects, drains);
    /// purely passive, detached by default.
    obs: ObsSink,
    /// Kept so replicas added after [`Fleet::set_observer`] attach too.
    log: Option<SharedLog>,
}

impl Default for Fleet {
    fn default() -> Self {
        Self::new()
    }
}

impl Fleet {
    pub fn new() -> Fleet {
        Fleet {
            replicas: Vec::new(),
            router: FleetRouter::new(0),
            requests: Vec::new(),
            local_map: HashMap::new(),
            prefix: None,
            obs: ObsSink::none(),
            log: None,
        }
    }

    /// Attach one shared flight recorder to the fleet and to every
    /// replica, current and future: fleet-level placement / redirect /
    /// drain decisions record here, and each replica's backend records
    /// its own events, recovery spans, and gauges stamped with its
    /// replica id. Recording is purely passive — placement and token
    /// streams are bit-exact with or without it.
    pub fn set_observer(&mut self, log: &SharedLog) {
        self.obs.set(log.observer());
        for (i, r) in self.replicas.iter_mut().enumerate() {
            r.backend.set_observer(log.observer());
            r.backend.set_obs_replica(i);
        }
        self.log = Some(log.clone());
    }

    /// Event-edge sample of the router's booked load per replica.
    fn sample_fleet_load(&mut self) {
        if !self.obs.enabled() {
            return;
        }
        for r in 0..self.replicas.len() {
            let t = self.replicas[r].backend.now();
            let pending = self.router.pending(r);
            self.obs.set_replica(r);
            self.obs.gauge(t, None, "fleet.load", pending);
        }
    }

    /// Turn on prefix-affinity placement: submissions whose prompt prefix
    /// was recently served by a replica are credited the covered tokens
    /// on that replica (see [`FleetRouter::place_with_affinity`]), so
    /// repeat-fanout traffic lands where its KV is already warm instead
    /// of on an idle cold replica. Pair with
    /// [`crate::simulator::OnlineSim::with_prefix_sharing`] (or the
    /// engine's `--prefix-sharing`) so the chosen replica actually reuses
    /// the cache.
    pub fn enable_prefix_affinity(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixDirectory::new());
        }
    }

    /// The affinity directory, when enabled (telemetry).
    pub fn prefix_directory(&self) -> Option<&PrefixDirectory> {
        self.prefix.as_ref()
    }

    /// Add a replica (any [`ServingBackend`]); its current world size is
    /// recorded as the healthy baseline. Returns its [`ReplicaId`].
    pub fn add_replica(&mut self, backend: Box<dyn ServingBackend>) -> ReplicaId {
        let spec_world = backend.world();
        self.replicas.push(Replica { backend, spec_world, draining: false });
        let id = self.router.grow();
        if let Some(log) = &self.log {
            let r = self.replicas.last_mut().unwrap();
            r.backend.set_observer(log.observer());
            r.backend.set_obs_replica(id);
        }
        id
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Current serving world size of `replica`.
    pub fn replica_world(&self, replica: ReplicaId) -> usize {
        self.replicas[replica].backend.world()
    }

    /// Current world size of every replica, by id.
    pub fn worlds(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r.backend.world()).collect()
    }

    /// The replica currently serving fleet request `id`.
    pub fn replica_of(&self, id: FleetRequestId) -> Option<ReplicaId> {
        self.requests.get(id as usize).map(|t| t.replica)
    }

    /// Shared read access to one replica's backend (assertions, clocks).
    pub fn backend(&self, replica: ReplicaId) -> &dyn ServingBackend {
        self.replicas[replica].backend.as_ref()
    }

    /// The cluster-level router (booked load inspection).
    pub fn router(&self) -> &FleetRouter {
        &self.router
    }

    /// `replica`'s backend clock.
    pub fn clock(&self, replica: ReplicaId) -> SimTime {
        self.replicas[replica].backend.now()
    }

    /// True while `replica` is draining (no new placements).
    pub fn is_draining(&self, replica: ReplicaId) -> bool {
        self.replicas[replica].draining
    }

    fn health(&self) -> Vec<ReplicaHealth> {
        self.replicas
            .iter()
            .map(|r| {
                let world = r.backend.world();
                // Soft degradation (throttled ranks) shows up as
                // effective capacity below the live world size.
                let speed = if world == 0 {
                    0.0
                } else {
                    (r.backend.effective_capacity() / world as f64).clamp(0.0, 1.0)
                };
                // Per-rank hardware throughput in H100-rank units: the
                // fix for scoring a 4×A100 replica like 4×H100.
                let unit = if world == 0 {
                    0.0
                } else {
                    r.backend.hardware_capacity() / world as f64
                };
                ReplicaHealth { world, spec_world: r.spec_world, speed, unit, draining: r.draining }
            })
            .collect()
    }

    /// Submit a request to the fleet: the router places it on the
    /// least-loaded placeable replica (capacity-normalized; deterministic
    /// tie-break to the lowest id) and books `prompt + budget` token
    /// units there until it finishes. Errors when every replica is
    /// draining, or the chosen backend rejects the submission.
    pub fn submit_with(
        &mut self,
        prompt: &[u32],
        opts: SubmitOptions,
    ) -> Result<FleetRequestId> {
        anyhow::ensure!(!self.replicas.is_empty(), "fleet has no replicas");
        let full_work = (prompt.len() + opts.max_new_tokens) as f64;
        let health = self.health();
        // Prefix affinity: credit the replica that last served this
        // prompt's prefix chain. A warm hit saves the covered prefill
        // twice over — once as compute the warm replica skips, once as
        // duplicate compute + resident KV the fleet avoids — so the
        // credit is `PREFIX_CREDIT_WEIGHT ×` the covered tokens.
        // Equivalently: a hit concentrates onto the warm replica until
        // its backlog exceeds that multiple of the prefix length, then
        // spills to the classic least-loaded choice. Empty bonus =
        // classic placement.
        let mut bonus = vec![0.0; self.replicas.len()];
        let mut hit: Option<(ReplicaId, usize)> = None;
        if let Some(dir) = &self.prefix {
            if let Some((warm, covered)) = dir.lookup(prompt) {
                if warm < bonus.len() {
                    bonus[warm] = PREFIX_CREDIT_WEIGHT * covered as f64;
                    hit = Some((warm, covered));
                }
            }
        }
        let replica = self
            .router
            .place_with_affinity(full_work, &health, &bonus)
            .context("no placeable replica (all draining)")?;
        // Honest booking: a warm replica will not run the covered
        // prefill, so it owes only the discounted work.
        let work = match hit {
            Some((warm, covered)) if warm == replica => {
                let shaved = covered.min(prompt.len()) as f64;
                self.router.complete(replica, shaved);
                full_work - shaved
            }
            _ => full_work,
        };
        let local = match self.replicas[replica].backend.submit_with(prompt, opts) {
            Ok(l) => l,
            Err(e) => {
                self.router.complete(replica, work);
                return Err(e);
            }
        };
        if let Some(dir) = &mut self.prefix {
            dir.register(prompt, replica);
        }
        let id = self.requests.len() as FleetRequestId;
        self.requests.push(Tracked {
            replica,
            local,
            prompt: prompt.to_vec(),
            opts,
            emitted: 0,
            done: false,
            booked: work,
            redirects: 0,
        });
        self.local_map.insert((replica, local), id);
        if self.obs.enabled() {
            let t = self.replicas[replica].backend.now();
            let pending = self.router.pending(replica);
            self.obs.set_replica(replica);
            self.obs.decision(
                t,
                None,
                "fleet.place",
                vec![
                    ("fleet_id", id.into()),
                    ("replica", replica.into()),
                    ("work", work.into()),
                    ("booked", pending.into()),
                    ("affinity_hit", hit.is_some().into()),
                ],
            );
        }
        Ok(id)
    }

    /// Cancel a fleet request on whichever replica holds it.
    pub fn abort(&mut self, id: FleetRequestId) -> Result<()> {
        let (replica, local, booked, done) = {
            let t = self
                .requests
                .get(id as usize)
                .with_context(|| format!("abort: unknown fleet request {id}"))?;
            (t.replica, t.local, t.booked, t.done)
        };
        anyhow::ensure!(!done, "abort: fleet request {id} already finished");
        self.replicas[replica].backend.abort(local)?;
        let t = &mut self.requests[id as usize];
        t.done = true;
        t.prompt = Vec::new();
        self.router.complete(replica, booked);
        Ok(())
    }

    /// Inject a hard failure of `rank` on `replica`. The replica
    /// reconfigures to `world - 1` and keeps serving its started work;
    /// its fresh (zero-progress) requests are redirected to healthy
    /// replicas; the router's degraded down-weight steers new arrivals
    /// away until the GPU rejoins. Returns the modeled recovery latency.
    pub fn inject_failure(
        &mut self,
        replica: ReplicaId,
        rank: RankId,
        method: RecoveryMethod,
    ) -> Result<f64> {
        let latency = self.replicas[replica].backend.inject_failure(rank, method)?;
        // The replica's prefix cache went cold with the wiped rank (the
        // backends flush conservatively) — stop steering warm traffic at it.
        if let Some(dir) = &mut self.prefix {
            dir.purge_replica(replica);
        }
        self.redirect_fresh(replica)?;
        self.sample_fleet_load();
        Ok(latency)
    }

    /// Rejoin a previously failed GPU on `replica` (the inverse of
    /// [`Fleet::inject_failure`]); the replica's capacity grows back and
    /// placement re-attracts work naturally.
    pub fn inject_rejoin(&mut self, replica: ReplicaId, method: RecoveryMethod) -> Result<f64> {
        let latency = self.replicas[replica].backend.inject_rejoin(method)?;
        self.sample_fleet_load();
        Ok(latency)
    }

    /// Inject a *soft* fault on `replica`: `rank` keeps serving at
    /// `factor`× effective speed (1.0 restores). The replica stays fully
    /// placeable but its health-effective capacity shrinks, so the fleet
    /// router books proportionally less new work on it — no redirects,
    /// no drain: a throttled replica is slow, not gone. Returns the
    /// backend's modeled rebalance latency.
    pub fn inject_slowdown(
        &mut self,
        replica: ReplicaId,
        rank: RankId,
        factor: f64,
    ) -> Result<f64> {
        anyhow::ensure!(replica < self.replicas.len(), "no replica {replica}");
        self.replicas[replica].backend.inject_slowdown(rank, factor)
    }

    /// Health-effective capacity of `replica` in H100-rank units:
    /// hardware throughput (Σ per-rank device units) scaled by current
    /// health (Σ per-rank speed factors / world). A healthy 4×A100
    /// replica is ~1.6 units, not 4 — admission load math sees what the
    /// hardware actually delivers.
    pub fn replica_capacity(&self, replica: ReplicaId) -> f64 {
        let b = &self.replicas[replica].backend;
        let world = b.world();
        if world == 0 {
            return 0.0;
        }
        b.hardware_capacity() * b.effective_capacity() / world as f64
    }

    /// Begin draining `replica` (rolling maintenance, replica loss): no
    /// new work is placed on it, its fresh requests move to healthy
    /// replicas now, and its started requests finish in place. Returns
    /// how many requests were redirected.
    pub fn drain(&mut self, replica: ReplicaId) -> Result<usize> {
        anyhow::ensure!(replica < self.replicas.len(), "drain: no replica {replica}");
        self.replicas[replica].draining = true;
        if let Some(dir) = &mut self.prefix {
            dir.purge_replica(replica);
        }
        let moved = self.redirect_fresh(replica)?;
        if self.obs.enabled() {
            let t = self.replicas[replica].backend.now();
            self.obs.set_replica(replica);
            self.obs.decision(
                t,
                None,
                "fleet.drain",
                vec![("replica", replica.into()), ("redirected", moved.into())],
            );
            self.sample_fleet_load();
        }
        Ok(moved)
    }

    /// Return a drained replica to service.
    pub fn resume(&mut self, replica: ReplicaId) {
        self.replicas[replica].draining = false;
        if self.obs.enabled() {
            let t = self.replicas[replica].backend.now();
            self.obs.set_replica(replica);
            self.obs.decision(t, None, "fleet.resume", vec![("replica", replica.into())]);
        }
    }

    /// Move every zero-progress request off `from` onto the best healthy
    /// replica: abort on `from`, resubmit with the original prompt and
    /// options (same arrival — the fleet shares one time axis), rebook
    /// the load. Requests that already emitted tokens stay and drain in
    /// place (their continuation is bit-exact on the degraded replica).
    /// If no other replica is placeable, everything stays put.
    fn redirect_fresh(&mut self, from: ReplicaId) -> Result<usize> {
        let mut health = self.health();
        health[from].draining = true;
        let mut moved = 0usize;
        for id in 0..self.requests.len() {
            let (replica, emitted, done, booked, old_local) = {
                let t = &self.requests[id];
                (t.replica, t.emitted, t.done, t.booked, t.local)
            };
            if replica != from || done || emitted > 0 {
                continue;
            }
            let Some(target) = self.router.place(booked, &health) else {
                break; // no healthy replica to take the work
            };
            self.replicas[from].backend.abort(old_local)?;
            self.router.complete(from, booked);
            // The request is no longer live on `from` either way: unmap it
            // now so the buffered RequestAborted event cannot resolve and
            // double-retire the booking.
            self.local_map.remove(&(from, old_local));
            let (prompt, opts) = {
                let t = &self.requests[id];
                (t.prompt.clone(), t.opts)
            };
            let new_local = match self.replicas[target].backend.submit_with(&prompt, opts) {
                Ok(l) => l,
                Err(e) => {
                    // Already aborted on `from` and rejected by `target`:
                    // the request is gone. Settle its bookkeeping before
                    // surfacing the error so the fleet stays consistent.
                    self.router.complete(target, booked);
                    self.requests[id].done = true;
                    return Err(e);
                }
            };
            self.local_map.insert((target, new_local), id as FleetRequestId);
            let t = &mut self.requests[id];
            t.replica = target;
            t.local = new_local;
            t.redirects += 1;
            moved += 1;
            if self.obs.enabled() {
                let now = self.replicas[target].backend.now();
                self.obs.set_replica(target);
                self.obs.decision(
                    now,
                    None,
                    "fleet.redirect",
                    vec![
                        ("fleet_id", (id as u64).into()),
                        ("from", from.into()),
                        ("to", target.into()),
                        ("work", booked.into()),
                    ],
                );
            }
        }
        Ok(moved)
    }

    /// One fleet round: step every non-idle replica once (in replica-id
    /// order — deterministic) and return the events produced, tagged
    /// with their replica and translated to fleet request ids.
    pub fn step(&mut self) -> Result<Vec<FleetEvent>> {
        let mut out = Vec::new();
        for replica in 0..self.replicas.len() {
            if self.replicas[replica].backend.is_idle() {
                continue;
            }
            for event in self.replicas[replica].backend.step()? {
                let id = self.note_event(replica, &event);
                out.push(FleetEvent { replica, id, event });
            }
        }
        Ok(out)
    }

    /// Fold span-elided token progress into the fleet's bookkeeping: a
    /// span-core backend reports per-request token counts via
    /// [`crate::engine::AdvanceOutcome::progressed`] instead of
    /// materializing `TokenEmitted` events, and any progress disqualifies
    /// the request from redirects exactly as a delivered token would.
    fn note_progress(&mut self, replica: ReplicaId, local: RequestId, n: usize) {
        if n == 0 {
            return;
        }
        let Some(&id) = self.local_map.get(&(replica, local)) else { return };
        let t = &mut self.requests[id as usize];
        t.emitted += n;
        // The prompt copy exists only for redirects, which require zero
        // progress — once a token lands it is dead weight.
        t.prompt = Vec::new();
    }

    /// Update per-request bookkeeping from one replica event; returns the
    /// fleet id for request-scoped events (stale ids from redirected-away
    /// requests resolve to `None`).
    fn note_event(&mut self, replica: ReplicaId, event: &EngineEvent) -> Option<FleetRequestId> {
        let local = match event {
            EngineEvent::TokenEmitted { id, .. }
            | EngineEvent::RequestFinished { id }
            | EngineEvent::RequestAborted { id } => *id,
            _ => return None,
        };
        let id = *self.local_map.get(&(replica, local))?;
        let t = &mut self.requests[id as usize];
        match event {
            EngineEvent::TokenEmitted { .. } => {
                t.emitted += 1;
                // The prompt copy exists only for redirects, which require
                // zero progress — once a token lands it is dead weight.
                t.prompt = Vec::new();
            }
            EngineEvent::RequestFinished { .. } | EngineEvent::RequestAborted { .. } => {
                if !t.done {
                    t.done = true;
                    t.prompt = Vec::new();
                    let booked = t.booked;
                    self.router.complete(replica, booked);
                }
            }
            _ => {}
        }
        Some(id)
    }

    /// True when every replica is idle (all work served, all events
    /// delivered).
    pub fn is_idle(&self) -> bool {
        self.replicas.iter().all(|r| r.backend.is_idle())
    }

    /// Step until the whole fleet is idle; returns the aggregate report.
    pub fn run_to_completion(&mut self) -> Result<FleetReport> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.report())
    }

    /// Aggregate the per-replica reports into a [`FleetReport`], resolving
    /// every fleet request on the replica that finally served it.
    pub fn report(&self) -> FleetReport {
        let replicas: Vec<ServeReport> =
            self.replicas.iter().map(|r| r.backend.report()).collect();
        let wall_s = replicas.iter().map(|r| r.wall_s).fold(0.0, f64::max);
        let results = self
            .requests
            .iter()
            .enumerate()
            .map(|(id, t)| {
                let mut result =
                    replicas[t.replica].result(t.local).cloned().unwrap_or_else(|| {
                        GenerationResult { id: t.local, ..GenerationResult::default() }
                    });
                result.id = id as FleetRequestId;
                FleetResult {
                    id: id as FleetRequestId,
                    replica: t.replica,
                    redirects: t.redirects,
                    result,
                }
            })
            .collect();
        FleetReport { replicas, results, wall_s }
    }
}
