//! Cluster-level load-aware placement: [`FleetRouter`] generalizes the
//! intra-group [`crate::router::DpRouter`] / [`crate::router::LoadTracker`]
//! pair from *ranks inside one TP group* to *replicas inside one fleet*.
//!
//! The same greedy online-makespan rule applies — place each arrival where
//! the estimated pending work is smallest — but at replica granularity the
//! denominators differ: replicas are not interchangeable. A replica
//! serving on 7 of 8 GPUs (mid-reconfiguration after a failure) has less
//! capacity than a healthy one, and a replica an operator is draining must
//! receive no new work at all. So the score is *capacity-normalized*
//! pending work, with a configurable extra down-weight while a replica is
//! degraded, and draining replicas are excluded outright.

use crate::fleet::ReplicaId;

/// What the router needs to know about one replica at placement time:
/// capacity comes from the replica's *current* shard plan (its serving
/// world size right now vs. the world it was built for) scaled by its
/// health-effective speed (soft faults — a replica with one rank
/// throttled to 0.5× serves with 7.5 effective ranks of 8), draining
/// from the fleet's operator state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaHealth {
    /// Ranks currently serving (the backend's live `ShardPlan` world).
    pub world: usize,
    /// Ranks the replica serves with when fully healthy.
    pub spec_world: usize,
    /// Health-effective speed multiplier in `[0, 1]`:
    /// `effective_capacity() / world` of the backend — 1.0 when no rank
    /// is degraded. Zero removes the replica from placement.
    pub speed: f64,
    /// Per-rank *hardware* throughput in H100-rank units:
    /// `hardware_capacity() / world` of the backend — 1.0 for an H100
    /// replica, ~0.4 per rank for an all-A100 one. Orthogonal to
    /// `speed` (what the hardware is, not its current health); the fix
    /// for scoring a 4×A100 replica like 4×H100.
    pub unit: f64,
    /// True while the operator is draining this replica: in-flight work
    /// finishes, no new work is placed.
    pub draining: bool,
}

impl ReplicaHealth {
    /// A replica currently serving with all of its `spec_world` ranks at
    /// full speed on reference (H100-class) hardware.
    pub fn healthy(spec_world: usize) -> Self {
        ReplicaHealth { world: spec_world, spec_world, speed: 1.0, unit: 1.0, draining: false }
    }

    /// Serving on fewer ranks than built for — mid-reconfiguration after
    /// a failure, before every lost GPU has rejoined.
    pub fn degraded(&self) -> bool {
        self.world < self.spec_world
    }

    /// Serving with at least one throttled rank (soft degradation).
    pub fn throttled(&self) -> bool {
        self.speed < 1.0
    }
}

/// Admission-time placement of requests onto replicas.
///
/// Booked work is tracked in token units, exactly like
/// [`crate::router::LoadTracker`] — prefill plus generation budget at
/// submission, retired when the request finishes or aborts. Scores are
/// `pending / capacity` where capacity is the replica's live world size,
/// times `degraded_weight` while the replica is mid-reconfiguration, so a
/// TP7-of-8 replica keeps serving but attracts proportionally (and then
/// some) less new work. Ties break to the lowest replica id,
/// deterministically.
#[derive(Debug, Clone)]
pub struct FleetRouter {
    booked: Vec<f64>,
    degraded_weight: f64,
}

/// Default extra down-weight applied to a degraded replica's capacity
/// (on top of the missing ranks already shrinking it).
pub const DEGRADED_WEIGHT: f64 = 0.5;

impl FleetRouter {
    pub fn new(replicas: usize) -> Self {
        FleetRouter { booked: vec![0.0; replicas], degraded_weight: DEGRADED_WEIGHT }
    }

    /// Override the degraded-capacity multiplier (clamped to `(0, 1]`).
    pub fn with_degraded_weight(mut self, w: f64) -> Self {
        assert!(w > 0.0 && w <= 1.0, "degraded weight must be in (0, 1], got {w}");
        self.degraded_weight = w;
        self
    }

    /// Number of replicas tracked.
    pub fn replicas(&self) -> usize {
        self.booked.len()
    }

    /// Add one replica slot (booked load zero) — how [`crate::fleet::Fleet`]
    /// grows the router as replicas are added. Returns the new id.
    pub fn grow(&mut self) -> ReplicaId {
        self.booked.push(0.0);
        self.booked.len() - 1
    }

    /// Booked (not yet retired) work on `replica`, in token units.
    pub fn pending(&self, replica: ReplicaId) -> f64 {
        self.booked[replica]
    }

    /// Effective placement capacity of a replica: live world × per-rank
    /// hardware unit × health speed, down-weighted while
    /// mid-reconfiguration. `None` when the replica must not receive new
    /// work (draining, no ranks, zero health-effective speed, or no
    /// hardware throughput).
    fn capacity(&self, health: &ReplicaHealth) -> Option<f64> {
        if health.draining
            || health.world == 0
            || health.speed <= 0.0
            || health.speed.is_nan()
            || health.unit <= 0.0
            || health.unit.is_nan()
        {
            return None;
        }
        let mut capacity = health.world as f64 * health.unit * health.speed.min(1.0);
        if health.degraded() {
            capacity *= self.degraded_weight;
        }
        Some(capacity)
    }

    /// The placement score of one replica given its health: pending work
    /// per unit of effective capacity (lower is better), or `None` when
    /// the replica must not receive new work (draining, no ranks, or
    /// zero health-effective speed) — so a replica with a thermally
    /// throttled rank attracts proportionally less, exactly like one
    /// serving on fewer ranks.
    pub fn score(&self, replica: ReplicaId, health: &ReplicaHealth) -> Option<f64> {
        Some(self.booked[replica] / self.capacity(health)?)
    }

    /// Place `work_tokens` of new work: pick the placeable replica with
    /// the lowest capacity-normalized score (ties → lowest id), book the
    /// work on it, and return it. `None` when every replica is draining.
    /// `health` must have one entry per replica.
    pub fn place(&mut self, work_tokens: f64, health: &[ReplicaHealth]) -> Option<ReplicaId> {
        self.place_with_affinity(work_tokens, health, &[])
    }

    /// [`FleetRouter::place`] with a per-replica prefix credit in token
    /// units (hit depth × continuation fan-in — the prefill work the
    /// replica's warm prefix cache saves). The credit is subtracted from
    /// booked work *before* capacity normalization and may push the score
    /// negative, so a loaded-but-warm replica strictly beats an idle cold
    /// one while the credit exceeds its queue. An all-zero (or empty)
    /// `bonus` reduces exactly to the classic rule, deterministic
    /// lowest-id tie-break included.
    pub fn place_with_affinity(
        &mut self,
        work_tokens: f64,
        health: &[ReplicaHealth],
        bonus: &[f64],
    ) -> Option<ReplicaId> {
        assert_eq!(health.len(), self.replicas(), "one health entry per replica");
        let chosen = (0..self.replicas())
            .filter_map(|r| {
                let capacity = self.capacity(&health[r])?;
                let credit = bonus.get(r).copied().unwrap_or(0.0).max(0.0);
                Some((r, (self.booked[r] - credit) / capacity))
            })
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(r, _)| r)?;
        self.book(chosen, work_tokens);
        Some(chosen)
    }

    /// Book `work_tokens` on `replica` directly (used when the caller has
    /// already chosen — e.g. re-booking redirected work). Non-finite
    /// amounts are dropped, mirroring [`crate::router::LoadTracker`]: one
    /// NaN would poison every later comparison.
    pub fn book(&mut self, replica: ReplicaId, work_tokens: f64) {
        if work_tokens.is_finite() {
            self.booked[replica] += work_tokens;
        }
    }

    /// Retire `work_tokens` of completed (or cancelled) work from
    /// `replica`; floors at zero.
    pub fn complete(&mut self, replica: ReplicaId, work_tokens: f64) {
        if work_tokens.is_finite() {
            self.booked[replica] = (self.booked[replica] - work_tokens).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn healthy(n: usize, world: usize) -> Vec<ReplicaHealth> {
        vec![ReplicaHealth::healthy(world); n]
    }

    #[test]
    fn equal_load_ties_break_to_lowest_id_deterministically() {
        let mut r = FleetRouter::new(4);
        let h = healthy(4, 8);
        // All empty → replica 0; each placement books equal work, so the
        // sequence cycles deterministically.
        let picks: Vec<_> = (0..8).map(|_| r.place(100.0, &h).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn degraded_replica_is_down_weighted() {
        let mut r = FleetRouter::new(2);
        // Equal booked work; replica 0 lost a GPU (7 of 8) → its score is
        // worse both from the missing rank and the degraded weight.
        r.book(0, 700.0);
        r.book(1, 700.0);
        let h = vec![
            ReplicaHealth { world: 7, ..ReplicaHealth::healthy(8) },
            ReplicaHealth::healthy(8),
        ];
        assert_eq!(r.place(10.0, &h), Some(1));
        // Even a *less* loaded degraded replica loses while the capacity
        // gap exceeds the load gap.
        let mut r = FleetRouter::new(2);
        r.book(0, 500.0);
        r.book(1, 700.0);
        assert_eq!(r.place(10.0, &h), Some(1), "500/3.5 > 700/8");
    }

    #[test]
    fn draining_replica_receives_nothing_and_all_draining_is_none() {
        let mut r = FleetRouter::new(2);
        let h = vec![
            ReplicaHealth { draining: true, ..ReplicaHealth::healthy(8) },
            ReplicaHealth::healthy(8),
        ];
        for _ in 0..4 {
            assert_eq!(r.place(50.0, &h), Some(1));
        }
        let all = vec![ReplicaHealth { draining: true, ..ReplicaHealth::healthy(8) }; 2];
        assert_eq!(r.place(1.0, &all), None);
    }

    #[test]
    fn completion_rebalances_and_floors_at_zero() {
        let mut r = FleetRouter::new(2);
        let h = healthy(2, 4);
        assert_eq!(r.place(100.0, &h), Some(0));
        assert_eq!(r.place(10.0, &h), Some(1));
        r.complete(0, 100.0);
        assert_eq!(r.place(10.0, &h), Some(0));
        r.complete(1, 1e9);
        assert_eq!(r.pending(1), 0.0);
    }

    #[test]
    fn throttled_replica_is_down_weighted_capacity_proportionally() {
        // Same booked work; replica 0 has one rank at 0.5× (speed 7.5/8).
        let mut r = FleetRouter::new(2);
        r.book(0, 700.0);
        r.book(1, 700.0);
        let h = vec![
            ReplicaHealth { speed: 7.5 / 8.0, ..ReplicaHealth::healthy(8) },
            ReplicaHealth::healthy(8),
        ];
        assert_eq!(r.place(10.0, &h), Some(1), "700/7.5 > 700/8");
        // A fully stalled replica (speed 0) is unplaceable, like draining.
        let h = vec![
            ReplicaHealth { speed: 0.0, ..ReplicaHealth::healthy(8) },
            ReplicaHealth::healthy(8),
        ];
        for _ in 0..3 {
            assert_eq!(r.place(10.0, &h), Some(1));
        }
    }

    #[test]
    fn affinity_credit_beats_an_idle_cold_replica() {
        let mut r = FleetRouter::new(3);
        let h = healthy(3, 8);
        // Replica 2 is loaded but holds a 1024-token warm prefix; the
        // credit pushes its score negative, strictly below the idle cold
        // replicas at 0.
        r.book(2, 300.0);
        assert_eq!(r.place_with_affinity(50.0, &h, &[0.0, 0.0, 1024.0]), Some(2));
        // Credit below the queue loses to an idle replica again.
        let mut r = FleetRouter::new(3);
        r.book(2, 300.0);
        assert_eq!(r.place_with_affinity(50.0, &h, &[0.0, 0.0, 200.0]), Some(0));
        // Negative bonus entries are clamped, never a penalty.
        let mut r = FleetRouter::new(2);
        assert_eq!(r.place_with_affinity(1.0, &healthy(2, 8), &[-1e9, 0.0]), Some(0));
    }

    #[test]
    fn zero_affinity_preserves_the_classic_tie_break() {
        let mut classic = FleetRouter::new(4);
        let mut biased = FleetRouter::new(4);
        let h = healthy(4, 8);
        let a: Vec<_> = (0..8).map(|_| classic.place(100.0, &h).unwrap()).collect();
        let b: Vec<_> =
            (0..8).map(|_| biased.place_with_affinity(100.0, &h, &[0.0; 4]).unwrap()).collect();
        assert_eq!(a, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        assert_eq!(a, b, "all-zero bonus must reduce to the classic rule");
    }

    #[test]
    fn non_finite_work_is_rejected() {
        let mut r = FleetRouter::new(2);
        r.book(0, f64::NAN);
        r.book(1, f64::INFINITY);
        r.complete(0, f64::NAN);
        assert_eq!(r.pending(0), 0.0);
        assert_eq!(r.pending(1), 0.0);
        assert_eq!(r.place(1.0, &healthy(2, 4)), Some(0));
    }

    #[test]
    fn a100_replica_not_scored_like_h100() {
        // Same world, same load: the 4×A100 replica (unit 0.4) has less
        // hardware capacity than the 4×H100 one, so new work lands on
        // the H100s — previously both scored world × speed identically.
        let mut r = FleetRouter::new(2);
        r.book(0, 400.0);
        r.book(1, 400.0);
        let h = vec![
            ReplicaHealth { unit: 0.4, ..ReplicaHealth::healthy(4) },
            ReplicaHealth::healthy(4),
        ];
        assert_eq!(r.place(10.0, &h), Some(1), "400/1.6 > 400/4");
        // Units compose with health speed; zero unit is unplaceable.
        let h = vec![
            ReplicaHealth { unit: 0.0, ..ReplicaHealth::healthy(4) },
            ReplicaHealth::healthy(4),
        ];
        let mut r = FleetRouter::new(2);
        for _ in 0..3 {
            assert_eq!(r.place(10.0, &h), Some(1));
        }
    }

    #[test]
    fn capacity_normalization_prefers_bigger_worlds_under_equal_load() {
        let mut r = FleetRouter::new(2);
        r.book(0, 400.0);
        r.book(1, 400.0);
        let h = vec![ReplicaHealth::healthy(4), ReplicaHealth::healthy(8)];
        assert_eq!(r.place(10.0, &h), Some(1), "same load, twice the capacity");
    }
}
