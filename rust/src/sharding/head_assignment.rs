//! Attention-head placement across an irregular number of ranks.


use crate::{HeadId, LayerId, RankId};

/// Sentinel owner for heads that are DP-replicated on *all* ranks.
pub const DP_OWNER: RankId = usize::MAX;

/// Head placement policy (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionPolicy {
    /// Contiguous split, identical every layer: rank 0 gets
    /// ⌈H/W⌉ heads, later ranks ⌊H/W⌋ — the §2.2.1 strawman with up to 2×
    /// compute skew and permanent KV hot spots.
    NaiveContiguous,
    /// Same per-layer split sizes, but the assignment rotates layer by
    /// layer so every contiguous window of W layers gives each rank the
    /// same aggregate number of head-layers (Fig 1).
    Cyclic,
    /// Hybrid TP+DP (Fig 2): every rank owns exactly ⌊H/W⌋ TP heads per
    /// layer; the `H mod W` remainder heads are replicated on all ranks and
    /// served data-parallel. TP head ownership still rotates cyclically.
    Hybrid,
}

/// Head layout of a single layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerHeads {
    /// `owner[h]` = rank owning KV head `h`, or [`DP_OWNER`] if replicated.
    pub owner: Vec<RankId>,
}

impl LayerHeads {
    /// TP heads owned by `rank` in this layer.
    pub fn tp_heads_of(&self, rank: RankId) -> Vec<HeadId> {
        self.owner
            .iter()
            .enumerate()
            .filter(|&(_, &o)| o == rank)
            .map(|(h, _)| h)
            .collect()
    }

    /// Heads replicated on every rank (DP heads).
    pub fn dp_heads(&self) -> Vec<HeadId> {
        self.owner
            .iter()
            .enumerate()
            .filter(|&(_, &o)| o == DP_OWNER)
            .map(|(h, _)| h)
            .collect()
    }

    pub fn n_dp(&self) -> usize {
        self.owner.iter().filter(|&&o| o == DP_OWNER).count()
    }
}

/// Full per-layer head→rank map for a model under a given policy and world
/// size. This is *the* data structure the scheduler, the KV accountant, and
/// the recovery planner all consult.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HeadAssignment {
    pub policy: AttentionPolicy,
    pub world: usize,
    pub n_heads: usize,
    pub layers: Vec<LayerHeads>,
}

impl HeadAssignment {
    pub fn new(policy: AttentionPolicy, n_heads: usize, n_layers: usize, world: usize) -> Self {
        assert!(world >= 1, "world size must be >= 1");
        assert!(n_heads >= world || policy == AttentionPolicy::Hybrid || n_heads >= 1);
        let layers = (0..n_layers)
            .map(|l| Self::layer_map(policy, n_heads, world, l))
            .collect();
        HeadAssignment { policy, world, n_heads, layers }
    }

    fn layer_map(policy: AttentionPolicy, n_heads: usize, world: usize, layer: LayerId) -> LayerHeads {
        let base = n_heads / world;
        let rem = n_heads % world;
        let mut owner = vec![0usize; n_heads];
        match policy {
            AttentionPolicy::NaiveContiguous => {
                // Rank r owns a contiguous range; first `rem` ranks get base+1.
                let mut h = 0;
                for r in 0..world {
                    let take = base + usize::from(r < rem);
                    for _ in 0..take {
                        if h < n_heads {
                            owner[h] = r;
                            h += 1;
                        }
                    }
                }
            }
            AttentionPolicy::Cyclic => {
                // Same sizes, but which ranks get the extra head rotates by
                // layer, and the contiguous window start also rotates so
                // aggregate head-layers even out over any W-layer window.
                let mut h = 0;
                for i in 0..world {
                    let r = (i + layer) % world;
                    let take = base + usize::from(i < rem);
                    for _ in 0..take {
                        if h < n_heads {
                            owner[(h + layer) % n_heads] = r;
                            h += 1;
                        }
                    }
                }
            }
            AttentionPolicy::Hybrid => {
                // First `rem` heads (rotated by layer) are DP; the remaining
                // base*world heads are dealt round-robin starting at a
                // rotated rank.
                for slot in 0..n_heads {
                    let h = (slot + layer) % n_heads;
                    if slot < rem {
                        owner[h] = DP_OWNER;
                    } else {
                        owner[h] = (slot - rem + layer) % world;
                    }
                }
            }
        }
        LayerHeads { owner }
    }

    /// Hybrid head placement for ranks of *unequal* effective capacity —
    /// the `health` layer's mitigation for degraded-but-alive GPUs
    /// (thermal throttle, ECC pressure): shift TP heads (and with them
    /// all future cyclic KV growth) off the slow ranks, capacity-
    /// proportionally, and serve the remainder data-parallel so the
    /// capacity-aware router can steer that work too.
    ///
    /// `weights[r]` is rank `r`'s effective speed (1.0 = healthy; 0
    /// excludes the rank from TP head ownership entirely). Each rank owns
    /// `⌊n_heads · w_r / Σw⌋` TP heads per layer; the remainder heads are
    /// DP-replicated, rotating by layer exactly like
    /// [`AttentionPolicy::Hybrid`]. With equal weights this degenerates
    /// to the hybrid per-rank counts (`⌊H/W⌋` TP + `H mod W` DP).
    ///
    /// The returned assignment reports `policy == Hybrid`: reconfiguration
    /// rebuilds (shrink/expand) start from the healthy hybrid plan, and
    /// mitigation re-applies its weights afterwards.
    pub fn capacity_weighted(n_heads: usize, n_layers: usize, weights: &[f64]) -> Self {
        let world = weights.len();
        assert!(world >= 1, "world size must be >= 1");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "capacity weights must be finite and non-negative: {weights:?}"
        );
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "at least one rank must have capacity");
        // Per-layer TP quota per rank; the remainder goes DP.
        let quota: Vec<usize> =
            weights.iter().map(|w| (n_heads as f64 * w / total).floor() as usize).collect();
        let tp_total: usize = quota.iter().sum();
        debug_assert!(tp_total <= n_heads);
        let dp = n_heads - tp_total;
        // Deal order: each rank repeated by its quota, in id order; the
        // layer rotation spreads which physical heads land on which rank
        // (cyclic cross-layer balance, as in the equal-weight policies).
        let seq: Vec<RankId> =
            (0..world).flat_map(|r| std::iter::repeat(r).take(quota[r])).collect();
        let layers = (0..n_layers)
            .map(|layer| {
                let mut owner = vec![0usize; n_heads];
                for slot in 0..n_heads {
                    let h = (slot + layer) % n_heads;
                    owner[h] = if slot < dp {
                        DP_OWNER
                    } else {
                        seq[(slot - dp + layer) % seq.len()]
                    };
                }
                LayerHeads { owner }
            })
            .collect();
        HeadAssignment { policy: AttentionPolicy::Hybrid, world, n_heads, layers }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Number of DP-replicated heads per layer (0 unless Hybrid with H % W ≠ 0).
    pub fn dp_heads_per_layer(&self) -> usize {
        self.layers.first().map_or(0, |l| l.n_dp())
    }

    /// Total TP head-layer units owned by `rank` across all layers — the
    /// quantity cyclic placement equalizes (∝ both KV bytes and TP attention
    /// compute).
    pub fn tp_head_layers_of(&self, rank: RankId) -> usize {
        self.layers.iter().map(|l| l.tp_heads_of(rank).len()).sum()
    }

    /// (min, max) TP head-layers across ranks — the balance metric of Fig 1.
    pub fn tp_balance(&self) -> (usize, usize) {
        let counts: Vec<usize> = (0..self.world).map(|r| self.tp_head_layers_of(r)).collect();
        (*counts.iter().min().unwrap(), *counts.iter().max().unwrap())
    }

    /// Max TP heads any rank owns in layer `l` — the per-layer straggler
    /// width that hybrid attention eliminates (Fig 2).
    pub fn max_tp_heads_in_layer(&self, l: LayerId) -> usize {
        (0..self.world).map(|r| self.layers[l].tp_heads_of(r).len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coverage_ok(a: &HeadAssignment) {
        for lh in &a.layers {
            for &o in &lh.owner {
                assert!(o == DP_OWNER || o < a.world);
            }
            // every head appears exactly once by construction (owner vec)
            assert_eq!(lh.owner.len(), a.n_heads);
        }
    }

    #[test]
    fn naive_is_skewed_8_heads_7_ranks() {
        let a = HeadAssignment::new(AttentionPolicy::NaiveContiguous, 8, 80, 7);
        coverage_ok(&a);
        let (min, max) = a.tp_balance();
        // rank 0 owns 2 heads every layer: 160 vs 80 → the 2× skew of §2.2.1.
        assert_eq!(max, 160);
        assert_eq!(min, 80);
        assert_eq!(a.max_tp_heads_in_layer(0), 2);
    }

    #[test]
    fn cyclic_balances_aggregate() {
        let a = HeadAssignment::new(AttentionPolicy::Cyclic, 8, 70, 7);
        coverage_ok(&a);
        let (min, max) = a.tp_balance();
        // 8 heads × 70 layers / 7 ranks = 80 exactly.
        assert_eq!((min, max), (80, 80));
        // ...but per layer someone still owns 2 heads (compute straggler remains).
        assert_eq!(a.max_tp_heads_in_layer(0), 2);
    }

    #[test]
    fn hybrid_equal_tp_heads_every_layer() {
        let a = HeadAssignment::new(AttentionPolicy::Hybrid, 8, 80, 7);
        coverage_ok(&a);
        assert_eq!(a.dp_heads_per_layer(), 1);
        for l in 0..80 {
            for r in 0..7 {
                assert_eq!(a.layers[l].tp_heads_of(r).len(), 1, "layer {l} rank {r}");
            }
            assert_eq!(a.layers[l].n_dp(), 1);
        }
    }

    #[test]
    fn hybrid_uniform_world_degenerates_to_tp() {
        // H % W == 0 → no DP heads; identical to standard TP (Fig 10: TP4/TP8
        // show no difference between systems).
        let a = HeadAssignment::new(AttentionPolicy::Hybrid, 8, 4, 8);
        assert_eq!(a.dp_heads_per_layer(), 0);
        let (min, max) = a.tp_balance();
        assert_eq!(min, max);
    }

    #[test]
    fn fig1_example_cyclic_capacity_gain() {
        // Paper Fig 1: 4 KV heads, TP3. Naive: worst rank owns 2 of 4 head
        // slots per layer (share 1/2). Cyclic: over 3 layers each rank owns
        // 4 head-layers of 12 (share 1/3). Capacity gain = (1/2)/(1/3) = 1.5×.
        let naive = HeadAssignment::new(AttentionPolicy::NaiveContiguous, 4, 3, 3);
        let cyclic = HeadAssignment::new(AttentionPolicy::Cyclic, 4, 3, 3);
        let naive_max = (0..3).map(|r| naive.tp_head_layers_of(r)).max().unwrap();
        let cyclic_max = (0..3).map(|r| cyclic.tp_head_layers_of(r)).max().unwrap();
        assert_eq!(naive_max, 6);
        assert_eq!(cyclic_max, 4);
        let gain = naive_max as f64 / cyclic_max as f64;
        assert!((gain - 1.5).abs() < 1e-9, "Fig 1 promises ~50% capacity gain, got {gain}");
    }

    #[test]
    fn capacity_weighted_equal_weights_matches_hybrid_counts() {
        let w = vec![1.0; 7];
        let a = HeadAssignment::capacity_weighted(8, 80, &w);
        let h = HeadAssignment::new(AttentionPolicy::Hybrid, 8, 80, 7);
        coverage_ok(&a);
        assert_eq!(a.dp_heads_per_layer(), h.dp_heads_per_layer());
        for l in 0..80 {
            for r in 0..7 {
                assert_eq!(
                    a.layers[l].tp_heads_of(r).len(),
                    h.layers[l].tp_heads_of(r).len(),
                    "layer {l} rank {r}"
                );
            }
        }
    }

    #[test]
    fn capacity_weighted_shifts_heads_off_the_throttled_rank() {
        // TP8, 8 heads, rank 2 at half speed: Σw = 7.5 → healthy ranks
        // keep ⌊8/7.5⌋ = 1 TP head per layer, the throttled rank keeps
        // ⌊8·0.5/7.5⌋ = 0, and exactly one head per layer goes DP (routed
        // capacity-aware). No rank ever owns 2 heads — the per-layer
        // straggler the weighted plan exists to avoid.
        let mut w = vec![1.0; 8];
        w[2] = 0.5;
        let a = HeadAssignment::capacity_weighted(8, 80, &w);
        coverage_ok(&a);
        assert_eq!(a.dp_heads_per_layer(), 1);
        for l in 0..80 {
            assert_eq!(a.layers[l].tp_heads_of(2).len(), 0, "layer {l}: throttled rank owns TP");
            assert_eq!(a.max_tp_heads_in_layer(l), 1, "layer {l} straggles");
        }
        assert_eq!(a.tp_head_layers_of(2), 0);
        // A zero-weight (drained/suspect) rank owns nothing either.
        let mut w = vec![1.0; 8];
        w[5] = 0.0;
        let a = HeadAssignment::capacity_weighted(8, 80, &w);
        coverage_ok(&a);
        assert_eq!(a.tp_head_layers_of(5), 0);
    }

    #[test]
    fn dp_heads_rotate_across_layers() {
        // The DP head identity should rotate so the same physical head is
        // not permanently replicated (keeps backup traffic even).
        let a = HeadAssignment::new(AttentionPolicy::Hybrid, 8, 8, 7);
        let dp0 = a.layers[0].dp_heads();
        let dp1 = a.layers[1].dp_heads();
        assert_ne!(dp0, dp1);
    }
}
