//! Non-uniform tensor-parallel sharding: *where every weight byte and KV
//! block lives*, for an arbitrary (possibly irregular) number of ranks.
//!
//! This module implements the paper's placement contributions:
//!
//! * [`HeadAssignment`] — attention-head → rank maps per layer under three
//!   policies: naive contiguous (the §2.2.1 strawman), **cyclic placement**
//!   (§3.1, Fig 1), and **hybrid attention** (§3.1, Fig 2) which splits
//!   heads into per-rank TP heads plus DP-replicated remainder heads.
//! * [`FfnPartition`] — intermediate-dimension column blocks → rank maps,
//!   either contiguous (the conventional layout that misaligns on reshard)
//!   or **commutative** (§3.2), which exploits the reduction-dimension
//!   commutativity of matmul to keep surviving blocks in place on
//!   reconfiguration and move only the delta.
//! * [`ShardPlan`] — the combined per-rank layout with byte accounting and
//!   balance metrics, plus [`plan_reconfig`] which computes the exact
//!   movement delta between two plans (consumed by [`crate::recovery`]).

mod ffn_partition;
mod head_assignment;
mod plan;
mod reconfig;

pub use ffn_partition::{FfnPartition, FfnPolicy};
pub use head_assignment::{AttentionPolicy, HeadAssignment, LayerHeads, DP_OWNER};
pub use plan::{RankLoad, ShardPlan, CAPACITY_DECODE_FRAC};
pub use reconfig::{plan_reconfig, ReconfigDelta, UnitLocation, WeightUnit};
