//! Reconfiguration deltas: the exact weight movement required to go from
//! one shard plan to another after a failure (or a device rejoin).
//!
//! This is the data the recovery planner (§3.2, Fig 4) consumes. Every
//! weight *unit* (an attention head-group in one layer, or an FFN column
//! block in one layer) has a pre-reconfig location set; each rank's
//! post-reconfig requirement is satisfied from the cheapest source:
//!
//! * already resident → free;
//! * resident on a surviving peer → NVLink;
//! * lost with the failed device (or policy forbids peer reuse) → host DRAM
//!   over PCIe. FailSafe splits these fetches **jointly and
//!   non-redundantly** across ranks and redistributes over NVLink.

use std::collections::HashSet;


use super::{ShardPlan, DP_OWNER};
use crate::{LayerId, RankId};

/// A shardable weight unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WeightUnit {
    /// KV-head group `head` of `layer` (Wq/Wk/Wv/Wo slices).
    HeadGroup { layer: LayerId, head: usize },
    /// FFN column block `block` of `layer` (all experts).
    FfnBlock { layer: LayerId, block: usize },
}

/// Pre-reconfig location of a unit: the set of *new-rank ids* (survivors,
/// renumbered) that already hold it.
pub type UnitLocation = HashSet<RankId>;

/// Per-rank transfer totals for one reconfiguration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReconfigDelta {
    /// Bytes each rank pulls from host DRAM over its PCIe link.
    pub pcie_bytes: Vec<usize>,
    /// Bytes each rank receives from peers over NVLink.
    pub nvlink_recv_bytes: Vec<usize>,
    /// Bytes each rank sends to peers over NVLink.
    pub nvlink_send_bytes: Vec<usize>,
    /// Bytes of weight units that were lost with failed devices (had no
    /// surviving replica) — informational.
    pub lost_bytes: usize,
}

impl ReconfigDelta {
    pub fn total_pcie(&self) -> usize {
        self.pcie_bytes.iter().sum()
    }
    pub fn max_pcie(&self) -> usize {
        self.pcie_bytes.iter().copied().max().unwrap_or(0)
    }
    pub fn max_nvlink(&self) -> usize {
        self.nvlink_recv_bytes
            .iter()
            .zip(&self.nvlink_send_bytes)
            .map(|(r, s)| r + s)
            .max()
            .unwrap_or(0)
    }
}

/// Flat unit indexing: per layer, `n_heads` head-group units followed by
/// `n_blocks` FFN block units. Presence/need sets are `u64` rank bitsets
/// (world ≤ 64 always holds for a scale-up domain).
struct UnitIndex {
    n_heads: usize,
    n_blocks: usize,
    n_layers: usize,
    head_bytes: usize,
    block_bytes: usize,
}

impl UnitIndex {
    fn per_layer(&self) -> usize {
        self.n_heads + self.n_blocks
    }
    fn total(&self) -> usize {
        self.n_layers * self.per_layer()
    }
    #[inline]
    fn bytes(&self, unit: usize) -> usize {
        if unit % self.per_layer() < self.n_heads {
            self.head_bytes
        } else {
            self.block_bytes
        }
    }
    /// Flat id of a [`WeightUnit`] (exposed for diagnostics/tests).
    #[allow(dead_code)]
    fn unit_of(&self, u: WeightUnit) -> usize {
        match u {
            WeightUnit::HeadGroup { layer, head } => layer * self.per_layer() + head,
            WeightUnit::FfnBlock { layer, block } => {
                layer * self.per_layer() + self.n_heads + block
            }
        }
    }
}

fn index_for(plan: &ShardPlan) -> UnitIndex {
    UnitIndex {
        n_heads: plan.model.n_kv_heads,
        n_blocks: plan.ffn.n_blocks,
        n_layers: plan.model.n_layers,
        head_bytes: plan.model.head_group_weight_bytes(),
        block_bytes: plan.ffn_block_layer_bytes(),
    }
}

/// Per-unit requirement bitsets for all ranks of `plan`.
fn required_bits(plan: &ShardPlan, idx: &UnitIndex, world: usize) -> Vec<u64> {
    let all: u64 = if world == 64 { u64::MAX } else { (1u64 << world) - 1 };
    let mut req = vec![0u64; idx.total()];
    for (layer, lh) in plan.heads.layers.iter().enumerate() {
        let base = layer * idx.per_layer();
        for (head, &owner) in lh.owner.iter().enumerate() {
            req[base + head] = if owner == DP_OWNER { all } else { 1u64 << owner };
        }
    }
    for layer in 0..idx.n_layers {
        let base = layer * idx.per_layer() + idx.n_heads;
        for (block, &owner) in plan.ffn.owner.iter().enumerate() {
            req[base + block] = 1u64 << owner;
        }
    }
    req
}

/// Pre-reconfig presence bitsets in *new rank* numbering.
fn presence_bits(old: &ShardPlan, idx: &UnitIndex, survivor_map: &[Option<RankId>]) -> Vec<u64> {
    let survivors: u64 = survivor_map.iter().flatten().fold(0u64, |m, &r| m | (1u64 << r));
    let mut map = vec![0u64; idx.total()];
    for (layer, lh) in old.heads.layers.iter().enumerate() {
        let base = layer * idx.per_layer();
        for (head, &owner) in lh.owner.iter().enumerate() {
            map[base + head] = if owner == DP_OWNER {
                survivors
            } else {
                match survivor_map.get(owner).copied().flatten() {
                    Some(r) => 1u64 << r,
                    None => 0,
                }
            };
        }
    }
    for layer in 0..idx.n_layers {
        let base = layer * idx.per_layer() + idx.n_heads;
        for (block, &owner) in old.ffn.owner.iter().enumerate() {
            map[base + block] = match survivor_map.get(owner).copied().flatten() {
                Some(r) => 1u64 << r,
                None => 0,
            };
        }
    }
    map
}

/// Compute the transfer delta to realize `new` starting from `old`, where
/// `survivor_map[old_rank]` gives the new rank id of each surviving device.
///
/// `on_demand = true` is FailSafe's recovery (§3.2): peer-resident units
/// come over NVLink, host fetches of lost units are split across ranks
/// non-redundantly and re-shared over NVLink. `on_demand = false` models
/// the conventional fallback: each rank reloads **all** units it needs but
/// does not already hold from host over PCIe (no peer reuse, redundant
/// fetches of shared units).
pub fn plan_reconfig(
    old: &ShardPlan,
    new: &ShardPlan,
    survivor_map: &[Option<RankId>],
    on_demand: bool,
) -> ReconfigDelta {
    let world = new.world();
    debug_assert!(world <= 64, "rank bitsets assume world <= 64");
    let idx = index_for(new);
    debug_assert_eq!(index_for(old).total(), idx.total(), "plans must share unit geometry");
    let presence = presence_bits(old, &idx, survivor_map);
    let required = required_bits(new, &idx, world);

    let mut delta = ReconfigDelta {
        pcie_bytes: vec![0; world],
        nvlink_recv_bytes: vec![0; world],
        nvlink_send_bytes: vec![0; world],
        lost_bytes: 0,
    };

    for unit in 0..idx.total() {
        let needers = required[unit] & !presence[unit];
        if needers == 0 {
            continue; // every consumer already holds it
        }
        let bytes = idx.bytes(unit);
        let holders = presence[unit];
        if holders == 0 {
            delta.lost_bytes += bytes;
        }

        if !on_demand {
            // Conventional: every needer pulls its own copy over PCIe.
            let mut n = needers;
            while n != 0 {
                let r = n.trailing_zeros() as usize;
                delta.pcie_bytes[r] += bytes;
                n &= n - 1;
            }
            continue;
        }

        // FailSafe on-demand: peer-resident units come over NVLink from
        // the least-send-loaded holder; lost units are host-fetched once
        // by the least-PCIe-loaded needer and re-shared over NVLink.
        if holders != 0 {
            let mut best = usize::MAX;
            let mut src = 0usize;
            let mut h = holders;
            while h != 0 {
                let r = h.trailing_zeros() as usize;
                if delta.nvlink_send_bytes[r] < best {
                    best = delta.nvlink_send_bytes[r];
                    src = r;
                }
                h &= h - 1;
            }
            let mut n = needers;
            while n != 0 {
                let r = n.trailing_zeros() as usize;
                delta.nvlink_send_bytes[src] += bytes;
                delta.nvlink_recv_bytes[r] += bytes;
                n &= n - 1;
            }
        } else {
            let mut best = usize::MAX;
            let mut fetcher = 0usize;
            let mut n = needers;
            while n != 0 {
                let r = n.trailing_zeros() as usize;
                if delta.pcie_bytes[r] < best {
                    best = delta.pcie_bytes[r];
                    fetcher = r;
                }
                n &= n - 1;
            }
            delta.pcie_bytes[fetcher] += bytes;
            let mut n = needers & !(1u64 << fetcher);
            while n != 0 {
                let r = n.trailing_zeros() as usize;
                delta.nvlink_send_bytes[fetcher] += bytes;
                delta.nvlink_recv_bytes[r] += bytes;
                n &= n - 1;
            }
        }
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama3_70b;
    use crate::sharding::{AttentionPolicy, FfnPolicy};

    fn fail_rank(w: usize, f: usize) -> Vec<Option<RankId>> {
        (0..w)
            .map(|r| if r == f { None } else { Some(if r < f { r } else { r - 1 }) })
            .collect()
    }

    /// TP8 → TP7 with FailSafe policies: PCIe traffic must be close to the
    /// lost shard size (1/8 of sharded weights) split across 7 ranks, far
    /// below a full per-rank shard reload.
    #[test]
    fn on_demand_pcie_is_fraction_of_naive() {
        let m = llama3_70b();
        let old = ShardPlan::failsafe(&m, 8);
        let map = fail_rank(8, 3);
        let new = ShardPlan {
            model: m.clone(),
            heads: crate::sharding::HeadAssignment::new(
                AttentionPolicy::Hybrid,
                m.n_kv_heads,
                m.n_layers,
                7,
            ),
            ffn: old.ffn.reshard(&map, 7),
        };
        let fs = plan_reconfig(&old, &new, &map, true);
        let naive = plan_reconfig(&old, &new, &map, false);
        assert!(fs.total_pcie() > 0);
        // Note: this naive side still benefits from the commutative FFN
        // reshard (same `new` plan); the full Table 3 baseline also pays
        // contiguous re-layout and is compared in the tab03 bench.
        assert!(
            naive.max_pcie() as f64 > 2.0 * fs.max_pcie() as f64,
            "naive max-PCIe {} should dwarf on-demand {}",
            naive.max_pcie(),
            fs.max_pcie()
        );
        // On-demand PCIe totals ≈ lost bytes (each lost unit fetched once).
        assert_eq!(fs.total_pcie(), fs.lost_bytes);
    }

    /// The conventional contiguous-FFN reload: old/new both contiguous
    /// means nearly every block misaligns and gets re-pulled redundantly.
    #[test]
    fn contiguous_baseline_reloads_whole_shards() {
        let m = llama3_70b();
        let old = ShardPlan::nonuniform_naive(&m, 8);
        let map = fail_rank(8, 7);
        let new = ShardPlan::nonuniform_naive(&m, 7);
        let d = plan_reconfig(&old, &new, &map, false);
        // Every rank's PCIe load should be of the order of a whole new shard
        // (1/7 of FFN+attn weights ≈ 18 GB for llama-70B).
        let shard = (m.weight_bytes() - m.replicated_weight_bytes()) / 7;
        assert!(
            d.max_pcie() > shard / 3,
            "expected near-shard reload, got {} vs shard {}",
            d.max_pcie(),
            shard
        );
    }

    /// No movement when nothing changes.
    #[test]
    fn identity_reconfig_is_free() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 8);
        let map: Vec<Option<RankId>> = (0..8).map(Some).collect();
        let d = plan_reconfig(&p, &p, &map, true);
        assert_eq!(d.total_pcie(), 0);
        assert_eq!(d.max_nvlink(), 0);
        assert_eq!(d.lost_bytes, 0);
    }

    /// Every needed unit is satisfied exactly once (no redundant PCIe in
    /// on-demand mode): pcie total == lost bytes, and NVLink recv covers the
    /// rest of the needs.
    #[test]
    fn on_demand_is_non_redundant() {
        let m = llama3_70b();
        let old = ShardPlan::failsafe(&m, 7);
        let map = fail_rank(7, 2);
        let new = ShardPlan {
            model: m.clone(),
            heads: crate::sharding::HeadAssignment::new(
                AttentionPolicy::Hybrid,
                m.n_kv_heads,
                m.n_layers,
                6,
            ),
            ffn: old.ffn.reshard(&map, 6),
        };
        let d = plan_reconfig(&old, &new, &map, true);
        assert_eq!(d.total_pcie(), d.lost_bytes);
        let sends: usize = d.nvlink_send_bytes.iter().sum();
        let recvs: usize = d.nvlink_recv_bytes.iter().sum();
        assert_eq!(sends, recvs);
    }

    /// FFN commutativity: with commutative policy, surviving FFN blocks
    /// never move, so FFN NVLink traffic only covers lost blocks.
    #[test]
    fn commutative_ffn_keeps_surviving_blocks() {
        let m = llama3_70b();
        let old = ShardPlan::new(&m, 8, AttentionPolicy::Hybrid, FfnPolicy::Commutative);
        let map = fail_rank(8, 0);
        let new_ffn = old.ffn.reshard(&map, 7);
        let moved = old.ffn.moved_blocks(&map, &new_ffn);
        let lost = old.ffn.blocks_of(0).len();
        assert!(moved <= lost + 7, "moved {moved} vs lost {lost}");
    }
}
