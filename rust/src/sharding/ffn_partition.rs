//! FFN intermediate-dimension partitioning.
//!
//! FFN weights are sharded along the intermediate dimension in column
//! *blocks* (the "12 shards" of paper Fig 4). Because matrix multiplication
//! is commutative along the reduction dimension, block→rank assignment is a
//! free choice: `down(act(x·gate) ⊙ (x·up))` sums over columns in any
//! order. FailSafe exploits this (§3.2) to keep surviving blocks in place
//! on reconfiguration and move only the minimum delta.


use crate::RankId;

/// Block assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnPolicy {
    /// Conventional layout: rank r owns the r-th contiguous range. On a
    /// world-size change every range shifts, so *every* rank must reload
    /// its full new shard — the baseline FailSafe beats.
    Contiguous,
    /// Commutativity-aware layout: block positions are arbitrary, so a
    /// reconfig keeps each surviving block on its current owner when quota
    /// allows and reassigns only orphaned/excess blocks.
    Commutative,
}

/// Assignment of FFN column blocks to ranks (identical across layers and
/// experts; byte accounting multiplies out).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FfnPartition {
    pub policy: FfnPolicy,
    pub world: usize,
    pub n_blocks: usize,
    /// `owner[b]` = rank owning block `b`.
    pub owner: Vec<RankId>,
}

impl FfnPartition {
    /// Fresh partition over `world` ranks. Both policies produce the same
    /// *sizes* (⌈/⌋ within one block); they differ in how [`Self::reshard`]
    /// treats existing placement.
    pub fn new(policy: FfnPolicy, n_blocks: usize, world: usize) -> Self {
        assert!(world >= 1 && n_blocks >= world, "need at least one block per rank");
        let mut owner = vec![0usize; n_blocks];
        let base = n_blocks / world;
        let rem = n_blocks % world;
        let mut b = 0;
        for r in 0..world {
            let take = base + usize::from(r < rem);
            for _ in 0..take {
                owner[b] = r;
                b += 1;
            }
        }
        FfnPartition { policy, world, n_blocks, owner }
    }

    /// Quota of blocks each rank should own under `world` ranks.
    fn quota(n_blocks: usize, world: usize) -> Vec<usize> {
        let base = n_blocks / world;
        let rem = n_blocks % world;
        (0..world).map(|r| base + usize::from(r < rem)).collect()
    }

    /// Capacity-proportional quota by largest remainder: rank `r` gets
    /// `≈ n_blocks · w_r / Σw` blocks, deterministic ties to the lowest
    /// rank id. Zero-weight ranks get zero blocks.
    fn weighted_quota(n_blocks: usize, weights: &[f64]) -> Vec<usize> {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "capacity weights must be finite, non-negative, and not all zero: {weights:?}"
        );
        let exact: Vec<f64> = weights.iter().map(|w| n_blocks as f64 * w / total).collect();
        let mut quota: Vec<usize> = exact.iter().map(|e| e.floor() as usize).collect();
        let mut short = n_blocks - quota.iter().sum::<usize>();
        // Hand the remainder out by largest fractional part (ties → lowest id).
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_by(|&a, &b| {
            let fa = exact[a] - exact[a].floor();
            let fb = exact[b] - exact[b].floor();
            fb.total_cmp(&fa).then(a.cmp(&b))
        });
        for &r in order.iter().cycle() {
            if short == 0 {
                break;
            }
            // Never hand blocks to a zero-capacity rank unless every rank
            // with capacity is already saturated (cannot happen: quotas
            // sum short of n_blocks only by rounding, bounded by world).
            if weights[r] > 0.0 {
                quota[r] += 1;
                short -= 1;
            }
        }
        quota
    }

    /// Re-partition the same world capacity-proportionally: rank `r`'s
    /// quota becomes `≈ n_blocks · w_r / Σw`. Commutative partitions keep
    /// every block within quota in place and move only the spill from
    /// over-quota (newly throttled) ranks to under-quota ones — so the
    /// weight bytes moved by a mitigation rebalance are the minimum delta,
    /// exactly as in failure reconfiguration. Contiguous partitions
    /// re-deal from scratch (the conventional-system behaviour).
    pub fn reweight(&self, weights: &[f64]) -> FfnPartition {
        assert_eq!(weights.len(), self.world, "one weight per rank");
        let quota = Self::weighted_quota(self.n_blocks, weights);
        match self.policy {
            FfnPolicy::Contiguous => {
                let mut owner = vec![0usize; self.n_blocks];
                let mut b = 0;
                for (r, &q) in quota.iter().enumerate() {
                    for _ in 0..q {
                        owner[b] = r;
                        b += 1;
                    }
                }
                FfnPartition {
                    policy: self.policy,
                    world: self.world,
                    n_blocks: self.n_blocks,
                    owner,
                }
            }
            FfnPolicy::Commutative => {
                self.repack(self.owner.iter().map(|&o| Some(o)).collect(), &quota)
            }
        }
    }

    /// Keep-in-place repack against an explicit per-rank quota: blocks
    /// whose (pre-mapped) owner is `Some` and within quota stay put; the
    /// rest — orphaned (`None`) and over-quota spill — move to the
    /// under-quota ranks. The commutative second half of
    /// [`FfnPartition::reshard`], shared with [`FfnPartition::reweight`].
    fn repack(&self, mut owner: Vec<Option<RankId>>, quota: &[usize]) -> FfnPartition {
        let mut count = vec![0usize; quota.len()];
        // First pass: keep surviving blocks within quota.
        for o in owner.iter_mut() {
            if let Some(r) = *o {
                if count[r] < quota[r] {
                    count[r] += 1;
                } else {
                    *o = None; // over quota: spill
                }
            }
        }
        // Second pass: hand orphaned blocks to under-quota ranks.
        let mut next = 0usize;
        for o in owner.iter_mut() {
            if o.is_none() {
                while count[next] >= quota[next] {
                    next += 1;
                }
                *o = Some(next);
                count[next] += 1;
            }
        }
        FfnPartition {
            policy: self.policy,
            world: quota.len(),
            n_blocks: self.n_blocks,
            owner: owner.into_iter().map(Option::unwrap).collect(),
        }
    }

    /// Blocks owned by `rank`.
    pub fn blocks_of(&self, rank: RankId) -> Vec<usize> {
        self.owner
            .iter()
            .enumerate()
            .filter(|&(_, &o)| o == rank)
            .map(|(b, _)| b)
            .collect()
    }

    /// Re-partition for a new world size after ranks were renumbered by
    /// `survivor_map`: `survivor_map[old_rank] = Some(new_rank)` for
    /// survivors, `None` for failed ranks. Returns the new partition.
    ///
    /// * `Contiguous`: fresh contiguous layout (every rank's range shifts —
    ///   maximal movement, the conventional-system behaviour).
    /// * `Commutative`: blocks on survivors stay put up to the new quota;
    ///   only orphaned blocks (owner failed) and over-quota spill move.
    pub fn reshard(&self, survivor_map: &[Option<RankId>], new_world: usize) -> FfnPartition {
        assert_eq!(survivor_map.len(), self.world);
        match self.policy {
            FfnPolicy::Contiguous => FfnPartition::new(self.policy, self.n_blocks, new_world),
            FfnPolicy::Commutative => {
                let quota = Self::quota(self.n_blocks, new_world);
                let owner: Vec<Option<RankId>> = self
                    .owner
                    .iter()
                    .map(|&o| survivor_map.get(o).copied().flatten())
                    .collect();
                self.repack(owner, &quota)
            }
        }
    }

    /// Number of blocks that changed owner between `self` (pre-reconfig,
    /// with `survivor_map` renumbering) and `new` — ∝ weight bytes moved.
    pub fn moved_blocks(&self, survivor_map: &[Option<RankId>], new: &FfnPartition) -> usize {
        self.owner
            .iter()
            .zip(&new.owner)
            .filter(|&(&old, &new_o)| survivor_map[old] != Some(new_o))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// survivor_map for "rank `f` of `w` failed", survivors renumbered densely.
    fn fail_rank(w: usize, f: usize) -> Vec<Option<RankId>> {
        (0..w)
            .map(|r| {
                if r == f {
                    None
                } else {
                    Some(if r < f { r } else { r - 1 })
                }
            })
            .collect()
    }

    #[test]
    fn fresh_partition_balanced() {
        let p = FfnPartition::new(FfnPolicy::Commutative, 12, 7);
        let sizes: Vec<usize> = (0..7).map(|r| p.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 12);
        assert!(sizes.iter().all(|&s| s == 1 || s == 2));
    }

    #[test]
    fn commutative_moves_only_lost_plus_rebalance() {
        // Fig 4: 12 blocks, TP4 → TP3 after rank 3 fails. Rank 3 owned 3
        // blocks; new quota is 4 each. Only the 3 orphaned blocks move.
        let p = FfnPartition::new(FfnPolicy::Commutative, 12, 4);
        let map = fail_rank(4, 3);
        let q = p.reshard(&map, 3);
        assert_eq!(p.moved_blocks(&map, &q), 3);
        for r in 0..3 {
            assert_eq!(q.blocks_of(r).len(), 4);
        }
    }

    #[test]
    fn contiguous_moves_much_more() {
        let p = FfnPartition::new(FfnPolicy::Contiguous, 12, 4);
        let map = fail_rank(4, 3);
        let q = p.reshard(&map, 3);
        // Contiguous re-layout moves blocks on survivors too.
        assert!(p.moved_blocks(&map, &q) > 3, "moved {}", p.moved_blocks(&map, &q));
    }

    #[test]
    fn commutative_handles_middle_rank_failure() {
        let p = FfnPartition::new(FfnPolicy::Commutative, 24, 8);
        let map = fail_rank(8, 2);
        let q = p.reshard(&map, 7);
        // Quotas: 24/7 → 3,3,3,3,4,... check all blocks assigned & balanced.
        let sizes: Vec<usize> = (0..7).map(|r| q.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 24);
        assert!(sizes.iter().all(|&s| (3..=4).contains(&s)), "{sizes:?}");
        // Moves = 3 orphans + at most small rebalance spill.
        assert!(p.moved_blocks(&map, &q) <= 4, "moved {}", p.moved_blocks(&map, &q));
    }

    #[test]
    fn reweight_moves_only_the_throttled_ranks_spill() {
        // 16 blocks, TP8, rank 0 at half speed: quotas become
        // 16·0.5/7.5 ≈ 1 for rank 0 and ≈ 2.1 for the rest — the spill off
        // rank 0 is the only movement (plus rounding), and healthy ranks'
        // blocks stay put.
        let p = FfnPartition::new(FfnPolicy::Commutative, 16, 8);
        let mut w = vec![1.0; 8];
        w[0] = 0.5;
        let q = p.reweight(&w);
        assert_eq!(q.world, 8);
        let sizes: Vec<usize> = (0..8).map(|r| q.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 16);
        assert!(q.blocks_of(0).len() < p.blocks_of(0).len(), "throttled rank sheds blocks");
        let identity: Vec<Option<RankId>> = (0..8).map(Some).collect();
        assert!(
            p.moved_blocks(&identity, &q) <= p.blocks_of(0).len() + 1,
            "moved {} — only the spill should travel",
            p.moved_blocks(&identity, &q)
        );
        // Equal weights are a no-op for a fresh balanced partition.
        let same = p.reweight(&[1.0; 8]);
        assert_eq!(p.moved_blocks(&identity, &same), 0);
        // A zero-weight rank sheds everything.
        let mut w = vec![1.0; 8];
        w[3] = 0.0;
        let q = p.reweight(&w);
        assert!(q.blocks_of(3).is_empty());
        assert_eq!((0..8).map(|r| q.blocks_of(r).len()).sum::<usize>(), 16);
    }

    #[test]
    fn reshard_up_on_recovery() {
        // Device returns: TP7 → TP8; commutative moves ≈ one new shard's worth.
        let p = FfnPartition::new(FfnPolicy::Commutative, 56, 7);
        let map: Vec<Option<RankId>> = (0..7).map(Some).collect();
        let q = p.reshard(&map, 8);
        let sizes: Vec<usize> = (0..8).map(|r| q.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 56);
        assert!(sizes.iter().all(|&s| s == 7), "{sizes:?}");
        assert_eq!(p.moved_blocks(&map, &q), 7, "exactly the new rank's quota moves");
    }
}
