//! FFN intermediate-dimension partitioning.
//!
//! FFN weights are sharded along the intermediate dimension in column
//! *blocks* (the "12 shards" of paper Fig 4). Because matrix multiplication
//! is commutative along the reduction dimension, block→rank assignment is a
//! free choice: `down(act(x·gate) ⊙ (x·up))` sums over columns in any
//! order. FailSafe exploits this (§3.2) to keep surviving blocks in place
//! on reconfiguration and move only the minimum delta.


use crate::RankId;

/// Block assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnPolicy {
    /// Conventional layout: rank r owns the r-th contiguous range. On a
    /// world-size change every range shifts, so *every* rank must reload
    /// its full new shard — the baseline FailSafe beats.
    Contiguous,
    /// Commutativity-aware layout: block positions are arbitrary, so a
    /// reconfig keeps each surviving block on its current owner when quota
    /// allows and reassigns only orphaned/excess blocks.
    Commutative,
}

/// Assignment of FFN column blocks to ranks (identical across layers and
/// experts; byte accounting multiplies out).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FfnPartition {
    pub policy: FfnPolicy,
    pub world: usize,
    pub n_blocks: usize,
    /// `owner[b]` = rank owning block `b`.
    pub owner: Vec<RankId>,
}

impl FfnPartition {
    /// Fresh partition over `world` ranks. Both policies produce the same
    /// *sizes* (⌈/⌋ within one block); they differ in how [`Self::reshard`]
    /// treats existing placement.
    pub fn new(policy: FfnPolicy, n_blocks: usize, world: usize) -> Self {
        assert!(world >= 1 && n_blocks >= world, "need at least one block per rank");
        let mut owner = vec![0usize; n_blocks];
        let base = n_blocks / world;
        let rem = n_blocks % world;
        let mut b = 0;
        for r in 0..world {
            let take = base + usize::from(r < rem);
            for _ in 0..take {
                owner[b] = r;
                b += 1;
            }
        }
        FfnPartition { policy, world, n_blocks, owner }
    }

    /// Quota of blocks each rank should own under `world` ranks.
    fn quota(n_blocks: usize, world: usize) -> Vec<usize> {
        let base = n_blocks / world;
        let rem = n_blocks % world;
        (0..world).map(|r| base + usize::from(r < rem)).collect()
    }

    /// Blocks owned by `rank`.
    pub fn blocks_of(&self, rank: RankId) -> Vec<usize> {
        self.owner
            .iter()
            .enumerate()
            .filter(|&(_, &o)| o == rank)
            .map(|(b, _)| b)
            .collect()
    }

    /// Re-partition for a new world size after ranks were renumbered by
    /// `survivor_map`: `survivor_map[old_rank] = Some(new_rank)` for
    /// survivors, `None` for failed ranks. Returns the new partition.
    ///
    /// * `Contiguous`: fresh contiguous layout (every rank's range shifts —
    ///   maximal movement, the conventional-system behaviour).
    /// * `Commutative`: blocks on survivors stay put up to the new quota;
    ///   only orphaned blocks (owner failed) and over-quota spill move.
    pub fn reshard(&self, survivor_map: &[Option<RankId>], new_world: usize) -> FfnPartition {
        assert_eq!(survivor_map.len(), self.world);
        match self.policy {
            FfnPolicy::Contiguous => FfnPartition::new(self.policy, self.n_blocks, new_world),
            FfnPolicy::Commutative => {
                let quota = Self::quota(self.n_blocks, new_world);
                let mut owner: Vec<Option<RankId>> = self
                    .owner
                    .iter()
                    .map(|&o| survivor_map.get(o).copied().flatten())
                    .collect();
                let mut count = vec![0usize; new_world];
                // First pass: keep surviving blocks within quota.
                for o in owner.iter_mut() {
                    if let Some(r) = *o {
                        if count[r] < quota[r] {
                            count[r] += 1;
                        } else {
                            *o = None; // over quota: spill
                        }
                    }
                }
                // Second pass: hand orphaned blocks to under-quota ranks.
                let mut next = 0usize;
                for o in owner.iter_mut() {
                    if o.is_none() {
                        while count[next] >= quota[next] {
                            next += 1;
                        }
                        *o = Some(next);
                        count[next] += 1;
                    }
                }
                FfnPartition {
                    policy: self.policy,
                    world: new_world,
                    n_blocks: self.n_blocks,
                    owner: owner.into_iter().map(Option::unwrap).collect(),
                }
            }
        }
    }

    /// Number of blocks that changed owner between `self` (pre-reconfig,
    /// with `survivor_map` renumbering) and `new` — ∝ weight bytes moved.
    pub fn moved_blocks(&self, survivor_map: &[Option<RankId>], new: &FfnPartition) -> usize {
        self.owner
            .iter()
            .zip(&new.owner)
            .filter(|&(&old, &new_o)| survivor_map[old] != Some(new_o))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// survivor_map for "rank `f` of `w` failed", survivors renumbered densely.
    fn fail_rank(w: usize, f: usize) -> Vec<Option<RankId>> {
        (0..w)
            .map(|r| {
                if r == f {
                    None
                } else {
                    Some(if r < f { r } else { r - 1 })
                }
            })
            .collect()
    }

    #[test]
    fn fresh_partition_balanced() {
        let p = FfnPartition::new(FfnPolicy::Commutative, 12, 7);
        let sizes: Vec<usize> = (0..7).map(|r| p.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 12);
        assert!(sizes.iter().all(|&s| s == 1 || s == 2));
    }

    #[test]
    fn commutative_moves_only_lost_plus_rebalance() {
        // Fig 4: 12 blocks, TP4 → TP3 after rank 3 fails. Rank 3 owned 3
        // blocks; new quota is 4 each. Only the 3 orphaned blocks move.
        let p = FfnPartition::new(FfnPolicy::Commutative, 12, 4);
        let map = fail_rank(4, 3);
        let q = p.reshard(&map, 3);
        assert_eq!(p.moved_blocks(&map, &q), 3);
        for r in 0..3 {
            assert_eq!(q.blocks_of(r).len(), 4);
        }
    }

    #[test]
    fn contiguous_moves_much_more() {
        let p = FfnPartition::new(FfnPolicy::Contiguous, 12, 4);
        let map = fail_rank(4, 3);
        let q = p.reshard(&map, 3);
        // Contiguous re-layout moves blocks on survivors too.
        assert!(p.moved_blocks(&map, &q) > 3, "moved {}", p.moved_blocks(&map, &q));
    }

    #[test]
    fn commutative_handles_middle_rank_failure() {
        let p = FfnPartition::new(FfnPolicy::Commutative, 24, 8);
        let map = fail_rank(8, 2);
        let q = p.reshard(&map, 7);
        // Quotas: 24/7 → 3,3,3,3,4,... check all blocks assigned & balanced.
        let sizes: Vec<usize> = (0..7).map(|r| q.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 24);
        assert!(sizes.iter().all(|&s| (3..=4).contains(&s)), "{sizes:?}");
        // Moves = 3 orphans + at most small rebalance spill.
        assert!(p.moved_blocks(&map, &q) <= 4, "moved {}", p.moved_blocks(&map, &q));
    }

    #[test]
    fn reshard_up_on_recovery() {
        // Device returns: TP7 → TP8; commutative moves ≈ one new shard's worth.
        let p = FfnPartition::new(FfnPolicy::Commutative, 56, 7);
        let map: Vec<Option<RankId>> = (0..7).map(Some).collect();
        let q = p.reshard(&map, 8);
        let sizes: Vec<usize> = (0..8).map(|r| q.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 56);
        assert!(sizes.iter().all(|&s| s == 7), "{sizes:?}");
        assert_eq!(p.moved_blocks(&map, &q), 7, "exactly the new rank's quota moves");
    }
}
