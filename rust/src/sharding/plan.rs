//! [`ShardPlan`]: the combined attention + FFN layout for one TP
//! configuration, with exact per-rank byte and compute-share accounting.


use super::{AttentionPolicy, FfnPartition, FfnPolicy, HeadAssignment};
use crate::cluster::{capacity_weights, GpuSpec};
use crate::model::ModelSpec;
use crate::RankId;

/// Fraction of serving wall-clock assumed memory-bound when deriving
/// capacity weights for [`ShardPlan::capacity_proportional`] — chunked
/// prefill interleaves prefill and decode roughly evenly.
pub const CAPACITY_DECODE_FRAC: f64 = 0.5;

/// Per-rank load summary under a plan (consumed by the simulator and by
/// balance assertions in tests).
#[derive(Debug, Clone, PartialEq)]
pub struct RankLoad {
    pub rank: RankId,
    /// Model weight bytes resident on this rank.
    pub weight_bytes: usize,
    /// KV bytes per cached token for TP heads (always paid on this rank).
    pub kv_tp_bytes_per_token: usize,
    /// KV bytes per cached token for DP heads (paid only for requests homed
    /// on this rank).
    pub kv_dp_bytes_per_token: usize,
    /// TP attention head-layers owned (∝ TP attention compute share).
    pub tp_head_layers: usize,
    /// FFN blocks owned (∝ FFN compute share).
    pub ffn_blocks: usize,
}

/// A complete non-uniform TP layout: which rank holds which attention head
/// group per layer and which FFN column blocks, plus the byte math derived
/// from the model spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    pub model: ModelSpec,
    pub heads: HeadAssignment,
    pub ffn: FfnPartition,
}

/// Number of FFN column blocks used for shard accounting: the largest
/// divisor of `d_ff` not exceeding 128. Fine enough that block granularity
/// never dominates balance (the paper's Fig 4 uses 12 blocks for a TP4
/// illustration), constant across world sizes so reconfiguration compares
/// like with like.
pub fn default_ffn_blocks(d_ff: usize) -> usize {
    (1..=128.min(d_ff)).rev().find(|b| d_ff % b == 0).unwrap_or(1)
}

impl ShardPlan {
    /// Build a plan for `world` ranks under the given policies.
    pub fn new(
        model: &ModelSpec,
        world: usize,
        attn_policy: AttentionPolicy,
        ffn_policy: FfnPolicy,
    ) -> Self {
        let n_blocks = default_ffn_blocks(model.d_ff);
        assert!(n_blocks >= world, "d_ff too small to shard over {world} ranks");
        ShardPlan {
            model: model.clone(),
            heads: HeadAssignment::new(attn_policy, model.n_kv_heads, model.n_layers, world),
            ffn: FfnPartition::new(ffn_policy, n_blocks, world),
        }
    }

    /// The fully-optimized FailSafe plan: hybrid attention + commutative FFN.
    pub fn failsafe(model: &ModelSpec, world: usize) -> Self {
        Self::new(model, world, AttentionPolicy::Hybrid, FfnPolicy::Commutative)
    }

    /// The naive non-uniform TP plan (the paper's `Nonuniform-TP` baseline).
    pub fn nonuniform_naive(model: &ModelSpec, world: usize) -> Self {
        Self::new(model, world, AttentionPolicy::NaiveContiguous, FfnPolicy::Contiguous)
    }

    /// A plan that is capacity-proportional *by construction* for a
    /// mixed-generation TP group: rank `r` runs on `devices[r]` and gets
    /// head/FFN shares proportional to its blended roofline rate
    /// ([`crate::cluster::capacity_weights`], clamped by relative HBM so
    /// KV placement respects per-device memory). Head quotas come from
    /// largest-remainder apportionment and the FFN repack reuses
    /// [`FfnPartition::reweight`], so building this plan is exactly
    /// reweighting the uniform FailSafe plan — which makes reweighting a
    /// uniform plan to the same capacities a fixed point (the property
    /// test relies on this identity).
    pub fn capacity_proportional(model: &ModelSpec, devices: &[GpuSpec]) -> Self {
        let w = capacity_weights(devices, CAPACITY_DECODE_FRAC);
        Self::failsafe(model, devices.len()).reweight(&w)
    }

    pub fn world(&self) -> usize {
        self.heads.world
    }

    /// The post-failure plan after removing `rank`: survivors are
    /// renumbered densely, the head assignment is rebuilt for the smaller
    /// world under the same policy, and FFN blocks are resharded (the
    /// commutative policy keeps surviving blocks in place). Returns the
    /// new plan and the old→new survivor map — the pair every
    /// reconfiguration consumer (engine, simulator, coordinator, recovery
    /// planner) needs together.
    pub fn shrink(&self, rank: RankId) -> (ShardPlan, Vec<Option<RankId>>) {
        let w = self.world();
        assert!(rank < w, "shrink: rank {rank} out of range (world {w})");
        assert!(w > 1, "shrink: cannot remove the last rank");
        let map: Vec<Option<RankId>> = (0..w)
            .map(|r| if r == rank { None } else { Some(if r < rank { r } else { r - 1 }) })
            .collect();
        let plan = ShardPlan {
            model: self.model.clone(),
            heads: HeadAssignment::new(
                self.heads.policy,
                self.heads.n_heads,
                self.model.n_layers,
                w - 1,
            ),
            ffn: self.ffn.reshard(&map, w - 1),
        };
        (plan, map)
    }

    /// The post-rejoin plan with one rank appended at the end: existing
    /// ranks keep their ids (the survivor map is the identity), so nothing
    /// already resident has to move except what the commutative FFN
    /// reshard hands to the new rank. Inverse of [`ShardPlan::shrink`].
    pub fn expand(&self) -> (ShardPlan, Vec<Option<RankId>>) {
        let w = self.world();
        let map: Vec<Option<RankId>> = (0..w).map(Some).collect();
        let plan = ShardPlan {
            model: self.model.clone(),
            heads: HeadAssignment::new(
                self.heads.policy,
                self.heads.n_heads,
                self.model.n_layers,
                w + 1,
            ),
            ffn: self.ffn.reshard(&map, w + 1),
        };
        (plan, map)
    }

    /// The capacity-aware mitigation plan for degraded-but-alive ranks:
    /// the same world, with TP attention heads
    /// ([`HeadAssignment::capacity_weighted`]) and FFN column blocks
    /// ([`FfnPartition::reweight`]) redistributed in proportion to
    /// `weights[r]` (each rank's effective speed, 1.0 = healthy). The
    /// remainder attention heads go DP so the capacity-aware router can
    /// steer that work as well — together this is the
    /// Nonuniform-Tensor-Parallelism response to a straggler: uneven
    /// shards for uneven GPUs. With all weights equal the plan keeps
    /// hybrid-equivalent per-rank loads.
    pub fn reweight(&self, weights: &[f64]) -> ShardPlan {
        assert_eq!(weights.len(), self.world(), "one weight per rank");
        ShardPlan {
            model: self.model.clone(),
            heads: HeadAssignment::capacity_weighted(
                self.heads.n_heads,
                self.model.n_layers,
                weights,
            ),
            ffn: self.ffn.reweight(weights),
        }
    }

    /// Bytes of one FFN block across all layers and experts.
    pub fn ffn_block_bytes(&self) -> usize {
        // cols per block × 3 d_model-vectors per col × layers × experts
        let cols_per_block = self.model.d_ff / self.ffn.n_blocks;
        cols_per_block
            * self.model.ffn_col_weight_bytes()
            * self.model.n_layers
            * self.model.n_experts
    }

    /// Bytes of one FFN block in a single layer (all experts).
    pub fn ffn_block_layer_bytes(&self) -> usize {
        let cols_per_block = self.model.d_ff / self.ffn.n_blocks;
        cols_per_block * self.model.ffn_col_weight_bytes() * self.model.n_experts
    }

    /// Per-rank load summary.
    pub fn rank_load(&self, rank: RankId) -> RankLoad {
        let tp_head_layers = self.heads.tp_head_layers_of(rank);
        let dp_per_layer = self.heads.dp_heads_per_layer();
        let dp_head_layers = dp_per_layer * self.model.n_layers;
        let hg = self.model.head_group_weight_bytes();
        let ffn_blocks = self.ffn.blocks_of(rank).len();
        let weight_bytes = self.model.replicated_weight_bytes()
            + (tp_head_layers + dp_head_layers) * hg // DP head weights replicated everywhere
            + ffn_blocks * self.ffn_block_bytes();
        let kvb = self.model.kv_bytes_per_token_per_head_layer();
        RankLoad {
            rank,
            weight_bytes,
            kv_tp_bytes_per_token: tp_head_layers * kvb,
            kv_dp_bytes_per_token: dp_head_layers * kvb,
            tp_head_layers,
            ffn_blocks,
        }
    }

    /// All rank loads.
    pub fn rank_loads(&self) -> Vec<RankLoad> {
        (0..self.world()).map(|r| self.rank_load(r)).collect()
    }

    /// Whether the plan fits: max per-rank weight bytes + `min_kv_budget`
    /// within `hbm_budget` per rank.
    pub fn fits(&self, hbm_budget: usize, min_kv_budget: usize) -> bool {
        self.rank_loads()
            .iter()
            .all(|l| l.weight_bytes + min_kv_budget <= hbm_budget)
    }

    /// System KV token capacity: the number of cached tokens the whole TP
    /// group can hold, limited by the *most loaded* rank (synchronized TP —
    /// §2.2.1). `kv_budget[r]` = KV bytes available on rank r. Assumes
    /// balanced DP homing (each rank homes 1/W of tokens).
    pub fn kv_token_capacity(&self, kv_budget: &[usize]) -> usize {
        assert_eq!(kv_budget.len(), self.world());
        let w = self.world();
        (0..w)
            .map(|r| {
                let l = self.rank_load(r);
                // Per token globally: tp share always; dp share if homed here
                // (1/W of tokens on average).
                let per_token =
                    l.kv_tp_bytes_per_token as f64 + l.kv_dp_bytes_per_token as f64 / w as f64;
                if per_token == 0.0 {
                    usize::MAX
                } else {
                    (kv_budget[r] as f64 / per_token) as usize
                }
            })
            .min()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama3_70b, small_real};

    #[test]
    fn weight_bytes_cover_model_once_tp() {
        // Uniform TP8 on llama: sum of per-rank sharded bytes + replication
        // overhead == total weights + (W-1)×replicated.
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 8);
        let total: usize = p.rank_loads().iter().map(|l| l.weight_bytes).sum();
        let expect = m.weight_bytes() + 7 * m.replicated_weight_bytes();
        assert_eq!(total, expect);
    }

    #[test]
    fn hybrid_tp7_has_dp_replication_overhead() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 7);
        // Every rank holds 1 TP head-layer per layer + the 1 DP head-layer.
        for l in p.rank_loads() {
            assert_eq!(l.tp_head_layers, m.n_layers);
            assert_eq!(l.kv_dp_bytes_per_token, m.n_layers * m.kv_bytes_per_token_per_head_layer());
        }
    }

    #[test]
    fn failsafe_capacity_beats_naive_tp7() {
        let m = llama3_70b();
        let fs = ShardPlan::failsafe(&m, 7);
        let nv = ShardPlan::nonuniform_naive(&m, 7);
        let budget = vec![40usize << 30; 7];
        let cap_fs = fs.kv_token_capacity(&budget);
        let cap_nv = nv.kv_token_capacity(&budget);
        assert!(
            cap_fs as f64 > 1.5 * cap_nv as f64,
            "failsafe {cap_fs} vs naive {cap_nv}: cyclic+hybrid must lift capacity"
        );
    }

    #[test]
    fn small_model_fits_plan() {
        let m = small_real();
        for w in 1..=4 {
            let p = ShardPlan::failsafe(&m, w);
            let loads = p.rank_loads();
            assert_eq!(loads.len(), w);
            let max_w = loads.iter().map(|l| l.weight_bytes).max().unwrap();
            assert!(max_w < 64 << 20, "small model shard must be tiny, got {max_w}");
        }
    }

    #[test]
    fn shrink_then_expand_restores_world_and_balance() {
        let m = llama3_70b();
        let p8 = ShardPlan::failsafe(&m, 8);
        let (p7, map) = p8.shrink(3);
        assert_eq!(p7.world(), 7);
        assert_eq!(map[3], None);
        assert_eq!(map[4], Some(3));
        // Commutative reshard: surviving blocks stay put.
        assert!(p8.ffn.moved_blocks(&map, &p7.ffn) <= p8.ffn.blocks_of(3).len() + 7);
        let (p8b, up_map) = p7.expand();
        assert_eq!(p8b.world(), 8);
        assert_eq!(up_map, (0..7).map(Some).collect::<Vec<_>>());
        let sizes: Vec<usize> = (0..8).map(|r| p8b.ffn.blocks_of(r).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), p8b.ffn.n_blocks);
    }

    #[test]
    fn reweight_shifts_load_off_the_throttled_rank() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 8);
        let mut w = vec![1.0; 8];
        w[2] = 0.5;
        let q = p.reweight(&w);
        assert_eq!(q.world(), 8);
        let before = p.rank_load(2);
        let after = q.rank_load(2);
        // The throttled rank sheds TP head-layers (and with them its
        // per-token KV growth) and FFN blocks.
        assert!(after.tp_head_layers < before.tp_head_layers);
        assert!(after.kv_tp_bytes_per_token < before.kv_tp_bytes_per_token);
        assert!(after.ffn_blocks < before.ffn_blocks);
        // Healthy ranks absorb the difference; the partition still covers.
        let total_blocks: usize = q.rank_loads().iter().map(|l| l.ffn_blocks).sum();
        assert_eq!(total_blocks, q.ffn.n_blocks);
        // Equal weights keep hybrid-equivalent per-rank counts.
        let same = p.reweight(&[1.0; 8]);
        for r in 0..8 {
            assert_eq!(same.rank_load(r).tp_head_layers, p.rank_load(r).tp_head_layers);
            assert_eq!(same.rank_load(r).ffn_blocks, p.rank_load(r).ffn_blocks);
        }
    }

    #[test]
    fn capacity_proportional_shifts_load_onto_fast_devices() {
        use crate::cluster::GpuSpec;
        let m = llama3_70b();
        let devs: Vec<GpuSpec> = (0..8)
            .map(|i| if i < 4 { GpuSpec::h100() } else { GpuSpec::a100() })
            .collect();
        let p = ShardPlan::capacity_proportional(&m, &devs);
        assert_eq!(p.world(), 8);
        // H100 ranks carry strictly more TP head-layers and FFN blocks
        // than A100 ranks; the partition still covers everything.
        let loads = p.rank_loads();
        for h in 0..4 {
            for a in 4..8 {
                assert!(loads[h].tp_head_layers > loads[a].tp_head_layers);
                assert!(loads[h].ffn_blocks > loads[a].ffn_blocks);
            }
        }
        let total_blocks: usize = loads.iter().map(|l| l.ffn_blocks).sum();
        assert_eq!(total_blocks, p.ffn.n_blocks);
        // Identity: it IS the uniform plan reweighted to the capacities,
        // so reweighting again with the same weights changes nothing.
        let w = crate::cluster::capacity_weights(&devs, CAPACITY_DECODE_FRAC);
        assert_eq!(p.reweight(&w), p);
        // Uniform fleet degenerates to the plain FailSafe plan's loads.
        let uni = ShardPlan::capacity_proportional(&m, &vec![GpuSpec::h100(); 8]);
        let fs = ShardPlan::failsafe(&m, 8);
        for r in 0..8 {
            assert_eq!(uni.rank_load(r).tp_head_layers, fs.rank_load(r).tp_head_layers);
            assert_eq!(uni.rank_load(r).ffn_blocks, fs.rank_load(r).ffn_blocks);
        }
    }

    #[test]
    fn ffn_blocks_partition_d_ff() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 7);
        assert_eq!(m.d_ff % p.ffn.n_blocks, 0, "blocks must divide d_ff");
        let total_blocks: usize = p.rank_loads().iter().map(|l| l.ffn_blocks).sum();
        assert_eq!(total_blocks, p.ffn.n_blocks);
    }
}
