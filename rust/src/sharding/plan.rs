//! [`ShardPlan`]: the combined attention + FFN layout for one TP
//! configuration, with exact per-rank byte and compute-share accounting.


use super::{AttentionPolicy, FfnPartition, FfnPolicy, HeadAssignment};
use crate::model::ModelSpec;
use crate::RankId;

/// Per-rank load summary under a plan (consumed by the simulator and by
/// balance assertions in tests).
#[derive(Debug, Clone, PartialEq)]
pub struct RankLoad {
    pub rank: RankId,
    /// Model weight bytes resident on this rank.
    pub weight_bytes: usize,
    /// KV bytes per cached token for TP heads (always paid on this rank).
    pub kv_tp_bytes_per_token: usize,
    /// KV bytes per cached token for DP heads (paid only for requests homed
    /// on this rank).
    pub kv_dp_bytes_per_token: usize,
    /// TP attention head-layers owned (∝ TP attention compute share).
    pub tp_head_layers: usize,
    /// FFN blocks owned (∝ FFN compute share).
    pub ffn_blocks: usize,
}

/// A complete non-uniform TP layout: which rank holds which attention head
/// group per layer and which FFN column blocks, plus the byte math derived
/// from the model spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    pub model: ModelSpec,
    pub heads: HeadAssignment,
    pub ffn: FfnPartition,
}

/// Number of FFN column blocks used for shard accounting: the largest
/// divisor of `d_ff` not exceeding 128. Fine enough that block granularity
/// never dominates balance (the paper's Fig 4 uses 12 blocks for a TP4
/// illustration), constant across world sizes so reconfiguration compares
/// like with like.
pub fn default_ffn_blocks(d_ff: usize) -> usize {
    (1..=128.min(d_ff)).rev().find(|b| d_ff % b == 0).unwrap_or(1)
}

impl ShardPlan {
    /// Build a plan for `world` ranks under the given policies.
    pub fn new(
        model: &ModelSpec,
        world: usize,
        attn_policy: AttentionPolicy,
        ffn_policy: FfnPolicy,
    ) -> Self {
        let n_blocks = default_ffn_blocks(model.d_ff);
        assert!(n_blocks >= world, "d_ff too small to shard over {world} ranks");
        ShardPlan {
            model: model.clone(),
            heads: HeadAssignment::new(attn_policy, model.n_kv_heads, model.n_layers, world),
            ffn: FfnPartition::new(ffn_policy, n_blocks, world),
        }
    }

    /// The fully-optimized FailSafe plan: hybrid attention + commutative FFN.
    pub fn failsafe(model: &ModelSpec, world: usize) -> Self {
        Self::new(model, world, AttentionPolicy::Hybrid, FfnPolicy::Commutative)
    }

    /// The naive non-uniform TP plan (the paper's `Nonuniform-TP` baseline).
    pub fn nonuniform_naive(model: &ModelSpec, world: usize) -> Self {
        Self::new(model, world, AttentionPolicy::NaiveContiguous, FfnPolicy::Contiguous)
    }

    pub fn world(&self) -> usize {
        self.heads.world
    }

    /// Bytes of one FFN block across all layers and experts.
    pub fn ffn_block_bytes(&self) -> usize {
        // cols per block × 3 d_model-vectors per col × layers × experts
        let cols_per_block = self.model.d_ff / self.ffn.n_blocks;
        cols_per_block
            * self.model.ffn_col_weight_bytes()
            * self.model.n_layers
            * self.model.n_experts
    }

    /// Bytes of one FFN block in a single layer (all experts).
    pub fn ffn_block_layer_bytes(&self) -> usize {
        let cols_per_block = self.model.d_ff / self.ffn.n_blocks;
        cols_per_block * self.model.ffn_col_weight_bytes() * self.model.n_experts
    }

    /// Per-rank load summary.
    pub fn rank_load(&self, rank: RankId) -> RankLoad {
        let tp_head_layers = self.heads.tp_head_layers_of(rank);
        let dp_per_layer = self.heads.dp_heads_per_layer();
        let dp_head_layers = dp_per_layer * self.model.n_layers;
        let hg = self.model.head_group_weight_bytes();
        let ffn_blocks = self.ffn.blocks_of(rank).len();
        let weight_bytes = self.model.replicated_weight_bytes()
            + (tp_head_layers + dp_head_layers) * hg // DP head weights replicated everywhere
            + ffn_blocks * self.ffn_block_bytes();
        let kvb = self.model.kv_bytes_per_token_per_head_layer();
        RankLoad {
            rank,
            weight_bytes,
            kv_tp_bytes_per_token: tp_head_layers * kvb,
            kv_dp_bytes_per_token: dp_head_layers * kvb,
            tp_head_layers,
            ffn_blocks,
        }
    }

    /// All rank loads.
    pub fn rank_loads(&self) -> Vec<RankLoad> {
        (0..self.world()).map(|r| self.rank_load(r)).collect()
    }

    /// Whether the plan fits: max per-rank weight bytes + `min_kv_budget`
    /// within `hbm_budget` per rank.
    pub fn fits(&self, hbm_budget: usize, min_kv_budget: usize) -> bool {
        self.rank_loads()
            .iter()
            .all(|l| l.weight_bytes + min_kv_budget <= hbm_budget)
    }

    /// System KV token capacity: the number of cached tokens the whole TP
    /// group can hold, limited by the *most loaded* rank (synchronized TP —
    /// §2.2.1). `kv_budget[r]` = KV bytes available on rank r. Assumes
    /// balanced DP homing (each rank homes 1/W of tokens).
    pub fn kv_token_capacity(&self, kv_budget: &[usize]) -> usize {
        assert_eq!(kv_budget.len(), self.world());
        let w = self.world();
        (0..w)
            .map(|r| {
                let l = self.rank_load(r);
                // Per token globally: tp share always; dp share if homed here
                // (1/W of tokens on average).
                let per_token =
                    l.kv_tp_bytes_per_token as f64 + l.kv_dp_bytes_per_token as f64 / w as f64;
                if per_token == 0.0 {
                    usize::MAX
                } else {
                    (kv_budget[r] as f64 / per_token) as usize
                }
            })
            .min()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama3_70b, small_real};

    #[test]
    fn weight_bytes_cover_model_once_tp() {
        // Uniform TP8 on llama: sum of per-rank sharded bytes + replication
        // overhead == total weights + (W-1)×replicated.
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 8);
        let total: usize = p.rank_loads().iter().map(|l| l.weight_bytes).sum();
        let expect = m.weight_bytes() + 7 * m.replicated_weight_bytes();
        assert_eq!(total, expect);
    }

    #[test]
    fn hybrid_tp7_has_dp_replication_overhead() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 7);
        // Every rank holds 1 TP head-layer per layer + the 1 DP head-layer.
        for l in p.rank_loads() {
            assert_eq!(l.tp_head_layers, m.n_layers);
            assert_eq!(l.kv_dp_bytes_per_token, m.n_layers * m.kv_bytes_per_token_per_head_layer());
        }
    }

    #[test]
    fn failsafe_capacity_beats_naive_tp7() {
        let m = llama3_70b();
        let fs = ShardPlan::failsafe(&m, 7);
        let nv = ShardPlan::nonuniform_naive(&m, 7);
        let budget = vec![40usize << 30; 7];
        let cap_fs = fs.kv_token_capacity(&budget);
        let cap_nv = nv.kv_token_capacity(&budget);
        assert!(
            cap_fs as f64 > 1.5 * cap_nv as f64,
            "failsafe {cap_fs} vs naive {cap_nv}: cyclic+hybrid must lift capacity"
        );
    }

    #[test]
    fn small_model_fits_plan() {
        let m = small_real();
        for w in 1..=4 {
            let p = ShardPlan::failsafe(&m, w);
            let loads = p.rank_loads();
            assert_eq!(loads.len(), w);
            let max_w = loads.iter().map(|l| l.weight_bytes).max().unwrap();
            assert!(max_w < 64 << 20, "small model shard must be tiny, got {max_w}");
        }
    }

    #[test]
    fn ffn_blocks_partition_d_ff() {
        let m = llama3_70b();
        let p = ShardPlan::failsafe(&m, 7);
        assert_eq!(m.d_ff % p.ffn.n_blocks, 0, "blocks must divide d_ff");
        let total_blocks: usize = p.rank_loads().iter().map(|l| l.ffn_blocks).sum();
        assert_eq!(total_blocks, p.ffn.n_blocks);
    }
}
