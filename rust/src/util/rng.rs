//! Deterministic PRNG + distribution samplers (offline stand-in for the
//! `rand`/`rand_distr` crates).
//!
//! xoshiro256++ seeded through SplitMix64 — the standard, well-tested
//! construction (Blackman & Vigna). Every experiment in this repo threads
//! an explicit seed through one of these so runs are exactly reproducible.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (recommended initialization for xoshiro).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range [{lo},{hi})");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn std_normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal(mu, sigma).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.std_normal()
    }

    /// LogNormal with underlying Normal(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/λ).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Pick a uniformly random element index.
    pub fn pick(&mut self, len: usize) -> usize {
        self.range(0, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(2);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean {mean}");
        assert!((var - 4.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(3);
        let mu = 5.0f64;
        let mut samples: Vec<f64> = (0..50_001).map(|_| r.lognormal(mu, 0.7)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[25_000];
        assert!((median.ln() - mu).abs() < 0.03, "median {median}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_covers_all() {
        let mut r = Rng::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
