//! Small self-contained utilities that replace unavailable third-party
//! crates in this offline build (see the note in `Cargo.toml`).

pub mod cli;
pub mod rng;

pub use rng::Rng;
