//! Minimal `--flag value` argument parser (offline stand-in for `clap`).

use std::collections::HashMap;

/// Parsed command line: a subcommand (first bare word) plus `--key value`
/// pairs and `--switch` booleans.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => out.switches.push(key.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse("serve --world 7 --rate 3.5 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("world", 0), 7);
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("model", "llama"), "llama");
        assert_eq!(a.get_u64("seed", 42), 42);
    }
}
