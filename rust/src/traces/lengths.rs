//! Length-distribution generators matching the paper's Table 1 and 2.

use super::TraceRequest;
use crate::util::Rng;

/// Summary statistics of a trace side (for Table 1/2 regeneration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStats {
    pub mean: f64,
    pub median: f64,
    pub max: usize,
}

impl TraceStats {
    pub fn of(lengths: &[usize]) -> Self {
        let mut v: Vec<usize> = lengths.to_vec();
        v.sort_unstable();
        let n = v.len();
        TraceStats {
            mean: v.iter().sum::<usize>() as f64 / n as f64,
            median: if n % 2 == 1 {
                v[n / 2] as f64
            } else {
                (v[n / 2 - 1] + v[n / 2]) as f64 / 2.0
            },
            max: v.last().copied().unwrap_or(0),
        }
    }
}

/// Sample a log-normal with the given *median* and *mean*, clamped to
/// `[1, max]`. (For a log-normal, median = e^μ and mean = e^(μ+σ²/2), so
/// σ² = 2·ln(mean/median) — we fit the two published moments exactly.)
fn lognormal_by_moments(rng: &mut Rng, median: f64, mean: f64, max: usize) -> usize {
    let mu = median.ln();
    let sigma = (2.0 * (mean / median).ln()).max(1e-9).sqrt();
    (rng.lognormal(mu, sigma).round() as usize).clamp(1, max)
}

/// OpenThoughts-114k-like offline workload (paper Table 1):
/// input mean 422 / median 352 / max 7,633;
/// output mean 7,295 / median 5,583 / max 37,817.
/// Long "thinking" generations dominating input length — the regime where
/// decode-side memory balance decides throughput.
pub fn openthoughts_trace(n: usize, seed: u64) -> Vec<TraceRequest> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|i| TraceRequest {
            id: i as u64,
            arrival: 0.0,
            input_tokens: lognormal_by_moments(&mut rng, 352.0, 422.0, 7633),
            output_tokens: lognormal_by_moments(&mut rng, 5583.0, 7295.0, 37817),
        })
        .collect()
}

/// Mooncake-conversation-like online workload (paper Table 2):
/// input mean 13,516 / median 8,001 / max 123,192 (heavy long-context tail);
/// output mean 349 / median 362 / max 2,000.
///
/// The output side is slightly *left*-skewed (mean < median), which a
/// log-normal cannot produce; we use a clamped normal matched to the
/// median and max — the output side only sets decode lengths, where the
/// ±4% mean discrepancy is immaterial.
pub fn mooncake_trace(n: usize, seed: u64) -> Vec<TraceRequest> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|i| TraceRequest {
            id: i as u64,
            arrival: 0.0,
            input_tokens: lognormal_by_moments(&mut rng, 8001.0, 13516.0, 123_192),
            output_tokens: (rng.normal(358.0, 160.0).round() as i64).clamp(1, 2000) as usize,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn openthoughts_matches_table1() {
        let t = openthoughts_trace(20_000, 1);
        let inp = TraceStats::of(&t.iter().map(|r| r.input_tokens).collect::<Vec<_>>());
        let out = TraceStats::of(&t.iter().map(|r| r.output_tokens).collect::<Vec<_>>());
        assert!(rel_err(inp.mean, 422.0) < 0.06, "input mean {}", inp.mean);
        assert!(rel_err(inp.median, 352.0) < 0.06, "input median {}", inp.median);
        assert!(inp.max <= 7633);
        assert!(rel_err(out.mean, 7295.0) < 0.08, "output mean {}", out.mean);
        assert!(rel_err(out.median, 5583.0) < 0.06, "output median {}", out.median);
        assert!(out.max <= 37817);
    }

    #[test]
    fn mooncake_matches_table2() {
        let t = mooncake_trace(20_000, 2);
        let inp = TraceStats::of(&t.iter().map(|r| r.input_tokens).collect::<Vec<_>>());
        let out = TraceStats::of(&t.iter().map(|r| r.output_tokens).collect::<Vec<_>>());
        assert!(rel_err(inp.mean, 13516.0) < 0.08, "input mean {}", inp.mean);
        assert!(rel_err(inp.median, 8001.0) < 0.06, "input median {}", inp.median);
        assert!(inp.max <= 123_192);
        assert!(rel_err(out.median, 362.0) < 0.05, "output median {}", out.median);
        assert!(out.max <= 2000);
    }

    #[test]
    fn deterministic() {
        assert_eq!(openthoughts_trace(100, 7), openthoughts_trace(100, 7));
        assert_ne!(openthoughts_trace(100, 7), openthoughts_trace(100, 8));
    }

    #[test]
    fn stats_of_simple() {
        let s = TraceStats::of(&[1, 2, 3, 4, 100]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 100);
        assert!((s.mean - 22.0).abs() < 1e-9);
    }
}
