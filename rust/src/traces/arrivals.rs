//! Arrival processes: Poisson stamping and rate rescaling.

use super::TraceRequest;
use crate::util::Rng;

/// Stamp Poisson arrivals at `rate` requests/second onto a trace (in
/// place order). This is how the Mooncake trace is replayed at different
/// request rates (§4.2: "scale the timestamp for scanning different
/// request rates").
pub fn poisson_arrivals(reqs: &mut [TraceRequest], rate: f64, seed: u64) {
    assert!(rate > 0.0);
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    for r in reqs.iter_mut() {
        t += rng.exp(rate);
        r.arrival = t;
    }
}

/// Rescale existing arrival timestamps by `factor` (>1 → slower arrivals).
pub fn scale_arrivals(reqs: &mut [TraceRequest], factor: f64) {
    for r in reqs.iter_mut() {
        r.arrival *= factor;
    }
}

/// Split a shared arrival trace across `n` replicas round-robin in
/// arrival order — the *static* baseline for multi-replica serving.
/// Every replica sees arrivals in the original time order and the split
/// is load-oblivious; the [`crate::fleet::FleetRouter`] is the
/// load-aware alternative that places each arrival by per-replica
/// booked work instead.
pub fn split_arrivals(reqs: &[TraceRequest], n: usize) -> Vec<Vec<TraceRequest>> {
    assert!(n > 0, "cannot split a trace across zero replicas");
    let mut out = vec![Vec::with_capacity(reqs.len() / n + 1); n];
    for (i, r) in reqs.iter().enumerate() {
        out[i % n].push(*r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::mooncake_trace;

    #[test]
    fn poisson_rate_approximately_met() {
        let mut reqs = mooncake_trace(5000, 3);
        poisson_arrivals(&mut reqs, 10.0, 3);
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 10.0).abs() / 10.0 < 0.1, "rate {rate}");
        // monotone arrivals
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn split_round_robins_in_arrival_order() {
        let mut reqs = mooncake_trace(10, 5);
        poisson_arrivals(&mut reqs, 5.0, 5);
        let parts = split_arrivals(&reqs, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), 10);
        assert_eq!(parts[0].len(), 4);
        assert_eq!(parts[1].len(), 3);
        // Each shard preserves the global arrival order.
        for p in &parts {
            assert!(p.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        }
        assert_eq!(parts[1][0], reqs[1]);
        assert_eq!(parts[2][1], reqs[5]);
    }

    #[test]
    fn scaling_changes_rate_linearly() {
        let mut reqs = mooncake_trace(100, 4);
        poisson_arrivals(&mut reqs, 5.0, 4);
        let before = reqs.last().unwrap().arrival;
        scale_arrivals(&mut reqs, 2.0);
        assert!((reqs.last().unwrap().arrival - 2.0 * before).abs() < 1e-9);
    }
}
