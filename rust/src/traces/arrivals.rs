//! Arrival processes: Poisson stamping and rate rescaling.

use super::TraceRequest;
use crate::util::Rng;

/// Stamp Poisson arrivals at `rate` requests/second onto a trace (in
/// place order). This is how the Mooncake trace is replayed at different
/// request rates (§4.2: "scale the timestamp for scanning different
/// request rates").
pub fn poisson_arrivals(reqs: &mut [TraceRequest], rate: f64, seed: u64) {
    assert!(rate > 0.0);
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    for r in reqs.iter_mut() {
        t += rng.exp(rate);
        r.arrival = t;
    }
}

/// Rescale existing arrival timestamps by `factor` (>1 → slower arrivals).
pub fn scale_arrivals(reqs: &mut [TraceRequest], factor: f64) {
    for r in reqs.iter_mut() {
        r.arrival *= factor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::mooncake_trace;

    #[test]
    fn poisson_rate_approximately_met() {
        let mut reqs = mooncake_trace(5000, 3);
        poisson_arrivals(&mut reqs, 10.0, 3);
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 10.0).abs() / 10.0 < 0.1, "rate {rate}");
        // monotone arrivals
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn scaling_changes_rate_linearly() {
        let mut reqs = mooncake_trace(100, 4);
        poisson_arrivals(&mut reqs, 5.0, 4);
        let before = reqs.last().unwrap().arrival;
        scale_arrivals(&mut reqs, 2.0);
        assert!((reqs.last().unwrap().arrival - 2.0 * before).abs() < 1e-9);
    }
}
