//! The trace record consumed by simulators and the real engine.


use crate::{RequestId, SimTime};

/// One request of a workload trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRequest {
    pub id: RequestId,
    /// Arrival time; 0 for offline (all-at-once) workloads.
    pub arrival: SimTime,
    /// Prompt length in tokens.
    pub input_tokens: usize,
    /// Generation length in tokens (oracle from the trace; the simulator
    /// decodes exactly this many).
    pub output_tokens: usize,
}

impl TraceRequest {
    pub fn total_tokens(&self) -> usize {
        self.input_tokens + self.output_tokens
    }
}
