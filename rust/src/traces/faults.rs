//! Named availability scenarios — the fault-timeline family the replay
//! driver ([`crate::engine::replay()`]) opens up: a flaky GPU cycling in
//! and out, rolling maintenance across a whole group, a failure cascade
//! followed by staggered rejoins, and a thermally throttling GPU that
//! stays in the group but serves slow ([`thermal_throttle`]). Each
//! returns a [`FaultTimeline`] over stable physical GPU ids;
//! replayability against a concrete group size is checked by
//! [`FaultTimeline::validate`] (the replay driver runs it before anything
//! fires).

use crate::cluster::{FaultTimeline, TimelineEvent};
use crate::SimTime;

/// One flaky GPU: `gpu` fails at `first_fail`, rejoins `downtime` later,
/// and repeats every `downtime + uptime` for `cycles` cycles.
pub fn flaky_gpu(
    gpu: usize,
    cycles: usize,
    first_fail: SimTime,
    downtime: SimTime,
    uptime: SimTime,
) -> FaultTimeline {
    assert!(downtime > 0.0 && uptime > 0.0 && cycles >= 1);
    let mut events = Vec::with_capacity(cycles * 2);
    let mut t = first_fail;
    for _ in 0..cycles {
        events.push(TimelineEvent::fail(t, gpu));
        events.push(TimelineEvent::rejoin(t + downtime, gpu));
        t += downtime + uptime;
    }
    FaultTimeline::new(events)
}

/// One thermally throttling GPU — the soft-fault sibling of
/// [`flaky_gpu`]: `gpu` slows to `factor`× effective speed at
/// `first_slow`, restores full speed `slow_for` later, and repeats every
/// `slow_for + uptime` for `cycles` cycles. The GPU never leaves the
/// group: without mitigation every synchronized TP step runs at the
/// straggler's pace, which is exactly the regime the `health` layer's
/// capacity-aware rebalancing targets.
pub fn thermal_throttle(
    gpu: usize,
    cycles: usize,
    first_slow: SimTime,
    factor: f64,
    slow_for: SimTime,
    uptime: SimTime,
) -> FaultTimeline {
    assert!(slow_for > 0.0 && uptime > 0.0 && cycles >= 1);
    assert!(
        factor.is_finite() && factor > 0.0 && factor < 1.0,
        "throttle factor must be in (0, 1), got {factor}"
    );
    let mut events = Vec::with_capacity(cycles * 2);
    let mut t = first_slow;
    for _ in 0..cycles {
        events.push(TimelineEvent::slow_down(t, gpu, factor));
        events.push(TimelineEvent::restore(t + slow_for, gpu));
        t += slow_for + uptime;
    }
    FaultTimeline::new(events)
}

/// Rolling maintenance: each GPU of `world` is taken down for `downtime`
/// and rejoined, one after another, with `gap` between consecutive
/// take-downs starting at `start`. With `gap < downtime` the windows
/// overlap (up to `⌈downtime/gap⌉` GPUs down at once), which is exactly
/// the multi-failure regime the paper's §5 timeline exercises.
pub fn rolling_maintenance(
    world: usize,
    start: SimTime,
    downtime: SimTime,
    gap: SimTime,
) -> FaultTimeline {
    assert!(world >= 2 && downtime > 0.0 && gap > 0.0);
    let max_overlap = (downtime / gap).ceil() as usize;
    assert!(
        max_overlap < world,
        "downtime/gap would overlap {max_overlap} windows and take the whole {world}-GPU group down"
    );
    let mut events = Vec::with_capacity(world * 2);
    for g in 0..world {
        let t = start + g as f64 * gap;
        events.push(TimelineEvent::fail(t, g));
        events.push(TimelineEvent::rejoin(t + downtime, g));
    }
    FaultTimeline::new(events)
}

/// A failure cascade: GPUs `0..k` fail in quick succession (one every
/// `stagger` starting at `at`), then rejoin in the same staggered order
/// once each has been down for `downtime`. The cascade overlaps fully
/// when `downtime > k × stagger`.
pub fn cascade_then_heal(
    k: usize,
    at: SimTime,
    stagger: SimTime,
    downtime: SimTime,
) -> FaultTimeline {
    assert!(k >= 1 && stagger >= 0.0 && downtime > 0.0);
    let mut events = Vec::with_capacity(k * 2);
    for g in 0..k {
        let t = at + g as f64 * stagger;
        events.push(TimelineEvent::fail(t, g));
        events.push(TimelineEvent::rejoin(t + downtime, g));
    }
    FaultTimeline::new(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flaky_gpu_cycles_validate() {
        let tl = flaky_gpu(3, 4, 1.0, 0.5, 2.0);
        assert_eq!(tl.len(), 8);
        tl.validate(8).unwrap();
        assert_eq!(tl.max_concurrent_down(), 1);
    }

    #[test]
    fn rolling_maintenance_overlaps_when_gap_is_short() {
        let overlapped = rolling_maintenance(8, 0.0, 10.0, 4.0);
        overlapped.validate(8).unwrap();
        assert_eq!(overlapped.max_concurrent_down(), 3, "ceil(10/4) windows overlap");
        let serial = rolling_maintenance(8, 0.0, 2.0, 5.0);
        serial.validate(8).unwrap();
        assert_eq!(serial.max_concurrent_down(), 1);
    }

    #[test]
    fn cascade_overlaps_fully_then_heals() {
        let tl = cascade_then_heal(3, 1.0, 0.1, 5.0);
        tl.validate(8).unwrap();
        assert_eq!(tl.max_concurrent_down(), 3);
        // A TP4 group survives a 3-cascade; a TP3 group would not.
        assert!(tl.validate(4).is_ok());
        assert!(tl.validate(3).is_err());
    }

    #[test]
    fn thermal_throttle_cycles_validate() {
        let tl = thermal_throttle(3, 4, 1.0, 0.5, 2.0, 3.0);
        assert_eq!(tl.len(), 8);
        tl.validate(8).unwrap();
        assert_eq!(tl.max_concurrent_down(), 0, "soft faults never shrink the world");
        assert_eq!(tl.max_concurrent_degraded(), 1);
        // The smallest group containing gpu 3 tolerates the whole spell —
        // soft faults never violate the ≤ world-1 concurrent-down rule.
        tl.validate(4).unwrap();
    }
}
