//! Workload and availability traces.
//!
//! The paper evaluates on three external datasets we do not have:
//! OpenThoughts-114k (offline workload, Table 1), the Mooncake conversation
//! trace (online workload, Table 2), and a GCP cloud availability trace
//! (Fig 5). Each generator here reproduces the *published statistics* of
//! its dataset (length moments, arrival process, availability dynamics)
//! with a seeded RNG, which is what the experiments actually consume.
//!
//! The fault-scenario generators ([`flaky_gpu`], [`rolling_maintenance`],
//! [`cascade_then_heal`], [`thermal_throttle`], [`thundering_herd`])
//! additionally express
//! named availability scenarios — hard failures and soft (degraded-GPU)
//! spells — as [`crate::cluster::FaultTimeline`]s for the replay driver.
//!
//! ```
//! use failsafe::traces::{mooncake_trace, poisson_arrivals, split_arrivals};
//!
//! let mut trace = mooncake_trace(64, 7);  // seeded: reproducible statistics
//! poisson_arrivals(&mut trace, 4.0, 7);   // stamp ~4 req/s Poisson arrivals
//! assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
//! // Round-robin replica split — the static baseline the fleet router's
//! // load-aware placement is measured against.
//! let shards = split_arrivals(&trace, 4);
//! assert_eq!(shards.len(), 4);
//! assert_eq!(shards.iter().map(Vec::len).sum::<usize>(), 64);
//! ```

mod arrivals;
mod faults;
mod gcp;
mod lengths;
mod overload;
mod repeat_fanout;
mod request;
mod spot;

pub use arrivals::{poisson_arrivals, scale_arrivals, split_arrivals};
pub use faults::{cascade_then_heal, flaky_gpu, rolling_maintenance, thermal_throttle};
pub use gcp::gcp_availability;
pub use lengths::{mooncake_trace, openthoughts_trace, TraceStats};
pub use overload::{
    overload_storm, priority_tiers, thundering_herd, OverloadRequest, TIER_BEST_EFFORT,
    TIER_PREMIUM, TIER_STANDARD,
};
pub use repeat_fanout::{repeat_fanout, FanoutRequest};
pub use request::TraceRequest;
pub use spot::{
    diurnal_arrivals, spot_preemptions, spot_timeline, SpotPreemption, SPOT_WARN_MAX_S,
    SPOT_WARN_MIN_S,
};
