//! Repeat-fanout workload: K distinct shared prefixes, each continued by
//! N requests — the spnl-style inner/outer repeat pattern that dominates
//! agentic and few-shot traffic. Unlike the length-only generators, this
//! one materializes actual prompt tokens, because prefix sharing keys on
//! token content: every continuation of a prefix carries the *same*
//! leading tokens plus a distinct suffix.
//!
//! Token ids stay below 512 so the prompts are valid for every model
//! preset, including `small_real` on the real engine.

use super::TraceRequest;
use crate::util::Rng;

/// One repeat-fanout request: the trace record (lengths/arrival) plus the
/// materialized prompt the trace generators normally omit.
#[derive(Debug, Clone, PartialEq)]
pub struct FanoutRequest {
    pub request: TraceRequest,
    /// Actual prompt tokens: shared prefix then private suffix.
    pub prompt: Vec<u32>,
}

/// Generate `prefixes` distinct prefix chains of `prefix_tokens` tokens,
/// each fanned out into `fanout` continuations with a distinct
/// `suffix_tokens`-token tail. Requests are ordered donor-first per
/// prefix (prefix 0's continuations, then prefix 1's, ...) with ids
/// sequential in that order and arrival 0 — callers stamp arrivals or
/// [`crate::engine::SubmitOptions::at`] times as the experiment needs.
/// Output budgets are a small deterministic cycle (4..=11) so decode work
/// is non-trivial but the workload stays prefill-dominated. Seeded and
/// fully deterministic.
pub fn repeat_fanout(
    prefixes: usize,
    fanout: usize,
    prefix_tokens: usize,
    suffix_tokens: usize,
    seed: u64,
) -> Vec<FanoutRequest> {
    assert!(prefix_tokens > 0 && suffix_tokens > 0, "prefix and suffix must be non-empty");
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::with_capacity(prefixes * fanout);
    for _ in 0..prefixes {
        let prefix: Vec<u32> = (0..prefix_tokens).map(|_| rng.pick(512) as u32).collect();
        for _ in 0..fanout {
            let mut prompt = prefix.clone();
            prompt.extend((0..suffix_tokens).map(|_| rng.pick(512) as u32));
            let id = out.len() as u64;
            out.push(FanoutRequest {
                request: TraceRequest {
                    id,
                    arrival: 0.0,
                    input_tokens: prompt.len(),
                    output_tokens: 4 + (id as usize % 8),
                },
                prompt,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanout_shares_prefixes_and_diverges_suffixes() {
        let reqs = repeat_fanout(3, 4, 64, 16, 9);
        assert_eq!(reqs.len(), 12);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.request.id, i as u64);
            assert_eq!(r.request.input_tokens, 80);
            assert_eq!(r.prompt.len(), 80);
            assert!(r.prompt.iter().all(|&t| t < 512), "vocab-safe tokens");
            let donor = &reqs[(i / 4) * 4];
            assert_eq!(r.prompt[..64], donor.prompt[..64], "prefix shared within a group");
        }
        // Distinct prefixes across groups, distinct suffixes within one.
        assert_ne!(reqs[0].prompt[..64], reqs[4].prompt[..64]);
        assert_ne!(reqs[0].prompt[64..], reqs[1].prompt[64..]);
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(repeat_fanout(2, 3, 32, 8, 5), repeat_fanout(2, 3, 32, 8, 5));
        assert_ne!(repeat_fanout(2, 3, 32, 8, 5), repeat_fanout(2, 3, 32, 8, 6));
    }
}
