//! Overload scenario generators: sustained storms of priority-tiered
//! tenants, and the thundering-herd rejoin timeline.
//!
//! The overload drill (`failsafe overload`, `benches/overload.rs`)
//! needs workloads where demand *sustainably* exceeds capacity — not a
//! burst the queue absorbs, but a regime where something must lose. The
//! generators here stamp Mooncake-statistics requests with SLO tiers:
//! a premium slice with tight deadlines, a standard slice with loose
//! ones, and a best-effort remainder with none — the population the
//! preemptive scheduler, swap tier, and admission gateway triage.

use super::{mooncake_trace, poisson_arrivals, TraceRequest};
use crate::cluster::{FaultTimeline, TimelineEvent};
use crate::engine::SubmitOptions;
use crate::util::Rng;
use crate::{RequestId, SimTime};

/// Premium tier priority (tight deadline).
pub const TIER_PREMIUM: i32 = 2;
/// Standard tier priority (loose deadline).
pub const TIER_STANDARD: i32 = 1;
/// Best-effort tier priority (no deadline — never triggers preemption,
/// first to be shed).
pub const TIER_BEST_EFFORT: i32 = 0;

/// One tiered request of an overload workload: a [`TraceRequest`] plus
/// the SLO contract it was sold under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadRequest {
    pub id: RequestId,
    pub arrival: SimTime,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// SLO tier (see [`TIER_PREMIUM`] / [`TIER_STANDARD`] /
    /// [`TIER_BEST_EFFORT`]).
    pub priority: i32,
    /// Completion deadline on the shared clock; `None` = best-effort.
    pub deadline: Option<SimTime>,
}

impl OverloadRequest {
    /// The submit options encoding this request's arrival and SLO.
    pub fn options(&self) -> SubmitOptions {
        let mut opts = SubmitOptions::new(self.output_tokens.max(1))
            .at(self.arrival)
            .priority(self.priority);
        if let Some(d) = self.deadline {
            opts = opts.deadline(d);
        }
        opts
    }

    /// A placeholder prompt of the right length (simulated backends only
    /// measure lengths).
    pub fn prompt(&self) -> Vec<u32> {
        vec![7; self.input_tokens.max(1)]
    }
}

/// Stamp SLO tiers onto a timed trace: a `premium` fraction at
/// [`TIER_PREMIUM`] with deadline `arrival + slo_s`, a `standard`
/// fraction at [`TIER_STANDARD`] with deadline `arrival + 4 × slo_s`,
/// and the remainder best-effort with no deadline. Tier assignment is
/// seeded-random per request, so tiers interleave in arrival order the
/// way tenant traffic does.
pub fn priority_tiers(
    trace: &[TraceRequest],
    premium: f64,
    standard: f64,
    slo_s: f64,
    seed: u64,
) -> Vec<OverloadRequest> {
    assert!(premium >= 0.0 && standard >= 0.0 && premium + standard <= 1.0);
    assert!(slo_s > 0.0, "SLO horizon must be positive");
    let mut rng = Rng::seed_from_u64(seed);
    trace
        .iter()
        .map(|r| {
            let roll = rng.range_f64(0.0, 1.0);
            let (priority, deadline) = if roll < premium {
                (TIER_PREMIUM, Some(r.arrival + slo_s))
            } else if roll < premium + standard {
                (TIER_STANDARD, Some(r.arrival + 4.0 * slo_s))
            } else {
                (TIER_BEST_EFFORT, None)
            };
            OverloadRequest {
                id: r.id,
                arrival: r.arrival,
                input_tokens: r.input_tokens,
                output_tokens: r.output_tokens,
                priority,
                deadline,
            }
        })
        .collect()
}

/// A sustained overload storm: `n` Mooncake-statistics requests arriving
/// Poisson at `rate` req/s, tiered 20% premium / 30% standard / 50%
/// best-effort with SLO horizon `slo_s`. Drive it at 1×, 1.5×, and 2×
/// the rate a fleet sustains to sweep the overload regimes the
/// admission gateway triages. Inputs are capped at 8k and outputs kept
/// short so drill runs stay tractable — the contention under test is
/// KV/batch admission, not raw token volume.
pub fn overload_storm(n: usize, rate: f64, slo_s: f64, seed: u64) -> Vec<OverloadRequest> {
    let mut trace = mooncake_trace(n, seed);
    for r in trace.iter_mut() {
        r.input_tokens = r.input_tokens.min(8192);
        r.output_tokens = (r.output_tokens / 8).clamp(4, 32);
    }
    poisson_arrivals(&mut trace, rate, seed ^ 0x5702_11AD);
    priority_tiers(&trace, 0.2, 0.3, slo_s, seed ^ 0x71E2_0AD5)
}

/// The thundering-herd rejoin: `k` GPUs fail staggered from `fail_at`,
/// then **all rejoin at the same instant** `rejoin_at` — capacity
/// returns as a step function while the gateway queue is at its
/// deepest, exercising the re-admission burst (the opposite shape of
/// [`super::cascade_then_heal`]'s staggered healing).
pub fn thundering_herd(
    k: usize,
    fail_at: SimTime,
    stagger: SimTime,
    rejoin_at: SimTime,
) -> FaultTimeline {
    assert!(k >= 1 && stagger >= 0.0);
    let last_fail = fail_at + (k - 1) as f64 * stagger;
    assert!(
        rejoin_at > last_fail,
        "herd rejoin at {rejoin_at} must follow the last failure at {last_fail}"
    );
    let mut events = Vec::with_capacity(k * 2);
    for g in 0..k {
        events.push(TimelineEvent::fail(fail_at + g as f64 * stagger, g));
    }
    for g in 0..k {
        events.push(TimelineEvent::rejoin(rejoin_at, g));
    }
    FaultTimeline::new(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_tiers_split_and_deadlines_follow_arrivals() {
        let storm = overload_storm(400, 20.0, 2.0, 17);
        assert_eq!(storm.len(), 400);
        let premium = storm.iter().filter(|r| r.priority == TIER_PREMIUM).count();
        let standard = storm.iter().filter(|r| r.priority == TIER_STANDARD).count();
        let best = storm.iter().filter(|r| r.priority == TIER_BEST_EFFORT).count();
        assert!(premium > 40 && premium < 120, "premium ~20% (got {premium})");
        assert!(standard > 70 && standard < 170, "standard ~30% (got {standard})");
        assert_eq!(premium + standard + best, 400);
        for r in &storm {
            match r.priority {
                TIER_PREMIUM => assert_eq!(r.deadline, Some(r.arrival + 2.0)),
                TIER_STANDARD => assert_eq!(r.deadline, Some(r.arrival + 8.0)),
                _ => assert_eq!(r.deadline, None),
            }
            let opts = r.options();
            assert_eq!(opts.priority, r.priority);
            assert_eq!(opts.deadline, r.deadline);
            assert_eq!(opts.arrival, r.arrival);
            assert_eq!(r.prompt().len(), r.input_tokens);
        }
        // Seeded: regenerating is bit-identical.
        assert_eq!(storm, overload_storm(400, 20.0, 2.0, 17));
    }

    #[test]
    fn thundering_herd_rejoins_as_a_step() {
        let tl = thundering_herd(3, 1.0, 0.2, 5.0);
        tl.validate(8).unwrap();
        assert_eq!(tl.len(), 6);
        assert_eq!(tl.max_concurrent_down(), 3, "all k down before the herd returns");
    }

    #[test]
    #[should_panic]
    fn herd_rejoin_must_follow_failures() {
        thundering_herd(3, 1.0, 1.0, 2.0);
    }
}
