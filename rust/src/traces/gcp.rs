//! GCP-derived GPU availability trace (paper Fig 5).
//!
//! The paper scales a GCP cloud availability dataset (as used by Bamboo,
//! Oobleck and ReCycle) so that full availability = 64 GPUs across eight
//! 8-GPU nodes. We do not have the original CSV, so we regenerate an
//! availability process with the same qualitative structure the figure
//! shows: long full-capacity stretches, bursts of preemptions taking
//! several GPUs out within minutes, partial recoveries, and a floor around
//! ~75% availability. The generator is a seeded birth–death process whose
//! parameters were chosen to visually match Fig 5.

use crate::util::Rng;
use crate::SimTime;

/// Step-function availability samples `(time_s, healthy_gpus)` spanning
/// `duration_s`, starting and ending near full availability of `total`.
pub fn gcp_availability(total: usize, duration_s: f64, seed: u64) -> Vec<(SimTime, usize)> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out: Vec<(SimTime, usize)> = vec![(0.0, total)];
    let mut t = 0.0;
    let mut avail = total;
    let floor = total * 3 / 4;

    while t < duration_s {
        // Mean ~6 minutes between events; bursty failures of 1-3 GPUs,
        // slower single/double recoveries — a birth–death walk whose
        // stationary mean sits near ~87% availability, matching the
        // sustained degraded stretches of the paper's Fig 5.
        t += rng.range_f64(90.0, 600.0);
        if t >= duration_s {
            break;
        }
        // Downward pressure near full capacity, upward near the floor.
        let p_fail = if avail == total {
            0.85
        } else if avail <= floor + 2 {
            0.2
        } else {
            0.5
        };
        let failing = avail > floor && rng.bool(p_fail);
        if failing {
            let k = rng.range(1, 4).min(avail - floor);
            avail -= k;
        } else if avail < total {
            let k = rng.range(1, 3).min(total - avail);
            avail += k;
        } else {
            continue; // at full capacity and not failing: no event
        }
        out.push((t, avail));
    }
    // Recover to full by the end (as the paper's trace window does).
    out.push((duration_s, total));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_matches_fig5() {
        let tr = gcp_availability(64, 4.0 * 3600.0, 42);
        assert_eq!(tr.first().unwrap().1, 64);
        assert_eq!(tr.last().unwrap().1, 64);
        let min = tr.iter().map(|&(_, a)| a).min().unwrap();
        assert!(min >= 48, "floor is 75%: {min}");
        assert!(min < 64, "must actually dip");
        assert!(tr.len() > 10, "needs enough events: {}", tr.len());
        assert!(tr.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn deterministic() {
        assert_eq!(gcp_availability(64, 3600.0, 1), gcp_availability(64, 3600.0, 1));
    }
}
