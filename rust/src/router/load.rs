//! Per-rank workload estimation in token units.


use crate::RankId;

/// Tracks the estimated pending DP computation queued on each rank.
///
/// "Workload" is counted in *token units*: prefill tokens count with their
/// context multiplier (attention over a long prefix costs more per token),
/// decode tokens count 1. The estimate deliberately mirrors what the
/// scheduler's `cost()` uses so routing and batch forming agree.
#[derive(Debug, Clone)]
pub struct LoadTracker {
    pending: Vec<f64>,
}

impl LoadTracker {
    pub fn new(world: usize) -> Self {
        LoadTracker { pending: vec![0.0; world] }
    }

    pub fn world(&self) -> usize {
        self.pending.len()
    }

    /// Queue `tokens` units of work on `rank`. Non-finite token counts
    /// (NaN/∞) are rejected — once a NaN enters the tracker every
    /// comparison-based decision (`least_loaded`, routing) is poisoned —
    /// so they are silently dropped here.
    pub fn add(&mut self, rank: RankId, tokens: f64) {
        if tokens.is_finite() {
            self.pending[rank] += tokens;
        }
    }

    /// Retire `tokens` units of completed work from `rank`. Non-finite
    /// token counts are rejected (see [`LoadTracker::add`]).
    pub fn complete(&mut self, rank: RankId, tokens: f64) {
        if tokens.is_finite() {
            self.pending[rank] = (self.pending[rank] - tokens).max(0.0);
        }
    }

    pub fn pending(&self, rank: RankId) -> f64 {
        self.pending[rank]
    }

    pub fn pending_all(&self) -> &[f64] {
        &self.pending
    }

    /// Rank with the smallest pending workload (ties → lowest id).
    /// Total-order comparison: cannot panic even if a NaN slipped past
    /// the `add`/`complete` guards.
    pub fn least_loaded(&self) -> RankId {
        self.pending
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
            .map(|(r, _)| r)
            .unwrap_or(0)
    }

    /// Max/mean pending ratio (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let mean = self.pending.iter().sum::<f64>() / self.pending.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        self.pending.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Rebuild for a new world size after reconfiguration, remapping
    /// surviving ranks' pending work and dropping the failed rank's (its
    /// requests get re-routed by the coordinator).
    pub fn remap(&self, survivor_map: &[Option<RankId>], new_world: usize) -> LoadTracker {
        let mut pending = vec![0.0; new_world];
        for (old, &p) in self.pending.iter().enumerate() {
            if let Some(new_r) = survivor_map.get(old).copied().flatten() {
                pending[new_r] += p;
            }
        }
        LoadTracker { pending }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_and_ties() {
        let mut t = LoadTracker::new(3);
        assert_eq!(t.least_loaded(), 0);
        t.add(0, 10.0);
        t.add(1, 5.0);
        assert_eq!(t.least_loaded(), 2);
        t.add(2, 5.0);
        assert_eq!(t.least_loaded(), 1);
    }

    #[test]
    fn non_finite_loads_are_rejected() {
        let mut t = LoadTracker::new(2);
        t.add(0, 5.0);
        t.add(0, f64::NAN);
        t.add(1, f64::INFINITY);
        t.complete(0, f64::NAN);
        assert_eq!(t.pending(0), 5.0);
        assert_eq!(t.pending(1), 0.0);
        // least_loaded still works (and can never panic).
        assert_eq!(t.least_loaded(), 1);
    }

    #[test]
    fn complete_floors_at_zero() {
        let mut t = LoadTracker::new(2);
        t.add(0, 3.0);
        t.complete(0, 5.0);
        assert_eq!(t.pending(0), 0.0);
    }

    #[test]
    fn remap_drops_failed_rank_load() {
        let mut t = LoadTracker::new(3);
        t.add(0, 1.0);
        t.add(1, 2.0);
        t.add(2, 3.0);
        let map = vec![Some(0), None, Some(1)];
        let r = t.remap(&map, 2);
        assert_eq!(r.pending_all(), &[1.0, 3.0]);
    }
}
