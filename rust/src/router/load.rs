//! Per-rank workload estimation in token units.


use crate::RankId;

/// Tracks the estimated pending DP computation queued on each rank.
///
/// "Workload" is counted in *token units*: prefill tokens count with their
/// context multiplier (attention over a long prefix costs more per token),
/// decode tokens count 1. The estimate deliberately mirrors what the
/// scheduler's `cost()` uses so routing and batch forming agree.
///
/// Ranks may have unequal *effective capacity* (a thermally throttled GPU
/// at 0.5× should receive half the work): [`LoadTracker::least_loaded`]
/// scores `pending / capacity`, so with the default all-1.0 capacities the
/// behaviour is the classic least-pending rule, and degraded ranks
/// naturally attract proportionally less work once the health layer calls
/// [`LoadTracker::set_capacity`].
#[derive(Debug, Clone)]
pub struct LoadTracker {
    pending: Vec<f64>,
    /// Effective capacity per rank (1.0 = healthy full speed; 0 excludes
    /// the rank from routing entirely, e.g. a Suspect rank being drained).
    capacity: Vec<f64>,
}

impl LoadTracker {
    pub fn new(world: usize) -> Self {
        LoadTracker { pending: vec![0.0; world], capacity: vec![1.0; world] }
    }

    pub fn world(&self) -> usize {
        self.pending.len()
    }

    /// Set `rank`'s effective capacity. Non-finite or negative values are
    /// rejected (dropped), mirroring the `add`/`complete` guards; `0.0`
    /// removes the rank from `least_loaded` consideration unless every
    /// rank is at zero.
    pub fn set_capacity(&mut self, rank: RankId, capacity: f64) {
        if capacity.is_finite() && capacity >= 0.0 {
            self.capacity[rank] = capacity;
        }
    }

    /// Effective capacity of `rank` (1.0 unless the health layer said
    /// otherwise).
    pub fn capacity(&self, rank: RankId) -> f64 {
        self.capacity[rank]
    }

    /// Queue `tokens` units of work on `rank`. Non-finite token counts
    /// (NaN/∞) are rejected — once a NaN enters the tracker every
    /// comparison-based decision (`least_loaded`, routing) is poisoned —
    /// so they are silently dropped here.
    pub fn add(&mut self, rank: RankId, tokens: f64) {
        if tokens.is_finite() {
            self.pending[rank] += tokens;
        }
    }

    /// Retire `tokens` units of completed work from `rank`. Non-finite
    /// token counts are rejected (see [`LoadTracker::add`]).
    pub fn complete(&mut self, rank: RankId, tokens: f64) {
        if tokens.is_finite() {
            self.pending[rank] = (self.pending[rank] - tokens).max(0.0);
        }
    }

    pub fn pending(&self, rank: RankId) -> f64 {
        self.pending[rank]
    }

    pub fn pending_all(&self) -> &[f64] {
        &self.pending
    }

    /// Rank with the smallest capacity-normalized pending workload
    /// (`pending / capacity`; ties → lowest id). Zero-capacity ranks
    /// score infinite and lose to any rank with capacity. Total-order
    /// comparison: cannot panic even if a NaN slipped past the
    /// `add`/`complete` guards.
    pub fn least_loaded(&self) -> RankId {
        self.pending
            .iter()
            .zip(&self.capacity)
            .map(|(&p, &c)| if c > 0.0 { p / c } else { f64::INFINITY })
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(r, _)| r)
            .unwrap_or(0)
    }

    /// [`LoadTracker::least_loaded`] with a per-rank score credit in token
    /// units — the prefix-affinity hook: a rank holding a request's warm
    /// KV prefix is credited the prefill work the hit would save, so it
    /// outranks an idle cold rank whenever the savings exceed its load
    /// surplus. The credit is subtracted from pending *before* capacity
    /// normalization and may drive the score negative — that is what lets
    /// a loaded-but-warm rank strictly beat an idle cold one. An all-zero
    /// `bonus` reduces exactly to the classic rule (same deterministic
    /// lowest-id ties).
    pub fn least_loaded_biased(&self, bonus: &[f64]) -> RankId {
        self.pending
            .iter()
            .zip(&self.capacity)
            .enumerate()
            .map(|(r, (&p, &c))| {
                let credit = bonus.get(r).copied().unwrap_or(0.0).max(0.0);
                let score = if c > 0.0 { (p - credit) / c } else { f64::INFINITY };
                (r, score)
            })
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(r, _)| r)
            .unwrap_or(0)
    }

    /// Max/mean pending ratio (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let mean = self.pending.iter().sum::<f64>() / self.pending.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        self.pending.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Rebuild for a new world size after reconfiguration, remapping
    /// surviving ranks' pending work and capacities and dropping the
    /// failed rank's (its requests get re-routed by the coordinator).
    /// Ranks appended beyond the survivors (rejoins) start empty at full
    /// capacity.
    pub fn remap(&self, survivor_map: &[Option<RankId>], new_world: usize) -> LoadTracker {
        let mut pending = vec![0.0; new_world];
        let mut capacity = vec![1.0; new_world];
        for (old, &p) in self.pending.iter().enumerate() {
            if let Some(new_r) = survivor_map.get(old).copied().flatten() {
                pending[new_r] += p;
                capacity[new_r] = self.capacity[old];
            }
        }
        LoadTracker { pending, capacity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_and_ties() {
        let mut t = LoadTracker::new(3);
        assert_eq!(t.least_loaded(), 0);
        t.add(0, 10.0);
        t.add(1, 5.0);
        assert_eq!(t.least_loaded(), 2);
        t.add(2, 5.0);
        assert_eq!(t.least_loaded(), 1);
    }

    #[test]
    fn non_finite_loads_are_rejected() {
        let mut t = LoadTracker::new(2);
        t.add(0, 5.0);
        t.add(0, f64::NAN);
        t.add(1, f64::INFINITY);
        t.complete(0, f64::NAN);
        assert_eq!(t.pending(0), 5.0);
        assert_eq!(t.pending(1), 0.0);
        // least_loaded still works (and can never panic).
        assert_eq!(t.least_loaded(), 1);
    }

    #[test]
    fn biased_routing_prefers_warm_over_idle() {
        let mut t = LoadTracker::new(3);
        t.add(1, 50.0); // warm rank, moderately busy
        // No bonus: identical to the classic rule (idle rank 0 wins).
        assert_eq!(t.least_loaded_biased(&[0.0; 3]), t.least_loaded());
        // A 512-token prefix hit on rank 1 outweighs its 50-token queue.
        assert_eq!(t.least_loaded_biased(&[0.0, 512.0, 0.0]), 1);
        // ...but not a queue larger than the savings.
        t.add(1, 600.0);
        assert_eq!(t.least_loaded_biased(&[0.0, 512.0, 0.0]), 0);
        // Zero-capacity ranks stay excluded even with a bonus.
        t.set_capacity(2, 0.0);
        assert_eq!(t.least_loaded_biased(&[0.0, 0.0, 1e9]), 0);
        // Short bonus slices are padded with zeros, not a panic.
        assert_eq!(t.least_loaded_biased(&[]), 0);
    }

    #[test]
    fn complete_floors_at_zero() {
        let mut t = LoadTracker::new(2);
        t.add(0, 3.0);
        t.complete(0, 5.0);
        assert_eq!(t.pending(0), 0.0);
    }

    #[test]
    fn remap_drops_failed_rank_load() {
        let mut t = LoadTracker::new(3);
        t.add(0, 1.0);
        t.add(1, 2.0);
        t.add(2, 3.0);
        let map = vec![Some(0), None, Some(1)];
        let r = t.remap(&map, 2);
        assert_eq!(r.pending_all(), &[1.0, 3.0]);
    }

    #[test]
    fn capacity_weights_routing_decisions() {
        let mut t = LoadTracker::new(2);
        t.set_capacity(1, 0.5); // throttled
        // Equal pending: the healthy rank wins (5/1 < 5/0.5).
        t.add(0, 5.0);
        t.add(1, 5.0);
        assert_eq!(t.least_loaded(), 0);
        // The throttled rank wins only when its normalized load is lower.
        t.add(0, 6.0); // 11/1 vs 5/0.5=10
        assert_eq!(t.least_loaded(), 1);
        // Zero capacity removes a rank from consideration entirely.
        t.set_capacity(1, 0.0);
        assert_eq!(t.least_loaded(), 0);
        // Bad capacities are dropped, not applied.
        t.set_capacity(0, f64::NAN);
        t.set_capacity(0, -1.0);
        assert_eq!(t.capacity(0), 1.0);
    }

    #[test]
    fn remap_carries_capacity_and_resets_appended_ranks() {
        let mut t = LoadTracker::new(3);
        t.set_capacity(2, 0.25);
        t.add(2, 1.0);
        // Rank 1 fails: survivor 2 renumbers to 1 and keeps its throttle.
        let shrunk = t.remap(&[Some(0), None, Some(1)], 2);
        assert_eq!(shrunk.capacity(1), 0.25);
        // Expansion appends a fresh full-capacity rank.
        let grown = shrunk.remap(&[Some(0), Some(1)], 3);
        assert_eq!(grown.capacity(1), 0.25);
        assert_eq!(grown.capacity(2), 1.0);
        assert_eq!(grown.pending(2), 0.0);
    }
}
