//! Fine-grained load-aware DP-rank routing (§3.1).
//!
//! With hybrid attention, each request has a *home* DP rank that computes
//! the replicated heads for it (and stores their KV). Picking homes is an
//! online makespan-minimization problem; FailSafe uses the classical greedy
//! rule — route each arrival to the rank with the least estimated pending
//! work (in token units) — which continuously adapts to skewed request
//! lengths. The round-robin router is the baseline of Fig 3.
//! [`crate::fleet::FleetRouter`] generalizes the same rule from ranks
//! inside one TP group to replicas inside a fleet.
//!
//! ```
//! use failsafe::router::{DpRouter, RoutePolicy};
//!
//! let mut router = DpRouter::new(RoutePolicy::LeastLoaded, 4);
//! let home = router.route(1000.0);    // empty tracker: ties break to rank 0
//! assert_eq!(home, 0);
//! assert_eq!(router.route(10.0), 1);  // least-loaded avoids the busy rank
//! router.complete(home, 1000.0);      // work retired: rank 0 attracts again
//! assert_eq!(router.route(10.0), 0);
//! assert_eq!(router.tracker().pending(1), 10.0);
//! ```

mod affinity;
mod load;
mod policy;

pub use affinity::{AffinityRouter, SessionId};
pub use load::LoadTracker;
pub use policy::{DpRouter, RoutePolicy};
