//! Fine-grained load-aware DP-rank routing (§3.1).
//!
//! With hybrid attention, each request has a *home* DP rank that computes
//! the replicated heads for it (and stores their KV). Picking homes is an
//! online makespan-minimization problem; FailSafe uses the classical greedy
//! rule — route each arrival to the rank with the least estimated pending
//! work (in token units) — which continuously adapts to skewed request
//! lengths. The round-robin router is the baseline of Fig 3.

mod affinity;
mod load;
mod policy;

pub use affinity::{AffinityRouter, SessionId};
pub use load::LoadTracker;
pub use policy::{DpRouter, RoutePolicy};
