//! Session-affinity wrapper around the DP router.
//!
//! Multi-turn conversations (the Mooncake workload) reuse their KV prefix
//! across turns; with hybrid attention the DP-head KV of a session lives
//! on its home rank, so re-routing a follow-up turn elsewhere would force
//! a prefix transfer. The affinity router pins sessions to their first
//! home but *breaks* the pin when the target rank's load exceeds the
//! fleet minimum by more than `spill_threshold` token-units — bounding the
//! imbalance a sticky session can cause (re-pinning after reconfiguration,
//! when the old home may be gone).

use std::collections::HashMap;

use super::{DpRouter, RoutePolicy};
use crate::RankId;

/// Opaque session identifier (e.g. a conversation id).
pub type SessionId = u64;

/// Sticky routing with load-bounded spill.
#[derive(Debug, Clone)]
pub struct AffinityRouter {
    inner: DpRouter,
    pins: HashMap<SessionId, RankId>,
    /// Re-route a pinned session if its rank's pending load exceeds the
    /// fleet minimum by more than this many token-units.
    pub spill_threshold: f64,
    /// Pins broken by load spill (telemetry).
    pub spills: u64,
}

impl AffinityRouter {
    pub fn new(policy: RoutePolicy, world: usize) -> Self {
        AffinityRouter {
            inner: DpRouter::new(policy, world),
            pins: HashMap::new(),
            spill_threshold: 5_000.0,
            spills: 0,
        }
    }

    pub fn inner(&self) -> &DpRouter {
        &self.inner
    }

    /// Route one turn of `session` with estimated `work_tokens`.
    pub fn route(&mut self, session: SessionId, work_tokens: f64) -> RankId {
        if let Some(&pinned) = self.pins.get(&session) {
            let t = self.inner.tracker();
            let min = (0..t.world()).map(|r| t.pending(r)).fold(f64::MAX, f64::min);
            if t.pending(pinned) - min <= self.spill_threshold {
                self.inner.add_load(pinned, work_tokens);
                return pinned;
            }
            self.spills += 1; // overloaded home: fall through and re-pin
        }
        let rank = self.inner.route(work_tokens);
        self.pins.insert(session, rank);
        rank
    }

    /// [`AffinityRouter::route`] with a per-rank prefix credit in token
    /// units (see [`DpRouter::route_biased`]): an existing pin still wins
    /// (subject to the spill bound — session KV locality dominates), but
    /// an unpinned or spilled turn is steered toward the rank already
    /// holding the request's shared prefix instead of an idle cold one.
    pub fn route_biased(&mut self, session: SessionId, work_tokens: f64, bonus: &[f64]) -> RankId {
        if let Some(&pinned) = self.pins.get(&session) {
            let t = self.inner.tracker();
            let min = (0..t.world()).map(|r| t.pending(r)).fold(f64::MAX, f64::min);
            if t.pending(pinned) - min <= self.spill_threshold {
                self.inner.add_load(pinned, work_tokens);
                return pinned;
            }
            self.spills += 1; // overloaded home: fall through and re-pin
        }
        let rank = self.inner.route_biased(work_tokens, bonus);
        self.pins.insert(session, rank);
        rank
    }

    /// Report completed work on `rank`.
    pub fn complete(&mut self, rank: RankId, work_tokens: f64) {
        self.inner.complete(rank, work_tokens);
    }

    /// Session ended: drop the pin.
    pub fn release(&mut self, session: SessionId) {
        self.pins.remove(&session);
    }

    /// Rebuild after a reconfiguration: surviving pins are renumbered,
    /// pins to the failed rank are dropped (their next turn re-routes).
    pub fn remap(&self, survivor_map: &[Option<RankId>], new_world: usize) -> AffinityRouter {
        let pins = self
            .pins
            .iter()
            .filter_map(|(&s, &r)| survivor_map.get(r).copied().flatten().map(|nr| (s, nr)))
            .collect();
        AffinityRouter {
            inner: self.inner.remap(survivor_map, new_world),
            pins,
            spill_threshold: self.spill_threshold,
            spills: self.spills,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_stick_to_their_home() {
        let mut r = AffinityRouter::new(RoutePolicy::LeastLoaded, 4);
        let home = r.route(1, 100.0);
        for _ in 0..5 {
            assert_eq!(r.route(1, 10.0), home);
        }
        // A different session lands elsewhere (least loaded).
        assert_ne!(r.route(2, 10.0), home);
    }

    #[test]
    fn prefix_bias_steers_new_sessions_but_not_pins() {
        let mut r = AffinityRouter::new(RoutePolicy::LeastLoaded, 3);
        r.inner.add_load(2, 30.0); // warm rank, modest queue
        // A new session with a 512-token prefix hit on rank 2 lands there
        // despite ranks 0 and 1 being idle.
        assert_eq!(r.route_biased(1, 64.0, &[0.0, 0.0, 512.0]), 2);
        // A pinned session ignores the bias: its own KV home dominates.
        let home = r.route(2, 10.0);
        assert_ne!(home, 2);
        assert_eq!(r.route_biased(2, 10.0, &[0.0, 0.0, 1e6]), home);
    }

    #[test]
    fn overload_breaks_the_pin() {
        let mut r = AffinityRouter::new(RoutePolicy::LeastLoaded, 2);
        let home = r.route(1, 10.0);
        // Pile unrelated load on the home rank far beyond the spill bound.
        r.inner.add_load(home, 10_000.0);
        let next = r.route(1, 10.0);
        assert_ne!(next, home, "pin must spill under overload");
        assert_eq!(r.spills, 1);
        // ...and the session is re-pinned to the new home.
        assert_eq!(r.route(1, 10.0), next);
    }

    #[test]
    fn remap_drops_failed_home_pins() {
        let mut r = AffinityRouter::new(RoutePolicy::LeastLoaded, 3);
        // Pin three sessions to distinct ranks.
        let h0 = r.route(10, 5.0);
        let h1 = r.route(11, 5.0);
        let h2 = r.route(12, 5.0);
        assert_eq!({ let mut v = vec![h0, h1, h2]; v.sort_unstable(); v }, vec![0, 1, 2]);
        // Rank 1 fails.
        let map = vec![Some(0), None, Some(1)];
        let mut r2 = r.remap(&map, 2);
        // The session homed on old rank 1 re-routes; others keep (renumbered) pins.
        let s_failed = [10u64, 11, 12][[h0, h1, h2].iter().position(|&h| h == 1).unwrap()];
        let s_kept = [10u64, 11, 12][[h0, h1, h2].iter().position(|&h| h == 0).unwrap()];
        assert_eq!(r2.route(s_kept, 1.0), 0);
        let re = r2.route(s_failed, 1.0);
        assert!(re < 2);
    }

    #[test]
    fn release_forgets_session() {
        let mut r = AffinityRouter::new(RoutePolicy::RoundRobin, 3);
        let h = r.route(1, 1.0);
        r.release(1);
        // Round-robin has advanced, so a re-route lands on the next rank.
        assert_ne!(r.route(1, 1.0), h);
    }
}
