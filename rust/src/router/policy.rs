//! Routing policies over the load tracker.


use super::LoadTracker;
use crate::RankId;

/// How arrivals are assigned a home DP rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through ranks regardless of load — the Fig 3 baseline.
    RoundRobin,
    /// Greedy online-makespan rule: route to the rank with least pending
    /// work (§3.1 Load-Aware DP-Rank Routing).
    LeastLoaded,
}

/// The DP-rank router: assigns each incoming request a home rank and books
/// its estimated work against that rank.
#[derive(Debug, Clone)]
pub struct DpRouter {
    pub policy: RoutePolicy,
    tracker: LoadTracker,
    rr_next: RankId,
}

impl DpRouter {
    pub fn new(policy: RoutePolicy, world: usize) -> Self {
        DpRouter { policy, tracker: LoadTracker::new(world), rr_next: 0 }
    }

    pub fn world(&self) -> usize {
        self.tracker.world()
    }

    pub fn tracker(&self) -> &LoadTracker {
        &self.tracker
    }

    /// Route a request with estimated `work_tokens` of DP computation.
    /// Returns the chosen home rank and books the work.
    pub fn route(&mut self, work_tokens: f64) -> RankId {
        let rank = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.tracker.world();
                r
            }
            RoutePolicy::LeastLoaded => self.tracker.least_loaded(),
        };
        self.tracker.add(rank, work_tokens);
        rank
    }

    /// [`DpRouter::route`] with a per-rank score credit in token units —
    /// the prefix-affinity hook (see [`LoadTracker::least_loaded_biased`]).
    /// Under [`RoutePolicy::LeastLoaded`] a rank holding the request's
    /// warm KV prefix is credited the prefill work the hit saves;
    /// round-robin ignores the bias (it is the baseline). Books
    /// `work_tokens` on the chosen rank like `route`.
    pub fn route_biased(&mut self, work_tokens: f64, bonus: &[f64]) -> RankId {
        let rank = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.tracker.world();
                r
            }
            RoutePolicy::LeastLoaded => self.tracker.least_loaded_biased(bonus),
        };
        self.tracker.add(rank, work_tokens);
        rank
    }

    /// Report completed work (scheduler/engine callback).
    pub fn complete(&mut self, rank: RankId, work_tokens: f64) {
        self.tracker.complete(rank, work_tokens);
    }

    /// Extra queued work the router should know about (e.g. decode carry).
    pub fn add_load(&mut self, rank: RankId, work_tokens: f64) {
        self.tracker.add(rank, work_tokens);
    }

    /// A request was aborted: un-book the work it had routed to `rank` but
    /// never completed, so the rank doesn't look busier than it is.
    pub fn cancel(&mut self, rank: RankId, work_tokens: f64) {
        self.tracker.complete(rank, work_tokens);
    }

    /// Set `rank`'s health-effective capacity (1.0 = healthy). Under
    /// [`RoutePolicy::LeastLoaded`] the router then books new work
    /// capacity-proportionally — a throttled rank attracts less, a
    /// zero-capacity (draining) rank attracts none. Round-robin ignores
    /// capacities, which is exactly why it is the baseline.
    pub fn set_capacity(&mut self, rank: RankId, capacity: f64) {
        self.tracker.set_capacity(rank, capacity);
    }

    /// Rebuild after reconfiguration.
    pub fn remap(&self, survivor_map: &[Option<RankId>], new_world: usize) -> DpRouter {
        DpRouter {
            policy: self.policy,
            tracker: self.tracker.remap(survivor_map, new_world),
            rr_next: self.rr_next % new_world.max(1),
        }
    }

    /// Grow to `new_world` ranks after a GPU rejoin: existing ranks keep
    /// their ids and booked load, the appended ranks start empty — so the
    /// least-loaded policy naturally rebalances by steering new arrivals
    /// onto the returning GPU until its queue catches up.
    pub fn expand(&self, new_world: usize) -> DpRouter {
        assert!(new_world >= self.world(), "expand cannot shrink the router");
        let identity: Vec<Option<RankId>> = (0..self.world()).map(Some).collect();
        self.remap(&identity, new_world)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The classic adversarial case for round-robin: alternating long/short
    /// requests pile all long ones on the same ranks; least-loaded spreads
    /// them (Fig 3's skew scenario).
    #[test]
    fn least_loaded_beats_round_robin_on_skew() {
        let mut rr = DpRouter::new(RoutePolicy::RoundRobin, 4);
        let mut ll = DpRouter::new(RoutePolicy::LeastLoaded, 4);
        for i in 0..64 {
            let work = if i % 4 == 0 { 1000.0 } else { 10.0 };
            rr.route(work);
            ll.route(work);
        }
        assert!(rr.tracker().imbalance() > 2.0, "rr imbalance {}", rr.tracker().imbalance());
        assert!(ll.tracker().imbalance() < 1.2, "ll imbalance {}", ll.tracker().imbalance());
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = DpRouter::new(RoutePolicy::RoundRobin, 3);
        let homes: Vec<RankId> = (0..6).map(|_| r.route(1.0)).collect();
        assert_eq!(homes, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn biased_route_books_on_the_warm_rank() {
        let mut r = DpRouter::new(RoutePolicy::LeastLoaded, 3);
        r.route(40.0); // rank 0 busy
        let warm = r.route_biased(8.0, &[500.0, 0.0, 0.0]);
        assert_eq!(warm, 0, "prefix credit outweighs the 40-token queue");
        assert_eq!(r.tracker().pending(0), 48.0);
        // Round-robin ignores the bias entirely (baseline behaviour).
        let mut rr = DpRouter::new(RoutePolicy::RoundRobin, 3);
        assert_eq!(rr.route_biased(1.0, &[0.0, 0.0, 1e9]), 0);
        assert_eq!(rr.route_biased(1.0, &[0.0, 0.0, 1e9]), 1);
    }

    #[test]
    fn cancel_releases_booked_work() {
        let mut r = DpRouter::new(RoutePolicy::LeastLoaded, 2);
        let home = r.route(100.0);
        assert_eq!(r.route(1.0), 1 - home);
        r.cancel(home, 100.0);
        assert_eq!(r.tracker().pending(home), 0.0);
    }

    #[test]
    fn expand_steers_arrivals_to_the_new_rank() {
        let mut r = DpRouter::new(RoutePolicy::LeastLoaded, 2);
        r.route(50.0);
        r.route(50.0); // both ranks loaded
        let mut grown = r.expand(3);
        assert_eq!(grown.world(), 3);
        assert_eq!(grown.tracker().pending(2), 0.0);
        assert_eq!(grown.route(1.0), 2, "empty new rank wins least-loaded");
    }

    #[test]
    fn throttled_rank_attracts_capacity_proportional_work() {
        let mut r = DpRouter::new(RoutePolicy::LeastLoaded, 4);
        r.set_capacity(2, 0.5);
        let mut booked = [0.0f64; 4];
        for _ in 0..70 {
            booked[r.route(10.0)] += 10.0;
        }
        // The throttled rank ends with ≈ half a healthy rank's share
        // (70 placements × 10 over capacity 3.5 → 200 per unit capacity).
        assert!(booked[2] <= 0.6 * booked[0], "throttled {} vs healthy {}", booked[2], booked[0]);
        assert!(booked[2] >= 0.3 * booked[0], "throttled rank must still serve");
    }

    #[test]
    fn completion_rebalances() {
        let mut r = DpRouter::new(RoutePolicy::LeastLoaded, 2);
        r.route(100.0); // → rank 0
        assert_eq!(r.route(1.0), 1);
        r.complete(0, 100.0);
        assert_eq!(r.route(1.0), 0);
    }
}
