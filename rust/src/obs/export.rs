//! Exporters over a [`TraceLog`]: Chrome/Perfetto `traceEvents` JSON,
//! a Prometheus-style text exposition snapshot, and the human-readable
//! incident timeline the `trace` subcommand prints.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

use super::{RecordKind, TraceLog, TraceRecord, Value};

/// Escape a string for a JSON literal body.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// A JSON-safe number: NaN/inf (never produced by healthy backends,
/// but a malformed trace must not poison the whole file) become 0.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn json_value(v: &Value) -> String {
    match v {
        Value::U(x) => format!("{x}"),
        Value::I(x) => format!("{x}"),
        Value::F(x) => num(*x),
        Value::B(x) => format!("{x}"),
        Value::S(x) => format!("\"{}\"", esc(x)),
    }
}

fn json_args(fields: &[(&'static str, Value)]) -> String {
    let body: Vec<String> =
        fields.iter().map(|(k, v)| format!("\"{}\":{}", esc(k), json_value(v))).collect();
    format!("{{{}}}", body.join(","))
}

/// Chrome thread id for a record: rank `r` maps to tid `r + 1`;
/// rank-less (replica-scoped) records share the control thread, tid 0.
fn tid(rec: &TraceRecord) -> usize {
    rec.rank.map(|r| r + 1).unwrap_or(0)
}

impl TraceLog {
    /// Serialize as Chrome/Perfetto trace JSON (`chrome://tracing`,
    /// <https://ui.perfetto.dev>): replicas as processes, ranks as
    /// threads (tid 0 is the replica-level "control" lane), spans as
    /// `B`/`E` pairs, events and decisions as instants, gauges as
    /// counter tracks. Timestamps convert from simulated seconds to
    /// microseconds.
    pub fn to_chrome_trace(&self) -> String {
        let mut events: Vec<String> = Vec::with_capacity(self.len() + 16);

        // Metadata: name every process/thread that appears.
        let mut replicas: BTreeSet<usize> = BTreeSet::new();
        let mut threads: BTreeSet<(usize, usize)> = BTreeSet::new();
        for rec in self.records() {
            replicas.insert(rec.replica);
            threads.insert((rec.replica, tid(rec)));
        }
        for &p in &replicas {
            events.push(format!(
                "{{\"ph\":\"M\",\"pid\":{p},\"tid\":0,\"name\":\"process_name\",\
                 \"args\":{{\"name\":\"replica {p}\"}}}}"
            ));
        }
        for &(p, t) in &threads {
            let name = if t == 0 { "control".to_string() } else { format!("rank {}", t - 1) };
            events.push(format!(
                "{{\"ph\":\"M\",\"pid\":{p},\"tid\":{t},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ));
        }

        for rec in self.records() {
            let ts = num(rec.t * 1e6);
            let pid = rec.replica;
            let t = tid(rec);
            let name = esc(rec.name);
            let line = match rec.kind {
                RecordKind::SpanBegin => format!(
                    "{{\"ph\":\"B\",\"name\":\"{name}\",\"cat\":\"span\",\"pid\":{pid},\
                     \"tid\":{t},\"ts\":{ts},\"args\":{}}}",
                    json_args(&rec.fields)
                ),
                RecordKind::SpanEnd => format!(
                    "{{\"ph\":\"E\",\"name\":\"{name}\",\"cat\":\"span\",\"pid\":{pid},\
                     \"tid\":{t},\"ts\":{ts}}}"
                ),
                RecordKind::Event | RecordKind::Decision => format!(
                    "{{\"ph\":\"i\",\"s\":\"t\",\"name\":\"{name}\",\"cat\":\"{}\",\
                     \"pid\":{pid},\"tid\":{t},\"ts\":{ts},\"args\":{}}}",
                    rec.kind.label(),
                    json_args(&rec.fields)
                ),
                RecordKind::Gauge => {
                    let value = match rec.field("value") {
                        Some(Value::F(v)) => *v,
                        Some(Value::U(v)) => *v as f64,
                        Some(Value::I(v)) => *v as f64,
                        _ => 0.0,
                    };
                    // Counter tracks are per (pid, name); fold the rank
                    // into the series name so per-rank gauges plot as
                    // separate lines of one track.
                    let series = match rec.rank {
                        Some(r) => format!("rank{r}"),
                        None => "replica".to_string(),
                    };
                    format!(
                        "{{\"ph\":\"C\",\"name\":\"{name}\",\"cat\":\"gauge\",\"pid\":{pid},\
                         \"tid\":{t},\"ts\":{ts},\"args\":{{\"{series}\":{}}}}}",
                        num(value)
                    )
                }
            };
            events.push(line);
        }

        format!(
            "{{\"displayTimeUnit\":\"ms\",\"droppedRecords\":{},\"traceEvents\":[{}]}}",
            self.dropped(),
            events.join(",\n")
        )
    }

    /// Human-readable incident timeline: one line per event, decision,
    /// and span edge (gauges are elided — they are plot data, not
    /// narrative), in record order.
    pub fn incident_timeline(&self) -> String {
        let mut out = String::new();
        for rec in self.records() {
            if rec.kind == RecordKind::Gauge {
                continue;
            }
            let scope = match rec.rank {
                Some(r) => format!("r{}/g{}", rec.replica, r),
                None => format!("r{}", rec.replica),
            };
            let mut fields = String::new();
            for (k, v) in &rec.fields {
                let _ = write!(fields, " {k}={v}");
            }
            let _ = writeln!(
                out,
                "[{:>12.6}s] {:<6} {:<10} {}{}",
                rec.t,
                scope,
                rec.kind.label(),
                rec.name,
                fields
            );
        }
        out
    }
}

/// Sanitize a record name into a Prometheus metric name segment.
fn metric_name(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// Prometheus text exposition snapshot of a [`TraceLog`]: the **last**
/// sample of every gauge series (keyed by name × replica × rank) plus
/// cumulative record counts per event/decision name. This is a
/// point-in-time scrape of the flight recorder, not a long-lived
/// registry — see `docs/OBSERVABILITY.md` for the field reference.
pub fn prometheus_text(log: &TraceLog) -> String {
    // name -> (replica, rank) -> (t, value); BTreeMaps for stable output.
    let mut gauges: BTreeMap<&'static str, BTreeMap<(usize, Option<usize>), f64>> =
        BTreeMap::new();
    let mut counts: BTreeMap<(&'static str, usize), u64> = BTreeMap::new();
    for rec in log.records() {
        match rec.kind {
            RecordKind::Gauge => {
                let v = match rec.field("value") {
                    Some(Value::F(v)) => *v,
                    Some(Value::U(v)) => *v as f64,
                    Some(Value::I(v)) => *v as f64,
                    _ => continue,
                };
                gauges.entry(rec.name).or_default().insert((rec.replica, rec.rank), v);
            }
            RecordKind::Event | RecordKind::Decision => {
                *counts.entry((rec.name, rec.replica)).or_insert(0) += 1;
            }
            RecordKind::SpanBegin | RecordKind::SpanEnd => {}
        }
    }

    let mut out = String::new();
    for (name, series) in &gauges {
        let metric = format!("failsafe_{}", metric_name(name));
        let _ = writeln!(out, "# HELP {metric} last sampled value of the `{name}` gauge");
        let _ = writeln!(out, "# TYPE {metric} gauge");
        for (&(replica, rank), v) in series {
            match rank {
                Some(r) => {
                    let _ = writeln!(
                        out,
                        "{metric}{{replica=\"{replica}\",rank=\"{r}\"}} {}",
                        num(*v)
                    );
                }
                None => {
                    let _ = writeln!(out, "{metric}{{replica=\"{replica}\"}} {}", num(*v));
                }
            }
        }
    }
    let _ = writeln!(out, "# HELP failsafe_records_total flight-recorder records by name");
    let _ = writeln!(out, "# TYPE failsafe_records_total counter");
    for (&(name, replica), n) in &counts {
        let _ =
            writeln!(out, "failsafe_records_total{{name=\"{name}\",replica=\"{replica}\"}} {n}");
    }
    let _ = writeln!(out, "# HELP failsafe_records_dropped_total ring-buffer evictions");
    let _ = writeln!(out, "# TYPE failsafe_records_dropped_total counter");
    let _ = writeln!(out, "failsafe_records_dropped_total {}", log.dropped());
    out
}

#[cfg(test)]
mod tests {
    use super::super::{ObsSink, SharedLog};
    use super::*;
    use crate::engine::EngineEvent;

    fn sample_log() -> TraceLog {
        let log = SharedLog::new();
        let mut sink = ObsSink::none();
        sink.set(log.observer());
        sink.event(0.5, &EngineEvent::RequestFinished { id: 1 });
        sink.decision(0.6, None, "gate.admit", vec![("id", 1u64.into())]);
        sink.gauge(0.7, Some(0), "kv.used_bytes", 1024.0);
        sink.gauge(0.8, Some(0), "kv.used_bytes", 2048.0);
        sink.span(1.0, 1.5, Some(1), "recovery", vec![("method", "Full".into())]);
        log.snapshot()
    }

    #[test]
    fn chrome_trace_shape() {
        let json = sample_log().to_chrome_trace();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"B\""));
        assert!(json.contains("\"ph\":\"E\""));
        assert!(json.contains("\"ph\":\"C\""));
        assert!(json.contains("\"process_name\""));
        // 0.5 s → 500000 µs.
        assert!(json.contains("\"ts\":500000"));
        // Rank 1 span lands on tid 2; replica-scoped instants on tid 0.
        assert!(json.contains("\"tid\":2"));
    }

    #[test]
    fn prometheus_last_sample_wins() {
        let text = prometheus_text(&sample_log());
        assert!(text.contains("failsafe_kv_used_bytes{replica=\"0\",rank=\"0\"} 2048"));
        assert!(!text.contains(" 1024"));
        assert!(text.contains("failsafe_records_total{name=\"gate.admit\",replica=\"0\"} 1"));
        assert!(text.contains("failsafe_records_dropped_total 0"));
    }

    #[test]
    fn timeline_elides_gauges() {
        let text = sample_log().incident_timeline();
        assert!(text.contains("gate.admit"));
        assert!(text.contains("recovery"));
        assert!(!text.contains("kv.used_bytes"));
        // One line per non-gauge record: event + decision + 2 span edges.
        assert_eq!(text.lines().count(), 4);
    }
}
