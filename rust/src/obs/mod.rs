//! Flight recorder: structured tracing, per-rank telemetry, and
//! recovery-latency breakdown across engine, fleet, and simulator.
//!
//! The paper's headline claims — two-orders-of-magnitude lower recovery
//! latency, balanced memory under cyclic KVCache placement, no
//! stragglers under hybrid attention — are *time-series and
//! phase-breakdown* claims. End-of-run aggregates
//! ([`crate::engine::ServeReport`], [`crate::metrics::ServingMetrics`])
//! cannot show what a rank's KV residency looked like during a cascade
//! or where the milliseconds of one recovery went. This module can:
//!
//! * [`TraceRecord`] — one timestamped, typed observation:
//!   an [`crate::engine::EngineEvent`] mirror, a subsystem *decision*
//!   (admission gate verdicts, autoscaler actions, fleet placements,
//!   mitigation plans), a recovery-phase *span* edge, or a sampled
//!   *gauge* (per-rank KV residency, speed factors, queue depths).
//! * [`TraceLog`] — a bounded ring buffer of records with drop
//!   accounting, plus exporters: [`TraceLog::to_chrome_trace`]
//!   (Chrome/Perfetto `traceEvents` JSON — replicas as processes, ranks
//!   as threads), [`prometheus_text`] (text exposition snapshot), and
//!   [`TraceLog::incident_timeline`] (one human-readable line per
//!   decision/event, aligned with recovery spans).
//! * [`Observer`] — the attachment seam. Backends hold an [`ObsSink`]
//!   (an optional boxed observer tagged with a replica id) and feed it
//!   passively at existing event/decision sites. The default is
//!   detached: every record helper early-returns before building
//!   anything, so the disabled path costs one branch.
//!
//! # Determinism contract
//!
//! Recording is **purely passive**: observer callbacks read state and
//! copy values; they never mutate backend state, reorder floating-point
//! operations, or advance clocks. Gauges are sampled at event edges
//! (failures, rejoins, preemptions, completions), never per token. With
//! an observer attached, the stepper-vs-event-core differential suite
//! and token-paced replay determinism tests still pass bit-exact —
//! `rust/tests/obs_tests.rs` asserts exactly that. One deliberate
//! elision keeps traces core-independent: `TokenEmitted` events are
//! *not* recorded (the Exact span core elides them by contract; see
//! [`crate::simulator::simcore`]).
//!
//! # Recovery-phase spans
//!
//! A failure or rejoin decomposes into the paper's recovery-latency
//! budget via [`RecoveryPhases`]: detect (the reconfiguration floor),
//! plan (modeled instantaneous), weight stream-in, KV
//! respread/restore, and resume (recompute of un-restored suffixes).
//! The phase spans are laid out back-to-back from the injection clock
//! and sum to the `RecoveryCompleted { latency_s }` the backend
//! reports (±1e-9 s), which `tools/check_trace.py` asserts in CI.

mod export;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt;
use std::rc::Rc;

use crate::engine::EngineEvent;
use crate::recovery::RecoveryOutcome;
use crate::{RankId, SimTime};

pub use export::prometheus_text;

/// Default ring capacity: enough for every decision of a large fleet
/// replay without unbounded growth on million-request sweeps.
pub const DEFAULT_CAPACITY: usize = 1 << 20;

/// One typed field value on a [`TraceRecord`].
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    U(u64),
    I(i64),
    F(f64),
    B(bool),
    S(String),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::U(v) => write!(f, "{v}"),
            Value::I(v) => write!(f, "{v}"),
            Value::F(v) => write!(f, "{v}"),
            Value::B(v) => write!(f, "{v}"),
            Value::S(v) => write!(f, "{v}"),
        }
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::U(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::U(v as u64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::I(v)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Value {
        Value::I(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::F(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::B(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::S(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Value {
        Value::S(v)
    }
}

/// What kind of observation a [`TraceRecord`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// Mirror of an [`EngineEvent`] (minus `TokenEmitted`).
    Event,
    /// A subsystem decision: gate verdict, scale action, placement,
    /// mitigation plan.
    Decision,
    /// Opening edge of a named span (recovery phases).
    SpanBegin,
    /// Closing edge of a named span.
    SpanEnd,
    /// A sampled numeric value (the single field is `value`).
    Gauge,
}

impl RecordKind {
    pub fn label(&self) -> &'static str {
        match self {
            RecordKind::Event => "event",
            RecordKind::Decision => "decision",
            RecordKind::SpanBegin => "span-begin",
            RecordKind::SpanEnd => "span-end",
            RecordKind::Gauge => "gauge",
        }
    }
}

/// One timestamped observation. `replica` scopes the record to a fleet
/// member (0 for single-backend runs); `rank` scopes it further to one
/// GPU where that is meaningful (gauges, failure events).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub t: SimTime,
    pub replica: usize,
    pub rank: Option<RankId>,
    pub kind: RecordKind,
    pub name: &'static str,
    pub fields: Vec<(&'static str, Value)>,
}

impl TraceRecord {
    /// First field named `key`, if present.
    pub fn field(&self, key: &str) -> Option<&Value> {
        self.fields.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

/// Bounded ring buffer of [`TraceRecord`]s. Pushing past capacity drops
/// the oldest record and counts it, so a long-running session keeps the
/// most recent window instead of growing without bound.
#[derive(Debug, Clone)]
pub struct TraceLog {
    cap: usize,
    records: VecDeque<TraceRecord>,
    dropped: u64,
}

impl TraceLog {
    pub fn new() -> TraceLog {
        TraceLog::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(cap: usize) -> TraceLog {
        TraceLog { cap: cap.max(1), records: VecDeque::new(), dropped: 0 }
    }

    pub fn push(&mut self, rec: TraceRecord) {
        if self.records.len() == self.cap {
            self.records.pop_front();
            self.dropped += 1;
        }
        self.records.push_back(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records in arrival order (oldest first).
    pub fn records(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.iter()
    }

    /// Records evicted by the ring since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn clear(&mut self) {
        self.records.clear();
        self.dropped = 0;
    }
}

impl Default for TraceLog {
    fn default() -> Self {
        TraceLog::new()
    }
}

/// The attachment seam: anything that wants the record stream.
///
/// `enabled()` is the zero-overhead gate — every recording helper
/// checks it before building a record, so a disabled observer (the
/// default [`NopObserver`], or simply no observer at all) costs one
/// branch on the event edge and nothing per token.
pub trait Observer {
    /// Whether records should be built and delivered at all.
    fn enabled(&self) -> bool {
        true
    }
    /// Deliver one record. Must be passive: no backend mutation, no
    /// clock advancement, no floating-point work that could reorder the
    /// caller's.
    fn record(&mut self, rec: TraceRecord);
}

/// The default observer: permanently disabled, records go nowhere.
#[derive(Debug, Clone, Copy, Default)]
pub struct NopObserver;

impl Observer for NopObserver {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&mut self, _rec: TraceRecord) {}
}

/// A shared, clonable handle to one [`TraceLog`] — the standard way to
/// attach one flight recorder to several backends (every session of a
/// fleet, plus the gateway and autoscaler) and read it back afterwards.
/// Single-threaded by design, like the backends themselves.
#[derive(Debug, Clone, Default)]
pub struct SharedLog(Rc<RefCell<TraceLog>>);

impl SharedLog {
    pub fn new() -> SharedLog {
        SharedLog(Rc::new(RefCell::new(TraceLog::new())))
    }

    pub fn with_capacity(cap: usize) -> SharedLog {
        SharedLog(Rc::new(RefCell::new(TraceLog::with_capacity(cap))))
    }

    /// A boxed observer feeding this log — what backends' `set_observer`
    /// takes. Clone-cheap: observers share the underlying buffer.
    pub fn observer(&self) -> Box<dyn Observer> {
        Box::new(self.clone())
    }

    /// Run `f` over the shared log (read path for exporters).
    pub fn with<R>(&self, f: impl FnOnce(&TraceLog) -> R) -> R {
        f(&self.0.borrow())
    }

    /// Owned copy of the current log contents.
    pub fn snapshot(&self) -> TraceLog {
        self.0.borrow().clone()
    }

    pub fn len(&self) -> usize {
        self.0.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.borrow().is_empty()
    }
}

impl Observer for SharedLog {
    fn record(&mut self, rec: TraceRecord) {
        self.0.borrow_mut().push(rec);
    }
}

/// The per-backend recording handle: an optional boxed [`Observer`]
/// plus the replica id stamped on every record. Detached by default
/// ([`ObsSink::none`]); fleets re-stamp replica ids as they attach
/// observers to their members.
pub struct ObsSink {
    observer: Option<Box<dyn Observer>>,
    replica: usize,
}

impl fmt::Debug for ObsSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ObsSink")
            .field("attached", &self.observer.is_some())
            .field("replica", &self.replica)
            .finish()
    }
}

impl Default for ObsSink {
    fn default() -> Self {
        ObsSink::none()
    }
}

impl ObsSink {
    /// The detached default: `enabled()` is false, helpers no-op.
    pub fn none() -> ObsSink {
        ObsSink { observer: None, replica: 0 }
    }

    /// Attach an observer (replacing any previous one).
    pub fn set(&mut self, observer: Box<dyn Observer>) {
        self.observer = Some(observer);
    }

    /// Re-stamp the replica id on subsequent records.
    pub fn set_replica(&mut self, replica: usize) {
        self.replica = replica;
    }

    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The zero-overhead gate: false when detached or the observer is
    /// a [`NopObserver`]. Callers with non-trivial field construction
    /// should check this first.
    pub fn enabled(&self) -> bool {
        self.observer.as_ref().is_some_and(|o| o.enabled())
    }

    /// Deliver one fully-built record (drops it when disabled).
    pub fn record(&mut self, rec: TraceRecord) {
        if let Some(o) = self.observer.as_mut() {
            if o.enabled() {
                o.record(rec);
            }
        }
    }

    /// Mirror an [`EngineEvent`] at time `t`. `TokenEmitted` is
    /// deliberately not recorded (see module docs).
    pub fn event(&mut self, t: SimTime, ev: &EngineEvent) {
        if !self.enabled() {
            return;
        }
        let (name, rank, fields): (&'static str, Option<RankId>, Vec<(&'static str, Value)>) =
            match ev {
                EngineEvent::TokenEmitted { .. } => return,
                EngineEvent::RequestFinished { id } => {
                    ("request.finished", None, vec![("id", (*id).into())])
                }
                EngineEvent::RequestAborted { id } => {
                    ("request.aborted", None, vec![("id", (*id).into())])
                }
                EngineEvent::FailureInjected { rank, method } => (
                    "failure.injected",
                    Some(*rank),
                    vec![("method", format!("{method:?}").into())],
                ),
                EngineEvent::RecoveryCompleted { method, latency_s } => (
                    "recovery.completed",
                    None,
                    vec![
                        ("method", format!("{method:?}").into()),
                        ("latency_s", (*latency_s).into()),
                    ],
                ),
                EngineEvent::Reconfigured { epoch, world } => (
                    "reconfigured",
                    None,
                    vec![("epoch", (*epoch).into()), ("world", (*world).into())],
                ),
                EngineEvent::GpuRejoined { rank, method } => (
                    "gpu.rejoined",
                    Some(*rank),
                    vec![("method", format!("{method:?}").into())],
                ),
                EngineEvent::ReconfigCompleted { epoch, world, latency_s } => (
                    "reconfig.completed",
                    None,
                    vec![
                        ("epoch", (*epoch).into()),
                        ("world", (*world).into()),
                        ("latency_s", (*latency_s).into()),
                    ],
                ),
                EngineEvent::GpuDegraded { rank, factor } => {
                    ("gpu.degraded", Some(*rank), vec![("factor", (*factor).into())])
                }
                EngineEvent::GpuRestored { rank } => ("gpu.restored", Some(*rank), vec![]),
                EngineEvent::RequestPreempted { id } => {
                    ("request.preempted", None, vec![("id", (*id).into())])
                }
                EngineEvent::RequestResumed { id } => {
                    ("request.resumed", None, vec![("id", (*id).into())])
                }
            };
        let replica = self.replica;
        self.record(TraceRecord { t, replica, rank, kind: RecordKind::Event, name, fields });
    }

    /// Record a subsystem decision, optionally scoped to one rank.
    pub fn decision(
        &mut self,
        t: SimTime,
        rank: Option<RankId>,
        name: &'static str,
        fields: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled() {
            return;
        }
        let replica = self.replica;
        self.record(TraceRecord {
            t,
            replica,
            rank,
            kind: RecordKind::Decision,
            name,
            fields,
        });
    }

    /// Sample one gauge value for `rank` (or the whole replica).
    pub fn gauge(&mut self, t: SimTime, rank: Option<RankId>, name: &'static str, value: f64) {
        if !self.enabled() {
            return;
        }
        let replica = self.replica;
        self.record(TraceRecord {
            t,
            replica,
            rank,
            kind: RecordKind::Gauge,
            name,
            fields: vec![("value", value.into())],
        });
    }

    /// Record one closed span `[t0, t1]`; fields ride on the opening
    /// edge.
    pub fn span(
        &mut self,
        t0: SimTime,
        t1: SimTime,
        rank: Option<RankId>,
        name: &'static str,
        fields: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled() {
            return;
        }
        let replica = self.replica;
        self.record(TraceRecord {
            t: t0,
            replica,
            rank,
            kind: RecordKind::SpanBegin,
            name,
            fields,
        });
        self.record(TraceRecord {
            t: t1,
            replica,
            rank,
            kind: RecordKind::SpanEnd,
            name,
            fields: Vec::new(),
        });
    }
}

/// The paper's recovery-latency budget, decomposed from one
/// [`RecoveryOutcome`]. Phases are laid out back-to-back from the
/// injection clock and **sum to the reported recovery latency** by
/// construction:
///
/// * `detect_s` — the reconfiguration floor (`total_s` minus the
///   modeled transfer/recompute work): failure detection plus group
///   re-formation.
/// * `plan_s` — always zero: planning is modeled instantaneous
///   (non-uniform shard planning is table arithmetic, §3.1).
/// * `stream_s` — on-demand weight stream-in ([`RecoveryOutcome::weight_time_s`]).
/// * `respread_s` — KV restore from host backup plus (on rejoin) the
///   cyclic re-spread onto the returning rank.
/// * `resume_s` — recompute of un-restored context before serving
///   resumes ([`RecoveryOutcome::recompute_time_s`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPhases {
    pub detect_s: f64,
    pub plan_s: f64,
    pub stream_s: f64,
    pub respread_s: f64,
    pub resume_s: f64,
}

impl RecoveryPhases {
    /// Decompose `outcome`, with `extra_respread_s` for costs the
    /// planner did not see (the rejoin path's KV re-spread onto the
    /// returning rank, costed by the backend itself).
    pub fn of(outcome: &RecoveryOutcome, extra_respread_s: f64) -> RecoveryPhases {
        let modeled =
            outcome.weight_time_s + outcome.kv_restore_time_s + outcome.recompute_time_s;
        RecoveryPhases {
            detect_s: outcome.total_s - modeled,
            plan_s: 0.0,
            stream_s: outcome.weight_time_s,
            respread_s: outcome.kv_restore_time_s + extra_respread_s,
            resume_s: outcome.recompute_time_s,
        }
    }

    /// Sum of the phases — equals the reported recovery latency within
    /// float re-association error (≪ 1e-9 s).
    pub fn total_s(&self) -> f64 {
        self.detect_s + self.plan_s + self.stream_s + self.respread_s + self.resume_s
    }

    /// Emit the parent `recovery` span plus the five phase spans,
    /// back-to-back from `t0`. `trigger` distinguishes failures from
    /// rejoins; `method` is the recovery method's debug name.
    pub fn emit(
        &self,
        sink: &mut ObsSink,
        t0: SimTime,
        rank: Option<RankId>,
        trigger: &'static str,
        method: String,
    ) {
        if !sink.enabled() {
            return;
        }
        let total = self.total_s();
        sink.span(
            t0,
            t0 + total,
            rank,
            "recovery",
            vec![
                ("trigger", trigger.into()),
                ("method", method.into()),
                ("latency_s", total.into()),
            ],
        );
        let mut at = t0;
        for (name, dur) in [
            ("recovery.detect", self.detect_s),
            ("recovery.plan", self.plan_s),
            ("recovery.stream", self.stream_s),
            ("recovery.respread", self.respread_s),
            ("recovery.resume", self.resume_s),
        ] {
            sink.span(at, at + dur, rank, name, vec![("dur_s", dur.into())]);
            at += dur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest() {
        let mut log = TraceLog::with_capacity(2);
        for i in 0..3u64 {
            log.push(TraceRecord {
                t: i as f64,
                replica: 0,
                rank: None,
                kind: RecordKind::Decision,
                name: "d",
                fields: vec![("i", i.into())],
            });
        }
        assert_eq!(log.len(), 2);
        assert_eq!(log.dropped(), 1);
        assert_eq!(log.records().next().unwrap().t, 1.0);
    }

    #[test]
    fn detached_sink_is_disabled_and_silent() {
        let mut sink = ObsSink::none();
        assert!(!sink.enabled());
        sink.gauge(0.0, Some(0), "kv.used", 1.0);
        sink.decision(0.0, None, "gate.admit", vec![]);
        // Nothing to observe — the helpers just returned.
        let mut nop = ObsSink::none();
        nop.set(Box::new(NopObserver));
        assert!(!nop.enabled());
    }

    #[test]
    fn shared_log_collects_and_stamps_replica() {
        let log = SharedLog::new();
        let mut sink = ObsSink::none();
        sink.set(log.observer());
        sink.set_replica(3);
        assert!(sink.enabled());
        sink.gauge(1.5, Some(2), "kv.used_bytes", 42.0);
        sink.event(2.0, &EngineEvent::RequestFinished { id: 7 });
        sink.event(2.0, &EngineEvent::TokenEmitted { id: 7, token: 1, index: 0 });
        assert_eq!(log.len(), 2, "TokenEmitted must not be recorded");
        log.with(|l| {
            let recs: Vec<_> = l.records().collect();
            assert_eq!(recs[0].replica, 3);
            assert_eq!(recs[0].rank, Some(2));
            assert_eq!(recs[1].name, "request.finished");
            assert_eq!(recs[1].field("id"), Some(&Value::U(7)));
        });
    }

    #[test]
    fn phases_sum_to_total() {
        let phases = RecoveryPhases {
            detect_s: 0.015,
            plan_s: 0.0,
            stream_s: 0.25,
            respread_s: 0.125,
            resume_s: 0.0625,
        };
        let total = phases.total_s();
        let log = SharedLog::new();
        let mut sink = ObsSink::none();
        sink.set(log.observer());
        phases.emit(&mut sink, 10.0, Some(1), "failure", "Full".to_string());
        // 6 spans (recovery + 5 phases), two edges each.
        assert_eq!(log.len(), 12);
        log.with(|l| {
            let parent_end = l
                .records()
                .filter(|r| r.kind == RecordKind::SpanEnd && r.name == "recovery")
                .map(|r| r.t)
                .next()
                .unwrap();
            assert!((parent_end - (10.0 + total)).abs() < 1e-12);
            let last_phase_end = l
                .records()
                .filter(|r| r.kind == RecordKind::SpanEnd && r.name != "recovery")
                .map(|r| r.t)
                .fold(0.0, f64::max);
            assert!((last_phase_end - parent_end).abs() < 1e-9);
        });
    }
}
