//! Capacity-aware mitigation planning from rank health states.
//!
//! The planner is pure: states in, plan out. Applying the plan is the
//! backend's job — [`crate::simulator::OnlineSession::apply_mitigation`]
//! rebuilds its cost model on the reweighted
//! [`crate::sharding::ShardPlan`] and re-weights its router;
//! [`crate::engine::Engine::inject_slowdown`] re-weights routing (the
//! engine's numerics-safe lever). Suspect ranks additionally escalate to
//! proactive backup and drain, so the hard failure they foreshadow costs
//! a cheap [`crate::recovery::RecoveryMethod::Full`] recovery instead of
//! a recompute storm.

use crate::RankId;

use super::monitor::RankHealth;

/// What the serving layer should do about the current health picture.
#[derive(Debug, Clone, PartialEq)]
pub struct MitigationPlan {
    /// Per-rank effective capacity weights (1.0 = healthy, 0 = down):
    /// feed to [`crate::sharding::ShardPlan::reweight`] and the routers.
    pub weights: Vec<f64>,
    /// Suspect ranks, due the full escalation: proactively host-mirror
    /// their in-flight KV (a later hard failure then restores from
    /// backup instead of recomputing) *and* drain — their weight is
    /// already near zero, so new work steers away while they empty.
    pub suspects: Vec<RankId>,
}

impl MitigationPlan {
    /// True when every rank is healthy and the plan is a no-op.
    pub fn is_noop(&self) -> bool {
        self.suspects.is_empty() && self.weights.iter().all(|&w| w == 1.0)
    }

    /// Total health-effective capacity in rank units (Σ weights) — what
    /// the fleet router normalizes replica load by.
    pub fn effective_capacity(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// Turn the monitor's per-rank states into a [`MitigationPlan`]:
/// capacity-proportional weights (Healthy 1.0, Throttled its estimated
/// factor, Suspect [`super::SUSPECT_WEIGHT`], Down 0.0), with Suspect
/// ranks listed for proactive backup + drain.
pub fn plan_mitigation(states: &[RankHealth]) -> MitigationPlan {
    let weights = states.iter().map(RankHealth::capacity_weight).collect();
    let suspects = states
        .iter()
        .enumerate()
        .filter(|(_, s)| matches!(s, RankHealth::Suspect))
        .map(|(r, _)| r)
        .collect();
    MitigationPlan { weights, suspects }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_group_plans_a_noop() {
        let plan = plan_mitigation(&[RankHealth::Healthy; 8]);
        assert!(plan.is_noop());
        assert_eq!(plan.effective_capacity(), 8.0);
    }

    #[test]
    fn throttled_and_suspect_ranks_are_weighted_down() {
        let states = [
            RankHealth::Healthy,
            RankHealth::Throttled(0.5),
            RankHealth::Suspect,
            RankHealth::Down,
        ];
        let plan = plan_mitigation(&states);
        assert_eq!(plan.weights[0], 1.0);
        assert_eq!(plan.weights[1], 0.5);
        assert_eq!(plan.weights[2], crate::health::SUSPECT_WEIGHT);
        assert_eq!(plan.weights[3], 0.0);
        assert_eq!(plan.suspects, vec![2]);
        assert!(!plan.is_noop());
        let cap = plan.effective_capacity();
        assert!((cap - (1.5 + crate::health::SUSPECT_WEIGHT)).abs() < 1e-12);
    }

    #[test]
    fn absurd_factors_are_clamped() {
        let plan = plan_mitigation(&[RankHealth::Throttled(1e-9), RankHealth::Throttled(7.0)]);
        assert_eq!(plan.weights[0], crate::health::MIN_FACTOR);
        assert_eq!(plan.weights[1], 1.0);
    }
}
