//! Straggler detection from per-rank step-time observations.
//!
//! Synchronized tensor parallelism makes soft faults invisible in
//! aggregate step time (every rank waits for the straggler) but obvious
//! in *per-rank* completion times: a thermally throttled GPU finishes its
//! share late, every step, while its peers idle at the barrier. The
//! [`HealthMonitor`] ingests those per-rank times, smooths them with an
//! EWMA, compares each rank against the **peer median** (robust to one
//! bad rank skewing the reference), and classifies ranks through a
//! hysteresis state machine with flap damping:
//!
//! ```text
//!            ratio ≥ trip for trip_after obs        ratio ≥ suspect_ratio
//!  Healthy ────────────────────────────▶ Throttled ─────────────────────▶ Suspect
//!     ▲                                   │  ▲                              │
//!     └──── ratio ≤ clear for clear_after ┘  └── ratio < suspect_ratio ─────┘
//!                                                  for clear_after obs
//!  (mark_down / mark_up move any state to Down and back to Healthy)
//! ```
//!
//! Trip and clear thresholds differ (classic hysteresis), and every
//! recent state transition *doubles* the required streak lengths (up to a
//! cap) — so a rank oscillating around the threshold settles into one
//! state instead of flapping the mitigation planner.

use crate::RankId;

/// Health classification of one rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RankHealth {
    /// Step times in line with peers.
    Healthy,
    /// Consistently slow by the contained factor (estimated effective
    /// speed in `(0, 1]`: 0.5 means the rank runs at half its peers'
    /// speed) but stable — serve it less, don't evict it.
    Throttled(f64),
    /// So slow (or so erratic) that a hard failure looks likely: escalate
    /// to proactive backup and drain so the failure, when it comes, is
    /// cheap.
    Suspect,
    /// Out of the group (hard failure) — set via
    /// [`HealthMonitor::mark_down`], never inferred from timing.
    Down,
}

impl RankHealth {
    /// The rank's effective capacity weight for the mitigation planner:
    /// 1.0 healthy, the estimated factor while throttled, near-zero for
    /// suspects (keep the plumbing alive, place almost nothing), zero
    /// when down.
    pub fn capacity_weight(&self) -> f64 {
        match *self {
            RankHealth::Healthy => 1.0,
            RankHealth::Throttled(f) => f.clamp(super::MIN_FACTOR, 1.0),
            RankHealth::Suspect => super::SUSPECT_WEIGHT,
            RankHealth::Down => 0.0,
        }
    }
}

/// Detector tuning. The defaults are deliberately conservative: a rank
/// must be ≥ 25% slower than the peer median for several consecutive
/// steps before anything reweights, and must be back within 10% for
/// longer before the mitigation is undone.
#[derive(Debug, Clone, Copy)]
pub struct MonitorConfig {
    /// EWMA weight of the newest sample.
    pub alpha: f64,
    /// `ewma / peer_median` at or above this is slow evidence.
    pub trip_ratio: f64,
    /// `ewma / peer_median` at or below this is healthy evidence (must be
    /// `< trip_ratio` — the hysteresis band).
    pub clear_ratio: f64,
    /// Ratio at or above this is Suspect evidence.
    pub suspect_ratio: f64,
    /// Consecutive slow observations before Healthy → Throttled (and
    /// suspect observations before Throttled → Suspect).
    pub trip_after: u32,
    /// Consecutive healthy observations before stepping back down
    /// (Suspect → Throttled, Throttled → Healthy).
    pub clear_after: u32,
    /// Transitions within this many observations count as flapping; each
    /// one doubles the required streaks.
    pub flap_window: u64,
    /// Cap on the damping exponent (streaks grow at most `2^max_damping`×).
    pub max_damping: u32,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            alpha: 0.2,
            trip_ratio: 1.25,
            clear_ratio: 1.10,
            suspect_ratio: 3.0,
            trip_after: 5,
            clear_after: 8,
            flap_window: 64,
            max_damping: 3,
        }
    }
}

/// One reported state change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthTransition {
    pub rank: RankId,
    pub from: RankHealth,
    pub to: RankHealth,
}

/// Per-rank streak counters and transition history.
#[derive(Debug, Clone, Default)]
struct RankTrack {
    ewma: Option<f64>,
    slow_streak: u32,
    fast_streak: u32,
    hot_streak: u32,
    cool_streak: u32,
    /// Observation indices of recent transitions (pruned to the flap
    /// window) — the flap-damping evidence.
    transitions: Vec<u64>,
}

/// The soft-fault detector. See the module docs for the state machine.
///
/// Feed it one step-time sample per rank per step
/// ([`HealthMonitor::observe`]); read the classification back with
/// [`HealthMonitor::states`] and hand
/// [`HealthMonitor::capacity_weights`] to the mitigation planner.
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    cfg: MonitorConfig,
    state: Vec<RankHealth>,
    track: Vec<RankTrack>,
    tick: u64,
    /// Median scratch (no per-observe allocation at steady state).
    scratch: Vec<f64>,
    /// Which ranks produced a valid sample this observation — the state
    /// machine only advances on fresh evidence, never on a stale EWMA.
    fresh: Vec<bool>,
}

impl HealthMonitor {
    pub fn new(world: usize) -> Self {
        Self::with_config(world, MonitorConfig::default())
    }

    pub fn with_config(world: usize, cfg: MonitorConfig) -> Self {
        assert!(world >= 1, "empty TP group");
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha must be in (0, 1]");
        assert!(
            cfg.clear_ratio < cfg.trip_ratio && cfg.trip_ratio <= cfg.suspect_ratio,
            "thresholds must satisfy clear < trip <= suspect"
        );
        assert!(cfg.trip_after >= 1 && cfg.clear_after >= 1);
        HealthMonitor {
            cfg,
            state: vec![RankHealth::Healthy; world],
            track: vec![RankTrack::default(); world],
            tick: 0,
            scratch: Vec::with_capacity(world),
            fresh: vec![false; world],
        }
    }

    pub fn world(&self) -> usize {
        self.state.len()
    }

    /// Current classification of every rank.
    pub fn states(&self) -> &[RankHealth] {
        &self.state
    }

    pub fn state(&self, rank: RankId) -> RankHealth {
        self.state[rank]
    }

    /// Per-rank capacity weights for the planner
    /// ([`RankHealth::capacity_weight`] of each state).
    pub fn capacity_weights(&self) -> Vec<f64> {
        self.state.iter().map(RankHealth::capacity_weight).collect()
    }

    /// The smoothed step-time estimate for `rank`, if any samples landed.
    pub fn smoothed(&self, rank: RankId) -> Option<f64> {
        self.track[rank].ewma
    }

    /// A hard failure took `rank` out of the group. Timing history is
    /// discarded — when the GPU rejoins it is judged fresh.
    pub fn mark_down(&mut self, rank: RankId) {
        self.state[rank] = RankHealth::Down;
        self.track[rank] = RankTrack::default();
    }

    /// `rank` rejoined (empty, full speed until the data says otherwise).
    pub fn mark_up(&mut self, rank: RankId) {
        self.state[rank] = RankHealth::Healthy;
        self.track[rank] = RankTrack::default();
    }

    /// Ingest one step's per-rank completion times (seconds; one slot per
    /// rank, `NaN`/non-positive slots and Down ranks are skipped) and run
    /// the state machine. Returns the transitions this observation caused.
    pub fn observe(&mut self, step_times: &[f64]) -> Vec<HealthTransition> {
        assert_eq!(step_times.len(), self.world(), "one sample per rank");
        self.tick += 1;
        let tick = self.tick;

        // Smooth, then take the peer median over live ranks.
        self.fresh.iter_mut().for_each(|f| *f = false);
        for (r, &x) in step_times.iter().enumerate() {
            if self.state[r] == RankHealth::Down || !x.is_finite() || x <= 0.0 {
                continue;
            }
            self.fresh[r] = true;
            let t = &mut self.track[r];
            t.ewma = Some(match t.ewma {
                Some(e) => self.cfg.alpha * x + (1.0 - self.cfg.alpha) * e,
                None => x,
            });
        }
        self.scratch.clear();
        for (r, t) in self.track.iter().enumerate() {
            if self.state[r] != RankHealth::Down {
                if let Some(e) = t.ewma {
                    self.scratch.push(e);
                }
            }
        }
        if self.scratch.is_empty() {
            return Vec::new();
        }
        self.scratch.sort_by(|a, b| a.total_cmp(b));
        // Lower-middle median: with an even peer count the reference must
        // not be the straggler's own EWMA (in a 2-rank group the upper
        // middle *is* the slow rank, which would make it undetectable).
        let median = self.scratch[(self.scratch.len() - 1) / 2];
        if median <= 0.0 {
            return Vec::new();
        }

        let mut out = Vec::new();
        for r in 0..self.world() {
            // Only fresh evidence advances the state machine: a rank with
            // a dropped/garbage sample this step keeps its streaks frozen
            // instead of re-judging a stale EWMA every tick.
            if self.state[r] == RankHealth::Down || !self.fresh[r] {
                continue;
            }
            let Some(ewma) = self.track[r].ewma else { continue };
            let ratio = ewma / median;
            let cfg = self.cfg;
            {
                let t = &mut self.track[r];
                if ratio >= cfg.trip_ratio {
                    t.slow_streak += 1;
                    t.fast_streak = 0;
                } else if ratio <= cfg.clear_ratio {
                    t.fast_streak += 1;
                    t.slow_streak = 0;
                } // in the hysteresis band: both streaks hold
                if ratio >= cfg.suspect_ratio {
                    t.hot_streak += 1;
                    t.cool_streak = 0;
                } else {
                    t.cool_streak += 1;
                    t.hot_streak = 0;
                }
            }
            // Flap damping: recent transitions stretch the streaks needed.
            let damp = {
                let t = &mut self.track[r];
                t.transitions.retain(|&at| tick.saturating_sub(at) <= cfg.flap_window);
                1u32 << (t.transitions.len() as u32).min(cfg.max_damping)
            };
            let trip_needed = cfg.trip_after.saturating_mul(damp);
            let clear_needed = cfg.clear_after.saturating_mul(damp);
            let factor = (median / ewma).clamp(super::MIN_FACTOR, 1.0);
            let t = &self.track[r];
            let next = match self.state[r] {
                RankHealth::Healthy if t.slow_streak >= trip_needed => {
                    Some(RankHealth::Throttled(factor))
                }
                RankHealth::Throttled(_) if t.hot_streak >= trip_needed => {
                    Some(RankHealth::Suspect)
                }
                RankHealth::Throttled(_) if t.fast_streak >= clear_needed => {
                    Some(RankHealth::Healthy)
                }
                RankHealth::Throttled(f) => {
                    // Track the drifting factor without a state transition
                    // (a deepening thermal ramp is not a flap).
                    if (factor - f).abs() > 0.01 {
                        self.state[r] = RankHealth::Throttled(factor);
                    }
                    None
                }
                RankHealth::Suspect if t.cool_streak >= clear_needed => {
                    Some(RankHealth::Throttled(factor))
                }
                _ => None,
            };
            if let Some(to) = next {
                let from = self.state[r];
                self.state[r] = to;
                let t = &mut self.track[r];
                t.transitions.push(tick);
                t.slow_streak = 0;
                t.fast_streak = 0;
                t.hot_streak = 0;
                out.push(HealthTransition { rank: r, from, to });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Feed `n` observations where rank `slow` runs `times`× the healthy
    /// 10 ms step with ±`noise` multiplicative jitter.
    fn drive(
        m: &mut HealthMonitor,
        n: usize,
        slow: usize,
        times: f64,
        noise: f64,
        rng: &mut Rng,
    ) -> Vec<HealthTransition> {
        let mut all = Vec::new();
        for _ in 0..n {
            let sample: Vec<f64> = (0..m.world())
                .map(|r| {
                    let base = if r == slow { 0.010 * times } else { 0.010 };
                    base * (1.0 + noise * (2.0 * rng.f64() - 1.0))
                })
                .collect();
            all.extend(m.observe(&sample));
        }
        all
    }

    #[test]
    fn converges_on_a_2x_straggler_under_noise() {
        let mut m = HealthMonitor::new(8);
        let mut rng = Rng::seed_from_u64(7);
        drive(&mut m, 40, 3, 2.0, 0.10, &mut rng);
        match m.state(3) {
            RankHealth::Throttled(f) => {
                assert!((0.35..=0.65).contains(&f), "estimated factor {f} not ≈ 0.5");
            }
            other => panic!("rank 3 should be Throttled, is {other:?}"),
        }
        for r in [0usize, 1, 2, 4, 5, 6, 7] {
            assert_eq!(m.state(r), RankHealth::Healthy, "rank {r} misclassified");
        }
        // Back to normal speed → eventually Healthy again.
        drive(&mut m, 120, 3, 1.0, 0.10, &mut rng);
        assert_eq!(m.state(3), RankHealth::Healthy);
    }

    #[test]
    fn escalates_a_collapsing_rank_to_suspect() {
        let mut m = HealthMonitor::new(4);
        let mut rng = Rng::seed_from_u64(11);
        let tr = drive(&mut m, 60, 1, 6.0, 0.05, &mut rng);
        assert_eq!(m.state(1), RankHealth::Suspect);
        // It passed through Throttled on the way (no teleporting).
        assert!(tr
            .iter()
            .any(|t| t.rank == 1 && matches!(t.to, RankHealth::Throttled(_))));
        assert!(m.capacity_weights()[1] <= crate::health::SUSPECT_WEIGHT);
    }

    #[test]
    fn flapping_is_damped() {
        // A rank oscillating 1×/2× every 6 steps would flap an undamped
        // detector; damping must keep the transition count small.
        let cfg = MonitorConfig { trip_after: 2, clear_after: 2, ..MonitorConfig::default() };
        let mut m = HealthMonitor::with_config(8, cfg);
        let mut transitions = 0usize;
        for i in 0..400 {
            let slow = (i / 6) % 2 == 0;
            let sample: Vec<f64> =
                (0..8).map(|r| if r == 3 && slow { 0.020 } else { 0.010 }).collect();
            transitions += m.observe(&sample).len();
        }
        assert!(
            transitions <= 12,
            "{transitions} transitions in 400 ticks — flap damping not working"
        );
    }

    #[test]
    fn down_ranks_are_excluded_and_rejoin_fresh() {
        let mut m = HealthMonitor::new(4);
        let mut rng = Rng::seed_from_u64(3);
        drive(&mut m, 40, 2, 2.0, 0.05, &mut rng);
        assert!(matches!(m.state(2), RankHealth::Throttled(_)));
        m.mark_down(2);
        assert_eq!(m.state(2), RankHealth::Down);
        assert_eq!(m.capacity_weights()[2], 0.0);
        // Observations while down are ignored; the median comes from the
        // three live ranks.
        m.observe(&[0.010, 0.010, 9.0, 0.010]);
        assert_eq!(m.state(2), RankHealth::Down);
        m.mark_up(2);
        assert_eq!(m.state(2), RankHealth::Healthy);
        assert_eq!(m.smoothed(2), None, "history discarded across the outage");
    }

    #[test]
    fn garbage_samples_are_ignored() {
        let mut m = HealthMonitor::new(3);
        for _ in 0..50 {
            m.observe(&[0.010, f64::NAN, -1.0]);
        }
        // Only rank 0 ever produced a valid sample; nobody flapped.
        assert_eq!(m.states(), &[RankHealth::Healthy; 3]);
        assert_eq!(m.smoothed(1), None);
    }

    #[test]
    fn two_rank_group_still_detects_its_straggler() {
        // With an even peer count the lower-middle median keeps the
        // reference on the healthy side — otherwise a TP2 straggler would
        // be its own reference and never trip.
        let mut m = HealthMonitor::new(2);
        for _ in 0..40 {
            m.observe(&[0.010, 0.020]);
        }
        assert!(matches!(m.state(1), RankHealth::Throttled(_)), "{:?}", m.state(1));
        assert_eq!(m.state(0), RankHealth::Healthy);
    }

    #[test]
    fn telemetry_gaps_freeze_streaks_instead_of_rejudging_stale_ewma() {
        let mut m = HealthMonitor::new(4);
        // One genuinely slow observation for rank 3...
        m.observe(&[0.010, 0.010, 0.020, 0.010]);
        // ...then its telemetry goes dark. A single sample must not
        // accumulate into a trip via the frozen EWMA.
        for _ in 0..100 {
            m.observe(&[0.010, 0.010, f64::NAN, 0.010]);
        }
        assert_eq!(m.state(3), RankHealth::Healthy, "no fresh evidence, no transition");
    }
}
