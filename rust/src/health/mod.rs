//! Soft-fault handling: straggler detection and capacity-aware
//! mitigation for GPUs that are **alive but slow**.
//!
//! Every other failure path in this crate is binary — a GPU is in the
//! group or it is not. Real fleets degrade more gradually: thermal
//! throttling, ECC row-retirement pressure, and noisy neighbors produce
//! ranks that answer every collective, correctly, late. Under
//! synchronized tensor parallelism one such rank sets the pace for the
//! whole group (`step = max_r work_r / speed_r`), so a 0.5× GPU halves
//! the group's throughput while every dashboard still shows it "up".
//!
//! This module closes the loop in three stages:
//!
//! * **Detect** ([`HealthMonitor`]) — per-rank step times, EWMA-smoothed
//!   and compared against the peer median, drive a
//!   Healthy → Throttled(factor) → Suspect → Down state machine with
//!   hysteresis and flap damping.
//! * **Plan** ([`plan_mitigation`]) — states become per-rank capacity
//!   weights plus a proactive backup + drain list for Suspect ranks.
//! * **Mitigate** — the weights feed
//!   [`crate::sharding::ShardPlan::reweight`] (uneven TP heads and FFN
//!   blocks, remainder heads served DP), the capacity-aware routers
//!   ([`crate::router::LoadTracker::set_capacity`],
//!   [`crate::fleet::FleetRouter`]), and the simulator's cost model
//!   ([`crate::simulator::StepCostModel::set_speed_factors`]), so a
//!   throttled rank does proportionally less work instead of stalling
//!   everyone.
//!
//! Timeline-driven experiments inject the ground truth with
//! [`crate::engine::ServingBackend::inject_slowdown`] /
//! `SlowDown`/`Restore` events ([`crate::cluster::TimelineEventKind`]),
//! and the `degrade` subcommand ties the whole loop together end to end.
//!
//! ```
//! use failsafe::health::{plan_mitigation, HealthMonitor, RankHealth};
//!
//! // Rank 2 of four runs at half speed; everyone else takes 10 ms/step.
//! let mut monitor = HealthMonitor::new(4);
//! for _ in 0..40 {
//!     monitor.observe(&[0.010, 0.010, 0.020, 0.010]);
//! }
//! assert!(matches!(monitor.state(2), RankHealth::Throttled(_)));
//!
//! let plan = plan_mitigation(monitor.states());
//! assert!(!plan.is_noop());
//! assert!(plan.weights[2] < 0.7, "throttled rank is down-weighted");
//! assert_eq!(plan.weights[0], 1.0);
//! // Σ weights is the group's health-effective capacity in rank units.
//! assert!(plan.effective_capacity() < 4.0);
//! ```

mod monitor;
mod planner;

pub use monitor::{HealthMonitor, HealthTransition, MonitorConfig, RankHealth};
pub use planner::{plan_mitigation, MitigationPlan};

/// Floor on estimated speed factors: below this a rank is effectively
/// unusable and should be Suspect/drained rather than micro-weighted.
pub const MIN_FACTOR: f64 = 0.05;

/// Capacity weight of a [`RankHealth::Suspect`] rank: near zero — keep
/// the rank serving what it already holds, place almost nothing new on
/// it while the proactive backup + drain runs.
pub const SUSPECT_WEIGHT: f64 = 0.05;
