//! The engine proper: submit → chunked prefill → continuous decode, with
//! failure injection and lightning recovery, all executing real AOT
//! artifacts through PJRT.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{GpuSpec, Interconnect};
use crate::config::EngineConfig;
use crate::coordinator::{Request, RequestState};
use crate::kvcache::{BackupStore, KvPlacement};
use crate::recovery::{plan_recovery, RecoveryInput, RecoveryMethod};
use crate::router::DpRouter;
use crate::runtime::{
    literal_f32, literal_i32, literal_tensor, to_vec_f32, Manifest, RuntimeClient, WeightStore,
};
use crate::scheduler::{adaptive_chunked_prefill, PrefillItem};
use crate::sharding::ShardPlan;
use crate::{LayerId, RankId, RequestId};

use super::shard::{pick_bucket, RankShard};
use super::KvStore;

/// Completed generation of one request.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    pub id: RequestId,
    pub output_tokens: Vec<u32>,
    /// Wall-clock time to first token.
    pub ttft_s: f64,
    /// Max wall-clock gap between output tokens.
    pub max_tbt_s: f64,
}

/// Report of a serve run.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub results: Vec<GenerationResult>,
    pub wall_s: f64,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub steps: usize,
    /// Simulated (modeled) recovery latencies of injected failures.
    pub recoveries: Vec<f64>,
}

impl ServeReport {
    pub fn decode_tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.wall_s
        }
    }

    pub fn outputs(&self) -> Vec<Vec<u32>> {
        self.results.iter().map(|r| r.output_tokens.clone()).collect()
    }
}

struct Timing {
    submitted: Instant,
    first_token: Option<f64>,
    last_token: Option<f64>,
    max_tbt: f64,
}

/// One forward item: (request, new tokens, cached ctx, home rank).
type FwdItem = (RequestId, Vec<u32>, usize, RankId);

/// The serving engine. See module docs.
pub struct Engine {
    pub config: EngineConfig,
    client: RuntimeClient,
    manifest: Manifest,
    store: WeightStore,
    plan: ShardPlan,
    placement: KvPlacement,
    shards: Vec<RankShard>,
    kv: KvStore,
    router: DpRouter,
    emb: xla::Literal,
    final_norm: xla::Literal,
    lm_head: xla::Literal,
    requests: HashMap<RequestId, Request>,
    timing: HashMap<RequestId, Timing>,
    order: Vec<RequestId>,
    next_id: RequestId,
    epoch: u64,
    recoveries: Vec<f64>,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Manifest::load(&config.artifacts_dir)?;
        anyhow::ensure!(
            manifest.model.n_heads == config.model.n_kv_heads
                && manifest.model.d_model == config.model.d_model
                && manifest.model.n_layers == config.model.n_layers,
            "artifacts were compiled for a different model than {}",
            config.model.name
        );
        let store = WeightStore::load(&manifest)?;
        let client = RuntimeClient::cpu()?;
        let plan = config.system.plan(&config.model, config.world);
        let placement = KvPlacement::new(&plan);
        let shards = (0..config.world)
            .map(|r| RankShard::build(&manifest, &store, &plan, r))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(RankShard::verify_cover(&shards, &plan), "shard cover check failed");
        let emb = literal_tensor(store.get("emb")?)?;
        let final_norm = literal_tensor(store.get("final_norm")?)?;
        let lm_head = literal_tensor(store.get("lm_head")?)?;
        let kv = KvStore::new(manifest.model.head_dim);
        let router = DpRouter::new(config.system.router, config.world);
        Ok(Engine {
            config,
            client,
            manifest,
            store,
            plan,
            placement,
            shards,
            kv,
            router,
            emb,
            final_norm,
            lm_head,
            requests: HashMap::new(),
            timing: HashMap::new(),
            order: Vec::new(),
            next_id: 0,
            epoch: 0,
            recoveries: Vec::new(),
        })
    }

    pub fn world(&self) -> usize {
        self.plan.world()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Per-rank (simulated-HBM) KV bytes — used by placement assertions.
    pub fn kv_bytes_by_rank(&self) -> Vec<usize> {
        self.kv.bytes_by_rank(self.world())
    }

    /// Submit a prompt; returns the request id.
    pub fn submit(&mut self, prompt: &[u32], max_new_tokens: usize) -> Result<RequestId> {
        let max_ctx = self.manifest.buckets("attn", |v| v.c).last().copied().unwrap_or(0);
        anyhow::ensure!(
            prompt.len() + max_new_tokens <= max_ctx + 1,
            "prompt {} + max_new {} exceeds compiled context {}",
            prompt.len(),
            max_new_tokens,
            max_ctx
        );
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.iter().all(|&t| (t as usize) < self.manifest.model.vocab),
            "token id out of vocab"
        );
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, 0.0, prompt.to_vec(), max_new_tokens.max(1));
        req.state = RequestState::Prefilling;
        req.home = self.router.route(prompt.len() as f64);
        self.requests.insert(id, req);
        self.timing.insert(
            id,
            Timing { submitted: Instant::now(), first_token: None, last_token: None, max_tbt: 0.0 },
        );
        self.order.push(id);
        Ok(id)
    }

    /// Drive all submitted requests to completion.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut report = ServeReport::default();
        loop {
            let any_prefill = self
                .requests
                .values()
                .any(|r| r.state == RequestState::Prefilling && r.prefill_remaining() > 0);
            if any_prefill {
                report.prefill_tokens += self.step_prefill()?;
                report.steps += 1;
                continue;
            }
            let decoding: Vec<RequestId> = self
                .order
                .iter()
                .copied()
                .filter(|id| self.requests[id].state == RequestState::Decoding)
                .collect();
            if decoding.is_empty() {
                break;
            }
            report.decode_tokens += self.step_decode(&decoding)?;
            report.steps += 1;
        }
        report.wall_s = t0.elapsed().as_secs_f64();
        report.recoveries = self.recoveries.clone();
        for id in &self.order {
            let r = &self.requests[id];
            let t = &self.timing[id];
            report.results.push(GenerationResult {
                id: *id,
                output_tokens: r.output_tokens.clone(),
                ttft_s: t.first_token.unwrap_or(0.0),
                max_tbt_s: t.max_tbt,
            });
        }
        Ok(report)
    }

    // ---------------------------------------------------------- failure --

    /// Inject a hard failure of TP rank `rank` and recover with `method`.
    /// Returns the modeled recovery latency in seconds. The engine
    /// continues serving on `world - 1` ranks; with backup-based methods
    /// the continuation is exact, with `Recompute` the affected context is
    /// re-prefilled from tokens.
    pub fn inject_failure(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64> {
        let old_world = self.world();
        anyhow::ensure!(old_world > 1, "cannot lose the last rank");
        anyhow::ensure!(rank < old_world);

        // In-flight state for the latency model.
        let reqs: Vec<(RequestId, usize, RankId)> = self
            .order
            .iter()
            .filter(|id| !self.requests[*id].is_done())
            .map(|id| {
                let r = &self.requests[id];
                (*id, r.context, r.home)
            })
            .collect();
        let mut backup_model = BackupStore::new(1 << 40);
        let bpt = self.config.model.kv_bytes_per_token();
        let use_backup = method != RecoveryMethod::Recompute;
        if use_backup {
            for &(id, _, _) in &reqs {
                backup_model.backup(id, self.kv.backed_tokens(id), bpt);
            }
        }

        // Plan the new epoch.
        let survivor_map: Vec<Option<RankId>> = (0..old_world)
            .map(|r| if r == rank { None } else { Some(if r < rank { r } else { r - 1 }) })
            .collect();
        let new_world = old_world - 1;
        let new_plan = ShardPlan {
            model: self.config.model.clone(),
            heads: crate::sharding::HeadAssignment::new(
                self.config.system.attn,
                self.config.model.n_kv_heads,
                self.config.model.n_layers,
                new_world,
            ),
            ffn: self.plan.ffn.reshard(&survivor_map, new_world),
        };

        // Latency model (what an H100 node would pay).
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        let outcome = plan_recovery(
            method,
            &RecoveryInput {
                spec: &spec,
                ic: &ic,
                old_plan: &self.plan,
                new_plan: &new_plan,
                survivor_map: &survivor_map,
                failed_rank: rank,
                requests: &reqs,
                backup: &backup_model,
            },
        );

        // Apply: wipe the failed rank's KV, re-tag survivors, reshard.
        let affected = self.kv.wipe_rank(rank);
        self.kv.remap_ranks(&survivor_map);
        self.plan = new_plan;
        self.placement = KvPlacement::new(&self.plan);
        self.shards = (0..new_world)
            .map(|r| RankShard::build(&self.manifest, &self.store, &self.plan, r))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(RankShard::verify_cover(&self.shards, &self.plan));
        self.router = self.router.remap(&survivor_map, new_world);
        self.epoch += 1;

        // Re-home requests and repair their KV state.
        let ids: Vec<RequestId> = self.order.clone();
        for id in ids {
            let (done, old_home, context) = {
                let r = &self.requests[&id];
                (r.is_done(), r.home, r.context)
            };
            if done {
                continue;
            }
            let new_home = survivor_map[old_home]
                .unwrap_or_else(|| self.router.tracker().least_loaded());
            self.requests.get_mut(&id).unwrap().home = new_home;

            if !affected.contains(&id) {
                continue;
            }
            let restored = if use_backup {
                self.kv.restore_request(id, &self.placement, new_home)
            } else {
                0
            };
            let keep = restored.min(context);
            self.kv.truncate(id, keep);
            // The un-restored suffix (backup lag or everything under
            // Recompute) is re-prefilled from known tokens: input + already
            // generated outputs.
            let r = self.requests.get_mut(&id).unwrap();
            if keep < r.context {
                let mut all: Vec<u32> = r.input_tokens.clone();
                all.extend(&r.output_tokens);
                let target_out = r.max_new_tokens;
                let produced = r.output_tokens.len();
                // Rebuild the request as: prefill all known tokens beyond
                // `keep`, then continue decoding the remaining budget.
                r.input_tokens = all;
                r.max_new_tokens = target_out; // unchanged budget
                r.context = keep;
                let _ = produced;
                r.state = RequestState::Prefilling;
            }
        }

        self.recoveries.push(outcome.total_s);
        Ok(outcome.total_s)
    }

    // ------------------------------------------------------------ steps --

    /// One prefill pass: form chunks with Algorithm 1, run them (b=1).
    fn step_prefill(&mut self) -> Result<usize> {
        let items: Vec<PrefillItem> = self
            .order
            .iter()
            .filter_map(|id| {
                let r = &self.requests[id];
                (r.state == RequestState::Prefilling && r.prefill_remaining() > 0).then_some(
                    PrefillItem {
                        request: *id,
                        rank: r.home,
                        context: r.context,
                        remaining: r.prefill_remaining(),
                    },
                )
            })
            .collect();
        if items.is_empty() {
            return Ok(0);
        }
        let carry = vec![0.0; self.world()];
        let batch =
            adaptive_chunked_prefill(self.config.token_budget, &items, &carry, self.world(), 8);
        let max_s = self.prefill_s_buckets().last().copied().unwrap_or(16);

        let mut done = 0usize;
        for chunk in &batch.chunks {
            let take = chunk.tokens.min(max_s);
            let (tokens, ctx) = {
                let r = &self.requests[&chunk.request];
                let take = take.min(r.prefill_remaining());
                (r.input_tokens[r.context..r.context + take].to_vec(), r.context)
            };
            if tokens.is_empty() {
                continue;
            }
            let logits = self.forward_chunk(chunk.request, &tokens, ctx)?;
            done += tokens.len();
            let finished = {
                let r = self.requests.get_mut(&chunk.request).unwrap();
                r.on_prefilled(tokens.len());
                r.state == RequestState::Decoding
            };
            if finished {
                // If this request still has generated tokens from before a
                // Recompute-style repair, it is mid-decode continuation and
                // the "first" token here would double-count; only sample
                // when output budget remains.
                let needs_token = {
                    let r = &self.requests[&chunk.request];
                    r.output_tokens.len() < r.max_new_tokens
                };
                if needs_token {
                    let tok = argmax(&logits);
                    self.requests.get_mut(&chunk.request).unwrap().on_decoded(tok);
                    self.note_token(chunk.request);
                } else {
                    self.requests.get_mut(&chunk.request).unwrap().state = RequestState::Finished;
                }
            }
            self.kv.backup_request(chunk.request); // proactive backup pass
        }
        Ok(done)
    }

    /// One decode step over `ids` (each produces one token).
    fn step_decode(&mut self, ids: &[RequestId]) -> Result<usize> {
        let mut produced = 0;
        let cap = self.config.max_batch.min(8).max(1);
        let groups: Vec<Vec<RequestId>> = ids.chunks(cap).map(|c| c.to_vec()).collect();
        for group in groups {
            let inputs: Vec<(RequestId, u32)> = group
                .iter()
                .map(|id| {
                    let r = &self.requests[id];
                    let t = r
                        .output_tokens
                        .last()
                        .copied()
                        .unwrap_or_else(|| *r.input_tokens.last().expect("nonempty prompt"));
                    (*id, t)
                })
                .collect();
            let logits = self.forward_decode(&inputs)?;
            for (i, &(id, _)) in inputs.iter().enumerate() {
                let tok = argmax(&logits[i]);
                self.requests.get_mut(&id).unwrap().on_decoded(tok);
                self.note_token(id);
                produced += 1;
                self.kv.backup_request(id);
            }
        }
        Ok(produced)
    }

    fn note_token(&mut self, id: RequestId) {
        let t = self.timing.get_mut(&id).unwrap();
        let now = t.submitted.elapsed().as_secs_f64();
        match t.last_token {
            None => t.first_token = Some(now),
            Some(prev) => t.max_tbt = t.max_tbt.max(now - prev),
        }
        t.last_token = Some(now);
    }

    // ---------------------------------------------------------- forward --

    fn prefill_s_buckets(&self) -> Vec<usize> {
        self.manifest
            .variants
            .iter()
            .filter(|v| v.kind == "attn" && v.b == 1 && v.s > 1)
            .map(|v| v.s)
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect()
    }

    fn decode_b_buckets(&self) -> Vec<usize> {
        self.manifest
            .variants
            .iter()
            .filter(|v| v.kind == "attn" && v.s == 1)
            .map(|v| v.b)
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect()
    }

    /// Prefill one chunk of `req` (b=1); returns last-position logits.
    fn forward_chunk(&mut self, req: RequestId, tokens: &[u32], ctx: usize) -> Result<Vec<f32>> {
        let s_real = tokens.len();
        let s = pick_bucket(&self.prefill_s_buckets(), s_real)
            .with_context(|| format!("no s bucket ≥ {s_real}"))?;
        let c = pick_bucket(&self.manifest.buckets("attn", |v| v.c), ctx)
            .with_context(|| format!("no c bucket ≥ {ctx}"))?;
        let home = self.requests[&req].home;
        let items = vec![(req, tokens.to_vec(), ctx, home)];
        let logits = self.forward_batch(&items, 1, s, c)?;
        let v = self.manifest.model.vocab;
        Ok(logits[(s_real - 1) * v..s_real * v].to_vec())
    }

    /// One decode token for each (req, last_token); returns per-request
    /// logits.
    fn forward_decode(&mut self, reqs: &[(RequestId, u32)]) -> Result<Vec<Vec<f32>>> {
        let b = pick_bucket(&self.decode_b_buckets(), reqs.len())
            .with_context(|| format!("no b bucket ≥ {}", reqs.len()))?;
        let max_ctx = reqs.iter().map(|&(id, _)| self.kv.tokens(id)).max().unwrap_or(0);
        let c = pick_bucket(&self.manifest.buckets("attn", |v| v.c), max_ctx)
            .with_context(|| format!("no c bucket ≥ ctx {max_ctx}"))?;
        let items: Vec<FwdItem> = reqs
            .iter()
            .map(|&(id, tok)| (id, vec![tok], self.kv.tokens(id), self.requests[&id].home))
            .collect();
        let logits = self.forward_batch(&items, b, 1, c)?;
        let v = self.manifest.model.vocab;
        Ok((0..reqs.len()).map(|i| logits[i * v..i * v + v].to_vec()).collect())
    }

    /// The generic bucketed forward. `items` padded to `b`×`s` with cache
    /// bucket `c`. Returns logits `[b, s, vocab]` flattened.
    fn forward_batch(&mut self, items: &[FwdItem], b: usize, s: usize, c: usize) -> Result<Vec<f32>> {
        let mm = self.manifest.model.clone();
        let (dm, hd, vocab) = (mm.d_model, mm.head_dim, mm.vocab);
        let b_real = items.len();
        anyhow::ensure!(b_real <= b && b_real > 0);

        // Tokens + positions, padded.
        let mut tok = vec![0i32; b * s];
        let mut pos = vec![0i32; b * s];
        for (i, (_, tokens, ctx, _)) in items.iter().enumerate() {
            for (j, &t) in tokens.iter().enumerate() {
                tok[i * s + j] = t as i32;
                pos[i * s + j] = (ctx + j) as i32;
            }
        }

        // x = embed(tokens, emb)
        let emb_v = self
            .manifest
            .simple_variant("embed", b, s)
            .with_context(|| format!("no embed variant b{b} s{s}"))?
            .clone();
        let tok_l = literal_i32(&tok, &[b as i64, s as i64])?;
        let outs = self.client.run(&emb_v, &[&tok_l, &self.emb])?;
        let mut x = to_vec_f32(&outs[0])?;
        debug_assert_eq!(x.len(), b * s * dm);

        let mask = build_mask(items, b, s, c);
        let mask_dims = [b as i64, 1, s as i64, (c + s) as i64];
        // The mask and positions are invariant across layers and ranks —
        // build the literals once per forward (see EXPERIMENTS.md §Perf).
        let mask_l = literal_f32(&mask, &mask_dims)?;
        let pos_l = literal_i32(&pos, &[b as i64, s as i64])?;

        for layer in 0..mm.n_layers {
            let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
            let mut partial = vec![0.0f32; x.len()];

            // --- TP attention: every rank, full batch.
            for rank in 0..self.world() {
                let (heads, hb) = match self.shards[rank].tp_attn[layer].as_ref() {
                    Some(aw) => (aw.heads.clone(), aw.h_bucket),
                    None => continue,
                };
                let variant = self
                    .manifest
                    .attn_variant(b, s, c, hb)
                    .with_context(|| format!("no attn variant b{b} s{s} c{c} h{hb}"))?
                    .clone();
                let (kc, vc) = self.gather_batch_kv(items, layer, b, c, &heads, hb);
                let kc_l = literal_f32(&kc, &[b as i64, c as i64, hb as i64, hd as i64])?;
                let vc_l = literal_f32(&vc, &[b as i64, c as i64, hb as i64, hd as i64])?;
                let aw = self.shards[rank].tp_attn[layer].as_ref().unwrap();
                let outs = self.client.run(
                    &variant,
                    &[
                        &x_l,
                        &self.shards[rank].attn_norm[layer],
                        &aw.wq,
                        &aw.wk,
                        &aw.wv,
                        &aw.wo,
                        &kc_l,
                        &vc_l,
                        &mask_l,
                        &pos_l,
                    ],
                )?;
                add_into(&mut partial, &to_vec_f32(&outs[0])?);
                self.append_new_kv(&outs[1], &outs[2], items, layer, b, s, &heads, hb, rank)?;
            }

            // --- DP attention: each home rank over its sub-batch.
            if self.plan.heads.dp_heads_per_layer() > 0 {
                for rank in 0..self.world() {
                    let sub_idx: Vec<usize> =
                        (0..b_real).filter(|&i| items[i].3 == rank).collect();
                    if sub_idx.is_empty() {
                        continue;
                    }
                    let (heads, hb) = match self.shards[rank].dp_attn[layer].as_ref() {
                        Some(aw) => (aw.heads.clone(), aw.h_bucket),
                        None => continue,
                    };
                    let sub_items: Vec<FwdItem> =
                        sub_idx.iter().map(|&i| items[i].clone()).collect();
                    let sb = if s == 1 {
                        pick_bucket(&self.decode_b_buckets(), sub_items.len())
                            .context("no dp sub-batch bucket")?
                    } else {
                        1 // prefill calls are b=1, so the sub-batch is that item
                    };
                    let variant = self
                        .manifest
                        .attn_variant(sb, s, c, hb)
                        .with_context(|| format!("no attn variant b{sb} s{s} c{c} h{hb}"))?
                        .clone();
                    let mut sx = vec![0.0f32; sb * s * dm];
                    let mut spos = vec![0i32; sb * s];
                    for (si, &i) in sub_idx.iter().enumerate() {
                        sx[si * s * dm..(si + 1) * s * dm]
                            .copy_from_slice(&x[i * s * dm..(i + 1) * s * dm]);
                        spos[si * s..(si + 1) * s].copy_from_slice(&pos[i * s..(i + 1) * s]);
                    }
                    let smask = build_mask(&sub_items, sb, s, c);
                    let (kc, vc) = self.gather_batch_kv(&sub_items, layer, sb, c, &heads, hb);
                    let sx_l = literal_f32(&sx, &[sb as i64, s as i64, dm as i64])?;
                    let kc_l = literal_f32(&kc, &[sb as i64, c as i64, hb as i64, hd as i64])?;
                    let vc_l = literal_f32(&vc, &[sb as i64, c as i64, hb as i64, hd as i64])?;
                    let smask_l =
                        literal_f32(&smask, &[sb as i64, 1, s as i64, (c + s) as i64])?;
                    let spos_l = literal_i32(&spos, &[sb as i64, s as i64])?;
                    let aw = self.shards[rank].dp_attn[layer].as_ref().unwrap();
                    let outs = self.client.run(
                        &variant,
                        &[
                            &sx_l,
                            &self.shards[rank].attn_norm[layer],
                            &aw.wq,
                            &aw.wk,
                            &aw.wv,
                            &aw.wo,
                            &kc_l,
                            &vc_l,
                            &smask_l,
                            &spos_l,
                        ],
                    )?;
                    let sub_out = to_vec_f32(&outs[0])?;
                    for (si, &i) in sub_idx.iter().enumerate() {
                        for j in 0..s * dm {
                            partial[i * s * dm + j] += sub_out[si * s * dm + j];
                        }
                    }
                    self.append_new_kv(&outs[1], &outs[2], &sub_items, layer, sb, s, &heads, hb, rank)?;
                }
            }

            // Combine (the "all-reduce") + residual.
            add_into(&mut x, &partial);

            // --- FFN: every rank's column slice.
            let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
            let mut fpartial = vec![0.0f32; x.len()];
            for rank in 0..self.world() {
                let col_bucket = self.shards[rank].ffn[layer].col_bucket;
                let variant = self
                    .manifest
                    .ffn_variant(b, s, col_bucket)
                    .with_context(|| format!("no ffn variant b{b} s{s} f{col_bucket}"))?
                    .clone();
                let fw = &self.shards[rank].ffn[layer];
                let outs = self.client.run(
                    &variant,
                    &[
                        &x_l,
                        &self.shards[rank].ffn_norm[layer],
                        &fw.gate,
                        &fw.up,
                        &fw.down,
                    ],
                )?;
                add_into(&mut fpartial, &to_vec_f32(&outs[0])?);
            }
            add_into(&mut x, &fpartial);
        }

        // LM head (rank 0 runs it; replicated weights).
        let head_v = self
            .manifest
            .simple_variant("head", b, s)
            .with_context(|| format!("no head variant b{b} s{s}"))?
            .clone();
        let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
        let outs = self.client.run(&head_v, &[&x_l, &self.final_norm, &self.lm_head])?;
        let logits = to_vec_f32(&outs[0])?;
        debug_assert_eq!(logits.len(), b * s * vocab);
        Ok(logits)
    }

    /// Gather padded K and V caches for a batch at `layer`.
    fn gather_batch_kv(
        &self,
        items: &[FwdItem],
        layer: LayerId,
        b: usize,
        c: usize,
        heads: &[usize],
        hb: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let hd = self.manifest.model.head_dim;
        let per = c * hb * hd;
        let mut kc = vec![0.0f32; b * per];
        let mut vc = vec![0.0f32; b * per];
        for (i, (req, _, _, _)) in items.iter().enumerate() {
            let k = self.kv.gather(*req, layer, heads, c, hb, false);
            let v = self.kv.gather(*req, layer, heads, c, hb, true);
            kc[i * per..(i + 1) * per].copy_from_slice(&k);
            vc[i * per..(i + 1) * per].copy_from_slice(&v);
        }
        (kc, vc)
    }

    /// Append freshly produced K/V (`[b, s, hb, hd]`) for real items.
    #[allow(clippy::too_many_arguments)]
    fn append_new_kv(
        &mut self,
        k_new: &xla::Literal,
        v_new: &xla::Literal,
        items: &[FwdItem],
        layer: LayerId,
        b: usize,
        s: usize,
        heads: &[usize],
        hb: usize,
        rank: RankId,
    ) -> Result<()> {
        let hd = self.manifest.model.head_dim;
        let k = to_vec_f32(k_new)?;
        let v = to_vec_f32(v_new)?;
        debug_assert_eq!(k.len(), b * s * hb * hd);
        for (i, (req, tokens, _, _)) in items.iter().enumerate() {
            let real = tokens.len();
            for (hi, &h) in heads.iter().enumerate() {
                let mut ks = Vec::with_capacity(real * hd);
                let mut vs = Vec::with_capacity(real * hd);
                for t in 0..real {
                    let off = ((i * s + t) * hb + hi) * hd;
                    ks.extend_from_slice(&k[off..off + hd]);
                    vs.extend_from_slice(&v[off..off + hd]);
                }
                self.kv.append(*req, layer, h, rank, &ks, &vs);
            }
        }
        Ok(())
    }
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as u32
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Additive mask `[b, 1, s, c+s]` for a padded batch.
fn build_mask(items: &[FwdItem], b: usize, s: usize, c: usize) -> Vec<f32> {
    let w = c + s;
    let mut m = vec![-1e9f32; b * s * w];
    for (i, (_, tokens, ctx, _)) in items.iter().enumerate() {
        let real = tokens.len();
        for q in 0..real {
            let row = (i * s + q) * w;
            for t in 0..(*ctx).min(c) {
                m[row + t] = 0.0; // cached positions
            }
            for t in 0..=q {
                m[row + c + t] = 0.0; // causal over the chunk
            }
        }
        // Padded query rows: self only (keeps softmax well-conditioned;
        // outputs and KV of padded rows are discarded).
        for q in real..s {
            m[(i * s + q) * w + c + q] = 0.0;
        }
    }
    for i in items.len()..b {
        for q in 0..s {
            m[(i * s + q) * w + c + q] = 0.0;
        }
    }
    m
}
