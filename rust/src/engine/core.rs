//! The engine proper: an event-driven serving session. Requests are
//! submitted with [`SubmitOptions`] (timed arrival, budget, priority),
//! the public [`Engine::step`] tick runs one scheduler-chosen unit of work
//! (a chunked-prefill pass or a continuous-decode step) and returns the
//! [`EngineEvent`]s it produced, and failures *and rejoins* can be
//! injected at *any* step boundary — including mid-decode with requests
//! in flight ([`Engine::inject_failure`] / [`Engine::inject_rejoin`]).
//! [`Engine::run_to_completion`] is a thin convenience wrapper over
//! `step()`. Everything executes real AOT artifacts through PJRT.
//!
//! # Hot-path discipline
//!
//! The decode inner loop is allocation-free at steady state on the
//! engine's side of the PJRT boundary: bucket tables and KV pool handles
//! are resolved once per epoch (construction / reconfiguration), the
//! padded token/position/mask/KV/partial buffers live in a
//! [`ForwardWorkspace`] reused across steps, KV moves through the paged
//! [`KvStore`] as block-indexed `copy_from_slice`, and the scheduler's
//! candidate lists reuse session scratch buffers. What still allocates
//! per call is the PJRT literal layer itself (`literal_f32` /
//! `to_vec_f32` marshal host buffers into and out of XLA) — that is the
//! runtime boundary, not coordinator churn. `benches/hotpath.rs` tracks
//! the KV gather/append and cost-model step times in
//! `BENCH_hotpath.json`.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{GpuSpec, Interconnect, TransferClass};
use crate::config::EngineConfig;
use crate::coordinator::RequestState;
use crate::kvcache::{BackupStore, KvPlacement};
use crate::obs::{ObsSink, Observer, RecoveryPhases};
use crate::prefix::{NodeId, PrefixStats, PrefixTrie};
use crate::recovery::{plan_recovery, RecoveryInput, RecoveryMethod};
use crate::router::DpRouter;
use crate::runtime::{
    literal_f32, literal_i32, literal_tensor, to_vec_f32, HloVariant, Manifest, RuntimeClient,
    WeightStore,
};
use crate::scheduler::{adaptive_chunked_prefill, form_decode_batch, DecodeItem, PrefillItem};
use crate::sharding::ShardPlan;
use crate::{RankId, RequestId, SimTime};

use super::report::{self, ServeReport};
use super::session::{Session, SubmitOptions};
use super::shard::{pick_bucket, RankShard};
use super::{KvStore, PoolId, BLOCK_TOKENS};

/// Something observable that happened during one engine step (or at a
/// step boundary: aborts, failure injections, and rejoins surface on the
/// next tick).
///
/// ```
/// use failsafe::engine::EngineEvent;
///
/// let ev = EngineEvent::TokenEmitted { id: 7, token: 42, index: 0 };
/// if let EngineEvent::TokenEmitted { id, token, index } = ev {
///     assert_eq!((id, token, index), (7, 42, 0));
/// } else {
///     unreachable!("streaming consumers match on the event kind");
/// }
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineEvent {
    /// Request `id` produced `token` — its `index`-th output token.
    TokenEmitted { id: RequestId, token: u32, index: usize },
    /// Request `id` produced its full generation budget.
    RequestFinished { id: RequestId },
    /// Request `id` was cancelled via `abort()`.
    RequestAborted { id: RequestId },
    /// A hard failure of `rank` was injected.
    FailureInjected { rank: RankId, method: RecoveryMethod },
    /// Recovery finished; `latency_s` is the modeled H100 stall.
    RecoveryCompleted { method: RecoveryMethod, latency_s: f64 },
    /// The session is serving on a new shard plan / world size.
    Reconfigured { epoch: u64, world: usize },
    /// A previously failed GPU rejoined the group as `rank` (always
    /// appended at the end of the rank order).
    GpuRejoined { rank: RankId, method: RecoveryMethod },
    /// The expand-reconfiguration for a rejoin completed: weights streamed
    /// onto the returning GPU and the cyclic KV placement re-spread, at the
    /// modeled `latency_s` cost.
    ReconfigCompleted { epoch: u64, world: usize, latency_s: f64 },
    /// `rank` is serving degraded at `factor`× effective speed (soft
    /// fault: thermal throttle, ECC pressure — alive, correct, slow). The
    /// rank stays in the group; capacity-aware rebalancing steers work
    /// off it.
    GpuDegraded { rank: RankId, factor: f64 },
    /// A previously degraded `rank` returned to full speed.
    GpuRestored { rank: RankId },
    /// Request `id` was preempted by the SLO scheduler: its device KV
    /// swapped out to the host tier (the proactive-backup mirror became
    /// authoritative). The request is paused, not aborted — it resumes
    /// via swap-in, never recompute.
    RequestPreempted { id: RequestId },
    /// A previously preempted request resumed decoding after its KV was
    /// swapped back in from the host tier.
    RequestResumed { id: RequestId },
}

/// The serving surface shared by the real [`Engine`] and the simulator's
/// [`crate::simulator::OnlineSession`]: online traces, benches, and the
/// fault-tolerance examples run identically against either backend.
///
/// ```
/// use failsafe::engine::{ServingBackend, SubmitOptions};
/// use failsafe::recovery::RecoveryMethod;
/// use failsafe::simulator::{OnlineMode, OnlineSim, SystemConfig};
///
/// // The cost-model backend serves without AOT artifacts — same API as
/// // the real `Engine`: submit, fail a GPU mid-flight, rejoin it, finish.
/// let mut session = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 4).session();
/// let id = session.submit_with(&vec![0u32; 512], SubmitOptions::new(4))?;
/// session.step()?; // admit + first decode tick
/// session.inject_failure(1, RecoveryMethod::Full)?;
/// assert_eq!(session.world(), 3);
/// session.inject_rejoin(RecoveryMethod::Full)?;
/// assert_eq!(session.world(), 4);
/// let report = session.run_to_completion()?;
/// assert_eq!(report.result(id).unwrap().output_tokens.len(), 4);
/// # anyhow::Ok(())
/// ```
pub trait ServingBackend {
    /// Submit a prompt with options; returns the request id.
    fn submit_with(&mut self, prompt: &[u32], opts: SubmitOptions) -> Result<RequestId>;
    /// Run one tick: admit due arrivals, execute one unit of work, return
    /// the events produced (plus any buffered from aborts/failures).
    fn step(&mut self) -> Result<Vec<EngineEvent>>;
    /// Cancel an unfinished request and release its resources.
    fn abort(&mut self, id: RequestId) -> Result<()>;
    /// Inject a hard failure of `rank` at this step boundary; returns the
    /// modeled recovery latency in seconds.
    fn inject_failure(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64>;
    /// Rejoin one previously failed GPU at this step boundary — the
    /// inverse of [`ServingBackend::inject_failure`]. The returning GPU is
    /// appended as rank `world()` (post-call `world() - 1`); weights
    /// stream in on demand, the cyclic KV placement re-spreads onto it,
    /// and the router rebalances. Errors if no GPU is currently failed.
    /// Returns the modeled reconfiguration latency in seconds.
    fn inject_rejoin(&mut self, method: RecoveryMethod) -> Result<f64>;
    /// Inject a *soft* fault at this step boundary: `rank` keeps serving
    /// but at `factor`× effective speed (`0 < factor ≤ 1`; `1.0` restores
    /// full speed — the inverse). The rank stays in the group and
    /// generation stays bit-exact; what changes is capacity: the backend
    /// re-weights routing (and, on the simulator, its cost model and
    /// shard plan) so the straggler stops pacing the whole group. Emits
    /// [`EngineEvent::GpuDegraded`] / [`EngineEvent::GpuRestored`] on the
    /// next `step()` and returns the modeled rebalance latency in seconds
    /// (`0.0` when only bookkeeping changes).
    fn inject_slowdown(&mut self, rank: RankId, factor: f64) -> Result<f64>;
    /// Current TP world size (number of ranks serving this session).
    fn world(&self) -> usize;
    /// Health-effective serving capacity in rank units: Σ over live ranks
    /// of their effective speed factor — `world()` as `f64` when fully
    /// healthy, less while ranks are degraded. Fleet-level placement
    /// normalizes by this.
    fn effective_capacity(&self) -> f64 {
        self.world() as f64
    }
    /// Hardware serving capacity in *H100-rank units*: Σ over live ranks
    /// of their device-class throughput relative to an H100. A uniform
    /// H100 backend returns `world()`; a 4×A100 replica returns ~4×0.4.
    /// Unlike [`ServingBackend::effective_capacity`] this reflects what
    /// the hardware *is*, not its current health — fleet routing and the
    /// autoscaler multiply the two (health as a fraction of hardware).
    fn hardware_capacity(&self) -> f64 {
        self.world() as f64
    }
    /// The backend clock in seconds (wall-based for the engine, simulated
    /// for the cost-model backend).
    fn now(&self) -> SimTime;
    /// True when no request can make further progress.
    fn is_idle(&self) -> bool;
    /// Cumulative report over every request this session has seen.
    fn report(&self) -> ServeReport;

    /// Attach a flight-recorder observer (see [`crate::obs`]). The
    /// default drops it — a backend without instrumentation stays
    /// valid, it just records nothing. Implementations must keep
    /// recording purely passive (bit-exact output with or without an
    /// observer attached).
    fn set_observer(&mut self, observer: Box<dyn Observer>) {
        let _ = observer;
    }

    /// Stamp the fleet replica id on this backend's trace records
    /// (ignored by backends that ignore `set_observer`).
    fn set_obs_replica(&mut self, replica: usize) {
        let _ = replica;
    }

    /// Drive `step()` until idle and return the report.
    fn run_to_completion(&mut self) -> Result<ServeReport> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.report())
    }

    /// Upper bound on the number of `TokenEmitted` events a single
    /// scheduler round can produce (the decode batch width). Span
    /// drivers ([`crate::engine::replay()`], `Fleet::replay`) divide a
    /// token deficit by this to bound how many rounds they may run
    /// without consulting the timeline — `usize::MAX` (the default)
    /// means "no bound known, advance one round at a time".
    fn max_tokens_per_step(&self) -> usize {
        usize::MAX
    }

    /// Advance until idle or until `limit` is hit, appending every event
    /// produced to `sink`. The default implementation is the plain
    /// step loop — one scheduler round per iteration, limits checked
    /// *before* each round exactly where [`drive`] and the replay
    /// drivers historically checked their triggers. Backends with an
    /// event-span core ([`crate::simulator::OnlineSession`]) override
    /// this to skip between boundary events; overrides must preserve
    /// the observational contract (same events, same report, same
    /// round count for the same limits).
    fn advance_until(
        &mut self,
        limit: AdvanceLimit,
        sink: &mut Vec<EngineEvent>,
    ) -> Result<AdvanceOutcome> {
        let mut out = AdvanceOutcome::default();
        while !self.is_idle() {
            if limit.reached(out.steps, out.tokens, self.now()) {
                break;
            }
            let events = self.step()?;
            out.steps += 1;
            out.tokens += events
                .iter()
                .filter(|e| matches!(e, EngineEvent::TokenEmitted { .. }))
                .count();
            sink.extend(events);
        }
        Ok(out)
    }
}

/// Stop condition for [`ServingBackend::advance_until`]: the backend
/// runs until idle or until any one of the set bounds is reached.
/// Bounds are checked *before* each scheduler round, so a round that
/// would start at or past a bound never runs — identical to where the
/// legacy drivers checked their fault/timeline triggers.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdvanceLimit {
    /// Stop before running round `max_steps` (counting from this call).
    pub max_steps: Option<usize>,
    /// Stop once at least this many tokens have been emitted (checked
    /// at round boundaries; a round may overshoot by up to the batch
    /// width, exactly as the legacy per-step drivers did).
    pub max_tokens: Option<usize>,
    /// Stop once the backend clock has reached this time.
    pub clock_at: Option<SimTime>,
}

impl AdvanceLimit {
    /// No bound: run to idle.
    pub fn unbounded() -> Self {
        Self::default()
    }

    /// Bound by scheduler rounds.
    pub fn steps(n: usize) -> Self {
        Self { max_steps: Some(n), ..Self::default() }
    }

    /// Bound by emitted tokens.
    pub fn tokens(n: usize) -> Self {
        Self { max_tokens: Some(n), ..Self::default() }
    }

    /// Bound by the backend clock.
    pub fn clock(at: SimTime) -> Self {
        Self { clock_at: Some(at), ..Self::default() }
    }

    /// True once any set bound is met for the given progress.
    pub fn reached(&self, steps: usize, tokens: usize, now: SimTime) -> bool {
        self.max_steps.is_some_and(|n| steps >= n)
            || self.max_tokens.is_some_and(|n| tokens >= n)
            || self.clock_at.is_some_and(|t| now >= t)
    }
}

/// What one [`ServingBackend::advance_until`] call did.
#[derive(Debug, Clone, Default)]
pub struct AdvanceOutcome {
    /// Scheduler rounds executed (each equals one legacy `step()`).
    pub steps: usize,
    /// `TokenEmitted` events produced (materialized into the sink *or*
    /// elided into `progressed` by a span core).
    pub tokens: usize,
    /// Per-request token counts the backend accounted for *without*
    /// materializing `TokenEmitted` events (empty for the default step
    /// loop). Span drivers that mirror per-request progress — e.g.
    /// `Fleet`'s redirect eligibility tracking — must fold these in.
    pub progressed: Vec<(RequestId, usize)>,
}

/// When a planned fault fires during [`drive`].
#[derive(Debug, Clone, Copy)]
pub enum FaultTrigger {
    /// Inject once the backend clock reaches this time.
    At(SimTime),
    /// Inject once this many tokens have been emitted (deterministic on
    /// both backends — preferred in tests).
    AfterTokens(usize),
}

/// A fault to inject mid-run while driving a backend.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub trigger: FaultTrigger,
    pub rank: RankId,
    pub method: RecoveryMethod,
}

/// Step any backend to completion, injecting `fault` at the first step
/// boundary where its trigger is due. Returns the final report and the
/// modeled recovery latency (if the fault fired).
pub fn drive<B: ServingBackend + ?Sized>(
    backend: &mut B,
    fault: Option<FaultPlan>,
) -> Result<(ServeReport, Option<f64>)> {
    let mut pending = fault;
    let mut emitted = 0usize;
    let mut recovery = None;
    while !backend.is_idle() {
        if let Some(f) = pending {
            let due = match f.trigger {
                FaultTrigger::At(t) => backend.now() >= t,
                FaultTrigger::AfterTokens(n) => emitted >= n,
            };
            if due {
                recovery = Some(backend.inject_failure(f.rank, f.method)?);
                pending = None;
            }
        }
        emitted += backend
            .step()?
            .iter()
            .filter(|e| matches!(e, EngineEvent::TokenEmitted { .. }))
            .count();
    }
    Ok((backend.report(), recovery))
}

/// One forward item: a span of new tokens (indices into the workspace
/// token buffer) on top of `ctx` cached tokens, homed on `home`.
#[derive(Debug, Clone, Copy)]
struct FwdItem {
    req: RequestId,
    /// Offset of this item's new tokens in `ForwardWorkspace::tok_buf`.
    tok_ofs: usize,
    n_tokens: usize,
    ctx: usize,
    home: RankId,
}

/// Preallocated buffers for the bucketed forward path, reused across
/// steps so the decode loop performs no per-layer/per-rank heap
/// allocation at steady state (capacities stabilize at the largest
/// bucket combination seen).
#[derive(Debug, Default)]
struct ForwardWorkspace {
    /// The forward batch (set by `forward_decode` / `forward_chunk`).
    items: Vec<FwdItem>,
    /// Flat new-token storage backing `FwdItem::tok_ofs`.
    tok_buf: Vec<u32>,
    tok: Vec<i32>,
    pos: Vec<i32>,
    mask: Vec<f32>,
    partial: Vec<f32>,
    fpartial: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    /// DP sub-batch scratch.
    sub_idx: Vec<usize>,
    sx: Vec<f32>,
    spos: Vec<i32>,
    smask: Vec<f32>,
    skc: Vec<f32>,
    svc: Vec<f32>,
    /// Scheduling-order id buffer for `step()`.
    sched: Vec<RequestId>,
    /// `step_decode` batch-forming scratch.
    decode_pool: Vec<DecodeItem>,
    decode_inputs: Vec<(RequestId, u32)>,
    /// `step_prefill` item scratch.
    prefill_items: Vec<PrefillItem>,
}

/// The serving engine. See module docs.
pub struct Engine {
    pub config: EngineConfig,
    client: RuntimeClient,
    manifest: Manifest,
    store: WeightStore,
    plan: ShardPlan,
    placement: KvPlacement,
    shards: Vec<RankShard>,
    kv: KvStore,
    router: DpRouter,
    emb: xla::Literal,
    final_norm: xla::Literal,
    lm_head: xla::Literal,
    session: Session,
    /// Shared-prefix trie (active when `config.prefix_sharing`): nodes
    /// hold refcounted CoW references into `kv`, invalidated and
    /// re-shared around every reconfiguration epoch.
    prefix: PrefixTrie,
    /// Home rank of the request that donated each trie node's blocks —
    /// the admission-time affinity hint.
    prefix_home: HashMap<NodeId, RankId>,
    /// Prompt tokens adopted from the trie instead of re-prefilled.
    prefix_saved_tokens: usize,
    epoch: u64,
    /// GPUs currently out of the group (failed and not yet rejoined) —
    /// the budget `inject_rejoin` draws from.
    lost: usize,
    /// Per-rank effective speed factors (1.0 = healthy). On the real
    /// engine a slowdown cannot change what the hardware does — the
    /// lever here is routing: degraded ranks are down-weighted in the
    /// capacity-aware router so new DP work lands elsewhere, and the
    /// factors surface through `effective_capacity()` for fleet-level
    /// placement. Generation stays bit-exact throughout.
    speed: Vec<f64>,
    recoveries: Vec<f64>,
    /// Events produced at step boundaries (aborts, failure injections),
    /// drained by the next `step()`.
    pending_events: Vec<EngineEvent>,
    /// Flight-recorder seam (detached by default). Purely passive:
    /// events mirror at the `step()` drain, recovery spans and gauges at
    /// injection edges — never on the per-token path.
    obs: ObsSink,
    // --- per-construction constants (hoisted out of the step loop) ---
    /// Prefill sequence buckets (attn, b=1, s>1), sorted.
    s_buckets: Vec<usize>,
    /// Decode batch buckets (attn, s=1), sorted.
    b_buckets: Vec<usize>,
    /// Cache-context buckets, sorted.
    c_buckets: Vec<usize>,
    // --- per-epoch constants (rebuilt on reconfiguration) ---
    /// `tp_pools[layer][rank]` = KV pool handle of the rank's TP head
    /// group (None where the rank owns no TP heads in that layer).
    tp_pools: Vec<Vec<Option<PoolId>>>,
    /// Per layer: pool handle of the DP (replicated) head group.
    dp_pools: Vec<Option<PoolId>>,
    ws: ForwardWorkspace,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Manifest::load(&config.artifacts_dir)?;
        anyhow::ensure!(
            manifest.model.n_heads == config.model.n_kv_heads
                && manifest.model.d_model == config.model.d_model
                && manifest.model.n_layers == config.model.n_layers,
            "artifacts were compiled for a different model than {}",
            config.model.name
        );
        let store = WeightStore::load(&manifest)?;
        let client = RuntimeClient::cpu()?;
        let plan = config.system.plan(&config.model, config.world);
        let placement = KvPlacement::new(&plan);
        let shards = (0..config.world)
            .map(|r| RankShard::build(&manifest, &store, &plan, r))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(RankShard::verify_cover(&shards, &plan), "shard cover check failed");
        let emb = literal_tensor(store.get("emb")?)?;
        let final_norm = literal_tensor(store.get("final_norm")?)?;
        let lm_head = literal_tensor(store.get("lm_head")?)?;
        let kv = KvStore::new(manifest.model.head_dim);
        let router = DpRouter::new(config.system.router, config.world);
        let s_buckets: Vec<usize> = {
            let mut v: Vec<usize> = manifest
                .variants
                .iter()
                .filter(|v| v.kind == "attn" && v.b == 1 && v.s > 1)
                .map(|v| v.s)
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let b_buckets: Vec<usize> = {
            let mut v: Vec<usize> = manifest
                .variants
                .iter()
                .filter(|v| v.kind == "attn" && v.s == 1)
                .map(|v| v.b)
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let c_buckets = manifest.buckets("attn", |v| v.c);
        let world = config.world;
        let mut engine = Engine {
            config,
            client,
            manifest,
            store,
            plan,
            placement,
            shards,
            kv,
            router,
            emb,
            final_norm,
            lm_head,
            session: Session::new(),
            prefix: PrefixTrie::new(),
            prefix_home: HashMap::new(),
            prefix_saved_tokens: 0,
            epoch: 0,
            lost: 0,
            speed: vec![1.0; world],
            recoveries: Vec::new(),
            pending_events: Vec::new(),
            obs: ObsSink::none(),
            s_buckets,
            b_buckets,
            c_buckets,
            tp_pools: Vec::new(),
            dp_pools: Vec::new(),
            ws: ForwardWorkspace::default(),
        };
        engine.rebuild_kv_handles();
        Ok(engine)
    }

    pub fn world(&self) -> usize {
        self.plan.world()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Per-rank (simulated-HBM) KV bytes — used by placement assertions.
    pub fn kv_bytes_by_rank(&self) -> Vec<usize> {
        self.kv.bytes_by_rank(self.world())
    }

    /// The session clock in seconds: advances with the wall time of each
    /// step and fast-forwards over idle waits for timed arrivals.
    pub fn now(&self) -> SimTime {
        self.session.clock
    }

    /// True when no submitted request can make further progress *and* no
    /// buffered events (aborts, failure notices) remain undelivered — so
    /// a step loop always drains the event stream before stopping, and
    /// stale events are never replayed into a later run.
    pub fn is_idle(&self) -> bool {
        self.pending_events.is_empty() && self.session.is_idle()
    }

    /// Submit a prompt with default options; returns the request id.
    pub fn submit(&mut self, prompt: &[u32], max_new_tokens: usize) -> Result<RequestId> {
        self.submit_with(prompt, SubmitOptions::new(max_new_tokens))
    }

    /// Submit a prompt with explicit [`SubmitOptions`].
    pub fn submit_with(&mut self, prompt: &[u32], opts: SubmitOptions) -> Result<RequestId> {
        anyhow::ensure!(
            opts.max_new_tokens > 0,
            "max_new_tokens must be at least 1 (a zero budget is a caller bug, not a no-op)"
        );
        anyhow::ensure!(
            opts.arrival.is_finite() && opts.arrival >= 0.0,
            "arrival must be a finite, non-negative time (got {})",
            opts.arrival
        );
        anyhow::ensure!(opts.deadline.unwrap_or(0.0).is_finite(), "deadline must be finite");
        let max_ctx = self.c_buckets.last().copied().unwrap_or(0);
        anyhow::ensure!(
            prompt.len() + opts.max_new_tokens <= max_ctx + 1,
            "prompt {} + max_new {} exceeds compiled context {}",
            prompt.len(),
            opts.max_new_tokens,
            max_ctx
        );
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.iter().all(|&t| (t as usize) < self.manifest.model.vocab),
            "token id out of vocab"
        );
        Ok(self.session.create(prompt.to_vec(), opts))
    }

    /// Cancel an unfinished request: release its KV (device slices and
    /// host mirror), un-book its routed work, and emit `RequestAborted`
    /// on the next step.
    pub fn abort(&mut self, id: RequestId) -> Result<()> {
        let (state, home, outstanding) = {
            let r = self
                .session
                .requests
                .get(&id)
                .with_context(|| format!("abort: unknown request {id}"))?;
            anyhow::ensure!(!r.is_done(), "abort: request {id} already {:?}", r.state);
            (r.state, r.home, r.prefill_remaining())
        };
        if state != RequestState::Queued {
            self.router.cancel(home, outstanding as f64);
        }
        self.kv.release(id);
        self.session.requests.get_mut(&id).unwrap().state = RequestState::Aborted;
        self.pending_events.push(EngineEvent::RequestAborted { id });
        self.sample_gauges();
        Ok(())
    }

    /// Preempt a decoding request to the KV swap tier (SLO scheduling):
    /// complete its host mirror, release its device blocks (blocks still
    /// shared with another request only drop a reference — the sharer's
    /// data stays put), and park it in [`RequestState::Swapped`]. Emits
    /// [`EngineEvent::RequestPreempted`] on the next step. The request
    /// resumes bit-exact via [`Engine::resume`] — and automatically when
    /// the decode batch would otherwise go idle, so a preempted request
    /// can never be stranded.
    pub fn preempt(&mut self, id: RequestId) -> Result<()> {
        let state = self
            .session
            .requests
            .get(&id)
            .with_context(|| format!("preempt: unknown request {id}"))?
            .state;
        anyhow::ensure!(
            state == RequestState::Decoding,
            "preempt: request {id} is {state:?}, not Decoding"
        );
        self.kv.swap_out(id);
        self.session.requests.get_mut(&id).unwrap().state = RequestState::Swapped;
        self.pending_events.push(EngineEvent::RequestPreempted { id });
        self.sample_gauges();
        Ok(())
    }

    /// Swap a preempted request back onto the device from its host
    /// mirror — the restore path recovery uses, never recompute — and
    /// return it to the decode batch. Emits
    /// [`EngineEvent::RequestResumed`] on the next step.
    pub fn resume(&mut self, id: RequestId) -> Result<()> {
        let (state, home, context) = {
            let r = self
                .session
                .requests
                .get(&id)
                .with_context(|| format!("resume: unknown request {id}"))?;
            (r.state, r.home, r.context)
        };
        anyhow::ensure!(
            state == RequestState::Swapped,
            "resume: request {id} is {state:?}, not Swapped"
        );
        let restored = self.kv.swap_in(id, &self.placement, home);
        anyhow::ensure!(
            restored >= context,
            "resume: mirror covers {restored} of {context} tokens for request {id} \
             (swap_out always completes the mirror first)"
        );
        self.session.requests.get_mut(&id).unwrap().state = RequestState::Decoding;
        self.pending_events.push(EngineEvent::RequestResumed { id });
        self.sample_gauges();
        Ok(())
    }

    /// Attach a flight-recorder observer (see [`crate::obs`]): engine
    /// events mirror into it at the `step()` drain, failure/rejoin
    /// injections emit recovery-phase spans, and KV/queue gauges sample
    /// at those edges. Recording is purely passive — generation stays
    /// bit-exact with an observer attached.
    pub fn set_observer(&mut self, observer: Box<dyn Observer>) {
        self.obs.set(observer);
    }

    /// Stamp the fleet replica id on subsequent trace records.
    pub fn set_obs_replica(&mut self, replica: usize) {
        self.obs.set_replica(replica);
    }

    /// Event-edge gauge sample: per-rank KV residency and speed factors,
    /// plus replica-level pool stats and lifecycle queue depths. Called
    /// at injection/reconfiguration edges only — never per token.
    fn sample_gauges(&mut self) {
        if !self.obs.enabled() {
            return;
        }
        let t = self.session.clock;
        let by_rank = self.kv_bytes_by_rank();
        for (r, bytes) in by_rank.iter().enumerate() {
            self.obs.gauge(t, Some(r), "kv.used_bytes", *bytes as f64);
        }
        for r in 0..self.speed.len() {
            let f = self.speed[r];
            self.obs.gauge(t, Some(r), "speed.factor", f);
        }
        let resident = self.kv_resident_bytes() as f64;
        let shared = self.kv_shared_blocks() as f64;
        let (mut queued, mut prefilling, mut decoding, mut swapped) = (0u64, 0u64, 0u64, 0u64);
        for r in self.session.requests.values() {
            match r.state {
                RequestState::Queued => queued += 1,
                RequestState::Prefilling => prefilling += 1,
                RequestState::Decoding => decoding += 1,
                RequestState::Swapped => swapped += 1,
                _ => {}
            }
        }
        let capacity: f64 = self.speed.iter().sum();
        self.obs.gauge(t, None, "kv.resident_bytes", resident);
        self.obs.gauge(t, None, "kv.shared_blocks", shared);
        self.obs.gauge(t, None, "queue.queued", queued as f64);
        self.obs.gauge(t, None, "queue.prefilling", prefilling as f64);
        self.obs.gauge(t, None, "queue.decoding", decoding as f64);
        self.obs.gauge(t, None, "queue.swapped", swapped as f64);
        self.obs.gauge(t, None, "capacity.effective", capacity);
    }

    /// Output tokens emitted so far for `id` — the streaming accessor.
    pub fn output_so_far(&self, id: RequestId) -> Option<&[u32]> {
        self.session.requests.get(&id).map(|r| r.output_tokens.as_slice())
    }

    /// Lifecycle state of `id`.
    pub fn request_state(&self, id: RequestId) -> Option<RequestState> {
        self.session.requests.get(&id).map(|r| r.state)
    }

    /// One engine tick. Admits requests whose arrival time has come,
    /// then runs *one* unit of work — a chunked-prefill pass if any
    /// request has prefill pending (prefill keeps priority over decode,
    /// exactly as the old monolithic loop ordered them), otherwise one
    /// continuous-decode step. With nothing runnable but arrivals still
    /// queued, the clock fast-forwards to the next arrival instead of
    /// busy-waiting. Returns the events produced.
    pub fn step(&mut self) -> Result<Vec<EngineEvent>> {
        let mut events = std::mem::take(&mut self.pending_events);
        let t0 = Instant::now();
        self.admit_due();
        let mut sched = std::mem::take(&mut self.ws.sched);
        self.session.prefilling_into(&mut sched);
        let outcome = if !sched.is_empty() {
            self.step_prefill(&sched, &mut events).map(|n| {
                self.session.prefill_tokens += n;
                self.session.steps += 1;
            })
        } else {
            self.session.decoding_into(&mut sched);
            if !sched.is_empty() {
                self.step_decode(&sched, &mut events).map(|n| {
                    self.session.decode_tokens += n;
                    self.session.steps += 1;
                })
            } else {
                // Decode went empty: swap back any preempted requests
                // (scheduling order) — capacity has freed, and a parked
                // request still owes tokens.
                self.session.swapped_into(&mut sched);
                if !sched.is_empty() {
                    let mut res = Ok(());
                    for i in 0..sched.len() {
                        if let Err(e) = self.resume(sched[i]) {
                            res = Err(e);
                            break;
                        }
                    }
                    res
                } else {
                    if let Some(next) = self.session.next_arrival() {
                        self.session.clock = self.session.clock.max(next);
                    }
                    Ok(())
                }
            }
        };
        self.ws.sched = sched;
        outcome?;
        self.session.clock += t0.elapsed().as_secs_f64();
        if self.obs.enabled() {
            // Mirror the drained events (TokenEmitted elided inside
            // `event`). Buffered boundary events are recorded here, at
            // delivery, exactly once.
            let t = self.session.clock;
            for ev in &events {
                self.obs.event(t, ev);
            }
        }
        Ok(events)
    }

    /// Drive all submitted requests to completion. The returned report's
    /// token/step counters and wall time cover *this call* (matching the
    /// old monolithic API); `results` covers every request of the session.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        let t0 = Instant::now();
        let (p0, d0, s0) =
            (self.session.prefill_tokens, self.session.decode_tokens, self.session.steps);
        while !self.is_idle() {
            self.step()?;
        }
        let mut rep = self.report();
        rep.wall_s = t0.elapsed().as_secs_f64();
        rep.prefill_tokens = self.session.prefill_tokens - p0;
        rep.decode_tokens = self.session.decode_tokens - d0;
        rep.steps = self.session.steps - s0;
        Ok(rep)
    }

    /// Cumulative report over every request this session has seen.
    pub fn report(&self) -> ServeReport {
        report::assemble(&self.session, &self.recoveries)
    }

    /// Route and admit every queued request whose arrival has come. With
    /// `config.prefix_sharing`, admission first matches the prompt
    /// against the trie: covered tokens adopt their cached blocks
    /// copy-on-write (zero prefill FLOPs, zero new KV blocks) and routing
    /// is biased toward the rank whose DP lanes already hold the prefix.
    fn admit_due(&mut self) {
        for id in self.session.ready_to_admit(self.session.clock) {
            let (len, delayed) = {
                let r = &self.session.requests[&id];
                (r.input_len(), r.arrival > 0.0)
            };
            let adoption =
                if self.config.prefix_sharing { self.plan_adoption(id) } else { None };
            let home = match &adoption {
                Some((adopt, _, hint)) => {
                    let mut bonus = vec![0.0; self.world()];
                    if let Some(h) = hint {
                        if *h < bonus.len() {
                            bonus[*h] = *adopt as f64;
                        }
                    }
                    self.router.route_biased((len - adopt) as f64, &bonus)
                }
                None => self.router.route(len as f64),
            };
            if let Some((adopt, pools, _)) = adoption {
                let ranks: HashMap<PoolId, RankId> =
                    self.pool_ranks(home).into_iter().collect();
                for (pool, blocks) in &pools {
                    self.kv.adopt_blocks(id, *pool, ranks[pool], blocks, adopt);
                }
                self.session.requests.get_mut(&id).unwrap().context = adopt;
                self.prefix_saved_tokens += adopt;
            }
            let r = self.session.requests.get_mut(&id).unwrap();
            r.home = home;
            r.state = RequestState::Prefilling;
            if delayed {
                // TTFT of a timed arrival measures service, not queueing
                // before its own arrival time.
                self.session.rebase_timing(id);
            }
        }
    }

    // ---------------------------------------------------- prefix sharing --

    /// Cumulative trie counters (lookups, hits, tokens saved, repairs).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.stats()
    }

    /// Trie chunks whose device blocks are currently resident.
    pub fn prefix_resident_chunks(&self) -> usize {
        self.prefix.resident_chunks()
    }

    /// Prompt tokens adopted from the shared-prefix cache instead of
    /// re-prefilled, cumulatively.
    pub fn prefix_saved_tokens(&self) -> usize {
        self.prefix_saved_tokens
    }

    /// Physically resident KV bytes — shared blocks counted once
    /// (contrast [`Engine::kv_bytes_by_rank`], the logical per-lane view).
    pub fn kv_resident_bytes(&self) -> usize {
        self.kv.resident_bytes()
    }

    /// Live KV blocks currently shared between runs and/or the trie.
    pub fn kv_shared_blocks(&self) -> usize {
        self.kv.shared_block_count()
    }

    /// Every KV pool handle of the current epoch paired with the rank
    /// holding its lanes: TP pools belong to their owning rank, DP
    /// (replicated) pools to the request's `home`.
    fn pool_ranks(&self, home: RankId) -> Vec<(PoolId, RankId)> {
        let mut out = Vec::new();
        for layer in 0..self.manifest.model.n_layers {
            for (rank, pid) in self.tp_pools[layer].iter().enumerate() {
                if let Some(pid) = pid {
                    out.push((*pid, rank));
                }
            }
            if let Some(pid) = self.dp_pools[layer] {
                out.push((pid, home));
            }
        }
        out
    }

    /// Match `id`'s prompt against the trie and build its adoption plan:
    /// covered tokens (capped one short of the full prompt so prefill
    /// still emits the first output token), the per-pool shared block
    /// lists (sorted by pool for determinism), and the affinity hint of
    /// the deepest matched node. `None` on a cold miss, or if the cached
    /// pool set doesn't cover the current epoch's — a defensive check;
    /// the trie is invalidated on every reconfiguration.
    #[allow(clippy::type_complexity)]
    fn plan_adoption(
        &mut self,
        id: RequestId,
    ) -> Option<(usize, Vec<(PoolId, Vec<u32>)>, Option<RankId>)> {
        let len = self.session.requests[&id].input_len();
        let m = self.prefix.lookup(&self.session.requests[&id].input_tokens);
        let adopt = m.live_tokens.min(len - 1);
        let n_nodes = adopt.div_ceil(BLOCK_TOKENS);
        if n_nodes == 0 {
            return None;
        }
        let chain = &m.nodes[..n_nodes];
        let mut per_pool: HashMap<PoolId, Vec<u32>> = HashMap::new();
        for &node in chain {
            for &(pool, b) in self.prefix.node_blocks(node) {
                per_pool.entry(pool).or_default().push(b);
            }
        }
        let epoch_pools = self.pool_ranks(0);
        if per_pool.len() != epoch_pools.len()
            || epoch_pools
                .iter()
                .any(|(p, _)| per_pool.get(p).map(Vec::len) != Some(n_nodes))
        {
            return None;
        }
        let mut pools: Vec<(PoolId, Vec<u32>)> = per_pool.into_iter().collect();
        pools.sort_unstable_by_key(|(p, _)| *p);
        let hint = self.prefix_home.get(&chain[n_nodes - 1]).copied();
        Some((adopt, pools, hint))
    }

    /// Find-or-create trie nodes for `id`'s freshly prefilled prompt and
    /// donate its blocks to any not yet resident — later arrivals with
    /// the same prefix then adopt them instead of re-prefilling.
    fn register_prefix(&mut self, id: RequestId) {
        let (prompt, home) = {
            let r = &self.session.requests[&id];
            (r.input_tokens.clone(), r.home)
        };
        let chain = self.prefix.insert(&prompt);
        self.donate_chain(id, &chain, home, false);
    }

    /// Cache `id`'s leading blocks as the device copy of every
    /// non-resident node of `chain` (root-first), down to the deepest
    /// chain prefix `id`'s runs fully cover in every pool. Returns that
    /// depth (0 when nothing is coverable).
    fn donate_chain(&mut self, id: RequestId, chain: &[NodeId], home: RankId, repair: bool) -> usize {
        if chain.is_empty() {
            return 0;
        }
        let pools = self.pool_ranks(home);
        if pools.is_empty() {
            return 0;
        }
        let mut n = chain.len();
        let mut per_pool: Vec<(PoolId, Vec<u32>)> = Vec::with_capacity(pools.len());
        'depth: loop {
            if n == 0 {
                return 0;
            }
            per_pool.clear();
            for &(pid, _) in &pools {
                match self.kv.prefix_blocks(id, pid, n) {
                    Some(blocks) => per_pool.push((pid, blocks)),
                    None => {
                        n -= 1;
                        continue 'depth;
                    }
                }
            }
            break;
        }
        for (i, &node) in chain[..n].iter().enumerate() {
            if self.prefix.is_resident(node) {
                continue;
            }
            let blocks: Vec<(PoolId, u32)> = per_pool.iter().map(|(p, b)| (*p, b[i])).collect();
            if repair {
                self.prefix.repair_blocks(node, &mut self.kv, blocks);
            } else {
                self.prefix.register_blocks(node, &mut self.kv, blocks);
            }
            self.prefix_home.insert(node, home);
        }
        n
    }

    /// Re-establish sharing after a reconfiguration epoch: the trie was
    /// invalidated (every device reference dropped), and affected
    /// requests were restored / re-laid-out with private blocks. The
    /// first request still covering each known chain is re-registered as
    /// its donor, then every other sharer's private leading blocks are
    /// swapped back to the shared copies — bit-identical by construction,
    /// since all of them were restored from mirrors of the same prefix
    /// rows. Sharing thus survives fail → shrink-reconfig → rejoin
    /// instead of decaying to N private copies.
    fn reshare_prefixes(&mut self) {
        if !self.config.prefix_sharing {
            return;
        }
        let ids: Vec<RequestId> = self.session.order.clone();
        for id in ids {
            let (done, prompt, home) = {
                let r = &self.session.requests[&id];
                (r.is_done(), r.input_tokens.clone(), r.home)
            };
            if done {
                continue;
            }
            let m = self.prefix.match_only(&prompt);
            let n = self.donate_chain(id, &m.nodes, home, true);
            if n == 0 {
                continue;
            }
            for (pid, _) in self.pool_ranks(home) {
                let shared: Option<Vec<u32>> = m.nodes[..n]
                    .iter()
                    .map(|&nd| {
                        self.prefix
                            .node_blocks(nd)
                            .iter()
                            .find(|&&(p, _)| p == pid)
                            .map(|&(_, b)| b)
                    })
                    .collect();
                if let Some(shared) = shared {
                    self.kv.switch_to_shared(id, pid, &shared);
                }
            }
        }
    }

    /// Re-resolve the per-(layer, rank) KV pool handles against the
    /// current shards. Cold path: construction and reconfiguration only —
    /// the step loop then uses the handles for O(1) pool access.
    fn rebuild_kv_handles(&mut self) {
        let Engine { kv, shards, manifest, tp_pools, dp_pools, .. } = self;
        let n_layers = manifest.model.n_layers;
        let world = shards.len();
        tp_pools.clear();
        dp_pools.clear();
        for layer in 0..n_layers {
            let mut row = Vec::with_capacity(world);
            for shard in shards.iter() {
                row.push(
                    shard.tp_attn[layer].as_ref().map(|aw| kv.pool_handle(layer, &aw.heads)),
                );
            }
            tp_pools.push(row);
            dp_pools.push(
                shards
                    .iter()
                    .find_map(|sh| sh.dp_attn[layer].as_ref())
                    .map(|aw| kv.pool_handle(layer, &aw.heads)),
            );
        }
    }

    // ---------------------------------------------------------- failure --

    /// Inject a hard failure of TP rank `rank` and recover with `method`,
    /// at any step boundary — before serving, between runs, or mid-decode
    /// with requests in flight. Returns the modeled recovery latency in
    /// seconds and buffers `FailureInjected` / `RecoveryCompleted` /
    /// `Reconfigured` events for the next `step()`. The engine continues
    /// serving on `world - 1` ranks; with backup-based methods the
    /// continuation is exact, with `Recompute` the affected context is
    /// re-prefilled from tokens.
    pub fn inject_failure(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64> {
        let old_world = self.world();
        anyhow::ensure!(old_world > 1, "cannot lose the last rank");
        anyhow::ensure!(rank < old_world);
        self.pending_events.push(EngineEvent::FailureInjected { rank, method });

        // In-flight state for the latency model.
        let reqs: Vec<(RequestId, usize, RankId)> = self
            .session
            .order
            .iter()
            .filter(|id| !self.session.requests[*id].is_done())
            .map(|id| {
                let r = &self.session.requests[id];
                (*id, r.context, r.home)
            })
            .collect();
        let mut backup_model = BackupStore::new(1 << 40);
        let bpt = self.config.model.kv_bytes_per_token();
        let use_backup = method != RecoveryMethod::Recompute;
        if use_backup {
            for &(id, _, _) in &reqs {
                backup_model.backup(id, self.kv.backed_tokens(id), bpt);
            }
        }

        // Plan the new epoch (survivors renumbered densely, commutative
        // FFN blocks staying put).
        let (new_plan, survivor_map) = self.plan.shrink(rank);
        let new_world = old_world - 1;

        // Latency model (what an H100 node would pay).
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        let outcome = plan_recovery(
            method,
            &RecoveryInput {
                spec: &spec,
                ic: &ic,
                old_plan: &self.plan,
                new_plan: &new_plan,
                survivor_map: &survivor_map,
                failed_rank: rank,
                requests: &reqs,
                backup: &backup_model,
            },
        );

        // Apply: wipe the failed rank's KV, re-tag survivors, reshard.
        let affected = self.kv.wipe_rank(rank);
        // The trie is an epoch-scoped cache: drop its device references
        // before restore/relayout (it must never pin stale-epoch blocks);
        // `reshare_prefixes` re-establishes sharing below.
        self.prefix.invalidate_device(&mut self.kv);
        self.kv.remap_ranks(&survivor_map);
        self.plan = new_plan;
        self.placement = KvPlacement::new(&self.plan);
        self.shards = (0..new_world)
            .map(|r| RankShard::build(&self.manifest, &self.store, &self.plan, r))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(RankShard::verify_cover(&self.shards, &self.plan));
        self.router = self.router.remap(&survivor_map, new_world);
        // Surviving ranks keep their degradation state under renumbering.
        let mut speed = vec![1.0; new_world];
        for (old, &s) in self.speed.iter().enumerate() {
            if let Some(new_r) = survivor_map[old] {
                speed[new_r] = s;
            }
        }
        self.speed = speed;
        self.epoch += 1;
        self.lost += 1;

        // Re-home requests and repair their KV state.
        let ids: Vec<RequestId> = self.session.order.clone();
        for id in ids {
            let (done, old_home, context) = {
                let r = &self.session.requests[&id];
                (r.is_done(), r.home, r.context)
            };
            if done {
                continue;
            }
            let new_home = survivor_map[old_home]
                .unwrap_or_else(|| self.router.tracker().least_loaded());
            self.session.requests.get_mut(&id).unwrap().home = new_home;

            if !affected.contains(&id) {
                continue;
            }
            let restored = if use_backup {
                self.kv.restore_request(id, &self.placement, new_home)
            } else {
                0
            };
            let keep = restored.min(context);
            self.kv.truncate(id, keep);
            // The un-restored suffix (backup lag or everything under
            // Recompute) is re-prefilled from known tokens: input + already
            // generated outputs.
            let outstanding_before = self.session.requests[&id].prefill_remaining();
            let r = self.session.requests.get_mut(&id).unwrap();
            if keep < r.context {
                let mut all: Vec<u32> = r.input_tokens.clone();
                all.extend(&r.output_tokens);
                r.input_tokens = all;
                r.context = keep;
                r.state = RequestState::Prefilling;
            }
            // Book the repair's extra prefill work: step_prefill completes
            // it against the router, and only admission booked work so far
            // — without this, completing unbooked tokens would drain other
            // requests' booked load on the recovering rank.
            let outstanding_after = self.session.requests[&id].prefill_remaining();
            if outstanding_after > outstanding_before {
                self.router
                    .add_load(new_home, (outstanding_after - outstanding_before) as f64);
            }
        }

        // Re-bucket resident KV into the new epoch's head groups so the
        // forward path stays on the fast block-indexed route, and refresh
        // the pool handles the step loop gathers through.
        self.kv.relayout(&self.plan);
        self.rebuild_kv_handles();
        self.reshare_prefixes();

        self.recoveries.push(outcome.total_s);
        if self.obs.enabled() {
            let t0 = self.session.clock;
            let epoch = self.epoch;
            let affected_n = affected.len();
            RecoveryPhases::of(&outcome, 0.0).emit(
                &mut self.obs,
                t0,
                Some(rank),
                "failure",
                format!("{method:?}"),
            );
            self.obs.decision(
                t0,
                Some(rank),
                "kv.relayout",
                vec![
                    ("epoch", epoch.into()),
                    ("world", new_world.into()),
                    ("affected_requests", affected_n.into()),
                ],
            );
        }
        self.sample_gauges();
        self.pending_events
            .push(EngineEvent::RecoveryCompleted { method, latency_s: outcome.total_s });
        self.pending_events
            .push(EngineEvent::Reconfigured { epoch: self.epoch, world: new_world });
        Ok(outcome.total_s)
    }

    /// Rejoin one previously failed GPU at this step boundary — the
    /// inverse of [`Engine::inject_failure`], usable at any point:
    /// mid-decode with requests in flight, mid-repair while a Recompute
    /// re-prefill is still running, or on an idle session. The returning
    /// GPU is appended as rank `world()` and the coordinator plans an
    /// expand-reconfiguration:
    ///
    /// * **weights** — on-demand recovery costed via
    ///   [`plan_recovery`]: with [`RecoveryMethod::Full`] the new rank's
    ///   shard streams from surviving peers over NVLink (zero PCIe — every
    ///   unit has a live replica), conventional methods pay full-shard
    ///   PCIe reloads;
    /// * **KV cache** — the cyclic placement re-spreads onto the new rank
    ///   (it absorbs ≈ `1/new_world` of resident KV), costed as the max
    ///   per-rank NVLink receive and applied by re-tagging slices;
    /// * **router** — existing ranks keep their booked load, the new rank
    ///   starts empty, so least-loaded routing rebalances onto it.
    ///
    /// Generation is untouched — continuation across a rejoin is bit-exact
    /// by construction, which the integration tests assert. Buffers
    /// [`EngineEvent::GpuRejoined`] / [`EngineEvent::ReconfigCompleted`]
    /// for the next `step()` and returns the modeled latency in seconds.
    pub fn inject_rejoin(&mut self, method: RecoveryMethod) -> Result<f64> {
        anyhow::ensure!(
            self.lost > 0,
            "inject_rejoin: no failed GPU to rejoin (world {}, none lost)",
            self.world()
        );
        let old_world = self.world();
        let new_world = old_world + 1;
        let joined: RankId = old_world;
        let (new_plan, survivor_map) = self.plan.expand();

        // Latency model: on-demand weight stream-in for the joining rank...
        let spec = GpuSpec::h100();
        let ic = Interconnect::new(spec.clone());
        let outcome = plan_recovery(
            method,
            &RecoveryInput {
                spec: &spec,
                ic: &ic,
                old_plan: &self.plan,
                new_plan: &new_plan,
                survivor_map: &survivor_map,
                failed_rank: usize::MAX, // nothing is lost on a rejoin
                requests: &[],
                backup: &BackupStore::new(0),
            },
        );
        // ...plus the cyclic KV re-spread onto it, bounded by the max
        // bytes any single rank receives over NVLink (serialized after the
        // weight phase: both directions share the peer fabric).
        let new_placement = KvPlacement::new(&new_plan);
        let mut recv = vec![0usize; new_world];
        for id in &self.session.order {
            let r = &self.session.requests[id];
            if r.is_done() {
                continue;
            }
            let per = self.placement.respread_bytes(&new_placement, r.context, r.home);
            for (rank, b) in per.iter().enumerate() {
                recv[rank] += b;
            }
        }
        let kv_move_s = ic
            .parallel_transfer_time(TransferClass::NvLink, recv.iter().copied().max().unwrap_or(0));
        let total_s = outcome.total_s + kv_move_s;

        // Apply: new plan + shards, re-spread KV tags, grow the router.
        self.plan = new_plan;
        self.placement = new_placement;
        self.shards = (0..new_world)
            .map(|r| RankShard::build(&self.manifest, &self.store, &self.plan, r))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(RankShard::verify_cover(&self.shards, &self.plan));
        self.router = self.router.expand(new_world);
        self.speed.push(1.0); // the returning GPU starts at full speed
        self.epoch += 1;
        self.lost -= 1;
        let homes: std::collections::HashMap<RequestId, RankId> = self
            .session
            .requests
            .iter()
            .filter(|(_, r)| !r.is_done())
            .map(|(id, r)| (*id, r.home))
            .collect();
        // Same epoch-boundary contract as the failure path: the trie must
        // not pin blocks across the relayout; sharing itself survives it
        // structurally (relayout memoizes identical source signatures)
        // and the trie re-pins the shared copies right after.
        self.prefix.invalidate_device(&mut self.kv);
        self.kv.retag_requests(&self.placement, &homes);
        // Host-side analogue of the costed re-spread: re-bucket resident
        // KV into the expanded plan's head groups, refresh pool handles.
        self.kv.relayout(&self.plan);
        self.rebuild_kv_handles();
        self.reshare_prefixes();

        self.recoveries.push(total_s);
        if self.obs.enabled() {
            let t0 = self.session.clock;
            let epoch = self.epoch;
            RecoveryPhases::of(&outcome, kv_move_s).emit(
                &mut self.obs,
                t0,
                Some(joined),
                "rejoin",
                format!("{method:?}"),
            );
            self.obs.decision(
                t0,
                Some(joined),
                "kv.relayout",
                vec![
                    ("epoch", epoch.into()),
                    ("world", new_world.into()),
                    ("kv_move_s", kv_move_s.into()),
                ],
            );
        }
        self.sample_gauges();
        self.pending_events.push(EngineEvent::GpuRejoined { rank: joined, method });
        self.pending_events.push(EngineEvent::ReconfigCompleted {
            epoch: self.epoch,
            world: new_world,
            latency_s: total_s,
        });
        // Consumers that track the serving plan via `Reconfigured` (as the
        // failure path trains them to) must see expansions too.
        self.pending_events
            .push(EngineEvent::Reconfigured { epoch: self.epoch, world: new_world });
        Ok(total_s)
    }

    // ------------------------------------------------------ soft faults --

    /// Mark `rank` as serving at `factor`× effective speed (`1.0`
    /// restores full speed). On the real engine a soft fault cannot be
    /// made *actually* slower — the executions are what they are — so
    /// the mitigation lever here is placement: the capacity-aware router
    /// down-weights the rank, steering new DP-attention work off it, and
    /// `effective_capacity()` shrinks so fleet-level routing sends this
    /// replica proportionally less. Token streams are untouched —
    /// continuation across degrade/restore is bit-exact by construction
    /// (homes only select *where* replicated DP heads run, never what
    /// they compute). Buffers [`EngineEvent::GpuDegraded`] /
    /// [`EngineEvent::GpuRestored`] for the next `step()`.
    pub fn inject_slowdown(&mut self, rank: RankId, factor: f64) -> Result<f64> {
        anyhow::ensure!(rank < self.world(), "rank {rank} out of range (world {})", self.world());
        anyhow::ensure!(
            factor.is_finite() && factor > 0.0 && factor <= 1.0,
            "speed factor must be in (0, 1], got {factor}"
        );
        let was = self.speed[rank];
        self.speed[rank] = factor;
        self.router.set_capacity(rank, factor);
        if factor < 1.0 {
            self.pending_events.push(EngineEvent::GpuDegraded { rank, factor });
        } else if was < 1.0 {
            self.pending_events.push(EngineEvent::GpuRestored { rank });
        }
        if self.obs.enabled() {
            let t = self.session.clock;
            self.obs.decision(
                t,
                Some(rank),
                "routing.downweight",
                vec![("factor", factor.into()), ("was", was.into())],
            );
            self.sample_gauges();
        }
        Ok(0.0) // routing-only mitigation: no modeled stall
    }

    /// Per-rank effective speed factors (1.0 = healthy).
    pub fn speed_factors(&self) -> &[f64] {
        &self.speed
    }

    /// Σ of live ranks' speed factors — the health-effective capacity in
    /// rank units.
    pub fn effective_capacity(&self) -> f64 {
        self.speed.iter().sum()
    }

    // ------------------------------------------------------------ steps --

    /// One prefill pass over `ids` (already in scheduling order): form
    /// chunks with Algorithm 1, run them (b=1).
    fn step_prefill(&mut self, ids: &[RequestId], events: &mut Vec<EngineEvent>) -> Result<usize> {
        let mut items = std::mem::take(&mut self.ws.prefill_items);
        items.clear();
        items.extend(ids.iter().map(|id| {
            let r = &self.session.requests[id];
            PrefillItem {
                request: *id,
                rank: r.home,
                context: r.context,
                remaining: r.prefill_remaining(),
            }
        }));
        if items.is_empty() {
            self.ws.prefill_items = items;
            return Ok(0);
        }
        let carry = vec![0.0; self.world()];
        let batch =
            adaptive_chunked_prefill(self.config.token_budget, &items, &carry, self.world(), 8);
        self.ws.prefill_items = items;
        let max_s = self.s_buckets.last().copied().unwrap_or(16);

        let mut done = 0usize;
        for chunk in &batch.chunks {
            let take = chunk.tokens.min(max_s);
            let (tokens, ctx) = {
                let r = &self.session.requests[&chunk.request];
                let take = take.min(r.prefill_remaining());
                (r.input_tokens[r.context..r.context + take].to_vec(), r.context)
            };
            if tokens.is_empty() {
                continue;
            }
            let logits = self.forward_chunk(chunk.request, &tokens, ctx)?;
            done += tokens.len();
            self.router.complete(chunk.rank, tokens.len() as f64);
            let finished = {
                let r = self.session.requests.get_mut(&chunk.request).unwrap();
                r.on_prefilled(tokens.len());
                r.state == RequestState::Decoding
            };
            if finished && self.config.prefix_sharing {
                // The full prompt is now resident: donate its blocks to
                // the trie so later arrivals share instead of re-prefill.
                self.register_prefix(chunk.request);
            }
            if finished {
                // If this request still has generated tokens from before a
                // Recompute-style repair, it is mid-decode continuation and
                // the "first" token here would double-count; only sample
                // when output budget remains.
                let needs_token = {
                    let r = &self.session.requests[&chunk.request];
                    r.output_tokens.len() < r.max_new_tokens
                };
                if needs_token {
                    let tok = argmax(&logits);
                    let (index, finished_now) = {
                        let r = self.session.requests.get_mut(&chunk.request).unwrap();
                        r.on_decoded(tok);
                        (r.output_tokens.len() - 1, r.state == RequestState::Finished)
                    };
                    self.session.note_token(chunk.request);
                    events.push(EngineEvent::TokenEmitted {
                        id: chunk.request,
                        token: tok,
                        index,
                    });
                    if finished_now {
                        self.session.mark_finished(chunk.request);
                        events.push(EngineEvent::RequestFinished { id: chunk.request });
                    }
                } else {
                    self.session.requests.get_mut(&chunk.request).unwrap().state =
                        RequestState::Finished;
                    self.session.mark_finished(chunk.request);
                    events.push(EngineEvent::RequestFinished { id: chunk.request });
                }
            }
            self.kv.backup_request(chunk.request); // proactive backup pass
        }
        Ok(done)
    }

    /// One decode step over `ids` (each produces one token). Batches are
    /// formed through the scheduler's continuous-decode batch former in
    /// scheduling order, capped at the compiled batch bucket.
    fn step_decode(&mut self, ids: &[RequestId], events: &mut Vec<EngineEvent>) -> Result<usize> {
        let mut produced = 0;
        let cap = self.config.max_batch.min(8).max(1);
        let vocab = self.manifest.model.vocab;
        let mut pool = std::mem::take(&mut self.ws.decode_pool);
        let mut inputs = std::mem::take(&mut self.ws.decode_inputs);
        pool.clear();
        pool.extend(ids.iter().map(|id| {
            let r = &self.session.requests[id];
            DecodeItem { request: *id, rank: r.home, context: r.context }
        }));
        while !pool.is_empty() {
            let batch = form_decode_batch(&pool, cap, self.world());
            pool.drain(..batch.len());
            inputs.clear();
            inputs.extend(batch.items.iter().map(|it| {
                let r = &self.session.requests[&it.request];
                let t = r
                    .output_tokens
                    .last()
                    .copied()
                    .unwrap_or_else(|| *r.input_tokens.last().expect("nonempty prompt"));
                (it.request, t)
            }));
            let logits = self.forward_decode(&inputs)?;
            for (i, &(id, _)) in inputs.iter().enumerate() {
                let tok = argmax(&logits[i * vocab..(i + 1) * vocab]);
                let (index, finished) = {
                    let r = self.session.requests.get_mut(&id).unwrap();
                    r.on_decoded(tok);
                    (r.output_tokens.len() - 1, r.state == RequestState::Finished)
                };
                self.session.note_token(id);
                events.push(EngineEvent::TokenEmitted { id, token: tok, index });
                if finished {
                    self.session.mark_finished(id);
                    events.push(EngineEvent::RequestFinished { id });
                }
                produced += 1;
                self.kv.backup_request(id);
            }
        }
        self.ws.decode_pool = pool;
        self.ws.decode_inputs = inputs;
        Ok(produced)
    }

    // ---------------------------------------------------------- forward --

    /// Prefill one chunk of `req` (b=1); returns last-position logits.
    fn forward_chunk(&mut self, req: RequestId, tokens: &[u32], ctx: usize) -> Result<Vec<f32>> {
        let s_real = tokens.len();
        let s = pick_bucket(&self.s_buckets, s_real)
            .with_context(|| format!("no s bucket ≥ {s_real}"))?;
        let c = pick_bucket(&self.c_buckets, ctx)
            .with_context(|| format!("no c bucket ≥ {ctx}"))?;
        let home = self.session.requests[&req].home;
        self.ws.items.clear();
        self.ws.tok_buf.clear();
        self.ws.tok_buf.extend_from_slice(tokens);
        self.ws.items.push(FwdItem { req, tok_ofs: 0, n_tokens: s_real, ctx, home });
        let logits = self.forward_batch(1, s, c)?;
        let v = self.manifest.model.vocab;
        Ok(logits[(s_real - 1) * v..s_real * v].to_vec())
    }

    /// One decode token for each (req, last_token); returns logits
    /// `[len, vocab]` flattened (callers slice per request).
    fn forward_decode(&mut self, reqs: &[(RequestId, u32)]) -> Result<Vec<f32>> {
        let b = pick_bucket(&self.b_buckets, reqs.len())
            .with_context(|| format!("no b bucket ≥ {}", reqs.len()))?;
        let mut max_ctx = 0usize;
        {
            let Engine { ws, kv, session, .. } = self;
            ws.items.clear();
            ws.tok_buf.clear();
            for &(id, tok) in reqs {
                let ctx = kv.tokens(id); // O(1): indexed, looked up once per request
                max_ctx = max_ctx.max(ctx);
                let tok_ofs = ws.tok_buf.len();
                ws.tok_buf.push(tok);
                ws.items.push(FwdItem {
                    req: id,
                    tok_ofs,
                    n_tokens: 1,
                    ctx,
                    home: session.requests[&id].home,
                });
            }
        }
        let c = pick_bucket(&self.c_buckets, max_ctx)
            .with_context(|| format!("no c bucket ≥ ctx {max_ctx}"))?;
        self.forward_batch(b, 1, c)
    }

    /// The generic bucketed forward over `ws.items`, padded to `b`×`s`
    /// with cache bucket `c`. Returns logits `[b, s, vocab]` flattened.
    fn forward_batch(&mut self, b: usize, s: usize, c: usize) -> Result<Vec<f32>> {
        let Engine {
            manifest,
            client,
            shards,
            kv,
            plan,
            ws,
            emb,
            final_norm,
            lm_head,
            tp_pools,
            dp_pools,
            b_buckets,
            ..
        } = self;
        let manifest: &Manifest = manifest;
        let ForwardWorkspace {
            items,
            tok_buf,
            tok,
            pos,
            mask,
            partial,
            fpartial,
            kc,
            vc,
            sub_idx,
            sx,
            spos,
            smask,
            skc,
            svc,
            ..
        } = ws;
        let items: &[FwdItem] = items;
        let mm = &manifest.model;
        let (dm, hd, vocab) = (mm.d_model, mm.head_dim, mm.vocab);
        let b_real = items.len();
        anyhow::ensure!(b_real <= b && b_real > 0);
        let world = shards.len();

        // Tokens + positions, padded — workspace reuse, fully rewritten.
        tok.clear();
        tok.resize(b * s, 0);
        pos.clear();
        pos.resize(b * s, 0);
        for (i, it) in items.iter().enumerate() {
            for j in 0..it.n_tokens {
                tok[i * s + j] = tok_buf[it.tok_ofs + j] as i32;
                pos[i * s + j] = (it.ctx + j) as i32;
            }
        }

        // x = embed(tokens, emb)
        let emb_v = manifest
            .simple_variant("embed", b, s)
            .with_context(|| format!("no embed variant b{b} s{s}"))?;
        let tok_l = literal_i32(tok, &[b as i64, s as i64])?;
        let outs = client.run(emb_v, &[&tok_l, &*emb])?;
        let mut x = to_vec_f32(&outs[0])?;
        debug_assert_eq!(x.len(), b * s * dm);

        build_mask_into(mask, items, None, b, s, c);
        let mask_dims = [b as i64, 1, s as i64, (c + s) as i64];
        // The mask and positions are invariant across layers and ranks —
        // build the literals once per forward (see EXPERIMENTS.md §Perf).
        let mask_l = literal_f32(mask, &mask_dims)?;
        let pos_l = literal_i32(pos, &[b as i64, s as i64])?;

        // Variant lookups are loop-invariant per (bucket combo) — resolve
        // once per forward instead of per layer × rank. FFN column
        // buckets are layer-invariant, so one variant per rank suffices.
        let mut attn_cache: Vec<((usize, usize), &HloVariant)> = Vec::new();
        let mut ffn_variants: Vec<&HloVariant> = Vec::with_capacity(world);
        for shard in shards.iter() {
            let cb = shard.ffn[0].col_bucket;
            ffn_variants.push(
                manifest
                    .ffn_variant(b, s, cb)
                    .with_context(|| format!("no ffn variant b{b} s{s} f{cb}"))?,
            );
        }
        let has_dp = plan.heads.dp_heads_per_layer() > 0;

        for layer in 0..mm.n_layers {
            let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
            partial.clear();
            partial.resize(x.len(), 0.0);

            // --- TP attention: every rank, full batch.
            for rank in 0..world {
                let Some(aw) = shards[rank].tp_attn[layer].as_ref() else { continue };
                let hb = aw.h_bucket;
                let variant = attn_variant_cached(manifest, &mut attn_cache, b, s, c, hb)?;
                let pool = tp_pools[layer][rank].expect("pool handle exists for shard group");
                let per = c * hb * hd;
                fit_buf(kc, b * per);
                fit_buf(vc, b * per);
                for (i, it) in items.iter().enumerate() {
                    kv.gather_into(it.req, pool, c, hb, false, &mut kc[i * per..(i + 1) * per]);
                    kv.gather_into(it.req, pool, c, hb, true, &mut vc[i * per..(i + 1) * per]);
                }
                kc[b_real * per..].fill(0.0);
                vc[b_real * per..].fill(0.0);
                let kc_l = literal_f32(kc, &[b as i64, c as i64, hb as i64, hd as i64])?;
                let vc_l = literal_f32(vc, &[b as i64, c as i64, hb as i64, hd as i64])?;
                let outs = client.run(
                    variant,
                    &[
                        &x_l,
                        &shards[rank].attn_norm[layer],
                        &aw.wq,
                        &aw.wk,
                        &aw.wv,
                        &aw.wo,
                        &kc_l,
                        &vc_l,
                        &mask_l,
                        &pos_l,
                    ],
                )?;
                add_into(partial, &to_vec_f32(&outs[0])?);
                let k_new = to_vec_f32(&outs[1])?;
                let v_new = to_vec_f32(&outs[2])?;
                debug_assert_eq!(k_new.len(), b * s * hb * hd);
                append_new_kv(kv, pool, &k_new, &v_new, items, None, s, hb, hd, rank);
            }

            // --- DP attention: each home rank over its sub-batch.
            if has_dp {
                for rank in 0..world {
                    sub_idx.clear();
                    sub_idx.extend((0..b_real).filter(|&i| items[i].home == rank));
                    if sub_idx.is_empty() {
                        continue;
                    }
                    let Some(aw) = shards[rank].dp_attn[layer].as_ref() else { continue };
                    let hb = aw.h_bucket;
                    let Some(pool) = dp_pools[layer] else { continue };
                    let sb = if s == 1 {
                        pick_bucket(b_buckets, sub_idx.len())
                            .context("no dp sub-batch bucket")?
                    } else {
                        1 // prefill calls are b=1, so the sub-batch is that item
                    };
                    let variant = attn_variant_cached(manifest, &mut attn_cache, sb, s, c, hb)?;
                    sx.clear();
                    sx.resize(sb * s * dm, 0.0);
                    spos.clear();
                    spos.resize(sb * s, 0);
                    for (si, &i) in sub_idx.iter().enumerate() {
                        sx[si * s * dm..(si + 1) * s * dm]
                            .copy_from_slice(&x[i * s * dm..(i + 1) * s * dm]);
                        spos[si * s..(si + 1) * s].copy_from_slice(&pos[i * s..(i + 1) * s]);
                    }
                    build_mask_into(smask, items, Some(sub_idx.as_slice()), sb, s, c);
                    let per = c * hb * hd;
                    fit_buf(skc, sb * per);
                    fit_buf(svc, sb * per);
                    for (si, &i) in sub_idx.iter().enumerate() {
                        let it = &items[i];
                        let span = si * per..(si + 1) * per;
                        kv.gather_into(it.req, pool, c, hb, false, &mut skc[span.clone()]);
                        kv.gather_into(it.req, pool, c, hb, true, &mut svc[span]);
                    }
                    skc[sub_idx.len() * per..].fill(0.0);
                    svc[sub_idx.len() * per..].fill(0.0);
                    let sx_l = literal_f32(sx, &[sb as i64, s as i64, dm as i64])?;
                    let kc_l = literal_f32(skc, &[sb as i64, c as i64, hb as i64, hd as i64])?;
                    let vc_l = literal_f32(svc, &[sb as i64, c as i64, hb as i64, hd as i64])?;
                    let smask_l =
                        literal_f32(smask, &[sb as i64, 1, s as i64, (c + s) as i64])?;
                    let spos_l = literal_i32(spos, &[sb as i64, s as i64])?;
                    let outs = client.run(
                        variant,
                        &[
                            &sx_l,
                            &shards[rank].attn_norm[layer],
                            &aw.wq,
                            &aw.wk,
                            &aw.wv,
                            &aw.wo,
                            &kc_l,
                            &vc_l,
                            &smask_l,
                            &spos_l,
                        ],
                    )?;
                    let sub_out = to_vec_f32(&outs[0])?;
                    for (si, &i) in sub_idx.iter().enumerate() {
                        for j in 0..s * dm {
                            partial[i * s * dm + j] += sub_out[si * s * dm + j];
                        }
                    }
                    let k_new = to_vec_f32(&outs[1])?;
                    let v_new = to_vec_f32(&outs[2])?;
                    append_new_kv(
                        kv,
                        pool,
                        &k_new,
                        &v_new,
                        items,
                        Some(sub_idx.as_slice()),
                        s,
                        hb,
                        hd,
                        rank,
                    );
                }
            }

            // Combine (the "all-reduce") + residual.
            add_into(&mut x, partial);

            // --- FFN: every rank's column slice.
            let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
            fpartial.clear();
            fpartial.resize(x.len(), 0.0);
            for rank in 0..world {
                let fw = &shards[rank].ffn[layer];
                let outs = client.run(
                    ffn_variants[rank],
                    &[&x_l, &shards[rank].ffn_norm[layer], &fw.gate, &fw.up, &fw.down],
                )?;
                add_into(fpartial, &to_vec_f32(&outs[0])?);
            }
            add_into(&mut x, fpartial);
        }

        // LM head (rank 0 runs it; replicated weights).
        let head_v = manifest
            .simple_variant("head", b, s)
            .with_context(|| format!("no head variant b{b} s{s}"))?;
        let x_l = literal_f32(&x, &[b as i64, s as i64, dm as i64])?;
        let outs = client.run(head_v, &[&x_l, &*final_norm, &*lm_head])?;
        let logits = to_vec_f32(&outs[0])?;
        debug_assert_eq!(logits.len(), b * s * vocab);
        Ok(logits)
    }
}

/// Resolve the attn variant for a bucket combo through a per-forward
/// cache (variant search is loop-invariant across layers and ranks with
/// the same head bucket).
fn attn_variant_cached<'m>(
    manifest: &'m Manifest,
    cache: &mut Vec<((usize, usize), &'m HloVariant)>,
    b: usize,
    s: usize,
    c: usize,
    hb: usize,
) -> Result<&'m HloVariant> {
    if let Some(&(_, v)) = cache.iter().find(|&&((cb, ch), _)| cb == b && ch == hb) {
        return Ok(v);
    }
    let v = manifest
        .attn_variant(b, s, c, hb)
        .with_context(|| format!("no attn variant b{b} s{s} c{c} h{hb}"))?;
    cache.push(((b, hb), v));
    Ok(v)
}

/// Resize `buf` to `len` without re-zeroing retained capacity — callers
/// overwrite every element they read (gather_into zero-fills its region,
/// padded tails are filled explicitly).
fn fit_buf(buf: &mut Vec<f32>, len: usize) {
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
}

/// Append freshly produced K/V (`[slots, s, hb, hd]`) for real items:
/// rows are copied straight from the output literal's buffer into the
/// paged pool (strided source, no per-head temporaries). With `sub`,
/// slot `si` holds item `sub[si]`; otherwise slot `i` holds item `i`.
#[allow(clippy::too_many_arguments)]
fn append_new_kv(
    kv: &mut KvStore,
    pool: PoolId,
    k: &[f32],
    v: &[f32],
    items: &[FwdItem],
    sub: Option<&[usize]>,
    s: usize,
    hb: usize,
    hd: usize,
    rank: RankId,
) {
    let src_stride = hb * hd;
    let mut push = |slot: usize, it: &FwdItem| {
        if it.n_tokens == 0 {
            return;
        }
        let base = slot * s * src_stride;
        kv.append_group(it.req, pool, rank, it.n_tokens, &k[base..], &v[base..], src_stride);
    };
    match sub {
        None => {
            for (i, it) in items.iter().enumerate() {
                push(i, it);
            }
        }
        Some(idx) => {
            for (si, &i) in idx.iter().enumerate() {
                push(si, &items[i]);
            }
        }
    }
}

impl ServingBackend for Engine {
    fn submit_with(&mut self, prompt: &[u32], opts: SubmitOptions) -> Result<RequestId> {
        Engine::submit_with(self, prompt, opts)
    }

    fn step(&mut self) -> Result<Vec<EngineEvent>> {
        Engine::step(self)
    }

    fn abort(&mut self, id: RequestId) -> Result<()> {
        Engine::abort(self, id)
    }

    fn inject_failure(&mut self, rank: RankId, method: RecoveryMethod) -> Result<f64> {
        Engine::inject_failure(self, rank, method)
    }

    fn inject_rejoin(&mut self, method: RecoveryMethod) -> Result<f64> {
        Engine::inject_rejoin(self, method)
    }

    fn inject_slowdown(&mut self, rank: RankId, factor: f64) -> Result<f64> {
        Engine::inject_slowdown(self, rank, factor)
    }

    fn world(&self) -> usize {
        Engine::world(self)
    }

    fn effective_capacity(&self) -> f64 {
        Engine::effective_capacity(self)
    }

    fn now(&self) -> SimTime {
        Engine::now(self)
    }

    fn is_idle(&self) -> bool {
        Engine::is_idle(self)
    }

    fn report(&self) -> ServeReport {
        Engine::report(self)
    }

    fn set_observer(&mut self, observer: Box<dyn Observer>) {
        Engine::set_observer(self, observer)
    }

    fn set_obs_replica(&mut self, replica: usize) {
        Engine::set_obs_replica(self, replica)
    }

    fn run_to_completion(&mut self) -> Result<ServeReport> {
        Engine::run_to_completion(self)
    }
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as u32
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Additive mask `[slots, 1, s, c+s]` for a padded batch, written into
/// the reused workspace buffer. With `sub`, slot `si` masks item
/// `sub[si]`; otherwise slot `i` masks item `i`.
fn build_mask_into(
    m: &mut Vec<f32>,
    items: &[FwdItem],
    sub: Option<&[usize]>,
    slots: usize,
    s: usize,
    c: usize,
) {
    let w = c + s;
    m.clear();
    m.resize(slots * s * w, -1e9);
    let n_real = sub.map(|x| x.len()).unwrap_or(items.len());
    for slot in 0..n_real {
        let it = &items[sub.map(|x| x[slot]).unwrap_or(slot)];
        let real = it.n_tokens;
        for q in 0..real {
            let row = (slot * s + q) * w;
            for t in 0..it.ctx.min(c) {
                m[row + t] = 0.0; // cached positions
            }
            for t in 0..=q {
                m[row + c + t] = 0.0; // causal over the chunk
            }
        }
        // Padded query rows: self only (keeps softmax well-conditioned;
        // outputs and KV of padded rows are discarded).
        for q in real..s {
            m[(slot * s + q) * w + c + q] = 0.0;
        }
    }
    for slot in n_real..slots {
        for q in 0..s {
            m[(slot * s + q) * w + c + q] = 0.0;
        }
    }
}
