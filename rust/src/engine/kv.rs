//! Engine KV store: contiguous paged per-(layer, head-group) pools with
//! rank tags, host backup mirroring, and failure wipes.
//!
//! All data physically lives in host memory (the engine runs on CPU-PJRT),
//! but every (request, layer, head) lane carries the rank whose simulated
//! HBM holds it. A device failure deletes exactly the lanes tagged with
//! that rank — recovery must then restore them from the backup mirror
//! (FailSafe) or re-prefill (the baseline), and the continuation is
//! checked bit-exact in tests.
//!
//! # Hot-path layout
//!
//! KV is stored in **pools**, one per interned `(layer, head-group)` pair
//! (a head group is the exact head list one rank's attention shard gathers
//! — `AttnWeights::heads`). A pool is a pair of arenas (`k`, `v`) carved
//! into fixed-size blocks of [`BLOCK_TOKENS`] rows; each row is one
//! token's `heads.len() × head_dim` floats, i.e. exactly the inner
//! `[hb, hd]` slice of the XLA attention literal `[c, hb, hd]`. A request
//! holds a block list per pool (a [`Run`]), so:
//!
//! * `tokens()` is O(1) — an indexed counter, never a scan;
//! * [`KvStore::gather_into`] is block-indexed `copy_from_slice` into the
//!   caller's reused padded buffer (whole-block copies when the head
//!   bucket equals the group size);
//! * [`KvStore::append_group`] copies rows straight out of the forward
//!   pass's output literal (strided source) into pool blocks — no
//!   per-head temporaries;
//! * finished requests return their blocks to the pool free lists, so the
//!   decode loop allocates nothing from the global heap at steady state.
//!
//! Reconfiguration (failure shrink / rejoin expand) changes the head
//! grouping; [`KvStore::relayout`] re-buckets resident data into the new
//! epoch's canonical pools (the host-side analogue of the KV re-spread
//! whose simulated NVLink cost the recovery planner accounts).
//!
//! # Invariant
//!
//! Within one run, every present lane has the same token count at append
//! time. The engine maintains this by construction: the failure dance is
//! always `wipe → restore → truncate` (ending with all lanes equal)
//! before decoding resumes. Reviving a wiped lane by appending at a
//! nonzero offset is a caller bug (debug-asserted).

use std::collections::HashMap;

use crate::kvcache::KvPlacement;
use crate::sharding::ShardPlan;
use crate::{HeadId, LayerId, RankId, RequestId};

/// Tokens per paged KV block.
pub const BLOCK_TOKENS: usize = 16;

/// Handle to one interned (layer, head-group) pool — resolve once per
/// epoch with [`KvStore::pool_handle`], then use on the hot path.
pub type PoolId = u32;

/// Source identity of one lane of one relayouted block: `(old pool, old
/// lane index, old block id, rows copied)`, `None` for an absent lane.
/// Two target blocks with identical signatures copy the very same
/// physical rows — relayout shares them instead of duplicating.
type LaneSrc = Option<(PoolId, u32, u32, u32)>;

/// One `relayout()` pass's signature → new-block memo, per target pool.
type RelayoutMemo = HashMap<(PoolId, Vec<LaneSrc>), u32>;

/// One paged pool: K and V arenas for one (layer, head-group).
#[derive(Debug, Default)]
struct Pool {
    layer: LayerId,
    /// Lane order of heads interleaved in each token row.
    heads: Vec<HeadId>,
    /// `heads.len() * head_dim` — one token row.
    stride: usize,
    /// `BLOCK_TOKENS * stride`.
    block_elems: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free block indices; popped from the back (descending push order,
    /// so the lowest id is reused first — deterministic).
    free: Vec<u32>,
    /// Per-block reference count, parallel to the arena. 1 for a private
    /// block, >1 for a block shared copy-on-write between runs and/or the
    /// prefix trie, 0 exactly when the block is on the free list.
    refs: Vec<u32>,
    n_blocks: u32,
}

impl Pool {
    fn alloc_block(&mut self) -> u32 {
        if let Some(b) = self.free.pop() {
            debug_assert_eq!(self.refs[b as usize], 0, "free-list block {b} still referenced");
            self.refs[b as usize] = 1;
            return b;
        }
        let b = self.n_blocks;
        self.n_blocks += 1;
        self.k.resize(self.n_blocks as usize * self.block_elems, 0.0);
        self.v.resize(self.n_blocks as usize * self.block_elems, 0.0);
        self.refs.push(1);
        b
    }

    /// Add one reference to an already-live block (prefix sharing).
    fn retain_block(&mut self, b: u32) {
        debug_assert!(self.refs[b as usize] > 0, "retaining freed block {b}");
        self.refs[b as usize] += 1;
    }

    fn buf(&self, want_v: bool) -> &[f32] {
        if want_v {
            &self.v
        } else {
            &self.k
        }
    }

    /// Arena offset of token row `t` of a run with the given block list.
    fn row_offset(&self, blocks: &[u32], t: usize) -> usize {
        blocks[t / BLOCK_TOKENS] as usize * self.block_elems + (t % BLOCK_TOKENS) * self.stride
    }

    /// Drop one reference per block in `blocks`; blocks whose count hits
    /// zero return to the free list in descending id order — within one
    /// freed batch the lowest id is reused first, so reuse order is a
    /// deterministic function of the alloc/free/share history. Blocks
    /// still referenced elsewhere (a sharing run, the prefix trie) stay
    /// live and keep their data.
    fn free_blocks(&mut self, blocks: &mut Vec<u32>) {
        let mut dead: Vec<u32> = Vec::new();
        for &b in blocks.iter() {
            let r = &mut self.refs[b as usize];
            debug_assert!(*r > 0, "double-free of block {b}");
            *r -= 1;
            if *r == 0 {
                debug_assert!(
                    !self.free.contains(&b),
                    "freed block {b} already on the free list"
                );
                dead.push(b);
            }
        }
        blocks.clear();
        dead.sort_unstable_by(|a, b| b.cmp(a));
        self.free.append(&mut dead);
    }
}

/// Per-(request, head-lane) state: the rank tag and valid token prefix.
#[derive(Debug, Clone, Copy)]
struct Lane {
    rank: RankId,
    tokens: usize,
    /// False after a wipe: the head has no resident KV (gathers read
    /// zeros; `restore_request` re-fills it). Distinct from `tokens == 0`
    /// — a truncated-to-zero lane still *exists* and is not restored.
    present: bool,
}

const ABSENT: Lane = Lane { rank: 0, tokens: 0, present: false };

/// One request's block list in one pool.
#[derive(Debug)]
struct Run {
    pool: PoolId,
    /// Parallel to the pool's `heads`.
    lanes: Vec<Lane>,
    blocks: Vec<u32>,
    /// Physical rows written (the high-water mark of lane tokens).
    rows: usize,
}

/// One request's resident KV: runs sorted by pool id.
#[derive(Debug, Default)]
struct ReqKv {
    /// Max tokens over layer-0 lanes — the O(1) `tokens()` index.
    tokens: usize,
    runs: Vec<Run>,
}

impl ReqKv {
    fn run_mut(&mut self, pool: PoolId, n_lanes: usize) -> &mut Run {
        let i = match self.runs.binary_search_by_key(&pool, |r| r.pool) {
            Ok(i) => i,
            Err(i) => {
                self.runs.insert(
                    i,
                    Run { pool, lanes: vec![ABSENT; n_lanes], blocks: Vec::new(), rows: 0 },
                );
                i
            }
        };
        &mut self.runs[i]
    }

    fn run(&self, pool: PoolId) -> Option<&Run> {
        self.runs.binary_search_by_key(&pool, |r| r.pool).ok().map(|i| &self.runs[i])
    }
}

/// Host-DRAM mirror of one request's KV in one pool grouping: contiguous
/// `[rows, stride]` token-prefix copies (proactive backup §3.2).
#[derive(Debug)]
struct BackupRun {
    pool: PoolId,
    lane_tokens: Vec<usize>,
    rows: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

#[derive(Debug, Default)]
struct ReqBackup {
    /// Max tokens over layer-0 lanes — O(1) `backed_tokens()`.
    tokens: usize,
    runs: Vec<BackupRun>,
}

/// The engine's KV state. See module docs for the paged layout.
#[derive(Debug, Default)]
pub struct KvStore {
    head_dim: usize,
    pools: Vec<Pool>,
    pool_ids: HashMap<(LayerId, Vec<HeadId>), PoolId>,
    reqs: HashMap<RequestId, ReqKv>,
    backup: HashMap<RequestId, ReqBackup>,
}

impl KvStore {
    pub fn new(head_dim: usize) -> Self {
        KvStore { head_dim, ..Default::default() }
    }

    /// Intern the pool for `(layer, heads)` and return its stable handle.
    /// Cold path — call once per epoch per shard group, not per step.
    pub fn pool_handle(&mut self, layer: LayerId, heads: &[HeadId]) -> PoolId {
        if let Some(&id) = self.pool_ids.get(&(layer, heads.to_vec())) {
            return id;
        }
        let stride = heads.len() * self.head_dim;
        let id = self.pools.len() as PoolId;
        self.pools.push(Pool {
            layer,
            heads: heads.to_vec(),
            stride,
            block_elems: BLOCK_TOKENS * stride,
            ..Default::default()
        });
        self.pool_ids.insert((layer, heads.to_vec()), id);
        id
    }

    /// Tokens cached for `req` (layer 0, any head — all heads agree).
    /// O(1): reads the per-request index maintained by every mutation.
    pub fn tokens(&self, req: RequestId) -> usize {
        self.reqs.get(&req).map(|r| r.tokens).unwrap_or(0)
    }

    /// Append `n_new` token rows for `req` into `pool`, held by `rank`.
    /// Source row `r` is `src[r*src_stride .. r*src_stride + stride]` —
    /// i.e. KV can be copied straight out of a padded `[b, s, hb, hd]`
    /// forward output with `src_stride = hb*hd`, no per-head temporaries.
    pub fn append_group(
        &mut self,
        req: RequestId,
        pool: PoolId,
        rank: RankId,
        n_new: usize,
        k_src: &[f32],
        v_src: &[f32],
        src_stride: usize,
    ) {
        if n_new == 0 {
            return;
        }
        let p = &mut self.pools[pool as usize];
        let stride = p.stride;
        debug_assert!(src_stride >= stride, "source rows narrower than the pool group");
        let entry = self.reqs.entry(req).or_default();
        let run = entry.run_mut(pool, p.heads.len());
        // Copy-on-write split: appending into a partially-filled tail
        // block that another holder (a sharing run, the prefix trie) still
        // references must not mutate the sharers' view. Full shared blocks
        // are never written (appends start at `rows`), so the partial tail
        // is the only divergence point.
        let filled = run.rows % BLOCK_TOKENS;
        if filled != 0 {
            let bi = run.rows / BLOCK_TOKENS;
            let old = run.blocks[bi];
            if p.refs[old as usize] > 1 {
                let fresh = p.alloc_block();
                let s0 = old as usize * p.block_elems;
                let d0 = fresh as usize * p.block_elems;
                p.k.copy_within(s0..s0 + filled * stride, d0);
                p.v.copy_within(s0..s0 + filled * stride, d0);
                p.refs[old as usize] -= 1;
                run.blocks[bi] = fresh;
            }
        }
        let need = (run.rows + n_new).div_ceil(BLOCK_TOKENS);
        while run.blocks.len() < need {
            run.blocks.push(p.alloc_block());
        }
        let mut r = 0;
        while r < n_new {
            let t = run.rows + r;
            let in_block = (BLOCK_TOKENS - t % BLOCK_TOKENS).min(n_new - r);
            let dst = p.row_offset(&run.blocks, t);
            if src_stride == stride {
                // Contiguous source (exact-width rows): whole-chunk copy.
                let src = r * stride..(r + in_block) * stride;
                p.k[dst..dst + in_block * stride].copy_from_slice(&k_src[src.clone()]);
                p.v[dst..dst + in_block * stride].copy_from_slice(&v_src[src]);
            } else {
                for j in 0..in_block {
                    let s0 = (r + j) * src_stride;
                    let d0 = dst + j * stride;
                    p.k[d0..d0 + stride].copy_from_slice(&k_src[s0..s0 + stride]);
                    p.v[d0..d0 + stride].copy_from_slice(&v_src[s0..s0 + stride]);
                }
            }
            r += in_block;
        }
        let rows = run.rows;
        for lane in run.lanes.iter_mut() {
            debug_assert!(
                !lane.present || lane.tokens == rows,
                "non-uniform lanes at append (tokens {} vs rows {rows})",
                lane.tokens,
            );
            debug_assert!(lane.present || rows == 0, "appending to a wiped lane mid-stream");
            *lane = Lane { rank, tokens: rows + n_new, present: true };
        }
        run.rows = rows + n_new;
        if p.layer == 0 {
            entry.tokens = entry.tokens.max(rows + n_new);
        }
    }

    /// Append `s` new tokens of K/V for (req, layer, head), held by
    /// `rank` — the single-head compatibility surface over
    /// [`KvStore::append_group`].
    pub fn append(
        &mut self,
        req: RequestId,
        layer: LayerId,
        head: HeadId,
        rank: RankId,
        k_new: &[f32],
        v_new: &[f32],
    ) {
        debug_assert_eq!(k_new.len(), v_new.len());
        debug_assert_eq!(k_new.len() % self.head_dim, 0);
        let pool = self.pool_handle(layer, &[head]);
        let n = k_new.len() / self.head_dim;
        self.append_group(req, pool, rank, n, k_new, v_new, self.head_dim);
    }

    // ---------------------------------------------------- prefix sharing --
    //
    // Sharing is at whole-block granularity: the prefix trie caches, per
    // trie node (one BLOCK_TOKENS-token chunk), the physical block that
    // chunk occupies in every pool, holding one reference on each. A new
    // request with a warm prefix *adopts* those blocks (one more reference
    // each) instead of re-prefilling; the first divergent append into a
    // partially-used shared block CoW-splits it (see `append_group`).

    /// Block ids covering `req`'s first `n_blocks` full blocks in `pool`,
    /// for registration into the prefix trie. `None` unless every lane is
    /// present over the covered rows (mid-recovery runs don't donate).
    pub fn prefix_blocks(&self, req: RequestId, pool: PoolId, n_blocks: usize) -> Option<Vec<u32>> {
        let run = self.reqs.get(&req)?.run(pool)?;
        let covered = n_blocks * BLOCK_TOKENS;
        if run.blocks.len() < n_blocks || run.rows < covered {
            return None;
        }
        if run.lanes.iter().any(|l| !l.present || l.tokens < covered) {
            return None;
        }
        Some(run.blocks[..n_blocks].to_vec())
    }

    /// Add one external reference to each of `blocks` in `pool` (the
    /// prefix trie pinning a donor's chunk blocks).
    pub fn retain_blocks(&mut self, pool: PoolId, blocks: &[u32]) {
        let p = &mut self.pools[pool as usize];
        for &b in blocks {
            p.retain_block(b);
        }
    }

    /// Drop one external reference from each of `blocks` in `pool`; blocks
    /// nobody else references return to the free list.
    pub fn release_external(&mut self, pool: PoolId, blocks: &[u32]) {
        let mut v = blocks.to_vec();
        self.pools[pool as usize].free_blocks(&mut v);
    }

    /// Seed `req`'s (empty) run in `pool` with shared `blocks` covering its
    /// first `tokens` tokens, every lane present and held by `rank` — the
    /// admission-time warm-prefix adoption: zero prefill FLOPs and zero new
    /// KV blocks for the covered tokens. `tokens` may end inside the last
    /// block (a full-prompt hit keeps the final token for recompute); the
    /// first append then CoW-splits that block.
    pub fn adopt_blocks(
        &mut self,
        req: RequestId,
        pool: PoolId,
        rank: RankId,
        blocks: &[u32],
        tokens: usize,
    ) {
        if blocks.is_empty() {
            return;
        }
        debug_assert!(tokens <= blocks.len() * BLOCK_TOKENS, "adopted tokens exceed blocks");
        debug_assert!(tokens > (blocks.len() - 1) * BLOCK_TOKENS, "trailing adopted block unused");
        let p = &mut self.pools[pool as usize];
        for &b in blocks {
            p.retain_block(b);
        }
        let n_lanes = p.heads.len();
        let layer = p.layer;
        let entry = self.reqs.entry(req).or_default();
        let run = entry.run_mut(pool, n_lanes);
        debug_assert!(run.blocks.is_empty() && run.rows == 0, "adopting into a non-empty run");
        run.blocks.extend_from_slice(blocks);
        run.rows = tokens;
        for lane in run.lanes.iter_mut() {
            *lane = Lane { rank, tokens, present: true };
        }
        if layer == 0 {
            entry.tokens = entry.tokens.max(tokens);
        }
    }

    /// Swap `req`'s first `blocks.len()` blocks in `pool` for the given
    /// shared blocks, dropping the private copies. The caller guarantees
    /// the contents are bit-identical (both sides restored from mirrors of
    /// the same prefix rows) — recovery uses this to re-deduplicate
    /// prefixes that a wipe → restore cycle materialized privately.
    /// Returns false (and does nothing) unless the run fully covers the
    /// swapped blocks with uniformly present lanes.
    pub fn switch_to_shared(&mut self, req: RequestId, pool: PoolId, blocks: &[u32]) -> bool {
        let Some(entry) = self.reqs.get_mut(&req) else { return false };
        let Ok(i) = entry.runs.binary_search_by_key(&pool, |r| r.pool) else { return false };
        let run = &mut entry.runs[i];
        let covered = blocks.len() * BLOCK_TOKENS;
        if run.blocks.len() < blocks.len() || run.rows < covered {
            return false;
        }
        if run.lanes.iter().any(|l| !l.present || l.tokens < covered) {
            return false;
        }
        if &run.blocks[..blocks.len()] == blocks {
            return true; // already the shared copies (the donor itself)
        }
        let p = &mut self.pools[pool as usize];
        for &b in blocks {
            p.retain_block(b);
        }
        let mut old: Vec<u32> = run.blocks[..blocks.len()].to_vec();
        run.blocks[..blocks.len()].copy_from_slice(blocks);
        p.free_blocks(&mut old);
        true
    }

    /// Physically resident KV bytes across all pools — shared blocks
    /// counted **once**. Contrast [`KvStore::bytes_by_rank`], the logical
    /// per-lane accounting in which every sharer claims its prefix.
    pub fn resident_bytes(&self) -> usize {
        self.pools
            .iter()
            .map(|p| {
                let live = p.n_blocks as usize - p.free.len();
                live * p.block_elems * 2 * 4 // K + V arenas, f32
            })
            .sum()
    }

    /// Live blocks referenced by more than one holder (sharing in effect).
    pub fn shared_block_count(&self) -> usize {
        self.pools.iter().flat_map(|p| p.refs.iter()).filter(|&&r| r > 1).count()
    }

    /// True when every pool's blocks are back on its free list — the
    /// refcount-drain invariant checked at the end of property runs.
    pub fn drained(&self) -> bool {
        self.pools.iter().all(|p| p.free.len() == p.n_blocks as usize)
    }

    /// Gather the K (or V) cache of `req` in `pool` into `out`, zero-padded
    /// to `[c_bucket, h_bucket, head_dim]` row-major — the hot path behind
    /// the engine's batched KV literals. `out` is the caller's reused
    /// buffer; it is fully overwritten (zero-filled then block-copied).
    pub fn gather_into(
        &self,
        req: RequestId,
        pool: PoolId,
        c_bucket: usize,
        h_bucket: usize,
        want_v: bool,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let p = &self.pools[pool as usize];
        debug_assert_eq!(out.len(), c_bucket * h_bucket * hd);
        debug_assert!(p.stride <= h_bucket * hd, "head bucket below the pool group size");
        out.fill(0.0);
        let Some(run) = self.reqs.get(&req).and_then(|e| e.run(pool)) else { return };
        let src = p.buf(want_v);
        let stride = p.stride;
        let row_out = h_bucket * hd;
        if run.lanes.iter().all(|l| l.present && l.tokens == run.rows) {
            // Uniform lanes: bulk block-indexed copies.
            let n = run.rows.min(c_bucket);
            let mut t = 0;
            while t < n {
                let in_block = (BLOCK_TOKENS - t % BLOCK_TOKENS).min(n - t);
                let base = p.row_offset(&run.blocks, t);
                if stride == row_out {
                    out[t * stride..(t + in_block) * stride]
                        .copy_from_slice(&src[base..base + in_block * stride]);
                } else {
                    for j in 0..in_block {
                        let o = (t + j) * row_out;
                        let b0 = base + j * stride;
                        out[o..o + stride].copy_from_slice(&src[b0..b0 + stride]);
                    }
                }
                t += in_block;
            }
        } else {
            // Mixed lanes (mid-recovery): per-lane prefix copies.
            for (li, lane) in run.lanes.iter().enumerate() {
                if !lane.present {
                    continue;
                }
                for t in 0..lane.tokens.min(c_bucket) {
                    let o = (t * h_bucket + li) * hd;
                    let b0 = p.row_offset(&run.blocks, t) + li * hd;
                    out[o..o + hd].copy_from_slice(&src[b0..b0 + hd]);
                }
            }
        }
    }

    /// Gather by explicit head list, zero-padded to `(c_bucket, h_bucket)`:
    /// output `[c_bucket, h_bucket, head_dim]` row-major. General path —
    /// works for any head subset regardless of pool grouping (each head
    /// must live in at most one run per layer).
    pub fn gather(
        &self,
        req: RequestId,
        layer: LayerId,
        heads: &[HeadId],
        c_bucket: usize,
        h_bucket: usize,
        want_v: bool,
    ) -> Vec<f32> {
        let hd = self.head_dim;
        let mut out = vec![0.0f32; c_bucket * h_bucket * hd];
        let Some(entry) = self.reqs.get(&req) else { return out };
        for (hi, &h) in heads.iter().enumerate() {
            let Some((run, li)) = self.find_lane(entry, layer, h) else { continue };
            let lane = run.lanes[li];
            if !lane.present {
                continue;
            }
            let p = &self.pools[run.pool as usize];
            let src = p.buf(want_v);
            for t in 0..lane.tokens.min(c_bucket) {
                let o = (t * h_bucket + hi) * hd;
                let b0 = p.row_offset(&run.blocks, t) + li * hd;
                out[o..o + hd].copy_from_slice(&src[b0..b0 + hd]);
            }
        }
        out
    }

    fn find_lane<'a>(
        &self,
        entry: &'a ReqKv,
        layer: LayerId,
        head: HeadId,
    ) -> Option<(&'a Run, usize)> {
        for run in &entry.runs {
            let p = &self.pools[run.pool as usize];
            if p.layer == layer {
                if let Some(li) = p.heads.iter().position(|&h| h == head) {
                    return Some((run, li));
                }
            }
        }
        None
    }

    /// Mirror `req`'s resident KV into the host backup (write-behind
    /// pass). Incremental: only rows beyond the already-mirrored prefix
    /// are copied, so the per-step cost is O(new tokens), not O(context).
    pub fn backup_request(&mut self, req: RequestId) {
        let KvStore { head_dim, pools, reqs, backup, .. } = self;
        let hd = *head_dim;
        let Some(entry) = reqs.get(&req) else { return };
        let b = backup.entry(req).or_default();
        for run in &entry.runs {
            let p = &pools[run.pool as usize];
            let stride = p.stride;
            let bi = match b.runs.binary_search_by_key(&run.pool, |r| r.pool) {
                Ok(i) => i,
                Err(i) => {
                    b.runs.insert(
                        i,
                        BackupRun {
                            pool: run.pool,
                            lane_tokens: vec![0; p.heads.len()],
                            rows: 0,
                            k: Vec::new(),
                            v: Vec::new(),
                        },
                    );
                    i
                }
            };
            let br = &mut b.runs[bi];
            let run_uniform = run.lanes.iter().all(|l| l.present && l.tokens == run.rows);
            let br_uniform = br.lane_tokens.iter().all(|&t| t == br.rows);
            if run_uniform && br_uniform {
                // Hot path: everything is a clean token prefix. Mirror
                // only the delta rows (bulk, block-indexed); a truncated
                // device prefix re-mirrors from scratch (cold, and safe —
                // no absent lane still references the old buffer).
                if br.rows > run.rows {
                    br.k.clear();
                    br.v.clear();
                    br.rows = 0;
                }
                let mut t = br.rows;
                while t < run.rows {
                    let in_block = (BLOCK_TOKENS - t % BLOCK_TOKENS).min(run.rows - t);
                    let base = p.row_offset(&run.blocks, t);
                    br.k.extend_from_slice(&p.k[base..base + in_block * stride]);
                    br.v.extend_from_slice(&p.v[base..base + in_block * stride]);
                    t += in_block;
                }
                br.rows = run.rows;
                br.lane_tokens.fill(run.rows);
            } else {
                // Mixed lanes (mid-recovery): refresh present lanes
                // column-wise, preserving absent lanes' older backup —
                // per-head mirrors are independent, exactly like the old
                // per-slice store.
                let rows = br.rows.max(run.rows);
                br.k.resize(rows * stride, 0.0);
                br.v.resize(rows * stride, 0.0);
                br.rows = rows;
                for (li, lane) in run.lanes.iter().enumerate() {
                    if !lane.present {
                        continue;
                    }
                    for t in 0..lane.tokens {
                        let s0 = p.row_offset(&run.blocks, t) + li * hd;
                        let d0 = t * stride + li * hd;
                        br.k[d0..d0 + hd].copy_from_slice(&p.k[s0..s0 + hd]);
                        br.v[d0..d0 + hd].copy_from_slice(&p.v[s0..s0 + hd]);
                    }
                    br.lane_tokens[li] = lane.tokens;
                }
            }
        }
        b.tokens = b
            .runs
            .iter()
            .filter(|r| pools[r.pool as usize].layer == 0)
            .flat_map(|r| r.lane_tokens.iter().copied())
            .max()
            .unwrap_or(0);
    }

    /// Tokens covered by backup for `req` (layer 0). O(1).
    pub fn backed_tokens(&self, req: RequestId) -> usize {
        self.backup.get(&req).map(|b| b.tokens).unwrap_or(0)
    }

    /// Hard failure of `rank`: drop every lane its HBM held (whole-group
    /// losses return their blocks to the pool). Returns the affected
    /// request ids (sorted, deduped).
    pub fn wipe_rank(&mut self, rank: RankId) -> Vec<RequestId> {
        let KvStore { pools, reqs, .. } = self;
        let mut lost: Vec<RequestId> = Vec::new();
        for (id, entry) in reqs.iter_mut() {
            let mut hit = false;
            for run in entry.runs.iter_mut() {
                for lane in run.lanes.iter_mut() {
                    if lane.present && lane.rank == rank {
                        *lane = ABSENT;
                        hit = true;
                    }
                }
                if run.lanes.iter().all(|l| !l.present) && !run.blocks.is_empty() {
                    pools[run.pool as usize].free_blocks(&mut run.blocks);
                    run.rows = 0;
                }
            }
            if hit {
                entry.tokens = layer0_max(pools, &entry.runs);
                lost.push(*id);
            }
        }
        lost.sort_unstable();
        lost.dedup();
        lost
    }

    /// Restore `req`'s missing lanes from backup, re-tagging by the new
    /// placement (`home` = new home rank). Returns restored token count,
    /// or 0 if no backup exists. May write lane columns into blocks still
    /// shared with other runs: the written rows are bit-identical by
    /// construction (the backup mirrors those very rows), so sharers'
    /// views are unaffected and sharing survives the restore.
    pub fn restore_request(
        &mut self,
        req: RequestId,
        placement: &KvPlacement,
        home: RankId,
    ) -> usize {
        let KvStore { head_dim, pools, reqs, backup, .. } = self;
        let hd = *head_dim;
        let Some(b) = backup.get(&req) else { return 0 };
        let entry = reqs.entry(req).or_default();
        let mut restored = 0;
        for br in &b.runs {
            let p = &mut pools[br.pool as usize];
            let run = entry.run_mut(br.pool, p.heads.len());
            let stride = p.stride;
            for (li, &bt) in br.lane_tokens.iter().enumerate() {
                if bt == 0 || run.lanes[li].present {
                    continue; // only missing lanes are restored
                }
                let need = bt.div_ceil(BLOCK_TOKENS);
                while run.blocks.len() < need {
                    run.blocks.push(p.alloc_block());
                }
                for t in 0..bt {
                    let d0 = p.row_offset(&run.blocks, t) + li * hd;
                    let s0 = t * stride + li * hd;
                    p.k[d0..d0 + hd].copy_from_slice(&br.k[s0..s0 + hd]);
                    p.v[d0..d0 + hd].copy_from_slice(&br.v[s0..s0 + hd]);
                }
                let head = p.heads[li];
                run.lanes[li] = Lane {
                    rank: placement.rank_for(p.layer, head, home),
                    tokens: bt,
                    present: true,
                };
                run.rows = run.rows.max(bt);
                restored = restored.max(bt);
            }
        }
        entry.tokens = layer0_max(pools, &entry.runs);
        restored
    }

    /// Swap `req` out to the host tier: complete its backup mirror (so
    /// the mirror is authoritative for every resident row), then release
    /// its device blocks and mark every lane absent. Returns the token
    /// count that was resident (0 for an unknown request).
    ///
    /// Refcount-safe by construction: [`Pool::free_blocks`] only
    /// *decrements* a shared block's refcount — a block another request
    /// still shares stays allocated and bit-identical for the sharer;
    /// only this request's reference is dropped. The swapped request
    /// itself resumes from the mirror via [`KvStore::swap_in`], so no
    /// shared data is ever lost to a swap.
    pub fn swap_out(&mut self, req: RequestId) -> usize {
        let resident = self.tokens(req);
        if resident == 0 && !self.reqs.contains_key(&req) {
            return 0;
        }
        self.backup_request(req);
        let KvStore { pools, reqs, .. } = self;
        let Some(entry) = reqs.get_mut(&req) else { return 0 };
        for run in entry.runs.iter_mut() {
            pools[run.pool as usize].free_blocks(&mut run.blocks);
            run.rows = 0;
            for lane in run.lanes.iter_mut() {
                *lane = ABSENT;
            }
        }
        entry.tokens = 0;
        resident
    }

    /// Swap `req` back onto the device from the host mirror — the exact
    /// restore path recovery uses ([`KvStore::restore_request`]), so the
    /// rows that come back are bit-identical to what [`KvStore::swap_out`]
    /// released and no recompute is needed. Freshly allocated blocks are
    /// private: a previously shared prefix re-deduplicates on the next
    /// `switch_to_shared`, exactly as after a failure recovery. Returns
    /// the restored token count.
    pub fn swap_in(&mut self, req: RequestId, placement: &KvPlacement, home: RankId) -> usize {
        self.restore_request(req, placement, home)
    }

    /// True when `req` lives only in the host tier: backup rows exist but
    /// nothing is resident on device.
    pub fn swapped_out(&self, req: RequestId) -> bool {
        self.backed_tokens(req) > 0 && self.tokens(req) == 0
    }

    /// Truncate every lane of `req` to `tokens` (used when restore lags
    /// behind the newest decode tokens — the lag gets recomputed). Tail
    /// blocks return to their pools.
    pub fn truncate(&mut self, req: RequestId, tokens: usize) {
        let KvStore { pools, reqs, .. } = self;
        let Some(entry) = reqs.get_mut(&req) else { return };
        for run in entry.runs.iter_mut() {
            for lane in run.lanes.iter_mut() {
                if lane.present && lane.tokens > tokens {
                    lane.tokens = tokens;
                }
            }
            if run.rows > tokens {
                run.rows = tokens;
                let mut tail = run.blocks.split_off(tokens.div_ceil(BLOCK_TOKENS));
                pools[run.pool as usize].free_blocks(&mut tail);
            }
        }
        entry.tokens = layer0_max(pools, &entry.runs);
    }

    /// Re-tag every lane of the requests in `homes` (request → home rank)
    /// to the rank `placement` assigns it, in one pass over the store —
    /// the KV re-spread of an expand-reconfiguration (GPU rejoin). Data
    /// stays put; the simulated NVLink move onto the new owners is costed
    /// by the rejoin latency model.
    pub fn retag_requests(&mut self, placement: &KvPlacement, homes: &HashMap<RequestId, RankId>) {
        let KvStore { pools, reqs, .. } = self;
        for (id, entry) in reqs.iter_mut() {
            let Some(&home) = homes.get(id) else { continue };
            for run in entry.runs.iter_mut() {
                let p = &pools[run.pool as usize];
                for (li, lane) in run.lanes.iter_mut().enumerate() {
                    if lane.present {
                        lane.rank = placement.rank_for(p.layer, p.heads[li], home);
                    }
                }
            }
        }
    }

    /// Re-tag surviving lanes after a reconfiguration: a lane held by old
    /// rank `o` now belongs to `survivor_map[o]` (data stays put; the
    /// simulated transfer cost is accounted by the recovery planner).
    pub fn remap_ranks(&mut self, survivor_map: &[Option<RankId>]) {
        for entry in self.reqs.values_mut() {
            for run in entry.runs.iter_mut() {
                for lane in run.lanes.iter_mut() {
                    if lane.present {
                        if let Some(new_r) = survivor_map.get(lane.rank).copied().flatten() {
                            lane.rank = new_r;
                        }
                    }
                }
            }
        }
    }

    /// Re-bucket every request's resident KV (and its backup mirror) into
    /// `plan`'s canonical head groups, so post-reconfiguration gathers and
    /// appends run on the fast block path again. Lane tags, token counts,
    /// and presence are preserved exactly — this moves host bytes between
    /// pools, never changes what they mean. Block sharing is preserved:
    /// requests re-bucketing the same source rows (a shared prefix) end up
    /// referencing one new block, not N copies. External block references
    /// (the prefix trie's) must be released before calling — the trie is
    /// an epoch-scoped cache and is rebuilt after reconfiguration. Cold
    /// path (once per epoch).
    pub fn relayout(&mut self, plan: &ShardPlan) {
        let n_layers = plan.model.n_layers;
        let mut targets: Vec<Vec<PoolId>> = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            let lh = &plan.heads.layers[layer];
            let mut g = Vec::new();
            for rank in 0..plan.world() {
                let tp = lh.tp_heads_of(rank);
                if !tp.is_empty() {
                    g.push(self.pool_handle(layer, &tp));
                }
            }
            let dp = lh.dp_heads();
            if !dp.is_empty() {
                g.push(self.pool_handle(layer, &dp));
            }
            targets.push(g);
        }
        let mut ids: Vec<RequestId> = self.reqs.keys().copied().collect();
        ids.sort_unstable();
        let mut memo: RelayoutMemo = HashMap::new();
        for id in ids {
            self.relayout_device(id, &targets, &mut memo);
            self.relayout_backup(id, &targets);
        }
        self.shrink_unused_pools();
    }

    fn is_canonical(&self, runs: &[Run], targets: &[Vec<PoolId>]) -> bool {
        runs.iter().all(|r| {
            let layer = self.pools[r.pool as usize].layer;
            targets.get(layer).is_some_and(|g| g.contains(&r.pool))
        })
    }

    fn relayout_device(&mut self, id: RequestId, targets: &[Vec<PoolId>], memo: &mut RelayoutMemo) {
        match self.reqs.get(&id) {
            Some(e) if !self.is_canonical(&e.runs, targets) => {}
            _ => return,
        }
        let old = self.reqs.remove(&id).unwrap();
        let mut new_runs: Vec<Run> = Vec::new();
        let hd = self.head_dim;
        let mut stage_k: Vec<f32> = Vec::new();
        let mut stage_v: Vec<f32> = Vec::new();
        for (layer, group) in targets.iter().enumerate() {
            for &pid in group {
                let heads = self.pools[pid as usize].heads.clone();
                let mut lanes = vec![ABSENT; heads.len()];
                let mut srcs: Vec<Option<(usize, usize)>> = vec![None; heads.len()];
                let mut rows = 0;
                for (li, &h) in heads.iter().enumerate() {
                    for (ri, run) in old.runs.iter().enumerate() {
                        let p = &self.pools[run.pool as usize];
                        if p.layer != layer {
                            continue;
                        }
                        if let Some(oli) = p.heads.iter().position(|&x| x == h) {
                            let lane = run.lanes[oli];
                            if lane.present {
                                lanes[li] = lane;
                                rows = rows.max(lane.tokens);
                                srcs[li] = Some((ri, oli));
                            }
                            break;
                        }
                    }
                }
                if rows == 0 && lanes.iter().all(|l| !l.present) {
                    continue;
                }
                // Per-block copies, memoized on source identity: two
                // requests whose new block would copy the very same old
                // rows (a shared prefix chunk) get **one** new block with
                // two references — relayout preserves sharing instead of
                // materializing N private copies. Old and new layouts use
                // the same BLOCK_TOKENS alignment, so target block `bi`
                // reads exactly old block `bi` of each source lane.
                let n_blocks = rows.div_ceil(BLOCK_TOKENS);
                let mut blocks = Vec::with_capacity(n_blocks);
                for bi in 0..n_blocks {
                    let t0 = bi * BLOCK_TOKENS;
                    let t1 = rows.min(t0 + BLOCK_TOKENS);
                    let sig: Vec<LaneSrc> = srcs
                        .iter()
                        .enumerate()
                        .map(|(li, src)| {
                            let &(ri, oli) = src.as_ref()?;
                            let n = lanes[li].tokens.min(t1);
                            if n <= t0 {
                                return None;
                            }
                            let run = &old.runs[ri];
                            Some((run.pool, oli as u32, run.blocks[bi], (n - t0) as u32))
                        })
                        .collect();
                    if let Some(&shared) = memo.get(&(pid, sig.clone())) {
                        self.pools[pid as usize].retain_block(shared);
                        blocks.push(shared);
                        continue;
                    }
                    let fresh = self.pools[pid as usize].alloc_block();
                    for (li, src) in srcs.iter().enumerate() {
                        let Some(&(ri, oli)) = src.as_ref() else { continue };
                        let n = lanes[li].tokens.min(t1);
                        if n <= t0 {
                            continue;
                        }
                        // Stage the old lane rows, then write them into the
                        // new pool — decouples the two arena borrows.
                        let run = &old.runs[ri];
                        let op = &self.pools[run.pool as usize];
                        stage_k.clear();
                        stage_v.clear();
                        for t in t0..n {
                            let s0 = op.row_offset(&run.blocks, t) + oli * hd;
                            stage_k.extend_from_slice(&op.k[s0..s0 + hd]);
                            stage_v.extend_from_slice(&op.v[s0..s0 + hd]);
                        }
                        let np = &mut self.pools[pid as usize];
                        let base = fresh as usize * np.block_elems;
                        for (j, t) in (t0..n).enumerate() {
                            let d0 = base + (t % BLOCK_TOKENS) * np.stride + li * hd;
                            np.k[d0..d0 + hd].copy_from_slice(&stage_k[j * hd..(j + 1) * hd]);
                            np.v[d0..d0 + hd].copy_from_slice(&stage_v[j * hd..(j + 1) * hd]);
                        }
                    }
                    memo.insert((pid, sig), fresh);
                    blocks.push(fresh);
                }
                new_runs.push(Run { pool: pid, lanes, blocks, rows });
            }
        }
        for mut run in old.runs {
            self.pools[run.pool as usize].free_blocks(&mut run.blocks);
        }
        new_runs.sort_unstable_by_key(|r| r.pool);
        let tokens = layer0_max(&self.pools, &new_runs);
        self.reqs.insert(id, ReqKv { tokens, runs: new_runs });
    }

    fn relayout_backup(&mut self, id: RequestId, targets: &[Vec<PoolId>]) {
        let canonical = match self.backup.get(&id) {
            Some(b) => b.runs.iter().all(|r| {
                let layer = self.pools[r.pool as usize].layer;
                targets.get(layer).is_some_and(|g| g.contains(&r.pool))
            }),
            None => return,
        };
        if canonical {
            return;
        }
        let old = self.backup.remove(&id).unwrap();
        let hd = self.head_dim;
        let mut new_runs: Vec<BackupRun> = Vec::new();
        for (layer, group) in targets.iter().enumerate() {
            for &pid in group {
                let heads = self.pools[pid as usize].heads.clone();
                let stride = self.pools[pid as usize].stride;
                let mut lane_tokens = vec![0usize; heads.len()];
                let mut srcs: Vec<Option<(usize, usize)>> = vec![None; heads.len()];
                let mut rows = 0;
                for (li, &h) in heads.iter().enumerate() {
                    for (ri, br) in old.runs.iter().enumerate() {
                        let p = &self.pools[br.pool as usize];
                        if p.layer != layer {
                            continue;
                        }
                        if let Some(oli) = p.heads.iter().position(|&x| x == h) {
                            lane_tokens[li] = br.lane_tokens[oli];
                            rows = rows.max(br.lane_tokens[oli]);
                            srcs[li] = Some((ri, oli));
                            break;
                        }
                    }
                }
                if rows == 0 {
                    continue;
                }
                let mut k = vec![0.0f32; rows * stride];
                let mut v = vec![0.0f32; rows * stride];
                for (li, src) in srcs.iter().enumerate() {
                    let Some(&(ri, oli)) = src.as_ref() else { continue };
                    let br = &old.runs[ri];
                    let ostride = self.pools[br.pool as usize].stride;
                    for t in 0..lane_tokens[li] {
                        let s0 = t * ostride + oli * hd;
                        let d0 = t * stride + li * hd;
                        k[d0..d0 + hd].copy_from_slice(&br.k[s0..s0 + hd]);
                        v[d0..d0 + hd].copy_from_slice(&br.v[s0..s0 + hd]);
                    }
                }
                new_runs.push(BackupRun { pool: pid, lane_tokens, rows, k, v });
            }
        }
        new_runs.sort_unstable_by_key(|r| r.pool);
        let tokens = new_runs
            .iter()
            .filter(|r| self.pools[r.pool as usize].layer == 0)
            .flat_map(|r| r.lane_tokens.iter().copied())
            .max()
            .unwrap_or(0);
        self.backup.insert(id, ReqBackup { tokens, runs: new_runs });
    }

    /// Drop the arenas of pools no run references (stale epoch groupings)
    /// so memory does not creep across reconfigurations.
    fn shrink_unused_pools(&mut self) {
        let mut live = vec![false; self.pools.len()];
        for e in self.reqs.values() {
            for r in &e.runs {
                live[r.pool as usize] = true;
            }
        }
        for b in self.backup.values() {
            for r in &b.runs {
                live[r.pool as usize] = true;
            }
        }
        for (i, p) in self.pools.iter_mut().enumerate() {
            if !live[i] && p.n_blocks > 0 {
                debug_assert_eq!(p.free.len(), p.n_blocks as usize, "unreferenced pool holds blocks");
                p.k = Vec::new();
                p.v = Vec::new();
                p.free = Vec::new();
                p.n_blocks = 0;
            }
        }
    }

    /// Drop all state of a finished request; its blocks return to the
    /// pool free lists for reuse (no global-heap traffic at steady state).
    pub fn release(&mut self, req: RequestId) {
        if let Some(entry) = self.reqs.remove(&req) {
            for mut run in entry.runs {
                self.pools[run.pool as usize].free_blocks(&mut run.blocks);
            }
        }
        self.backup.remove(&req);
    }

    /// Per-rank resident KV bytes (for accounting assertions).
    pub fn bytes_by_rank(&self, world: usize) -> Vec<usize> {
        let mut by = vec![0usize; world];
        for entry in self.reqs.values() {
            for run in &entry.runs {
                for lane in &run.lanes {
                    if lane.present && lane.rank < world {
                        // K + V, f32 each.
                        by[lane.rank] += lane.tokens * self.head_dim * 8;
                    }
                }
            }
        }
        by
    }
}

fn layer0_max(pools: &[Pool], runs: &[Run]) -> usize {
    runs.iter()
        .filter(|r| pools[r.pool as usize].layer == 0)
        .flat_map(|r| r.lanes.iter().filter(|l| l.present).map(|l| l.tokens))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::small_real;
    use crate::sharding::ShardPlan;

    #[test]
    fn append_gather_roundtrip() {
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 3, 0, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]); // 2 tokens
        assert_eq!(kv.tokens(1), 2);
        let k = kv.gather(1, 0, &[3], 4, 2, false);
        // [c=4, h=2, hd=2]: token0 head0 = [1,2], token1 head0 = [3,4], rest 0.
        assert_eq!(&k[0..2], &[1.0, 2.0]);
        assert_eq!(&k[4..6], &[3.0, 4.0]);
        assert_eq!(&k[2..4], &[0.0, 0.0]); // padded head
        assert_eq!(&k[8..], &[0.0; 8]); // padded tokens
        let v = kv.gather(1, 0, &[3], 4, 2, true);
        assert_eq!(&v[0..2], &[5.0, 6.0]);
    }

    #[test]
    fn wipe_and_restore() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.append(1, 0, 1, 1, &[5.0, 6.0], &[7.0, 8.0]);
        kv.backup_request(1);
        let lost = kv.wipe_rank(0);
        assert_eq!(lost, vec![1]);
        assert!(kv.gather(1, 0, &[0], 1, 1, false).iter().all(|&x| x == 0.0));
        let restored = kv.restore_request(1, &placement, 0);
        assert_eq!(restored, 1);
        assert_eq!(kv.gather(1, 0, &[0], 1, 1, false), vec![1.0, 2.0]);
        // Surviving slice untouched.
        assert_eq!(kv.gather(1, 0, &[1], 1, 1, false), vec![5.0, 6.0]);
    }

    #[test]
    fn wipe_without_backup_loses_data() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let mut kv = KvStore::new(2);
        kv.append(7, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.wipe_rank(0);
        assert_eq!(kv.restore_request(7, &placement, 0), 0);
        assert_eq!(kv.tokens(7), 0);
    }

    #[test]
    fn truncate_trims_lagged_tokens() {
        let mut kv = KvStore::new(1);
        kv.append(1, 0, 0, 0, &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        kv.truncate(1, 2);
        assert_eq!(kv.tokens(1), 2);
        assert_eq!(kv.gather(1, 0, &[0], 3, 1, false), vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn retag_follows_new_placement() {
        let m = small_real();
        let (plan3, _) = ShardPlan::failsafe(&m, 2).expand();
        let placement = KvPlacement::new(&plan3);
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.append(1, 1, 3, 1, &[5.0, 6.0], &[7.0, 8.0]);
        kv.append(2, 0, 0, 0, &[9.0, 9.0], &[9.0, 9.0]); // not re-tagged
        let homes = HashMap::from([(1u64, 0usize)]);
        kv.retag_requests(&placement, &homes);
        let by = kv.bytes_by_rank(3);
        assert_eq!(by.iter().sum::<usize>(), 96, "retag moves tags, never bytes");
        let r00 = placement.rank_for(0, 0, 0);
        assert!(by[r00] >= 32, "slice (0,0) tagged by the new placement: {by:?}");
    }

    #[test]
    fn bytes_by_rank_tracks_tags() {
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[0.0; 4], &[0.0; 4]);
        kv.append(1, 0, 1, 1, &[0.0; 4], &[0.0; 4]);
        kv.append(1, 1, 0, 1, &[0.0; 4], &[0.0; 4]);
        let by = kv.bytes_by_rank(2);
        assert_eq!(by[0], 32);
        assert_eq!(by[1], 64);
    }

    // ------------------------------------------------- paged-layout tests --

    /// Grouped append + grouped gather across a block boundary: the fast
    /// block path must agree with the per-head general path.
    #[test]
    fn grouped_append_crosses_blocks() {
        let hd = 3;
        let mut kv = KvStore::new(hd);
        let heads = [4usize, 7];
        let pool = kv.pool_handle(2, &heads);
        let n = BLOCK_TOKENS + 5;
        let stride = heads.len() * hd;
        let k: Vec<f32> = (0..n * stride).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..n * stride).map(|i| (i as f32) * 0.5).collect();
        kv.append_group(9, pool, 1, n, &k, &v, stride);
        assert_eq!(kv.tokens(9), 0, "layer 2 appends don't move the layer-0 token index");

        let c = n + 3;
        let hb = 2; // == group size → whole-block copies
        let mut fast = vec![1.0f32; c * hb * hd];
        kv.gather_into(9, pool, c, hb, false, &mut fast);
        let general = kv.gather(9, 2, &heads, c, hb, false);
        assert_eq!(fast, general);
        assert_eq!(&fast[0..stride], &k[0..stride]);
        assert_eq!(&fast[n * stride..], &vec![0.0; 3 * stride][..], "padded tokens are zero");

        // Padded head bucket (hb > group) exercises the per-row path.
        let hb = 4;
        let mut padded = vec![1.0f32; c * hb * hd];
        kv.gather_into(9, pool, c, hb, true, &mut padded);
        assert_eq!(padded, kv.gather(9, 2, &heads, c, hb, true));
    }

    /// Strided-source appends (padded `[s, hb, hd]` forward output) land
    /// the real lanes and skip the padding.
    #[test]
    fn strided_append_skips_padding() {
        let hd = 2;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[1]);
        // Source rows padded to hb=2 heads: real lane is lane 0.
        let src = [1.0, 2.0, 99.0, 99.0, 3.0, 4.0, 99.0, 99.0];
        kv.append_group(5, pool, 0, 2, &src, &src, 2 * hd);
        assert_eq!(kv.tokens(5), 2);
        assert_eq!(kv.gather(5, 0, &[1], 2, 1, false), vec![1.0, 2.0, 3.0, 4.0]);
    }

    /// Released blocks are reused: steady-state alloc/free cycles keep the
    /// pool arena at its high-water mark.
    #[test]
    fn release_returns_blocks_to_the_pool() {
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let rows = vec![0.5f32; BLOCK_TOKENS * 3];
        kv.append_group(1, pool, 0, BLOCK_TOKENS * 3, &rows, &rows, hd);
        let high_water = kv.pools[pool as usize].n_blocks;
        kv.release(1);
        assert_eq!(kv.pools[pool as usize].free.len() as u32, high_water);
        kv.append_group(2, pool, 0, BLOCK_TOKENS * 2, &rows, &rows, hd);
        assert_eq!(kv.pools[pool as usize].n_blocks, high_water, "blocks reused, arena unchanged");
        assert_eq!(kv.tokens(2), BLOCK_TOKENS * 2);
    }

    /// Incremental backup after truncation re-mirrors instead of keeping
    /// a stale suffix.
    #[test]
    fn backup_follows_truncation() {
        let mut kv = KvStore::new(1);
        kv.append(1, 0, 0, 0, &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        kv.backup_request(1);
        assert_eq!(kv.backed_tokens(1), 3);
        kv.truncate(1, 1);
        kv.append(1, 0, 0, 0, &[7.0], &[7.0]);
        kv.backup_request(1);
        assert_eq!(kv.backed_tokens(1), 2);
        kv.wipe_rank(0);
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        assert_eq!(kv.restore_request(1, &placement, 0), 2);
        assert_eq!(kv.gather(1, 0, &[0], 2, 1, false), vec![1.0, 7.0]);
    }

    // -------------------------------------------------------- swap tests --

    /// swap_out → swap_in round-trips the device KV bit-exact through the
    /// host mirror, across a block boundary and after an incremental
    /// backup had already mirrored a prefix.
    #[test]
    fn swap_roundtrip_is_bit_exact() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let n = BLOCK_TOKENS + 5;
        let rows: Vec<f32> = (0..n as i32).map(|i| i as f32).collect();
        kv.append_group(1, pool, 0, n, &rows, &rows, hd);
        kv.backup_request(1); // partial mirror: swap_out must complete it
        kv.append_group(1, pool, 0, 3, &[90.0, 91.0, 92.0], &[90.0, 91.0, 92.0], hd);
        let before_k = kv.gather(1, 0, &[0], n + 3, 1, false);
        let before_v = kv.gather(1, 0, &[0], n + 3, 1, true);

        assert_eq!(kv.swap_out(99), 0, "unknown request is a no-op");
        assert_eq!(kv.swap_out(1), n + 3);
        assert!(kv.swapped_out(1));
        assert_eq!(kv.tokens(1), 0);
        let p = &kv.pools[pool as usize];
        assert_eq!(p.free.len() as u32, p.n_blocks, "every device block released");

        assert_eq!(kv.swap_in(1, &placement, 0), n + 3);
        assert!(!kv.swapped_out(1));
        assert_eq!(kv.gather(1, 0, &[0], n + 3, 1, false), before_k);
        assert_eq!(kv.gather(1, 0, &[0], n + 3, 1, true), before_v);
    }

    /// Swapping either side of a shared prefix never frees a block the
    /// other request still references, and the swapped side resumes
    /// bit-exact from the mirror.
    #[test]
    fn swap_never_disturbs_prefix_sharers() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let n = BLOCK_TOKENS * 2;
        let rows: Vec<f32> = (0..n as i32).map(|i| i as f32).collect();
        kv.append_group(1, pool, 0, n, &rows, &rows, hd);
        let donor = kv.prefix_blocks(1, pool, 2).unwrap();
        kv.adopt_blocks(2, pool, 0, &donor, n);
        kv.append_group(2, pool, 0, 2, &[7.0, 8.0], &[7.0, 8.0], hd);
        let s2 = kv.gather(2, 0, &[0], n + 2, 1, false);

        // Swap the adopter: the two shared blocks only drop a reference.
        assert_eq!(kv.swap_out(2), n + 2);
        assert_eq!(kv.shared_block_count(), 0, "donor is sole holder again");
        assert_eq!(kv.gather(1, 0, &[0], n, 1, false), rows, "donor rows intact");
        assert_eq!(kv.swap_in(2, &placement, 0), n + 2);
        assert_eq!(kv.gather(2, 0, &[0], n + 2, 1, false), s2, "adopter resumes bit-exact");

        // Symmetric: swap the donor while the restored adopter is live.
        kv.backup_request(1);
        assert_eq!(kv.swap_out(1), n);
        assert_eq!(kv.gather(2, 0, &[0], n + 2, 1, false), s2, "sharer unaffected");
        assert_eq!(kv.swap_in(1, &placement, 0), n);
        assert_eq!(kv.gather(1, 0, &[0], n, 1, false), rows);
    }

    /// A request swapped out before a reconfiguration swaps back in
    /// bit-exact after `relayout()` regrouped the pools: the host mirror
    /// rides `relayout_backup` into the new canonical layout.
    #[test]
    fn swap_composes_with_relayout_across_epochs() {
        let m = small_real();
        let plan = ShardPlan::failsafe(&m, 2);
        let placement = KvPlacement::new(&plan);
        let mut kv = KvStore::new(m.head_dim);
        // Per-head appends (non-canonical grouping), like a pre-epoch
        // request.
        for layer in 0..m.n_layers {
            for head in 0..m.n_kv_heads {
                let data: Vec<f32> =
                    (0..2 * m.head_dim).map(|i| (layer * 100 + head * 10 + i) as f32).collect();
                kv.append(1, layer, head, head % 2, &data, &data);
            }
        }
        let heads: Vec<usize> = (0..m.n_kv_heads).collect();
        let before: Vec<Vec<f32>> = (0..m.n_layers)
            .map(|l| kv.gather(1, l, &heads, 4, m.n_kv_heads, false))
            .collect();
        assert_eq!(kv.swap_out(1), 2);
        kv.relayout(&plan); // reconfig epoch: pools regroup, mirror follows
        assert!(kv.swapped_out(1), "still parked after relayout");
        assert_eq!(kv.swap_in(1, &placement, 0), 2);
        for (l, want) in before.iter().enumerate() {
            assert_eq!(&kv.gather(1, l, &heads, 4, m.n_kv_heads, false), want, "layer {l}");
        }
    }

    // ------------------------------------------------ prefix-sharing tests --

    /// Adopted blocks are shared (one physical copy), and releasing one
    /// sharer keeps the other's data intact.
    #[test]
    fn adopt_shares_blocks_and_release_keeps_sharers() {
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let rows: Vec<f32> = (0..BLOCK_TOKENS as i32 * 2).map(|i| i as f32).collect();
        kv.append_group(1, pool, 0, BLOCK_TOKENS * 2, &rows, &rows, hd);
        let donor = kv.prefix_blocks(1, pool, 2).unwrap();
        let before = kv.resident_bytes();
        kv.adopt_blocks(2, pool, 0, &donor, BLOCK_TOKENS * 2);
        assert_eq!(kv.tokens(2), BLOCK_TOKENS * 2);
        assert_eq!(kv.resident_bytes(), before, "adoption allocates no new blocks");
        assert_eq!(kv.shared_block_count(), 2);
        assert_eq!(kv.gather(2, 0, &[0], BLOCK_TOKENS * 2, 1, false), rows);
        kv.release(1);
        assert_eq!(kv.shared_block_count(), 0, "sole holder left");
        assert_eq!(kv.gather(2, 0, &[0], BLOCK_TOKENS * 2, 1, false), rows);
        kv.release(2);
        assert!(kv.drained(), "all refcounts return to zero at drain");
    }

    /// A divergent append into a partially-used shared block splits it
    /// (copy-on-write) without disturbing the sharer.
    #[test]
    fn divergent_append_cow_splits_shared_tail() {
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let n = BLOCK_TOKENS + 4; // tail block partially used
        let rows: Vec<f32> = (0..n as i32).map(|i| i as f32).collect();
        kv.append_group(1, pool, 0, n, &rows, &rows, hd);
        // Adopt a partial hit: the sharer reuses both blocks but only the
        // first `n - 1` tokens (full-prompt hits keep the last token for
        // recompute), then diverges.
        let donor = kv.prefix_blocks(1, pool, 2);
        assert!(donor.is_none(), "partial tail block is not a full donor chunk");
        let donor = kv.prefix_blocks(1, pool, 1).unwrap();
        kv.adopt_blocks(2, pool, 0, &donor, BLOCK_TOKENS);
        // Fill the shared full block's sibling... diverge inside block 0?
        // Block 0 is full, so the append opens a private block: no split.
        kv.append_group(2, pool, 0, 2, &[100.0, 101.0], &[100.0, 101.0], hd);
        assert_eq!(kv.shared_block_count(), 1);
        // Now force a split: a third request adopts block 0 *partially*
        // (12 of 16 tokens) and appends into it.
        kv.adopt_blocks(3, pool, 0, &donor, 12);
        kv.append_group(3, pool, 0, 1, &[55.0], &[55.0], hd);
        let got = kv.gather(3, 0, &[0], 13, 1, false);
        assert_eq!(&got[..12], &rows[..12]);
        assert_eq!(got[12], 55.0);
        // The donor and its other sharer still see the original rows.
        assert_eq!(kv.gather(1, 0, &[0], n, 1, false), rows);
        let s2 = kv.gather(2, 0, &[0], BLOCK_TOKENS + 2, 1, false);
        assert_eq!(&s2[..BLOCK_TOKENS], &rows[..BLOCK_TOKENS]);
        assert_eq!(&s2[BLOCK_TOKENS..], &[100.0, 101.0]);
        for r in [1, 2, 3] {
            kv.release(r);
        }
        assert!(kv.drained());
    }

    /// `switch_to_shared` drops private duplicates for the shared copies
    /// — the recovery-side re-deduplication.
    #[test]
    fn switch_to_shared_dedups_private_copies() {
        let hd = 1;
        let mut kv = KvStore::new(hd);
        let pool = kv.pool_handle(0, &[0]);
        let rows: Vec<f32> = (0..BLOCK_TOKENS as i32).map(|i| i as f32).collect();
        kv.append_group(1, pool, 0, BLOCK_TOKENS, &rows, &rows, hd);
        kv.append_group(2, pool, 0, BLOCK_TOKENS, &rows, &rows, hd);
        let two_private = kv.resident_bytes();
        let donor = kv.prefix_blocks(1, pool, 1).unwrap();
        assert!(kv.switch_to_shared(2, pool, &donor));
        assert_eq!(kv.resident_bytes(), two_private / 2, "private copy freed");
        assert_eq!(kv.shared_block_count(), 1);
        assert_eq!(kv.gather(2, 0, &[0], BLOCK_TOKENS, 1, false), rows);
        assert!(kv.switch_to_shared(1, pool, &donor), "donor switch is a no-op");
        kv.release(1);
        kv.release(2);
        assert!(kv.drained());
    }

    /// Relayout re-buckets shared prefixes into **one** new block chain,
    /// not N private copies (the sharing-preservation contract across
    /// reconfiguration).
    #[test]
    fn relayout_preserves_sharing() {
        let m = small_real();
        let plan = ShardPlan::failsafe(&m, 2);
        let mut kv = KvStore::new(m.head_dim);
        // Two requests with identical per-head layouts sharing their
        // blocks: build request 1, then request 2 adopts every run.
        let n = BLOCK_TOKENS;
        for layer in 0..m.n_layers {
            for head in 0..m.n_kv_heads {
                let data: Vec<f32> =
                    (0..n * m.head_dim).map(|i| (layer * 100 + head * 10 + i) as f32).collect();
                kv.append(1, layer, head, head % 2, &data, &data);
            }
        }
        let mut pools: Vec<PoolId> = Vec::new();
        for layer in 0..m.n_layers {
            for head in 0..m.n_kv_heads {
                pools.push(kv.pool_handle(layer, &[head]));
            }
        }
        for &pool in &pools {
            let donor = kv.prefix_blocks(1, pool, 1).unwrap();
            kv.adopt_blocks(2, pool, 0, &donor, n);
        }
        let shared_resident = kv.resident_bytes();
        assert!(kv.shared_block_count() > 0);
        let heads: Vec<usize> = (0..m.n_kv_heads).collect();
        let want: Vec<Vec<f32>> = (0..m.n_layers)
            .map(|l| kv.gather(1, l, &heads, n, m.n_kv_heads, false))
            .collect();
        kv.relayout(&plan);
        assert_eq!(
            kv.resident_bytes(),
            shared_resident,
            "relayout kept one copy of the shared rows"
        );
        assert!(kv.shared_block_count() > 0, "sharing survives relayout");
        for (l, w) in want.iter().enumerate() {
            assert_eq!(&kv.gather(1, l, &heads, n, m.n_kv_heads, false), w, "req 1 layer {l}");
            assert_eq!(&kv.gather(2, l, &heads, n, m.n_kv_heads, false), w, "req 2 layer {l}");
        }
        kv.release(1);
        kv.release(2);
        assert!(kv.drained(), "no leaked blocks after relayout + release");
    }

    /// Relayout re-buckets data into a plan's canonical groups without
    /// changing a single gathered byte or any lane tag.
    #[test]
    fn relayout_preserves_data_and_tags() {
        let m = small_real();
        let plan = ShardPlan::failsafe(&m, 2);
        let mut kv = KvStore::new(m.head_dim);
        // Per-head appends (non-canonical grouping).
        for layer in 0..m.n_layers {
            for head in 0..m.n_kv_heads {
                let data: Vec<f32> =
                    (0..2 * m.head_dim).map(|i| (layer * 100 + head * 10 + i) as f32).collect();
                kv.append(1, layer, head, head % 2, &data, &data);
            }
        }
        kv.backup_request(1);
        let before: Vec<Vec<f32>> = (0..m.n_layers)
            .map(|l| {
                let heads: Vec<usize> = (0..m.n_kv_heads).collect();
                kv.gather(1, l, &heads, 4, m.n_kv_heads, false)
            })
            .collect();
        let by_before = kv.bytes_by_rank(2);
        kv.relayout(&plan);
        for (l, want) in before.iter().enumerate() {
            let heads: Vec<usize> = (0..m.n_kv_heads).collect();
            assert_eq!(&kv.gather(1, l, &heads, 4, m.n_kv_heads, false), want, "layer {l}");
        }
        assert_eq!(kv.bytes_by_rank(2), by_before);
        assert_eq!(kv.tokens(1), 2);
        assert_eq!(kv.backed_tokens(1), 2);
    }
}
