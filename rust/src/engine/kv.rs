//! Engine KV store: per-(request, layer, head) K/V slices with rank tags,
//! host backup mirroring, and failure wipes.
//!
//! All data physically lives in host memory (the engine runs on CPU-PJRT),
//! but every slice carries the rank whose simulated HBM holds it. A device
//! failure deletes exactly the slices tagged with that rank — recovery
//! must then restore them from the backup mirror (FailSafe) or re-prefill
//! (the baseline), and the continuation is checked bit-exact in tests.

use std::collections::HashMap;

use crate::kvcache::KvPlacement;
use crate::{HeadId, LayerId, RankId, RequestId};

/// K/V of one (request, layer, head): `tokens × head_dim` f32 each.
#[derive(Debug, Clone, Default)]
pub struct KvSlice {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub tokens: usize,
    /// Rank whose (simulated) HBM holds this slice.
    pub rank: RankId,
}

/// The engine's KV state.
#[derive(Debug, Default)]
pub struct KvStore {
    head_dim: usize,
    slices: HashMap<(RequestId, LayerId, HeadId), KvSlice>,
    /// Host-DRAM mirror (proactive backup §3.2): token-prefix copies.
    backup: HashMap<(RequestId, LayerId, HeadId), KvSlice>,
}

impl KvStore {
    pub fn new(head_dim: usize) -> Self {
        KvStore { head_dim, slices: HashMap::new(), backup: HashMap::new() }
    }

    /// Tokens cached for `req` (layer 0, any head — all heads agree).
    pub fn tokens(&self, req: RequestId) -> usize {
        self.slices
            .iter()
            .filter(|((r, l, _), _)| *r == req && *l == 0)
            .map(|(_, s)| s.tokens)
            .max()
            .unwrap_or(0)
    }

    /// Append `s` new tokens of K/V for (req, layer, head), held by `rank`.
    pub fn append(
        &mut self,
        req: RequestId,
        layer: LayerId,
        head: HeadId,
        rank: RankId,
        k_new: &[f32],
        v_new: &[f32],
    ) {
        debug_assert_eq!(k_new.len(), v_new.len());
        debug_assert_eq!(k_new.len() % self.head_dim, 0);
        let e = self.slices.entry((req, layer, head)).or_default();
        e.k.extend_from_slice(k_new);
        e.v.extend_from_slice(v_new);
        e.tokens += k_new.len() / self.head_dim;
        e.rank = rank;
    }

    /// Gather the K (or V) cache of `req` for `heads`, zero-padded to
    /// `(c_bucket, h_bucket)`: output `[c_bucket, h_bucket, head_dim]`
    /// row-major, ready to concatenate across a batch.
    pub fn gather(
        &self,
        req: RequestId,
        layer: LayerId,
        heads: &[HeadId],
        c_bucket: usize,
        h_bucket: usize,
        want_v: bool,
    ) -> Vec<f32> {
        let hd = self.head_dim;
        let mut out = vec![0.0f32; c_bucket * h_bucket * hd];
        for (hi, &h) in heads.iter().enumerate() {
            if let Some(s) = self.slices.get(&(req, layer, h)) {
                let src = if want_v { &s.v } else { &s.k };
                for t in 0..s.tokens.min(c_bucket) {
                    let dst = (t * h_bucket + hi) * hd;
                    out[dst..dst + hd].copy_from_slice(&src[t * hd..(t + 1) * hd]);
                }
            }
        }
        out
    }

    /// Mirror `req`'s slices into the host backup (write-behind pass).
    pub fn backup_request(&mut self, req: RequestId) {
        for ((r, l, h), s) in self.slices.iter() {
            if *r == req {
                self.backup.insert((*r, *l, *h), s.clone());
            }
        }
    }

    /// Tokens covered by backup for `req`.
    pub fn backed_tokens(&self, req: RequestId) -> usize {
        self.backup
            .iter()
            .filter(|((r, l, _), _)| *r == req && *l == 0)
            .map(|(_, s)| s.tokens)
            .max()
            .unwrap_or(0)
    }

    /// Hard failure of `rank`: drop every slice its HBM held. Returns the
    /// affected request ids (deduped).
    pub fn wipe_rank(&mut self, rank: RankId) -> Vec<RequestId> {
        let mut lost: Vec<RequestId> = Vec::new();
        self.slices.retain(|(r, _, _), s| {
            if s.rank == rank {
                lost.push(*r);
                false
            } else {
                true
            }
        });
        lost.sort_unstable();
        lost.dedup();
        lost
    }

    /// Restore `req`'s missing slices from backup, re-tagging by the new
    /// placement (`home` = new home rank). Returns restored token count,
    /// or 0 if no backup exists.
    pub fn restore_request(
        &mut self,
        req: RequestId,
        placement: &KvPlacement,
        home: RankId,
    ) -> usize {
        let mut restored = 0;
        for ((r, l, h), s) in self.backup.iter() {
            if *r != req {
                continue;
            }
            if !self.slices.contains_key(&(*r, *l, *h)) {
                let mut slice = s.clone();
                slice.rank = placement.rank_for(*l, *h, home);
                restored = restored.max(slice.tokens);
                self.slices.insert((*r, *l, *h), slice);
            }
        }
        restored
    }

    /// Truncate every slice of `req` to `tokens` (used when restore lags
    /// behind the newest decode tokens — the lag gets recomputed).
    pub fn truncate(&mut self, req: RequestId, tokens: usize) {
        let hd = self.head_dim;
        for ((r, _, _), s) in self.slices.iter_mut() {
            if *r == req && s.tokens > tokens {
                s.k.truncate(tokens * hd);
                s.v.truncate(tokens * hd);
                s.tokens = tokens;
            }
        }
    }

    /// Re-tag every slice of the requests in `homes` (request → home rank)
    /// to the rank `placement` assigns it, in one pass over the store —
    /// the KV re-spread of an expand-reconfiguration (GPU rejoin). Data
    /// stays put in the host-side store; the simulated NVLink move onto
    /// the new owners is costed by the rejoin latency model.
    pub fn retag_requests(&mut self, placement: &KvPlacement, homes: &HashMap<RequestId, RankId>) {
        for ((r, l, h), s) in self.slices.iter_mut() {
            if let Some(&home) = homes.get(r) {
                s.rank = placement.rank_for(*l, *h, home);
            }
        }
    }

    /// Re-tag surviving slices after a reconfiguration: slice held by old
    /// rank `o` now belongs to `survivor_map[o]` (data stays put; the
    /// simulated transfer cost is accounted by the recovery planner).
    pub fn remap_ranks(&mut self, survivor_map: &[Option<RankId>]) {
        for s in self.slices.values_mut() {
            if let Some(new_r) = survivor_map.get(s.rank).copied().flatten() {
                s.rank = new_r;
            }
        }
    }

    /// Drop all state of a finished request.
    pub fn release(&mut self, req: RequestId) {
        self.slices.retain(|(r, _, _), _| *r != req);
        self.backup.retain(|(r, _, _), _| *r != req);
    }

    /// Per-rank resident KV bytes (for accounting assertions).
    pub fn bytes_by_rank(&self, world: usize) -> Vec<usize> {
        let mut by = vec![0usize; world];
        for s in self.slices.values() {
            if s.rank < world {
                by[s.rank] += (s.k.len() + s.v.len()) * 4;
            }
        }
        by
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::small_real;
    use crate::sharding::ShardPlan;

    #[test]
    fn append_gather_roundtrip() {
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 3, 0, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]); // 2 tokens
        assert_eq!(kv.tokens(1), 2);
        let k = kv.gather(1, 0, &[3], 4, 2, false);
        // [c=4, h=2, hd=2]: token0 head0 = [1,2], token1 head0 = [3,4], rest 0.
        assert_eq!(&k[0..2], &[1.0, 2.0]);
        assert_eq!(&k[4..6], &[3.0, 4.0]);
        assert_eq!(&k[2..4], &[0.0, 0.0]); // padded head
        assert_eq!(&k[8..], &[0.0; 8]); // padded tokens
        let v = kv.gather(1, 0, &[3], 4, 2, true);
        assert_eq!(&v[0..2], &[5.0, 6.0]);
    }

    #[test]
    fn wipe_and_restore() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.append(1, 0, 1, 1, &[5.0, 6.0], &[7.0, 8.0]);
        kv.backup_request(1);
        let lost = kv.wipe_rank(0);
        assert_eq!(lost, vec![1]);
        assert!(kv.gather(1, 0, &[0], 1, 1, false).iter().all(|&x| x == 0.0));
        let restored = kv.restore_request(1, &placement, 0);
        assert_eq!(restored, 1);
        assert_eq!(kv.gather(1, 0, &[0], 1, 1, false), vec![1.0, 2.0]);
        // Surviving slice untouched.
        assert_eq!(kv.gather(1, 0, &[1], 1, 1, false), vec![5.0, 6.0]);
    }

    #[test]
    fn wipe_without_backup_loses_data() {
        let m = small_real();
        let placement = KvPlacement::new(&ShardPlan::failsafe(&m, 2));
        let mut kv = KvStore::new(2);
        kv.append(7, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.wipe_rank(0);
        assert_eq!(kv.restore_request(7, &placement, 0), 0);
        assert_eq!(kv.tokens(7), 0);
    }

    #[test]
    fn truncate_trims_lagged_tokens() {
        let mut kv = KvStore::new(1);
        kv.append(1, 0, 0, 0, &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        kv.truncate(1, 2);
        assert_eq!(kv.tokens(1), 2);
        assert_eq!(kv.gather(1, 0, &[0], 3, 1, false), vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn retag_follows_new_placement() {
        let m = small_real();
        let (plan3, _) = ShardPlan::failsafe(&m, 2).expand();
        let placement = KvPlacement::new(&plan3);
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.append(1, 1, 3, 1, &[5.0, 6.0], &[7.0, 8.0]);
        kv.append(2, 0, 0, 0, &[9.0, 9.0], &[9.0, 9.0]); // not re-tagged
        let homes = HashMap::from([(1u64, 0usize)]);
        kv.retag_requests(&placement, &homes);
        let by = kv.bytes_by_rank(3);
        assert_eq!(by.iter().sum::<usize>(), 96, "retag moves tags, never bytes");
        let r00 = placement.rank_for(0, 0, 0);
        assert!(by[r00] >= 32, "slice (0,0) tagged by the new placement: {by:?}");
    }

    #[test]
    fn bytes_by_rank_tracks_tags() {
        let mut kv = KvStore::new(2);
        kv.append(1, 0, 0, 0, &[0.0; 4], &[0.0; 4]);
        kv.append(1, 0, 1, 1, &[0.0; 4], &[0.0; 4]);
        kv.append(1, 1, 0, 1, &[0.0; 4], &[0.0; 4]);
        let by = kv.bytes_by_rank(2);
        assert_eq!(by[0], 32);
        assert_eq!(by[1], 64);
    }
}
