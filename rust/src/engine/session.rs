//! Session bookkeeping for the event-driven engine: per-request lifecycle
//! state, submission options, wall-clock timing, and the counters that the
//! final [`super::ServeReport`] is assembled from.
//!
//! The [`super::Engine`] owns exactly one `Session`; `core.rs` drives it
//! from the `step()` loop and `report.rs` turns it into a report. Nothing
//! in here touches PJRT — this file is pure request/timing bookkeeping.

use std::collections::HashMap;
use std::time::Instant;

use crate::coordinator::{Request, RequestState};
use crate::{RequestId, SimTime};

/// Options attached to a submitted request (builder style), passed to
/// [`ServingBackend::submit_with`](super::ServingBackend::submit_with):
///
/// ```
/// use failsafe::engine::SubmitOptions;
///
/// // 64-token budget, arriving 1.5 s into the session, high priority,
/// // 10 s SLO deadline — e.g. `backend.submit_with(&prompt, opts)?`.
/// let opts = SubmitOptions::new(64).at(1.5).priority(2).deadline(10.0);
/// assert_eq!(opts.max_new_tokens, 64);
/// assert_eq!(opts.arrival, 1.5);
/// assert_eq!((opts.priority, opts.deadline), (2, Some(10.0)));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmitOptions {
    /// Arrival time in seconds on the backend's clock. The request stays
    /// `Queued` and is not routed or scheduled before this time; `0.0`
    /// (the default) means "available immediately" — the offline case.
    pub arrival: SimTime,
    /// Generation budget (must be ≥ 1; validated at submit).
    pub max_new_tokens: usize,
    /// Scheduling priority: higher runs first within a step's admission,
    /// prefill ordering, and decode batch forming. Default 0.
    pub priority: i32,
    /// Optional SLO deadline (seconds on the backend clock). Among equal
    /// priorities, earlier deadlines are scheduled first.
    pub deadline: Option<SimTime>,
}

impl SubmitOptions {
    pub fn new(max_new_tokens: usize) -> Self {
        SubmitOptions { arrival: 0.0, max_new_tokens, priority: 0, deadline: None }
    }

    /// Set the arrival time (timed/online traces).
    pub fn at(mut self, arrival: SimTime) -> Self {
        self.arrival = arrival;
        self
    }

    /// Set the scheduling priority (higher = sooner).
    pub fn priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Set the SLO deadline.
    pub fn deadline(mut self, deadline: SimTime) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Skip-join MLFQ-style preemption policy (FastServe-inspired): decides
/// when a waiting high-SLO request may evict a running lower-priority
/// decode to the KV swap tier, and how starved requests are promoted so
/// best-effort work is never parked forever.
///
/// The policy is pure arithmetic over `(priority, deadline, waited)` —
/// both the real engine and the cost-model simulator call the same
/// methods, so preemption decisions are identical across backends.
///
/// ```
/// use failsafe::engine::PreemptPolicy;
///
/// let p = PreemptPolicy::default();
/// // A request that has waited 2.5 promotion periods gains 2 levels.
/// let eff = p.effective_priority(0, 2.5 * p.promote_after);
/// assert_eq!(eff, 2);
/// // Deadline risk: now + slack * est_remaining crosses the deadline.
/// assert!(p.deadline_at_risk(9.0, Some(10.0), 1.0));
/// assert!(!p.deadline_at_risk(0.0, Some(10.0), 1.0));
/// assert!(!p.deadline_at_risk(9.0, None, 1.0)); // best-effort: never
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptPolicy {
    /// Seconds of waiting that earn one level of priority promotion
    /// (starvation avoidance). `<= 0` disables promotion.
    pub promote_after: f64,
    /// Headroom multiplier on the remaining-service estimate when
    /// judging deadline risk: a deadline is "at risk" once
    /// `now + slack * est_remaining >= deadline`.
    pub slack: f64,
    /// Cap on preemptions per scheduler round (thrash guard).
    pub max_preemptions_per_round: usize,
}

impl Default for PreemptPolicy {
    fn default() -> Self {
        PreemptPolicy { promote_after: 10.0, slack: 1.5, max_preemptions_per_round: 4 }
    }
}

impl PreemptPolicy {
    /// Effective priority of a request with base priority `base` that has
    /// waited `waited` seconds for service: one promotion level per
    /// [`PreemptPolicy::promote_after`] seconds waited.
    pub fn effective_priority(&self, base: i32, waited: f64) -> i32 {
        if self.promote_after <= 0.0 || waited <= 0.0 {
            return base;
        }
        base.saturating_add((waited / self.promote_after) as i32)
    }

    /// Whether a deadline is at risk given the current clock and an
    /// estimate of remaining service time. Requests without a deadline
    /// (best-effort) are never at risk — they wait for capacity (with
    /// promotion) but never trigger a preemption themselves.
    pub fn deadline_at_risk(
        &self,
        now: SimTime,
        deadline: Option<SimTime>,
        est_remaining_s: f64,
    ) -> bool {
        match deadline {
            Some(d) => now + self.slack * est_remaining_s >= d,
            None => false,
        }
    }

    /// Whether `candidate` (effective priority) may evict `victim`
    /// (effective priority): strictly greater, so equal-tier requests
    /// never thrash each other.
    pub fn may_preempt(&self, candidate_eff: i32, victim_eff: i32) -> bool {
        candidate_eff > victim_eff
    }
}

/// Wall-clock timing of one request, relative to its admission.
#[derive(Debug)]
pub(super) struct Timing {
    pub submitted: Instant,
    pub first_token: Option<f64>,
    pub last_token: Option<f64>,
    pub max_tbt: f64,
    /// Session-clock time at which the request finished (all tokens
    /// produced) — `None` while in flight or aborted. Compared against
    /// the submitted deadline for the report's deadline-miss accounting.
    pub finished_at: Option<SimTime>,
}

impl Timing {
    fn new() -> Self {
        Timing {
            submitted: Instant::now(),
            first_token: None,
            last_token: None,
            max_tbt: 0.0,
            finished_at: None,
        }
    }
}

/// All request/timing state of one engine session, plus the cumulative
/// step counters. The scheduling order helpers here are the single source
/// of truth for "which request runs first" — both prefill and decode pull
/// their candidate lists from them so priority/deadline behave uniformly.
#[derive(Debug, Default)]
pub(super) struct Session {
    pub requests: HashMap<RequestId, Request>,
    pub timing: HashMap<RequestId, Timing>,
    /// Submission order — the tiebreaker after priority and deadline.
    pub order: Vec<RequestId>,
    next_id: RequestId,
    /// The session clock in seconds: advances by the measured wall time of
    /// each step, and fast-forwards over idle gaps to the next arrival.
    pub clock: SimTime,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub steps: usize,
}

impl Session {
    pub fn new() -> Self {
        Session::default()
    }

    /// Register a new request (state `Queued`; routing happens at
    /// admission). Returns its id.
    pub fn create(&mut self, prompt: Vec<u32>, opts: SubmitOptions) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, opts.arrival, prompt, opts.max_new_tokens);
        req.priority = opts.priority;
        req.deadline = opts.deadline;
        self.requests.insert(id, req);
        self.timing.insert(id, Timing::new());
        self.order.push(id);
        id
    }

    /// Queued requests whose arrival time has come, in scheduling order.
    pub fn ready_to_admit(&self, now: SimTime) -> Vec<RequestId> {
        self.in_sched_order(|r| r.state == RequestState::Queued && r.arrival <= now)
    }

    /// Earliest arrival among still-queued requests.
    pub fn next_arrival(&self) -> Option<SimTime> {
        self.requests
            .values()
            .filter(|r| r.state == RequestState::Queued)
            .map(|r| r.arrival)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Requests with prefill work pending, in scheduling order, written
    /// into the caller's reused buffer (the step loop's scratch — no
    /// per-step allocation).
    pub fn prefilling_into(&self, out: &mut Vec<RequestId>) {
        self.in_sched_order_into(
            |r| r.state == RequestState::Prefilling && r.prefill_remaining() > 0,
            out,
        );
    }

    /// Requests in decode, in scheduling order, into the caller's buffer.
    pub fn decoding_into(&self, out: &mut Vec<RequestId>) {
        self.in_sched_order_into(|r| r.state == RequestState::Decoding, out);
    }

    /// Requests parked in the swap tier, in scheduling order, into the
    /// caller's buffer — the resume order when capacity frees up.
    pub fn swapped_into(&self, out: &mut Vec<RequestId>) {
        self.in_sched_order_into(|r| r.state == RequestState::Swapped, out);
    }

    /// True when no request can ever make progress again without a new
    /// submission: nothing queued, prefilling, decoding, or swapped out
    /// (a swapped request still owes tokens — it resumes via swap-in).
    pub fn is_idle(&self) -> bool {
        !self.requests.values().any(|r| {
            matches!(
                r.state,
                RequestState::Queued
                    | RequestState::Prefilling
                    | RequestState::Decoding
                    | RequestState::Swapped
            )
        })
    }

    /// Record a token emission for `id`'s TTFT/TBT timing.
    pub fn note_token(&mut self, id: RequestId) {
        let t = self.timing.get_mut(&id).expect("timing exists for every request");
        let now = t.submitted.elapsed().as_secs_f64();
        match t.last_token {
            None => t.first_token = Some(now),
            Some(prev) => t.max_tbt = t.max_tbt.max(now - prev),
        }
        t.last_token = Some(now);
    }

    /// Stamp `id`'s completion on the session clock (called where
    /// `RequestFinished` is emitted) for deadline-miss accounting.
    pub fn mark_finished(&mut self, id: RequestId) {
        if let Some(t) = self.timing.get_mut(&id) {
            t.finished_at = Some(self.clock);
        }
    }

    /// Re-base `id`'s timing to now — called when a request with a future
    /// arrival is finally admitted, so TTFT measures service latency
    /// rather than time spent waiting to arrive.
    pub fn rebase_timing(&mut self, id: RequestId) {
        if let Some(t) = self.timing.get_mut(&id) {
            if t.first_token.is_none() {
                t.submitted = Instant::now();
            }
        }
    }

    /// Submission order filtered by `keep`, then sorted by (priority
    /// desc, deadline asc). Ties keep submission order.
    fn in_sched_order(&self, keep: impl Fn(&Request) -> bool) -> Vec<RequestId> {
        let mut ids = Vec::new();
        self.in_sched_order_into(keep, &mut ids);
        ids
    }

    /// [`Session::in_sched_order`] into a reused buffer. Uses an unstable
    /// sort (no temp allocation) with the request id as the final key —
    /// ids are handed out in submission order, so the id tiebreak *is*
    /// the stable submission-order tiebreak.
    fn in_sched_order_into(&self, keep: impl Fn(&Request) -> bool, out: &mut Vec<RequestId>) {
        out.clear();
        out.extend(self.order.iter().copied().filter(|id| keep(&self.requests[id])));
        out.sort_unstable_by(|a, b| {
            let ra = &self.requests[a];
            let rb = &self.requests[b];
            rb.priority
                .cmp(&ra.priority)
                .then_with(|| {
                    let da = ra.deadline.unwrap_or(f64::INFINITY);
                    let db = rb.deadline.unwrap_or(f64::INFINITY);
                    da.total_cmp(&db)
                })
                .then(a.cmp(b))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_options_builder() {
        let o = SubmitOptions::new(8).at(2.5).priority(3).deadline(10.0);
        assert_eq!(o.max_new_tokens, 8);
        assert_eq!(o.arrival, 2.5);
        assert_eq!(o.priority, 3);
        assert_eq!(o.deadline, Some(10.0));
        let d = SubmitOptions::new(4);
        assert_eq!(d.arrival, 0.0);
        assert_eq!(d.priority, 0);
        assert_eq!(d.deadline, None);
    }

    #[test]
    fn admission_respects_arrival_and_priority() {
        let mut s = Session::new();
        let a = s.create(vec![1, 2], SubmitOptions::new(4));
        let b = s.create(vec![1, 2], SubmitOptions::new(4).at(5.0));
        let c = s.create(vec![1, 2], SubmitOptions::new(4).priority(1));
        assert_eq!(s.ready_to_admit(0.0), vec![c, a], "priority first, b not arrived");
        assert_eq!(s.next_arrival(), Some(0.0));
        s.requests.get_mut(&a).unwrap().state = RequestState::Prefilling;
        s.requests.get_mut(&c).unwrap().state = RequestState::Prefilling;
        assert_eq!(s.next_arrival(), Some(5.0));
        assert_eq!(s.ready_to_admit(5.0), vec![b]);
        assert!(!s.is_idle());
    }

    #[test]
    fn sched_order_breaks_priority_ties_by_deadline() {
        let mut s = Session::new();
        let a = s.create(vec![1], SubmitOptions::new(1).deadline(9.0));
        let b = s.create(vec![1], SubmitOptions::new(1).deadline(3.0));
        let c = s.create(vec![1], SubmitOptions::new(1));
        assert_eq!(s.ready_to_admit(0.0), vec![b, a, c]);
    }

    #[test]
    fn swapped_blocks_idle_and_resumes_in_sched_order() {
        let mut s = Session::new();
        let a = s.create(vec![1], SubmitOptions::new(1));
        let b = s.create(vec![1], SubmitOptions::new(1).priority(2));
        s.requests.get_mut(&a).unwrap().state = RequestState::Swapped;
        s.requests.get_mut(&b).unwrap().state = RequestState::Swapped;
        assert!(!s.is_idle(), "swapped requests still owe tokens");
        let mut out = Vec::new();
        s.swapped_into(&mut out);
        assert_eq!(out, vec![b, a], "higher priority resumes first");
    }

    #[test]
    fn promotion_is_monotone_and_bounded_by_wait() {
        let p = PreemptPolicy { promote_after: 5.0, ..PreemptPolicy::default() };
        assert_eq!(p.effective_priority(1, 0.0), 1);
        assert_eq!(p.effective_priority(1, 4.9), 1);
        assert_eq!(p.effective_priority(1, 5.0), 2);
        assert_eq!(p.effective_priority(1, 14.9), 3);
        let off = PreemptPolicy { promote_after: 0.0, ..PreemptPolicy::default() };
        assert_eq!(off.effective_priority(0, 1e9), 0, "promotion disabled");
        assert!(p.may_preempt(2, 1));
        assert!(!p.may_preempt(2, 2), "equal tiers never thrash");
    }

    #[test]
    fn idle_when_all_finished_or_aborted() {
        let mut s = Session::new();
        let a = s.create(vec![1], SubmitOptions::new(1));
        let b = s.create(vec![1], SubmitOptions::new(1));
        assert!(!s.is_idle());
        s.requests.get_mut(&a).unwrap().state = RequestState::Finished;
        s.requests.get_mut(&b).unwrap().state = RequestState::Aborted;
        assert!(s.is_idle());
    }
}
