//! Session bookkeeping for the event-driven engine: per-request lifecycle
//! state, submission options, wall-clock timing, and the counters that the
//! final [`super::ServeReport`] is assembled from.
//!
//! The [`super::Engine`] owns exactly one `Session`; `core.rs` drives it
//! from the `step()` loop and `report.rs` turns it into a report. Nothing
//! in here touches PJRT — this file is pure request/timing bookkeeping.

use std::collections::HashMap;
use std::time::Instant;

use crate::coordinator::{Request, RequestState};
use crate::{RequestId, SimTime};

/// Options attached to a submitted request (builder style), passed to
/// [`ServingBackend::submit_with`](super::ServingBackend::submit_with):
///
/// ```
/// use failsafe::engine::SubmitOptions;
///
/// // 64-token budget, arriving 1.5 s into the session, high priority,
/// // 10 s SLO deadline — e.g. `backend.submit_with(&prompt, opts)?`.
/// let opts = SubmitOptions::new(64).at(1.5).priority(2).deadline(10.0);
/// assert_eq!(opts.max_new_tokens, 64);
/// assert_eq!(opts.arrival, 1.5);
/// assert_eq!((opts.priority, opts.deadline), (2, Some(10.0)));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmitOptions {
    /// Arrival time in seconds on the backend's clock. The request stays
    /// `Queued` and is not routed or scheduled before this time; `0.0`
    /// (the default) means "available immediately" — the offline case.
    pub arrival: SimTime,
    /// Generation budget (must be ≥ 1; validated at submit).
    pub max_new_tokens: usize,
    /// Scheduling priority: higher runs first within a step's admission,
    /// prefill ordering, and decode batch forming. Default 0.
    pub priority: i32,
    /// Optional SLO deadline (seconds on the backend clock). Among equal
    /// priorities, earlier deadlines are scheduled first.
    pub deadline: Option<SimTime>,
}

impl SubmitOptions {
    pub fn new(max_new_tokens: usize) -> Self {
        SubmitOptions { arrival: 0.0, max_new_tokens, priority: 0, deadline: None }
    }

    /// Set the arrival time (timed/online traces).
    pub fn at(mut self, arrival: SimTime) -> Self {
        self.arrival = arrival;
        self
    }

    /// Set the scheduling priority (higher = sooner).
    pub fn priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Set the SLO deadline.
    pub fn deadline(mut self, deadline: SimTime) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Wall-clock timing of one request, relative to its admission.
#[derive(Debug)]
pub(super) struct Timing {
    pub submitted: Instant,
    pub first_token: Option<f64>,
    pub last_token: Option<f64>,
    pub max_tbt: f64,
}

impl Timing {
    fn new() -> Self {
        Timing { submitted: Instant::now(), first_token: None, last_token: None, max_tbt: 0.0 }
    }
}

/// All request/timing state of one engine session, plus the cumulative
/// step counters. The scheduling order helpers here are the single source
/// of truth for "which request runs first" — both prefill and decode pull
/// their candidate lists from them so priority/deadline behave uniformly.
#[derive(Debug, Default)]
pub(super) struct Session {
    pub requests: HashMap<RequestId, Request>,
    pub timing: HashMap<RequestId, Timing>,
    /// Submission order — the tiebreaker after priority and deadline.
    pub order: Vec<RequestId>,
    next_id: RequestId,
    /// The session clock in seconds: advances by the measured wall time of
    /// each step, and fast-forwards over idle gaps to the next arrival.
    pub clock: SimTime,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub steps: usize,
}

impl Session {
    pub fn new() -> Self {
        Session::default()
    }

    /// Register a new request (state `Queued`; routing happens at
    /// admission). Returns its id.
    pub fn create(&mut self, prompt: Vec<u32>, opts: SubmitOptions) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, opts.arrival, prompt, opts.max_new_tokens);
        req.priority = opts.priority;
        req.deadline = opts.deadline;
        self.requests.insert(id, req);
        self.timing.insert(id, Timing::new());
        self.order.push(id);
        id
    }

    /// Queued requests whose arrival time has come, in scheduling order.
    pub fn ready_to_admit(&self, now: SimTime) -> Vec<RequestId> {
        self.in_sched_order(|r| r.state == RequestState::Queued && r.arrival <= now)
    }

    /// Earliest arrival among still-queued requests.
    pub fn next_arrival(&self) -> Option<SimTime> {
        self.requests
            .values()
            .filter(|r| r.state == RequestState::Queued)
            .map(|r| r.arrival)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Requests with prefill work pending, in scheduling order, written
    /// into the caller's reused buffer (the step loop's scratch — no
    /// per-step allocation).
    pub fn prefilling_into(&self, out: &mut Vec<RequestId>) {
        self.in_sched_order_into(
            |r| r.state == RequestState::Prefilling && r.prefill_remaining() > 0,
            out,
        );
    }

    /// Requests in decode, in scheduling order, into the caller's buffer.
    pub fn decoding_into(&self, out: &mut Vec<RequestId>) {
        self.in_sched_order_into(|r| r.state == RequestState::Decoding, out);
    }

    /// True when no request can ever make progress again without a new
    /// submission: nothing queued, prefilling, or decoding.
    pub fn is_idle(&self) -> bool {
        !self.requests.values().any(|r| {
            matches!(
                r.state,
                RequestState::Queued | RequestState::Prefilling | RequestState::Decoding
            )
        })
    }

    /// Record a token emission for `id`'s TTFT/TBT timing.
    pub fn note_token(&mut self, id: RequestId) {
        let t = self.timing.get_mut(&id).expect("timing exists for every request");
        let now = t.submitted.elapsed().as_secs_f64();
        match t.last_token {
            None => t.first_token = Some(now),
            Some(prev) => t.max_tbt = t.max_tbt.max(now - prev),
        }
        t.last_token = Some(now);
    }

    /// Re-base `id`'s timing to now — called when a request with a future
    /// arrival is finally admitted, so TTFT measures service latency
    /// rather than time spent waiting to arrive.
    pub fn rebase_timing(&mut self, id: RequestId) {
        if let Some(t) = self.timing.get_mut(&id) {
            if t.first_token.is_none() {
                t.submitted = Instant::now();
            }
        }
    }

    /// Submission order filtered by `keep`, then sorted by (priority
    /// desc, deadline asc). Ties keep submission order.
    fn in_sched_order(&self, keep: impl Fn(&Request) -> bool) -> Vec<RequestId> {
        let mut ids = Vec::new();
        self.in_sched_order_into(keep, &mut ids);
        ids
    }

    /// [`Session::in_sched_order`] into a reused buffer. Uses an unstable
    /// sort (no temp allocation) with the request id as the final key —
    /// ids are handed out in submission order, so the id tiebreak *is*
    /// the stable submission-order tiebreak.
    fn in_sched_order_into(&self, keep: impl Fn(&Request) -> bool, out: &mut Vec<RequestId>) {
        out.clear();
        out.extend(self.order.iter().copied().filter(|id| keep(&self.requests[id])));
        out.sort_unstable_by(|a, b| {
            let ra = &self.requests[a];
            let rb = &self.requests[b];
            rb.priority
                .cmp(&ra.priority)
                .then_with(|| {
                    let da = ra.deadline.unwrap_or(f64::INFINITY);
                    let db = rb.deadline.unwrap_or(f64::INFINITY);
                    da.total_cmp(&db)
                })
                .then(a.cmp(b))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_options_builder() {
        let o = SubmitOptions::new(8).at(2.5).priority(3).deadline(10.0);
        assert_eq!(o.max_new_tokens, 8);
        assert_eq!(o.arrival, 2.5);
        assert_eq!(o.priority, 3);
        assert_eq!(o.deadline, Some(10.0));
        let d = SubmitOptions::new(4);
        assert_eq!(d.arrival, 0.0);
        assert_eq!(d.priority, 0);
        assert_eq!(d.deadline, None);
    }

    #[test]
    fn admission_respects_arrival_and_priority() {
        let mut s = Session::new();
        let a = s.create(vec![1, 2], SubmitOptions::new(4));
        let b = s.create(vec![1, 2], SubmitOptions::new(4).at(5.0));
        let c = s.create(vec![1, 2], SubmitOptions::new(4).priority(1));
        assert_eq!(s.ready_to_admit(0.0), vec![c, a], "priority first, b not arrived");
        assert_eq!(s.next_arrival(), Some(0.0));
        s.requests.get_mut(&a).unwrap().state = RequestState::Prefilling;
        s.requests.get_mut(&c).unwrap().state = RequestState::Prefilling;
        assert_eq!(s.next_arrival(), Some(5.0));
        assert_eq!(s.ready_to_admit(5.0), vec![b]);
        assert!(!s.is_idle());
    }

    #[test]
    fn sched_order_breaks_priority_ties_by_deadline() {
        let mut s = Session::new();
        let a = s.create(vec![1], SubmitOptions::new(1).deadline(9.0));
        let b = s.create(vec![1], SubmitOptions::new(1).deadline(3.0));
        let c = s.create(vec![1], SubmitOptions::new(1));
        assert_eq!(s.ready_to_admit(0.0), vec![b, a, c]);
    }

    #[test]
    fn idle_when_all_finished_or_aborted() {
        let mut s = Session::new();
        let a = s.create(vec![1], SubmitOptions::new(1));
        let b = s.create(vec![1], SubmitOptions::new(1));
        assert!(!s.is_idle());
        s.requests.get_mut(&a).unwrap().state = RequestState::Finished;
        s.requests.get_mut(&b).unwrap().state = RequestState::Aborted;
        assert!(s.is_idle());
    }
}
