//! The real serving engine: the rust coordinator executing AOT-compiled
//! JAX/Pallas shards through PJRT, end to end — exposed as an
//! **event-driven session**.
//!
//! The public surface is the [`ServingBackend`] trait: submit requests
//! with [`SubmitOptions`] (timed arrival, generation budget, priority,
//! SLO deadline), tick the session with `step()` and consume the
//! [`EngineEvent`] stream it returns (token emissions, completions,
//! aborts, failure/recovery notifications), cancel requests with
//! `abort(id)`, and inject GPU failures *and rejoins* at *any* step
//! boundary — even mid-decode with requests in flight. The same trait is
//! implemented by the cost-model simulator
//! ([`crate::simulator::OnlineSession`]), so online traces, benches, and
//! the fault-tolerance examples run identically against either backend;
//! [`drive`] is the shared single-fault loop and [`replay()`] steps a
//! backend through a whole [`crate::cluster::FaultTimeline`] of
//! overlapping failures, cascades, and staggered rejoins.
//!
//! Internally the session splits into three layers:
//! * [`core`](self) — the step loop, event generation, failure recovery,
//!   and the bucketed PJRT forward path;
//! * `session` — request/timing bookkeeping ([`SubmitOptions`], the
//!   scheduling order, TTFT/TBT clocks);
//! * `report` — [`ServeReport`] assembly.
//!
//! Everything the simulators decide analytically happens here for real:
//! non-uniform head placement (the per-layer head→rank map drives which
//! weight slices each rank holds and which KV slices it stores), hybrid
//! attention (TP execs over the full batch + DP execs over each home
//! rank's sub-batch), partial-sum combining in place of all-reduce,
//! chunked prefill, continuous decode batching, proactive KV backup, and
//! failure recovery with bit-exact continuation.
//!
//! The per-rank executions run sequentially on one CPU-PJRT client —
//! "ranks" are logical shards (the paper's physical 8-GPU distribution is
//! modeled by [`crate::cluster`]); what is verified here is that the
//! coordinator's sharding math composes to the exact unsharded model.

mod core;
mod kv;
mod replay;
mod report;
mod session;
mod shard;

pub use self::core::{
    drive, AdvanceLimit, AdvanceOutcome, Engine, EngineEvent, FaultPlan, FaultTrigger,
    ServingBackend,
};
pub use kv::{KvStore, PoolId, BLOCK_TOKENS};
pub use replay::{replay, AppliedEvent, ReplayOutcome, ReplayPace, TimelineCursor};
pub use report::{GenerationResult, ServeReport};
pub use session::{PreemptPolicy, SubmitOptions};
pub use shard::RankShard;
