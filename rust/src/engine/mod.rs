//! The real serving engine: the rust coordinator executing AOT-compiled
//! JAX/Pallas shards through PJRT, end to end.
//!
//! Everything the simulators decide analytically happens here for real:
//! non-uniform head placement (the per-layer head→rank map drives which
//! weight slices each rank holds and which KV slices it stores), hybrid
//! attention (TP execs over the full batch + DP execs over each home
//! rank's sub-batch), partial-sum combining in place of all-reduce,
//! chunked prefill, continuous decode batching, proactive KV backup, and
//! failure recovery with bit-exact continuation.
//!
//! The per-rank executions run sequentially on one CPU-PJRT client —
//! "ranks" are logical shards (the paper's physical 8-GPU distribution is
//! modeled by [`crate::cluster`]); what is verified here is that the
//! coordinator's sharding math composes to the exact unsharded model.

mod core;
mod kv;
mod shard;

pub use self::core::{Engine, GenerationResult, ServeReport};
pub use kv::KvStore;
pub use shard::RankShard;
