//! Availability-timeline replay: step any [`ServingBackend`] through an
//! entire [`FaultTimeline`] of `Fail(gpu)` / `Rejoin(gpu)` /
//! `SlowDown(gpu, factor)` / `Restore(gpu)` events with requests in
//! flight — overlapping failures (up to TP−1 concurrent), cascades,
//! fail-during-recovery, staggered rejoins, and soft-fault spells where a
//! GPU stays in the group but throttles.
//!
//! The timeline speaks in *stable physical GPU ids*; the driver owns the
//! gpu↔rank map and keeps it consistent as ranks are renumbered by each
//! reconfiguration (survivors compact downward on a failure, a rejoining
//! GPU is appended at the end). Everything runs through the public
//! `step()` API, so the replayed session streams tokens, admits timed
//! arrivals, and emits failure/rejoin events exactly as live serving
//! would — and on the real engine the outputs stay bit-exact versus a
//! fault-free run.
//!
//! The per-backend state (pending events, the gpu↔rank map, applied and
//! skipped lists) lives in a [`TimelineCursor`] so drivers that interleave
//! *several* backends — the multi-replica [`crate::fleet`] layer — can run
//! one cursor per replica at each replica's own pace; [`replay()`] is the
//! single-backend loop over one cursor.

use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::cluster::{FaultTimeline, TimelineEvent, TimelineEventKind};
use crate::recovery::RecoveryMethod;
use crate::{RankId, SimTime};

use super::core::{AdvanceLimit, ServingBackend};
use super::report::ServeReport;

/// How timeline timestamps are matched against the backend's progress.
#[derive(Debug, Clone, Copy)]
pub enum ReplayPace {
    /// Fire an event once `backend.now()` reaches its timestamp — natural
    /// for the simulator, whose clock is deterministic simulated time.
    Clock,
    /// Fire an event once `⌈at × per_sec⌉` tokens have been emitted —
    /// deterministic on *both* backends (the real engine's clock is wall
    /// time), so bit-exactness tests replay identically every run.
    Tokens { per_sec: f64 },
}

impl ReplayPace {
    /// The emitted-token count at which an event timestamped `at` comes
    /// due under this pace (`None` for clock pacing). Equivalent to the
    /// historical `emitted as f64 >= at × per_sec` check: an integer
    /// count reaches a real threshold exactly when it reaches its
    /// ceiling. Span drivers use this to bound how far a backend may
    /// run before the event must be consulted again.
    pub fn token_threshold(&self, at: SimTime) -> Option<usize> {
        match *self {
            ReplayPace::Clock => None,
            ReplayPace::Tokens { per_sec } => Some((at * per_sec).ceil().max(0.0) as usize),
        }
    }
}

/// One timeline event as it was actually applied.
#[derive(Debug, Clone)]
pub struct AppliedEvent {
    pub event: TimelineEvent,
    /// The rank the event mapped to when it fired: for a failure, the
    /// failed rank in the pre-failure numbering; for a rejoin, the new
    /// rank the GPU came back as; for a slowdown/restore, the rank the
    /// GPU was serving as at that moment.
    pub rank: RankId,
    /// Modeled recovery/reconfiguration latency in seconds (for
    /// slowdown/restore: the capacity-rebalance cost, `0.0` when the
    /// backend only bookkeeps the degradation).
    pub latency_s: f64,
    /// Backend clock when the event was applied.
    pub applied_at: SimTime,
}

/// Result of replaying a timeline to completion.
#[derive(Debug)]
pub struct ReplayOutcome {
    /// The backend's cumulative report after the replay.
    pub report: ServeReport,
    /// Events applied, in order, with the ranks they resolved to.
    pub applied: Vec<AppliedEvent>,
    /// Events that could not be applied (e.g. a failure that would take
    /// the last remaining rank — impossible in a validated timeline).
    pub skipped: Vec<TimelineEvent>,
    /// World size after the replay.
    pub final_world: usize,
    /// Total tokens emitted during the replay.
    pub tokens_emitted: usize,
}

/// One backend's progress through a [`FaultTimeline`]: the queue of
/// not-yet-fired events plus the gpu↔rank map that survives rank
/// renumbering. [`replay()`] drives a single cursor to completion; the
/// fleet layer ([`crate::fleet::Fleet::replay`]) holds one cursor per
/// replica and fires each at its own replica's pace.
#[derive(Debug)]
pub struct TimelineCursor {
    pending: VecDeque<TimelineEvent>,
    /// `gpu_rank[g]` = the rank gpu `g` currently serves as (None while
    /// down).
    gpu_rank: Vec<Option<RankId>>,
    /// Events that could not be applied (world would drop to zero —
    /// unreachable with a validated timeline; recorded, not fatal).
    pub skipped: Vec<TimelineEvent>,
}

impl TimelineCursor {
    /// Validate `timeline` against a backend currently serving `world`
    /// ranks and position the cursor before its first event.
    pub fn new(timeline: &FaultTimeline, world: usize) -> Result<TimelineCursor> {
        timeline.validate(world)?;
        Ok(TimelineCursor {
            pending: timeline.events().iter().copied().collect(),
            gpu_rank: (0..world).map(Some).collect(),
            skipped: Vec::new(),
        })
    }

    /// True once every event has been applied (or recorded as skipped).
    pub fn is_done(&self) -> bool {
        self.pending.is_empty()
    }

    /// Events not yet fired.
    pub fn remaining(&self) -> usize {
        self.pending.len()
    }

    /// The next not-yet-fired event — the boundary span drivers must
    /// not advance past without re-consulting [`TimelineCursor::fire_due`].
    pub fn next_due(&self) -> Option<&TimelineEvent> {
        self.pending.front()
    }

    /// Fire every event that is due against `backend`, given that the
    /// backend has emitted `emitted` tokens so far. An idle (drained)
    /// backend advances neither clock nor token count, so on an idle
    /// backend the remaining events apply back-to-back instead of
    /// hanging the replay. Returns the events applied by *this* call, in
    /// order.
    pub fn fire_due<B: ServingBackend + ?Sized>(
        &mut self,
        backend: &mut B,
        method: RecoveryMethod,
        pace: ReplayPace,
        emitted: usize,
    ) -> Result<Vec<AppliedEvent>> {
        let mut applied = Vec::new();
        while let Some(&ev) = self.pending.front() {
            let due = match pace.token_threshold(ev.at) {
                None => backend.now() >= ev.at,
                Some(threshold) => emitted >= threshold,
            };
            if !due && !backend.is_idle() {
                break;
            }
            self.pending.pop_front();
            match ev.kind {
                TimelineEventKind::Fail => {
                    let rank = self.gpu_rank[ev.gpu]
                        .with_context(|| format!("gpu {} is already down", ev.gpu))?;
                    if backend.world() <= 1 {
                        // Unreachable with a validated timeline; recorded
                        // rather than failing the whole replay.
                        self.skipped.push(ev);
                        continue;
                    }
                    let latency_s = backend.inject_failure(rank, method)?;
                    for slot in self.gpu_rank.iter_mut() {
                        *slot = match *slot {
                            Some(r) if r == rank => None,
                            Some(r) if r > rank => Some(r - 1),
                            other => other,
                        };
                    }
                    let applied_at = backend.now();
                    applied.push(AppliedEvent { event: ev, rank, latency_s, applied_at });
                }
                TimelineEventKind::Rejoin => {
                    let latency_s = backend.inject_rejoin(method)?;
                    let rank = backend.world() - 1; // rejoins append
                    self.gpu_rank[ev.gpu] = Some(rank);
                    let applied_at = backend.now();
                    applied.push(AppliedEvent { event: ev, rank, latency_s, applied_at });
                }
                TimelineEventKind::SlowDown { factor } => {
                    let rank = self.gpu_rank[ev.gpu]
                        .with_context(|| format!("gpu {} slows down but is down", ev.gpu))?;
                    let latency_s = backend.inject_slowdown(rank, factor)?;
                    let applied_at = backend.now();
                    applied.push(AppliedEvent { event: ev, rank, latency_s, applied_at });
                }
                TimelineEventKind::Restore => {
                    let rank = self.gpu_rank[ev.gpu]
                        .with_context(|| format!("gpu {} restores but is down", ev.gpu))?;
                    // Full speed is the inverse of any slowdown.
                    let latency_s = backend.inject_slowdown(rank, 1.0)?;
                    let applied_at = backend.now();
                    applied.push(AppliedEvent { event: ev, rank, latency_s, applied_at });
                }
            }
        }
        Ok(applied)
    }
}

/// Step `backend` to completion while firing every timeline event at its
/// pace-determined due point. Events left over when the session drains
/// (nothing in flight, nothing arriving) are applied back-to-back so the
/// final world always reflects the whole timeline.
///
/// ```
/// use failsafe::cluster::FaultTimeline;
/// use failsafe::engine::{replay, ReplayPace, ServingBackend, SubmitOptions};
/// use failsafe::recovery::RecoveryMethod;
/// use failsafe::simulator::{OnlineMode, OnlineSim, SystemConfig};
///
/// let mut session = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8).session();
/// for i in 0..8 {
///     session.submit_with(&vec![0u32; 1024], SubmitOptions::new(8).at(i as f64 * 0.01))?;
/// }
/// // Two overlapping failures, then staggered rejoins.
/// let tl = FaultTimeline::parse("2 fail 1\n4 fail 5\n6 rejoin 1\n8 rejoin 5\n")?;
/// let out = replay(&mut session, &tl, RecoveryMethod::Full, ReplayPace::Tokens { per_sec: 1.0 })?;
/// assert_eq!(out.applied.len(), 4);
/// assert_eq!(out.final_world, 8);
/// # anyhow::Ok(())
/// ```
pub fn replay<B: ServingBackend + ?Sized>(
    backend: &mut B,
    timeline: &FaultTimeline,
    method: RecoveryMethod,
    pace: ReplayPace,
) -> Result<ReplayOutcome> {
    let mut cursor = TimelineCursor::new(timeline, backend.world())?;
    let mut applied = Vec::new();
    let mut emitted = 0usize;
    let mut sink = Vec::new();

    // Advance in spans between timeline events instead of stepping once
    // per loop: the limit encodes exactly the due-check the historical
    // per-step loop made before every `step()`, so backends with a span
    // core cover the distance in O(boundaries) iterations while the
    // event firing order (and, on the simulator, every bit of state)
    // stays identical.
    loop {
        applied.extend(cursor.fire_due(backend, method, pace, emitted)?);
        if cursor.is_done() && backend.is_idle() {
            break;
        }
        let limit = match cursor.next_due() {
            None => AdvanceLimit::unbounded(),
            Some(ev) => match pace.token_threshold(ev.at) {
                // fire_due left this event pending, so its threshold is
                // strictly ahead; max(1) guards progress regardless.
                Some(threshold) => {
                    AdvanceLimit::tokens(threshold.saturating_sub(emitted).max(1))
                }
                None => AdvanceLimit::clock(ev.at),
            },
        };
        emitted += backend.advance_until(limit, &mut sink)?.tokens;
        sink.clear();
    }

    Ok(ReplayOutcome {
        report: backend.report(),
        applied,
        skipped: cursor.skipped,
        final_world: backend.world(),
        tokens_emitted: emitted,
    })
}
