//! Report assembly: turning a finished (or in-flight) [`Session`] into the
//! [`ServeReport`] consumed by tests, examples, and benches.

use crate::coordinator::RequestState;
use crate::RequestId;

use super::session::Session;

/// Completed (or aborted) generation of one request.
#[derive(Debug, Clone, Default)]
pub struct GenerationResult {
    pub id: RequestId,
    pub output_tokens: Vec<u32>,
    /// Wall-clock time to first token, `None` if the request never
    /// produced one (aborted or still queued) — distinguishable from an
    /// instant first token, which `0.0` was not.
    pub ttft_s: Option<f64>,
    /// Max wall-clock gap between output tokens.
    pub max_tbt_s: f64,
    /// True if the request was cancelled via `abort()` before finishing.
    pub aborted: bool,
    /// SLO tier the request was submitted at (see
    /// [`SubmitOptions::priority`](super::SubmitOptions); default 0).
    pub priority: i32,
    /// SLO deadline on the backend clock, if one was submitted.
    pub deadline: Option<f64>,
    /// Backend-clock time at which the final output token was produced;
    /// `None` while in flight or if the request was aborted.
    pub finished_at: Option<f64>,
}

impl GenerationResult {
    /// Whether this request missed its SLO deadline: it carried one and
    /// did not finish by it (aborted or still-unfinished requests with a
    /// deadline count as misses; best-effort requests never do).
    pub fn deadline_missed(&self) -> bool {
        match self.deadline {
            Some(d) => self.finished_at.map_or(true, |t| t > d),
            None => false,
        }
    }
}

/// Report of a serve run, as returned by
/// [`ServingBackend::report`](super::ServingBackend::report) and
/// `run_to_completion()`.
///
/// ```
/// use failsafe::engine::{GenerationResult, ServeReport};
///
/// let report = ServeReport {
///     results: vec![GenerationResult {
///         id: 0,
///         output_tokens: vec![17, 4, 99],
///         ttft_s: Some(0.12),
///         max_tbt_s: 0.03,
///         ..GenerationResult::default()
///     }],
///     decode_tokens: 3,
///     wall_s: 1.5,
///     ..ServeReport::default()
/// };
/// assert_eq!(report.decode_tps(), 2.0);
/// assert_eq!(report.outputs(), vec![&[17u32, 4, 99][..]]);
/// assert!(report.result(1).is_none());
/// ```
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub results: Vec<GenerationResult>,
    pub wall_s: f64,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub steps: usize,
    /// Simulated (modeled) latencies of injected failures' recoveries and
    /// of rejoin reconfigurations, in injection order.
    pub recoveries: Vec<f64>,
}

impl ServeReport {
    pub fn decode_tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.wall_s
        }
    }

    /// Per-request output tokens, borrowed — callers that only compare or
    /// measure lengths don't pay for a deep copy of every token vector.
    pub fn outputs(&self) -> Vec<&[u32]> {
        self.results.iter().map(|r| r.output_tokens.as_slice()).collect()
    }

    /// Per-request output tokens, cloned — for callers that outlive the
    /// report.
    pub fn outputs_owned(&self) -> Vec<Vec<u32>> {
        self.results.iter().map(|r| r.output_tokens.clone()).collect()
    }

    /// Result of one request by id.
    pub fn result(&self, id: RequestId) -> Option<&GenerationResult> {
        self.results.iter().find(|r| r.id == id)
    }

    /// Output tokens of requests that were *not* aborted — the numerator
    /// of a goodput rate. Aborted requests' partial output is real work
    /// the backend performed, but work the client never got value from,
    /// so fleet-level aggregation (and anything else reasoning about
    /// useful throughput) counts only this.
    pub fn goodput_tokens(&self) -> usize {
        self.results.iter().filter(|r| !r.aborted).map(|r| r.output_tokens.len()).sum()
    }

    /// Distinct priority tiers seen in this report, highest first — the
    /// display order of the overload drill's per-tier tables.
    pub fn tiers(&self) -> Vec<i32> {
        let mut tiers: Vec<i32> = self.results.iter().map(|r| r.priority).collect();
        tiers.sort_unstable_by(|a, b| b.cmp(a));
        tiers.dedup();
        tiers
    }

    /// [`ServeReport::goodput_tokens`] restricted to one priority tier.
    pub fn tier_goodput_tokens(&self, priority: i32) -> usize {
        self.results
            .iter()
            .filter(|r| !r.aborted && r.priority == priority)
            .map(|r| r.output_tokens.len())
            .sum()
    }

    /// Requests in `priority`'s tier that missed their SLO deadline
    /// (see [`GenerationResult::deadline_missed`]).
    pub fn tier_deadline_misses(&self, priority: i32) -> usize {
        self.results
            .iter()
            .filter(|r| r.priority == priority && r.deadline_missed())
            .count()
    }

    /// Deadline misses across every tier.
    pub fn deadline_misses(&self) -> usize {
        self.results.iter().filter(|r| r.deadline_missed()).count()
    }
}

/// Build a cumulative report over every request the session has seen, in
/// submission order. Counters and wall time are session-lifetime values;
/// `Engine::run_to_completion` narrows them to the span of one call.
pub(super) fn assemble(session: &Session, recoveries: &[f64]) -> ServeReport {
    let mut report = ServeReport {
        results: Vec::with_capacity(session.order.len()),
        wall_s: session.clock,
        prefill_tokens: session.prefill_tokens,
        decode_tokens: session.decode_tokens,
        steps: session.steps,
        recoveries: recoveries.to_vec(),
    };
    for id in &session.order {
        let r = &session.requests[id];
        let t = &session.timing[id];
        report.results.push(GenerationResult {
            id: *id,
            output_tokens: r.output_tokens.clone(),
            ttft_s: t.first_token,
            max_tbt_s: t.max_tbt,
            aborted: r.state == RequestState::Aborted,
            priority: r.priority,
            deadline: r.deadline,
            finished_at: t.finished_at,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_borrow_matches_owned() {
        let report = ServeReport {
            results: vec![
                GenerationResult {
                    id: 0,
                    output_tokens: vec![1, 2, 3],
                    ttft_s: Some(0.1),
                    ..GenerationResult::default()
                },
                GenerationResult { id: 1, aborted: true, ..GenerationResult::default() },
            ],
            ..ServeReport::default()
        };
        assert_eq!(report.outputs(), vec![&[1u32, 2, 3][..], &[][..]]);
        assert_eq!(report.outputs_owned(), vec![vec![1, 2, 3], vec![]]);
        assert_eq!(report.result(1).unwrap().ttft_s, None);
        assert!(report.result(1).unwrap().aborted);
        assert!(report.result(2).is_none());
    }

    #[test]
    fn tier_goodput_and_deadline_misses() {
        let report = ServeReport {
            results: vec![
                // SLO tier 1: one on-time finish, one miss.
                GenerationResult {
                    id: 0,
                    output_tokens: vec![0; 10],
                    priority: 1,
                    deadline: Some(5.0),
                    finished_at: Some(4.0),
                    ..GenerationResult::default()
                },
                GenerationResult {
                    id: 1,
                    output_tokens: vec![0; 10],
                    priority: 1,
                    deadline: Some(5.0),
                    finished_at: Some(6.0),
                    ..GenerationResult::default()
                },
                // Best-effort: aborted (shed), no deadline — never a miss,
                // and its partial output is not goodput.
                GenerationResult {
                    id: 2,
                    output_tokens: vec![0; 7],
                    aborted: true,
                    ..GenerationResult::default()
                },
                // Best-effort finished: goodput in tier 0.
                GenerationResult {
                    id: 3,
                    output_tokens: vec![0; 3],
                    finished_at: Some(9.0),
                    ..GenerationResult::default()
                },
                // Deadline carried but never finished: a miss.
                GenerationResult {
                    id: 4,
                    priority: 1,
                    deadline: Some(2.0),
                    aborted: true,
                    ..GenerationResult::default()
                },
            ],
            ..ServeReport::default()
        };
        assert_eq!(report.tiers(), vec![1, 0]);
        assert_eq!(report.tier_goodput_tokens(1), 20);
        assert_eq!(report.tier_goodput_tokens(0), 3);
        assert_eq!(report.goodput_tokens(), 23);
        assert_eq!(report.tier_deadline_misses(1), 2);
        assert_eq!(report.tier_deadline_misses(0), 0);
        assert_eq!(report.deadline_misses(), 2);
    }
}
