//! Report assembly: turning a finished (or in-flight) [`Session`] into the
//! [`ServeReport`] consumed by tests, examples, and benches.

use crate::coordinator::RequestState;
use crate::RequestId;

use super::session::Session;

/// Completed (or aborted) generation of one request.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    pub id: RequestId,
    pub output_tokens: Vec<u32>,
    /// Wall-clock time to first token, `None` if the request never
    /// produced one (aborted or still queued) — distinguishable from an
    /// instant first token, which `0.0` was not.
    pub ttft_s: Option<f64>,
    /// Max wall-clock gap between output tokens.
    pub max_tbt_s: f64,
    /// True if the request was cancelled via `abort()` before finishing.
    pub aborted: bool,
}

/// Report of a serve run, as returned by
/// [`ServingBackend::report`](super::ServingBackend::report) and
/// `run_to_completion()`.
///
/// ```
/// use failsafe::engine::{GenerationResult, ServeReport};
///
/// let report = ServeReport {
///     results: vec![GenerationResult {
///         id: 0,
///         output_tokens: vec![17, 4, 99],
///         ttft_s: Some(0.12),
///         max_tbt_s: 0.03,
///         aborted: false,
///     }],
///     decode_tokens: 3,
///     wall_s: 1.5,
///     ..ServeReport::default()
/// };
/// assert_eq!(report.decode_tps(), 2.0);
/// assert_eq!(report.outputs(), vec![&[17u32, 4, 99][..]]);
/// assert!(report.result(1).is_none());
/// ```
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub results: Vec<GenerationResult>,
    pub wall_s: f64,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub steps: usize,
    /// Simulated (modeled) latencies of injected failures' recoveries and
    /// of rejoin reconfigurations, in injection order.
    pub recoveries: Vec<f64>,
}

impl ServeReport {
    pub fn decode_tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.wall_s
        }
    }

    /// Per-request output tokens, borrowed — callers that only compare or
    /// measure lengths don't pay for a deep copy of every token vector.
    pub fn outputs(&self) -> Vec<&[u32]> {
        self.results.iter().map(|r| r.output_tokens.as_slice()).collect()
    }

    /// Per-request output tokens, cloned — for callers that outlive the
    /// report.
    pub fn outputs_owned(&self) -> Vec<Vec<u32>> {
        self.results.iter().map(|r| r.output_tokens.clone()).collect()
    }

    /// Result of one request by id.
    pub fn result(&self, id: RequestId) -> Option<&GenerationResult> {
        self.results.iter().find(|r| r.id == id)
    }

    /// Output tokens of requests that were *not* aborted — the numerator
    /// of a goodput rate. Aborted requests' partial output is real work
    /// the backend performed, but work the client never got value from,
    /// so fleet-level aggregation (and anything else reasoning about
    /// useful throughput) counts only this.
    pub fn goodput_tokens(&self) -> usize {
        self.results.iter().filter(|r| !r.aborted).map(|r| r.output_tokens.len()).sum()
    }
}

/// Build a cumulative report over every request the session has seen, in
/// submission order. Counters and wall time are session-lifetime values;
/// `Engine::run_to_completion` narrows them to the span of one call.
pub(super) fn assemble(session: &Session, recoveries: &[f64]) -> ServeReport {
    let mut report = ServeReport {
        results: Vec::with_capacity(session.order.len()),
        wall_s: session.clock,
        prefill_tokens: session.prefill_tokens,
        decode_tokens: session.decode_tokens,
        steps: session.steps,
        recoveries: recoveries.to_vec(),
    };
    for id in &session.order {
        let r = &session.requests[id];
        let t = &session.timing[id];
        report.results.push(GenerationResult {
            id: *id,
            output_tokens: r.output_tokens.clone(),
            ttft_s: t.first_token,
            max_tbt_s: t.max_tbt,
            aborted: r.state == RequestState::Aborted,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_borrow_matches_owned() {
        let report = ServeReport {
            results: vec![
                GenerationResult {
                    id: 0,
                    output_tokens: vec![1, 2, 3],
                    ttft_s: Some(0.1),
                    max_tbt_s: 0.0,
                    aborted: false,
                },
                GenerationResult {
                    id: 1,
                    output_tokens: vec![],
                    ttft_s: None,
                    max_tbt_s: 0.0,
                    aborted: true,
                },
            ],
            ..ServeReport::default()
        };
        assert_eq!(report.outputs(), vec![&[1u32, 2, 3][..], &[][..]]);
        assert_eq!(report.outputs_owned(), vec![vec![1, 2, 3], vec![]]);
        assert_eq!(report.result(1).unwrap().ttft_s, None);
        assert!(report.result(1).unwrap().aborted);
        assert!(report.result(2).is_none());
    }
}
