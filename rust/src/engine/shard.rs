//! Per-rank weight shards as PJRT literals, built from the host store.
//!
//! A [`RankShard`] materializes, for one rank under one shard plan epoch:
//! per layer, the TP-head weight slices (Wq/Wk/Wv/Wo padded to the head
//! bucket) and the FFN column-block slices (padded to the column bucket);
//! plus the DP-head slices every rank carries under hybrid attention.
//! Rebuilt on reconfiguration — the bytes that *move* are what the
//! recovery planner accounts; here we re-slice from the host store, which
//! is exactly the on-demand read FailSafe performs.

use anyhow::Result;

use crate::runtime::{literal_tensor, Manifest, WeightStore};
use crate::sharding::{ShardPlan, DP_OWNER};
use crate::{LayerId, RankId};

/// Attention weights of one layer's local head set (padded to bucket).
pub struct AttnWeights {
    /// Real (unpadded) head ids, in slice order.
    pub heads: Vec<usize>,
    /// The compiled head bucket these literals are padded to.
    pub h_bucket: usize,
    pub wq: xla::Literal,
    pub wk: xla::Literal,
    pub wv: xla::Literal,
    pub wo: xla::Literal,
}

/// FFN weights of one layer's local column set (padded to bucket).
pub struct FfnWeights {
    pub cols: Vec<usize>,
    pub col_bucket: usize,
    pub gate: xla::Literal,
    pub up: xla::Literal,
    pub down: xla::Literal,
}

/// One rank's resident weights for an epoch.
pub struct RankShard {
    pub rank: RankId,
    /// Per layer: TP attention slice (None if this rank owns no TP heads
    /// in that layer — possible at world > n_heads).
    pub tp_attn: Vec<Option<AttnWeights>>,
    /// Per layer: the DP (replicated) head slice, present on every rank
    /// when the plan has remainder heads.
    pub dp_attn: Vec<Option<AttnWeights>>,
    /// Per layer: FFN column slice.
    pub ffn: Vec<FfnWeights>,
    /// Per layer norms.
    pub attn_norm: Vec<xla::Literal>,
    pub ffn_norm: Vec<xla::Literal>,
}

/// Pick the smallest compiled bucket ≥ `n` from `buckets` (sorted).
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

fn build_attn(
    store: &WeightStore,
    layer: LayerId,
    heads: &[usize],
    head_dim: usize,
    h_bucket: usize,
) -> Result<AttnWeights> {
    let wq = store.slice_head_cols(&format!("wq.{layer}"), heads, head_dim, h_bucket)?;
    let wk = store.slice_head_cols(&format!("wk.{layer}"), heads, head_dim, h_bucket)?;
    let wv = store.slice_head_cols(&format!("wv.{layer}"), heads, head_dim, h_bucket)?;
    let wo = store.slice_head_rows(&format!("wo.{layer}"), heads, head_dim, h_bucket)?;
    Ok(AttnWeights {
        heads: heads.to_vec(),
        h_bucket,
        wq: literal_tensor(&wq)?,
        wk: literal_tensor(&wk)?,
        wv: literal_tensor(&wv)?,
        wo: literal_tensor(&wo)?,
    })
}

impl RankShard {
    /// Materialize rank `rank`'s shard for `plan` from the host store.
    pub fn build(
        manifest: &Manifest,
        store: &WeightStore,
        plan: &ShardPlan,
        rank: RankId,
    ) -> Result<RankShard> {
        let hd = manifest.model.head_dim;
        let h_buckets = manifest.buckets("attn", |v| v.h);
        let col_buckets = manifest.buckets("ffn", |v| v.cols);
        let cols_per_block = manifest.model.d_ff / plan.ffn.n_blocks;

        let mut tp_attn = Vec::new();
        let mut dp_attn = Vec::new();
        let mut attn_norm = Vec::new();
        let mut ffn_norm = Vec::new();
        let mut ffn = Vec::new();

        // FFN columns are layer-invariant under the plan.
        let blocks = plan.ffn.blocks_of(rank);
        let cols: Vec<usize> = blocks
            .iter()
            .flat_map(|&b| b * cols_per_block..(b + 1) * cols_per_block)
            .collect();
        let col_bucket = pick_bucket(&col_buckets, cols.len())
            .ok_or_else(|| anyhow::anyhow!("no ffn bucket ≥ {} cols", cols.len()))?;

        for layer in 0..manifest.model.n_layers {
            let lh = &plan.heads.layers[layer];
            let tp_heads: Vec<usize> = lh.tp_heads_of(rank);
            let dp_heads: Vec<usize> = lh.dp_heads();

            tp_attn.push(if tp_heads.is_empty() {
                None
            } else {
                let hb = pick_bucket(&h_buckets, tp_heads.len())
                    .ok_or_else(|| anyhow::anyhow!("no head bucket ≥ {}", tp_heads.len()))?;
                Some(build_attn(store, layer, &tp_heads, hd, hb)?)
            });
            dp_attn.push(if dp_heads.is_empty() {
                None
            } else {
                let hb = pick_bucket(&h_buckets, dp_heads.len())
                    .ok_or_else(|| anyhow::anyhow!("no head bucket ≥ {}", dp_heads.len()))?;
                Some(build_attn(store, layer, &dp_heads, hd, hb)?)
            });

            attn_norm.push(literal_tensor(store.get(&format!("attn_norm.{layer}"))?)?);
            ffn_norm.push(literal_tensor(store.get(&format!("ffn_norm.{layer}"))?)?);

            let gate = store.slice_cols(&format!("w_gate.{layer}"), &cols, col_bucket)?;
            let up = store.slice_cols(&format!("w_up.{layer}"), &cols, col_bucket)?;
            let down = store.slice_rows(&format!("w_down.{layer}"), &cols, col_bucket)?;
            ffn.push(FfnWeights {
                cols: cols.clone(),
                col_bucket,
                gate: literal_tensor(&gate)?,
                up: literal_tensor(&up)?,
                down: literal_tensor(&down)?,
            });
        }

        Ok(RankShard { rank, tp_attn, dp_attn, ffn, attn_norm, ffn_norm })
    }

    /// Sanity check: across `shards`, every (layer, head) TP slice appears
    /// exactly once and DP heads appear on every rank.
    pub fn verify_cover(shards: &[RankShard], plan: &ShardPlan) -> bool {
        for (layer, lh) in plan.heads.layers.iter().enumerate() {
            for (head, &owner) in lh.owner.iter().enumerate() {
                if owner == DP_OWNER {
                    if !shards
                        .iter()
                        .all(|s| s.dp_attn[layer].as_ref().is_some_and(|a| a.heads.contains(&head)))
                    {
                        return false;
                    }
                } else {
                    let count = shards
                        .iter()
                        .filter(|s| {
                            s.tp_attn[layer].as_ref().is_some_and(|a| a.heads.contains(&head))
                        })
                        .count();
                    if count != 1 {
                        return false;
                    }
                }
            }
        }
        true
    }
}
