//! # FailSafe — high-performance resilient tensor-parallel LLM serving
//!
//! Reproduction of *FailSafe: High-performance Resilient Serving*
//! (Xu, Xie, Gandhi, Kozyrakis — 2025).
//!
//! FailSafe keeps a tensor-parallel (TP) serving deployment fast when GPUs
//! fail, by serving on an *irregular* number of devices (e.g. 7 of 8) while
//! balancing compute and memory:
//!
//! * [`sharding`] — non-uniform TP planning: cyclic KVCache placement,
//!   hybrid (TP + DP) attention head assignment, commutative FFN partitions.
//! * [`router`] — fine-grained load-aware DP-rank routing (online makespan).
//! * [`scheduler`] — DP-aware adaptive chunked prefill (paper Algorithm 1)
//!   and continuous decode batching.
//! * [`recovery`] — lightning recovery: proactive KVCache backup to host
//!   DRAM and on-demand, non-redundant weight recovery.
//! * [`kvcache`] — paged KV block management, placement, and backup store.
//! * [`cluster`] — the simulated multi-GPU node substrate (HBM accounting,
//!   NVLink/PCIe transfer model, fault injection).
//! * [`simulator`] — discrete-event performance simulator regenerating the
//!   paper's evaluation figures at H100 scale.
//! * [`engine`] + [`runtime`] — the *real* serving engine: a rust
//!   coordinator executing AOT-compiled JAX/Pallas shards via PJRT.
//! * [`fleet`] — multi-replica orchestration: N independent serving
//!   groups (engine or simulator) behind one cluster-level load-aware
//!   router, with per-replica fault-timeline replay and fleet-level
//!   goodput reporting.
//! * [`prefix`] — shared-prefix KV cache: a trie over token-block hashes
//!   whose nodes are refcounted copy-on-write references into the paged
//!   KV store, so repeated system prompts prefill once and stay resident
//!   once — including across failure/reconfiguration epochs.
//! * [`obs`] — the flight recorder: a determinism-preserving
//!   [`obs::Observer`] seam on every backend feeding a structured
//!   [`obs::TraceLog`] (engine events, subsystem decisions,
//!   recovery-phase spans, per-rank gauges), with Chrome-trace and
//!   Prometheus-text exporters behind the `trace` subcommand.
//! * [`health`] — soft-fault handling for GPUs that are alive but slow:
//!   straggler detection from per-rank step times, a
//!   Healthy → Throttled → Suspect → Down state machine, and
//!   capacity-aware rebalancing (uneven heads/FFN blocks, weighted
//!   routing) so a throttled rank does less work instead of pacing the
//!   whole group.
//!
//! ## The serving session API
//!
//! Serving is **event-driven**. Both the real engine
//! ([`engine::Engine`]) and the cost-model decode instance
//! ([`simulator::OnlineSession`]) implement one trait,
//! [`engine::ServingBackend`]:
//!
//! * `submit_with(prompt, SubmitOptions)` — timed arrival, generation
//!   budget, priority, and SLO deadline per request;
//! * `step()` — one tick of the serving loop, returning the
//!   [`engine::EngineEvent`]s it produced (token emissions, request
//!   completions, aborts, failure/recovery/reconfiguration notices);
//! * `abort(id)` — cancel an in-flight request and release its KV;
//! * `inject_failure(rank, method)` — kill a GPU at *any* step boundary,
//!   even mid-decode with requests in flight, and continue bit-exact
//!   under backup-based recovery;
//! * `inject_rejoin(method)` — the inverse: a failed GPU returns, its
//!   shard streams back over NVLink, the cyclic KV placement re-spreads
//!   onto it, and the router rebalances — still bit-exact;
//! * `run_to_completion()` — a thin convenience wrapper over `step()`.
//!
//! [`engine::drive`] steps any backend to completion with an optional
//! planned [`engine::FaultPlan`], and [`engine::replay()`] steps one
//! through an entire [`cluster::FaultTimeline`] of timestamped
//! `Fail(gpu)` / `Rejoin(gpu)` events — overlapping failures, cascades,
//! rolling maintenance — so online traces, benches, and the
//! fault-tolerance examples run identically against the real engine or
//! the simulator:
//!
//! ```
//! use failsafe::engine::{replay, ReplayPace, ServingBackend, SubmitOptions};
//! use failsafe::recovery::RecoveryMethod;
//! use failsafe::simulator::{OnlineMode, OnlineSim, SystemConfig};
//! use failsafe::traces::cascade_then_heal;
//!
//! let mut session = OnlineSim::new(SystemConfig::failsafe(), OnlineMode::Decode, 8).session();
//! for i in 0..6 {
//!     session.submit_with(&vec![0u32; 1024], SubmitOptions::new(8).at(i as f64 * 0.01))?;
//! }
//! // Two GPUs fail 100 ms in, both rejoin half a second later.
//! let timeline = cascade_then_heal(2, 0.1, 0.05, 0.5);
//! let out = replay(&mut session, &timeline, RecoveryMethod::Full, ReplayPace::Clock)?;
//! assert_eq!(out.final_world, 8);
//! assert_eq!(out.applied.len(), 4);
//! # anyhow::Ok(())
//! ```
//!
//! The three-layer architecture: Python (JAX + Pallas) authors the model and
//! kernels and lowers them **once** to HLO text (`make artifacts`); the rust
//! coordinator loads the artifacts through the PJRT C API and owns the
//! entire request path. Python never runs at serving time.

pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod health;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod prefix;
pub mod recovery;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod sharding;
pub mod simulator;
pub mod traces;
pub mod util;

/// Identifies a GPU rank within a tensor-parallel group (0-based).
pub type RankId = usize;
/// Identifies an attention (KV) head within a layer (0-based).
pub type HeadId = usize;
/// Identifies a transformer layer (0-based).
pub type LayerId = usize;
/// Identifies a serving request.
pub type RequestId = u64;
/// Simulated time in seconds.
pub type SimTime = f64;
