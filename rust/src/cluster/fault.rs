//! Fault injection: turning an availability trace into failure/recovery
//! events against [`super::Node`]s.
//!
//! Mirrors the paper's §4.1 failure simulation: each failure event disables
//! one random GPU across the fleet; each recovery event restores one random
//! failed GPU. The trace itself (GPU availability over time, Fig 5) comes
//! from [`crate::traces::gcp_availability`].

use crate::util::Rng;

use crate::SimTime;

/// Whether a fault event removes or restores capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Hard failure: device HBM lost.
    Fail,
    /// Device returns to service (empty).
    Recover,
}

/// One scheduled event against a specific device of a specific node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub node: usize,
    pub device: usize,
    pub kind: FaultKind,
}

/// Expands an aggregate availability trace (total healthy GPUs over time)
/// into per-device fail/recover events, choosing victims uniformly at
/// random with a seeded RNG so experiments are reproducible.
#[derive(Debug)]
pub struct FaultInjector {
    events: Vec<FaultEvent>,
}

impl FaultInjector {
    /// `availability` is a step function: `(time, total_healthy_gpus)`
    /// samples, monotonically increasing in time. `n_nodes` nodes of
    /// `gpus_per_node` devices each; full availability = n_nodes × gpus_per_node.
    pub fn from_availability(
        availability: &[(SimTime, usize)],
        n_nodes: usize,
        gpus_per_node: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let total = n_nodes * gpus_per_node;
        let mut healthy: Vec<(usize, usize)> =
            (0..n_nodes).flat_map(|n| (0..gpus_per_node).map(move |d| (n, d))).collect();
        let mut failed: Vec<(usize, usize)> = Vec::new();
        let mut events = Vec::new();
        let mut current = total;

        for &(t, avail) in availability {
            let avail = avail.min(total);
            while current > avail {
                // Fail a random healthy device.
                let idx = rng.pick(healthy.len());
                let (n, d) = healthy.swap_remove(idx);
                failed.push((n, d));
                events.push(FaultEvent { at: t, node: n, device: d, kind: FaultKind::Fail });
                current -= 1;
            }
            while current < avail {
                // Recover a random failed device.
                let idx = rng.pick(failed.len());
                let (n, d) = failed.swap_remove(idx);
                healthy.push((n, d));
                events.push(FaultEvent { at: t, node: n, device: d, kind: FaultKind::Recover });
                current += 1;
            }
        }
        FaultInjector { events }
    }

    /// A single failure of `device` on `node` at time `at` — the §4.3.3
    /// recovery-latency experiment setup.
    pub fn single_failure(at: SimTime, node: usize, device: usize) -> Self {
        FaultInjector {
            events: vec![FaultEvent { at, node, device, kind: FaultKind::Fail }],
        }
    }

    /// `k` distinct random failures at time `at` on one node.
    pub fn multi_failure(at: SimTime, node: usize, gpus_per_node: usize, k: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut devs: Vec<usize> = (0..gpus_per_node).collect();
        rng.shuffle(&mut devs);
        FaultInjector {
            events: devs[..k.min(gpus_per_node)]
                .iter()
                .map(|&d| FaultEvent { at, node, device: d, kind: FaultKind::Fail })
                .collect(),
        }
    }

    /// All events in time order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Events within `[from, to)`.
    pub fn events_between(&self, from: SimTime, to: SimTime) -> Vec<FaultEvent> {
        self.events.iter().copied().filter(|e| e.at >= from && e.at < to).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_expansion_conserves_count() {
        let trace = vec![(0.0, 64), (100.0, 62), (200.0, 63), (300.0, 60), (400.0, 64)];
        let inj = FaultInjector::from_availability(&trace, 8, 8, 42);
        let mut healthy = 64i64;
        let mut min_seen = 64i64;
        for e in inj.events() {
            match e.kind {
                FaultKind::Fail => healthy -= 1,
                FaultKind::Recover => healthy += 1,
            }
            min_seen = min_seen.min(healthy);
        }
        assert_eq!(healthy, 64);
        assert_eq!(min_seen, 60);
    }

    #[test]
    fn deterministic_under_seed() {
        let trace = vec![(0.0, 64), (50.0, 61)];
        let a = FaultInjector::from_availability(&trace, 8, 8, 7);
        let b = FaultInjector::from_availability(&trace, 8, 8, 7);
        assert_eq!(a.events(), b.events());
    }

    #[test]
    fn multi_failure_distinct_devices() {
        let inj = FaultInjector::multi_failure(1.0, 0, 8, 3, 9);
        let devs: Vec<_> = inj.events().iter().map(|e| e.device).collect();
        let mut dedup = devs.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 3);
        assert_eq!(devs.len(), 3);
    }
}
